//! Graph statistics used to validate the synthetic dataset analogues
//! (DESIGN.md §5): the substitution argument rests on the generators
//! matching the structural families of the originals — small-world for
//! `power`, clustered heavy-tailed for the `ca-*` nets. These are also
//! the quantities the Jaccard construction is sensitive to.

use super::Graph;

/// Summary statistics of a graph.
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub n: usize,
    pub m: usize,
    pub mean_degree: f64,
    pub max_degree: usize,
    /// Global clustering coefficient: 3 * #triangles / #wedges.
    pub clustering: f64,
    /// Mean local clustering coefficient (Watts–Strogatz definition).
    pub mean_local_clustering: f64,
    /// Degree assortativity is omitted; the construction does not use it.
    pub triangles: u64,
}

/// Count triangles through node `u` (edges among its neighbors).
fn local_triangles(g: &Graph, u: usize) -> u64 {
    let nb = g.neighbors(u);
    let mut count = 0u64;
    for (ai, &a) in nb.iter().enumerate() {
        for &b in &nb[(ai + 1)..] {
            if g.has_edge(a as usize, b as usize) {
                count += 1;
            }
        }
    }
    count
}

/// Compute summary statistics. O(sum_deg^2 / n)-ish; intended for the
/// evaluation-scale graphs, not million-node inputs.
pub fn stats(g: &Graph) -> GraphStats {
    let n = g.n();
    let m = g.m();
    let mut tri_total = 0u64;
    let mut wedges = 0u64;
    let mut local_sum = 0.0;
    let mut max_degree = 0usize;
    for u in 0..n {
        let d = g.degree(u);
        max_degree = max_degree.max(d);
        let t = local_triangles(g, u);
        tri_total += t;
        let w = (d * d.saturating_sub(1) / 2) as u64;
        wedges += w;
        if w > 0 {
            local_sum += t as f64 / w as f64;
        }
    }
    // each triangle counted at its 3 corners
    let triangles = tri_total / 3;
    GraphStats {
        n,
        m,
        mean_degree: if n > 0 { 2.0 * m as f64 / n as f64 } else { 0.0 },
        max_degree,
        clustering: if wedges > 0 { tri_total as f64 / wedges as f64 } else { 0.0 },
        mean_local_clustering: if n > 0 { local_sum / n as f64 } else { 0.0 },
        triangles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::Dataset;
    use crate::graph::generators;

    #[test]
    fn triangle_graph() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        let s = stats(&g);
        assert_eq!(s.triangles, 1);
        assert!((s.clustering - 1.0).abs() < 1e-12);
        assert!((s.mean_local_clustering - 1.0).abs() < 1e-12);
    }

    #[test]
    fn star_has_no_triangles() {
        let g = Graph::from_edges(5, &[(0, 1), (0, 2), (0, 3), (0, 4)]);
        let s = stats(&g);
        assert_eq!(s.triangles, 0);
        assert_eq!(s.clustering, 0.0);
        assert_eq!(s.max_degree, 4);
    }

    #[test]
    fn clique_fully_clustered() {
        let mut edges = Vec::new();
        for i in 0..6u32 {
            for j in (i + 1)..6 {
                edges.push((i, j));
            }
        }
        let g = Graph::from_edges(6, &edges);
        let s = stats(&g);
        assert_eq!(s.triangles, 20); // C(6,3)
        assert!((s.clustering - 1.0).abs() < 1e-12);
    }

    #[test]
    fn watts_strogatz_is_highly_clustered_vs_er() {
        // The defining property of the power-grid family (small-world):
        // clustering far above an ER graph of equal density.
        let ws = generators::watts_strogatz(400, 6, 0.1, 3);
        let er = generators::erdos_renyi(400, 6.0 / 399.0, 3);
        let s_ws = stats(&ws);
        let s_er = stats(&er);
        assert!(
            s_ws.mean_local_clustering > 5.0 * (s_er.mean_local_clustering + 1e-3),
            "WS {} vs ER {}",
            s_ws.mean_local_clustering,
            s_er.mean_local_clustering
        );
    }

    #[test]
    fn collaboration_analogues_are_clustered_and_heavy_tailed() {
        // The ca-* family: high clustering (co-authorship cliques) and a
        // degree tail well above the mean. Checked for every analogue the
        // Table I harness generates.
        for d in [Dataset::CaGrQc, Dataset::CaHepTh, Dataset::CaHepPh, Dataset::CaAstroPh] {
            let g = d.generate(300, 7);
            let s = stats(&g);
            assert!(
                s.mean_local_clustering > 0.3,
                "{}: clustering {} too low for a collaboration net",
                d.name(),
                s.mean_local_clustering
            );
            assert!(
                (s.max_degree as f64) > 2.0 * s.mean_degree,
                "{}: degree tail too flat (max {} vs mean {:.1})",
                d.name(),
                s.max_degree,
                s.mean_degree
            );
        }
    }
}
