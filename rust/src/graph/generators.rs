//! Deterministic synthetic graph generators.
//!
//! The paper's evaluation graphs come from SNAP / SuiteSparse, which are
//! unreachable in this offline environment. DESIGN.md §5 documents the
//! substitution: we generate structural analogues — Watts–Strogatz for the
//! `power` grid, planted-partition + preferential attachment for the
//! `ca-*` collaboration networks — with matched (scaled) LCC sizes. The
//! solver's per-iteration work is exactly `3·C(n,3)` constraint visits, so
//! Table I's parallel-scaling behaviour depends on `n` and memory layout,
//! not on where the weights came from; the instance construction (Jaccard +
//! sign map) is applied identically to real or synthetic graphs.

use super::Graph;
use crate::util::rng::Rng;

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    for i in 0..n as u32 {
        for j in (i + 1)..n as u32 {
            if rng.bool(p) {
                edges.push((i, j));
            }
        }
    }
    Graph::from_edges(n, &edges)
}

/// Watts–Strogatz small-world ring: each node connects to `k/2` neighbors
/// on each side, each edge rewired with probability `beta`. Structural
/// analogue for the Western US `power` grid (Watts & Strogatz 1998 — the
/// same paper the dataset comes from).
pub fn watts_strogatz(n: usize, k: usize, beta: f64, seed: u64) -> Graph {
    assert!(k < n && k % 2 == 0, "watts_strogatz requires even k < n");
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * k / 2);
    for u in 0..n {
        for d in 1..=(k / 2) {
            let v = (u + d) % n;
            let (mut a, mut b) = (u as u32, v as u32);
            if rng.bool(beta) {
                // Rewire endpoint b to a uniform non-self target; duplicate
                // edges are dropped by Graph::from_edges.
                let mut t = rng.usize_in(0, n - 1);
                if t >= u {
                    t += 1;
                }
                b = t as u32;
                a = u as u32;
            }
            edges.push((a, b));
        }
    }
    Graph::from_edges(n, &edges)
}

/// Barabási–Albert preferential attachment: each new node attaches to `m`
/// existing nodes chosen proportionally to degree.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Graph {
    assert!(m >= 1 && n > m);
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m);
    // Repeated-endpoint list: sampling uniformly from it = degree-biased.
    let mut endpoints: Vec<u32> = Vec::with_capacity(2 * n * m);
    // Seed clique on m+1 nodes.
    for i in 0..=(m as u32) {
        for j in (i + 1)..=(m as u32) {
            edges.push((i, j));
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    for u in (m + 1)..n {
        let mut targets = std::collections::HashSet::with_capacity(m);
        while targets.len() < m {
            let t = endpoints[rng.usize_in(0, endpoints.len())];
            targets.insert(t);
        }
        for &t in &targets {
            edges.push((u as u32, t));
            endpoints.push(u as u32);
            endpoints.push(t);
        }
    }
    Graph::from_edges(n, &edges)
}

/// Collaboration-network analogue: planted communities with dense in-group
/// wiring plus preferential cross-links, mimicking co-authorship structure
/// (high clustering, heavy-tailed degrees) of the SNAP `ca-*` graphs.
pub fn collaboration(n: usize, n_comm: usize, p_in: f64, m_cross: usize, seed: u64) -> Graph {
    assert!(n_comm >= 1 && n >= n_comm);
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::new();
    // Community sizes: heavy-ish tail via repeated halving.
    let mut comm_of = vec![0usize; n];
    for (u, c) in comm_of.iter_mut().enumerate() {
        // Zipf-ish assignment: community k gets ~ 1/(k+1) share.
        let r = rng.f64();
        let mut acc = 0.0;
        let norm: f64 = (0..n_comm).map(|k| 1.0 / (k + 1) as f64).sum();
        let mut chosen = n_comm - 1;
        for k in 0..n_comm {
            acc += (1.0 / (k + 1) as f64) / norm;
            if r < acc {
                chosen = k;
                break;
            }
        }
        *c = chosen;
        let _ = u;
    }
    // Dense in-community edges ("paper cliques").
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_comm];
    for (u, &c) in comm_of.iter().enumerate() {
        members[c].push(u as u32);
    }
    for group in &members {
        for ai in 0..group.len() {
            for bi in (ai + 1)..group.len() {
                if rng.bool(p_in) {
                    edges.push((group[ai], group[bi]));
                }
            }
        }
    }
    // Preferential cross-community links.
    let mut endpoints: Vec<u32> = edges.iter().flat_map(|&(a, b)| [a, b]).collect();
    if endpoints.is_empty() {
        endpoints.extend(0..n as u32);
    }
    for _ in 0..(n * m_cross) {
        let u = rng.usize_in(0, n) as u32;
        let v = endpoints[rng.usize_in(0, endpoints.len())];
        if u != v {
            edges.push((u.min(v), u.max(v)));
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    Graph::from_edges(n, &edges)
}

/// A ready-made small connected test graph (two cliques joined by a bridge),
/// handy for quickstart examples and unit tests.
pub fn two_cliques(k: usize) -> Graph {
    let n = 2 * k;
    let mut edges = Vec::new();
    for i in 0..k as u32 {
        for j in (i + 1)..k as u32 {
            edges.push((i, j));
            edges.push((i + k as u32, j + k as u32));
        }
    }
    edges.push((0, k as u32)); // bridge
    Graph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::components::largest_component;
    use crate::prop_assert;
    use crate::util::proptest::check;

    #[test]
    fn er_determinism() {
        let a = erdos_renyi(50, 0.1, 7);
        let b = erdos_renyi(50, 0.1, 7);
        assert_eq!(a.edges(), b.edges());
        let c = erdos_renyi(50, 0.1, 8);
        assert_ne!(a.edges(), c.edges());
    }

    #[test]
    fn er_density_sane() {
        let g = erdos_renyi(200, 0.05, 1);
        let expect = 0.05 * (200.0 * 199.0 / 2.0);
        let m = g.m() as f64;
        assert!((m - expect).abs() < 0.3 * expect, "m={m} expect~{expect}");
    }

    #[test]
    fn ws_ring_unrewired() {
        let g = watts_strogatz(20, 4, 0.0, 1);
        // Pure ring lattice: every node has degree 4.
        for u in 0..20 {
            assert_eq!(g.degree(u), 4);
        }
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(0, 2));
        assert!(g.has_edge(0, 19));
    }

    #[test]
    fn ws_rewired_keeps_edge_budget() {
        let g = watts_strogatz(100, 6, 0.3, 2);
        // Rewiring can only lose edges to dedup; never gain.
        assert!(g.m() <= 300);
        assert!(g.m() > 250);
        assert!(largest_component(&g).n() >= 95);
    }

    #[test]
    fn ba_degrees_heavy_tailed() {
        let g = barabasi_albert(500, 3, 3);
        let max_deg = (0..500).map(|u| g.degree(u)).max().unwrap();
        let mean_deg = 2.0 * g.m() as f64 / 500.0;
        assert!(max_deg as f64 > 4.0 * mean_deg, "max={max_deg} mean={mean_deg}");
        assert_eq!(largest_component(&g).n(), 500); // BA is connected
    }

    #[test]
    fn collaboration_clusters() {
        let g = collaboration(300, 12, 0.6, 2, 4);
        assert!(g.m() > 300);
        let lcc = largest_component(&g);
        assert!(lcc.n() > 150, "lcc={}", lcc.n());
    }

    #[test]
    fn two_cliques_shape() {
        let g = two_cliques(4);
        assert_eq!(g.n(), 8);
        assert_eq!(g.m(), 2 * 6 + 1);
        assert!(g.has_edge(0, 4));
    }

    #[test]
    fn generators_property_no_self_loops_or_dupes() {
        check("generators clean", 0xBEEF, 16, |rng, _| {
            let n = rng.usize_in(10, 120);
            let g = match rng.usize_in(0, 3) {
                0 => erdos_renyi(n, 0.1, rng.next_u64()),
                1 => watts_strogatz(n, 4.min((n - 1) & !1), 0.2, rng.next_u64()),
                _ => barabasi_albert(n, 2.min(n - 1), rng.next_u64()),
            };
            for u in 0..g.n() {
                let nb = g.neighbors(u);
                prop_assert!(!nb.contains(&(u as u32)), "self loop at {u}");
                for w in nb.windows(2) {
                    prop_assert!(w[0] < w[1], "unsorted/dup adjacency at {u}");
                }
            }
            Ok(())
        });
    }
}
