//! Graph substrate: CSR storage, IO, generators, connected components,
//! and Jaccard similarity — everything needed to build the paper's
//! correlation-clustering instances from undirected graphs (§IV-B).

pub mod components;
pub mod stats;
pub mod datasets;
pub mod generators;
pub mod io;
pub mod jaccard;

/// Simple undirected graph in CSR form with sorted adjacency lists.
#[derive(Clone, Debug)]
pub struct Graph {
    /// Number of nodes.
    n: usize,
    /// CSR row offsets, length n+1.
    offsets: Vec<usize>,
    /// Concatenated sorted neighbor lists.
    neighbors: Vec<u32>,
}

impl Graph {
    /// Build from an undirected edge list. Self-loops and duplicate edges
    /// are dropped. Node ids must be `< n`.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Graph {
        let mut deg = vec![0usize; n];
        let mut clean: Vec<(u32, u32)> = Vec::with_capacity(edges.len());
        for &(u, v) in edges {
            assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range");
            if u != v {
                clean.push((u.min(v), u.max(v)));
            }
        }
        clean.sort_unstable();
        clean.dedup();
        for &(u, v) in &clean {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut neighbors = vec![0u32; offsets[n]];
        let mut fill = offsets.clone();
        for &(u, v) in &clean {
            neighbors[fill[u as usize]] = v;
            fill[u as usize] += 1;
            neighbors[fill[v as usize]] = u;
            fill[v as usize] += 1;
        }
        // Each adjacency list is sorted because `clean` was processed in
        // lexicographic order for u but arbitrary for v; sort per row.
        for i in 0..n {
            neighbors[offsets[i]..offsets[i + 1]].sort_unstable();
        }
        Graph { n, offsets, neighbors }
    }

    /// Number of nodes.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    #[inline]
    pub fn m(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Sorted neighbors of node `u`.
    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.neighbors[self.offsets[u]..self.offsets[u + 1]]
    }

    /// Degree of node `u`.
    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        self.offsets[u + 1] - self.offsets[u]
    }

    /// True iff edge {u, v} exists (binary search).
    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// All undirected edges (u < v).
    pub fn edges(&self) -> Vec<(u32, u32)> {
        let mut out = Vec::with_capacity(self.m());
        for u in 0..self.n {
            for &v in self.neighbors(u) {
                if (u as u32) < v {
                    out.push((u as u32, v));
                }
            }
        }
        out
    }

    /// Induced subgraph on `nodes` (relabels to 0..nodes.len()).
    pub fn induced(&self, nodes: &[usize]) -> Graph {
        let mut label = vec![usize::MAX; self.n];
        for (new, &old) in nodes.iter().enumerate() {
            label[old] = new;
        }
        let mut edges = Vec::new();
        for &old_u in nodes {
            let u = label[old_u];
            for &v_old in self.neighbors(old_u) {
                let v = label[v_old as usize];
                if v != usize::MAX && u < v {
                    edges.push((u as u32, v as u32));
                }
            }
        }
        Graph::from_edges(nodes.len(), &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::from_edges(3, &[(0, 1), (1, 2)])
    }

    #[test]
    fn basic_csr() {
        let g = path3();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(1), 2);
        assert!(g.has_edge(0, 1));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn dedup_and_self_loops() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 0), (1, 2), (1, 2)]);
        assert_eq!(g.m(), 2);
        assert_eq!(g.degree(0), 1);
    }

    #[test]
    fn neighbors_sorted() {
        let g = Graph::from_edges(5, &[(4, 0), (2, 0), (0, 3), (0, 1)]);
        assert_eq!(g.neighbors(0), &[1, 2, 3, 4]);
    }

    #[test]
    fn edges_roundtrip() {
        let edges = vec![(0u32, 1u32), (1, 2), (2, 3), (0, 3)];
        let g = Graph::from_edges(4, &edges);
        let mut got = g.edges();
        got.sort_unstable();
        let mut want = edges;
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn induced_subgraph() {
        // square 0-1-2-3-0 plus chord 0-2; take {0,1,2}
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (0, 3), (0, 2)]);
        let sub = g.induced(&[0, 1, 2]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.m(), 3); // triangle 0-1-2 with chord
        assert!(sub.has_edge(0, 2));
        assert!(!sub.has_edge(0, 3).then_some(true).unwrap_or(false));
    }
}
