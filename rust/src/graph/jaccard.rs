//! All-pairs Jaccard similarity over closed neighborhoods.
//!
//! §IV-B: "we compute a signed and weighted edge between each pair of nodes
//! (i, j) by computing the Jaccard index between the nodes". We use closed
//! neighborhoods N[u] = N(u) ∪ {u} so that adjacent nodes always have
//! nonzero similarity (the convention of Wang et al. [40] / Veldt [37]).

use super::Graph;
use crate::matrix::PackedSym;
use crate::util::parallel::scoped_workers;

/// Jaccard index of the closed neighborhoods of `u` and `v`.
pub fn jaccard_pair(g: &Graph, u: usize, v: usize) -> f64 {
    debug_assert!(u != v);
    let inter = closed_intersection(g, u, v);
    let union = (g.degree(u) + 1) + (g.degree(v) + 1) - inter;
    inter as f64 / union as f64
}

/// |N[u] ∩ N[v]| via sorted-list merge, treating u and v as members of
/// their own closed neighborhoods.
fn closed_intersection(g: &Graph, u: usize, v: usize) -> usize {
    let a = g.neighbors(u);
    let b = g.neighbors(v);
    let (mut i, mut j, mut count) = (0usize, 0usize, 0usize);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                count += 1;
                i += 1;
                j += 1;
            }
        }
    }
    // Closed-neighborhood corrections: u ∈ N[u] always; u ∈ N[v] iff edge.
    // The merge above counted N(u) ∩ N(v). Add u if u ∈ N(v), v if v ∈ N(u),
    // noting u ∈ N(v) ⇔ v ∈ N(u) ⇔ has_edge.
    if g.has_edge(u, v) {
        count += 2;
    }
    count
}

/// All-pairs Jaccard matrix, computed with `p` workers.
pub fn all_pairs_jaccard(g: &Graph, p: usize) -> PackedSym {
    let n = g.n();
    let mut out = PackedSym::zeros(n);
    // Partition columns among workers; each column i covers pairs (i, j>i).
    // Work per column shrinks with i, so interleave columns round-robin for
    // balance: worker t takes columns t, t+p, t+2p, ...
    let col_starts = out.col_starts().to_vec();
    let data = out.as_mut_slice();
    let data_addr = data.as_mut_ptr() as usize;
    let data_len = data.len();
    scoped_workers(p, |tid, _barrier| {
        // SAFETY: workers write disjoint column ranges [col_starts[i], ...).
        let data =
            unsafe { std::slice::from_raw_parts_mut(data_addr as *mut f64, data_len) };
        let mut i = tid;
        while i < n {
            let base = col_starts[i];
            for j in (i + 1)..n {
                data[base + (j - i - 1)] = jaccard_pair(g, i, j);
            }
            i += p;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::erdos_renyi;

    #[test]
    fn triangle_jaccard_is_one() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 2), (0, 2)]);
        // closed neighborhoods are all {0,1,2}
        assert!((jaccard_pair(&g, 0, 1) - 1.0).abs() < 1e-12);
        assert!((jaccard_pair(&g, 1, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disconnected_pair_is_zero() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert_eq!(jaccard_pair(&g, 0, 2), 0.0);
    }

    #[test]
    fn path_values() {
        // path 0-1-2: N[0]={0,1}, N[2]={1,2} -> inter {1}, union {0,1,2} -> 1/3
        let g = Graph::from_edges(3, &[(0, 1), (1, 2)]);
        assert!((jaccard_pair(&g, 0, 2) - 1.0 / 3.0).abs() < 1e-12);
        // N[0]={0,1}, N[1]={0,1,2} -> inter {0,1}=2, union=3 -> 2/3
        assert!((jaccard_pair(&g, 0, 1) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn symmetric() {
        let g = erdos_renyi(30, 0.2, 5);
        for u in 0..30 {
            for v in (u + 1)..30 {
                assert!((jaccard_pair(&g, u, v) - jaccard_pair(&g, v, u)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn all_pairs_matches_pairwise_and_parallel_agrees() {
        let g = erdos_renyi(40, 0.15, 9);
        let serial = all_pairs_jaccard(&g, 1);
        let par = all_pairs_jaccard(&g, 4);
        assert_eq!(serial, par);
        for u in 0..40 {
            for v in (u + 1)..40 {
                assert!((serial.get(u, v) - jaccard_pair(&g, u, v)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn values_in_unit_interval() {
        let g = erdos_renyi(25, 0.3, 2);
        let j = all_pairs_jaccard(&g, 2);
        for (_, _, v) in j.iter_pairs() {
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
