//! Connected components; the paper takes the largest connected component
//! (LCC) of each input graph before building the instance (§IV-B).

use super::Graph;

/// Label each node with a component id (0-based, by discovery order).
pub fn components(g: &Graph) -> Vec<usize> {
    let n = g.n();
    let mut comp = vec![usize::MAX; n];
    let mut next = 0usize;
    let mut queue = std::collections::VecDeque::new();
    for s in 0..n {
        if comp[s] != usize::MAX {
            continue;
        }
        comp[s] = next;
        queue.push_back(s);
        while let Some(u) = queue.pop_front() {
            for &v in g.neighbors(u) {
                let v = v as usize;
                if comp[v] == usize::MAX {
                    comp[v] = next;
                    queue.push_back(v);
                }
            }
        }
        next += 1;
    }
    comp
}

/// Induced subgraph on the largest connected component.
/// Ties broken by smallest component id (deterministic).
pub fn largest_component(g: &Graph) -> Graph {
    let comp = components(g);
    let k = comp.iter().copied().max().map(|m| m + 1).unwrap_or(0);
    let mut sizes = vec![0usize; k];
    for &c in &comp {
        sizes[c] += 1;
    }
    let best = (0..k).max_by_key(|&c| (sizes[c], std::cmp::Reverse(c))).unwrap_or(0);
    let nodes: Vec<usize> = (0..g.n()).filter(|&u| comp[u] == best).collect();
    g.induced(&nodes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_component() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let c = components(&g);
        assert!(c.iter().all(|&x| x == 0));
    }

    #[test]
    fn two_components() {
        let g = Graph::from_edges(5, &[(0, 1), (2, 3), (3, 4)]);
        let c = components(&g);
        assert_eq!(c[0], c[1]);
        assert_eq!(c[2], c[3]);
        assert_eq!(c[3], c[4]);
        assert_ne!(c[0], c[2]);
    }

    #[test]
    fn isolated_nodes_are_own_components() {
        let g = Graph::from_edges(3, &[]);
        let c = components(&g);
        assert_eq!(c, vec![0, 1, 2]);
    }

    #[test]
    fn lcc_picks_larger() {
        // component {0,1} size 2; component {2,3,4} size 3
        let g = Graph::from_edges(5, &[(0, 1), (2, 3), (3, 4)]);
        let lcc = largest_component(&g);
        assert_eq!(lcc.n(), 3);
        assert_eq!(lcc.m(), 2);
    }

    #[test]
    fn lcc_of_connected_graph_is_identity_shape() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3), (3, 0)]);
        let lcc = largest_component(&g);
        assert_eq!(lcc.n(), 4);
        assert_eq!(lcc.m(), 4);
    }
}
