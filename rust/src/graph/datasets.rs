//! Catalog of the paper's evaluation datasets and their synthetic analogues.
//!
//! The paper (§IV-B) uses five graphs: `power` (SuiteSparse/Newman, Watts &
//! Strogatz's western-US power grid) and four SNAP collaboration networks
//! (`ca-GrQc`, `ca-HepTh`, `ca-HepPh`, `ca-AstroPh`), each reduced to its
//! largest connected component. This environment has no network access, so
//! each entry carries (a) the paper's LCC size, (b) a deterministic
//! generator reproducing the structural family, and (c) a file stem so a
//! real SNAP edge list is used instead when present under `data/`.
//! See DESIGN.md §5 for why this substitution preserves Table I's shape.

use super::components::largest_component;
use super::generators;
use super::io;
use super::Graph;

/// One paper dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    CaGrQc,
    Power,
    CaHepTh,
    CaHepPh,
    CaAstroPh,
}

impl Dataset {
    /// All datasets in Table I order.
    pub const ALL: [Dataset; 5] =
        [Dataset::CaGrQc, Dataset::Power, Dataset::CaHepTh, Dataset::CaHepPh, Dataset::CaAstroPh];

    /// Paper's name.
    pub fn name(self) -> &'static str {
        match self {
            Dataset::CaGrQc => "ca-GrQc",
            Dataset::Power => "power",
            Dataset::CaHepTh => "ca-HepTh",
            Dataset::CaHepPh => "ca-HepPh",
            Dataset::CaAstroPh => "ca-AstroPh",
        }
    }

    /// Parse a paper dataset name (case-insensitive).
    pub fn parse(s: &str) -> Option<Dataset> {
        Dataset::ALL.iter().copied().find(|d| d.name().eq_ignore_ascii_case(s))
    }

    /// LCC size used in the paper (Table I).
    pub fn paper_n(self) -> usize {
        match self {
            Dataset::CaGrQc => 4158,
            Dataset::Power => 4941,
            Dataset::CaHepTh => 8638,
            Dataset::CaHepPh => 11204,
            Dataset::CaAstroPh => 17903,
        }
    }

    /// Generate the synthetic analogue at target LCC size `n`, then take
    /// the LCC exactly as the paper does. The returned graph's node count
    /// is close to (and at most) `n_target`.
    pub fn generate(self, n_target: usize, seed: u64) -> Graph {
        let g = match self {
            // Watts–Strogatz: the power grid is the canonical small-world
            // example (same Watts–Strogatz 1998 paper the dataset is from);
            // mean degree ~2.7 in the real data → k=4 ring with rewiring.
            Dataset::Power => generators::watts_strogatz(n_target, 4, 0.1, seed),
            // Collaboration nets: planted co-authorship groups + heavy-tail
            // cross links. Group counts scale with n; densities tuned per
            // network family (GrQc sparse ... AstroPh dense).
            Dataset::CaGrQc => {
                generators::collaboration(n_target, (n_target / 24).max(2), 0.55, 1, seed)
            }
            Dataset::CaHepTh => {
                generators::collaboration(n_target, (n_target / 20).max(2), 0.5, 1, seed)
            }
            Dataset::CaHepPh => {
                generators::collaboration(n_target, (n_target / 16).max(2), 0.6, 2, seed)
            }
            Dataset::CaAstroPh => {
                generators::collaboration(n_target, (n_target / 12).max(2), 0.65, 3, seed)
            }
        };
        largest_component(&g)
    }

    /// Load the graph: a real edge list `data/<name>.txt` if present
    /// (taking the LCC), else the synthetic analogue at `n_target`.
    pub fn load_or_generate(self, data_dir: &std::path::Path, n_target: usize, seed: u64) -> Graph {
        let path = data_dir.join(format!("{}.txt", self.name()));
        if path.exists() {
            match io::load_edge_list(&path) {
                Ok(g) => return largest_component(&g),
                Err(e) => crate::telemetry::warn(&format!(
                    "failed to load {} ({e}); falling back to synthetic analogue",
                    path.display()
                )),
            }
        }
        self.generate(n_target, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for d in Dataset::ALL {
            assert_eq!(Dataset::parse(d.name()), Some(d));
            assert_eq!(Dataset::parse(&d.name().to_uppercase()), Some(d));
        }
        assert_eq!(Dataset::parse("nope"), None);
    }

    #[test]
    fn paper_sizes_ordered() {
        // Table I order is ascending in constraint count.
        let sizes: Vec<usize> = Dataset::ALL.iter().map(|d| d.paper_n()).collect();
        assert_eq!(sizes, vec![4158, 4941, 8638, 11204, 17903]);
    }

    #[test]
    fn generate_connected_and_near_target() {
        for d in Dataset::ALL {
            let g = d.generate(200, 1);
            assert!(g.n() >= 120, "{}: lcc too small ({})", d.name(), g.n());
            assert!(g.n() <= 200);
            // connectivity: LCC by construction
            let lcc = crate::graph::components::largest_component(&g);
            assert_eq!(lcc.n(), g.n());
        }
    }

    #[test]
    fn generate_deterministic() {
        let a = Dataset::CaGrQc.generate(150, 9);
        let b = Dataset::CaGrQc.generate(150, 9);
        assert_eq!(a.edges(), b.edges());
    }

    #[test]
    fn load_or_generate_falls_back() {
        let g = Dataset::Power.load_or_generate(std::path::Path::new("/nonexistent"), 100, 2);
        assert!(g.n() > 50);
    }
}
