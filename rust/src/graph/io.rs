//! Edge-list IO.
//!
//! Loads the standard whitespace-separated edge-list format used by the
//! SNAP repository (`ca-GrQc.txt` etc., `#` comments) and the SuiteSparse
//! exports, so the *real* paper datasets drop in unchanged when available.
//! Node ids are compacted to `0..n`.

use super::Graph;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// Parse an edge list from text. Lines starting with `#` or `%` are
/// comments; each data line holds two whitespace-separated node ids.
pub fn parse_edge_list(text: &str) -> Result<Graph> {
    let mut ids: HashMap<u64, u32> = HashMap::new();
    let mut edges: Vec<(u32, u32)> = Vec::new();
    let intern = |raw: u64, ids: &mut HashMap<u64, u32>| -> u32 {
        let next = ids.len() as u32;
        *ids.entry(raw).or_insert(next)
    };
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('%') {
            continue;
        }
        let mut it = line.split_whitespace();
        let a: u64 = it
            .next()
            .context("missing src")?
            .parse()
            .with_context(|| format!("line {}: bad src", lineno + 1))?;
        let b: u64 = it
            .next()
            .context("missing dst")?
            .parse()
            .with_context(|| format!("line {}: bad dst", lineno + 1))?;
        let u = intern(a, &mut ids);
        let v = intern(b, &mut ids);
        edges.push((u, v));
    }
    Ok(Graph::from_edges(ids.len(), &edges))
}

/// Load an edge-list file (SNAP format).
pub fn load_edge_list(path: &Path) -> Result<Graph> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_edge_list(&text)
}

/// Write a graph as an edge list (u v per line, 0-based ids).
pub fn write_edge_list(g: &Graph, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    writeln!(f, "# metric-proj edge list: n={} m={}", g.n(), g.m())?;
    for (u, v) in g.edges() {
        writeln!(f, "{u}\t{v}")?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let g = parse_edge_list("# comment\n0 1\n1 2\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn parse_compacts_sparse_ids() {
        let g = parse_edge_list("100 200\n200 4000\n").unwrap();
        assert_eq!(g.n(), 3);
        assert_eq!(g.m(), 2);
    }

    #[test]
    fn parse_skips_comments_and_blanks() {
        let g = parse_edge_list("% matrix market style\n\n# snap style\n5 6\n").unwrap();
        assert_eq!(g.n(), 2);
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_edge_list("a b\n").is_err());
        assert!(parse_edge_list("1\n").is_err());
    }

    #[test]
    fn roundtrip_via_file() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        let dir = std::env::temp_dir().join("metric_proj_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("g.txt");
        write_edge_list(&g, &path).unwrap();
        let g2 = load_edge_list(&path).unwrap();
        assert_eq!(g2.n(), 4);
        assert_eq!(g2.m(), 3);
        let mut e1 = g.edges();
        let mut e2 = g2.edges();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
    }
}
