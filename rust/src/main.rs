//! `metric-proj` — launcher for the parallel metric-constrained
//! optimization framework.
//!
//! Commands (all options have sensible defaults):
//!   info                         PJRT + machine info
//!   solve    --dataset ca-GrQc --n 300 --threads 8 --tile 40 --passes 20
//!            [--engine cpu|xla] [--assignment rr|rot] [--round] [--serial]
//!            [--strategy full|active --sweep-every 8 --forget-after 3]
//!            [--sweep-backend scalar|screened|engine] [--sweep-policy fixed|adaptive]
//!            [--store mem|disk|shard --store-dir store --store-budget-mb 64]
//!            [--workers 2] [--store-retries 4] [--fault-plan seed=1,read-eio=0.01]
//!            [--checkpoint state.ckpt --checkpoint-every 10]
//!            [--resume state.ckpt | --warm-start state.ckpt]
//!            [--recover-attempts 2] [--on-interrupt ignore|checkpoint]
//!            [--watchdog-stall 5 --watchdog-dump watchdog_dump.json]
//!            [--trace-out run.jsonl] [--progress]
//!   nearness --n 200 --threads 8 --tile 40 --passes 50
//!            [--algorithm dykstra|prox-mm|prox-sd]
//!            [--strategy full|active --sweep-every 8 --forget-after 3]
//!            [--sweep-backend scalar|screened|engine] [--sweep-policy fixed|adaptive]
//!            [--store mem|disk|shard --store-dir store --store-budget-mb 64]
//!            [--workers 2] [--store-retries 4] [--fault-plan seed=1,read-eio=0.01]
//!            [--checkpoint ... --checkpoint-every ... --resume ... --warm-start ...]
//!            [--recover-attempts 2] [--on-interrupt ignore|checkpoint]
//!            [--watchdog-stall 5 --watchdog-dump watchdog_dump.json]
//!            [--trace-out run.jsonl] [--progress]
//!   cross-check [--ns 8,12,16] [--seed 42] [--threads 4] [--out verdicts.json]
//!            [--self-test] — differential oracle: Dykstra vs the proximal family
//!   report   --trace run.jsonl[,run2.jsonl...]
//!   bench-gate --fresh rows.json[,rows2.json...] [--baseline bench/baseline.json]
//!            [--tolerance 0.25]
//!   warm-ablation --n 120 --perturb-frac 0.1 --perturb-rel 0.2
//!            [--strategy active] [--tol 1e-6] [--check-every 5]
//!   generate --dataset power --n 500 --out graph.txt
//!   table1   [--scale smoke|small|paper] [--passes 20] [--cores 8,16,32]
//!   fig6     [--dataset ca-HepPh] [--cores 2,4,...] [--scale ...]
//!   fig7     [--dataset ca-GrQc] [--cores-fixed 16] [--tiles 5,10,...,50]

use anyhow::{bail, Context, Result};
use metric_proj::cli::Args;
use metric_proj::eval::{self, EvalConfig, Scale};
use metric_proj::graph::datasets::Dataset;
use metric_proj::instance::{cc_objective, CcLpInstance};
use metric_proj::matrix::store::{
    clean_stale_artifacts, FaultPlan, StoreCfg, StoreKind, DEFAULT_STORE_RETRIES,
};
use metric_proj::rounding::{pivot, threshold};
use metric_proj::solver::checkpoint::{self, SolverState, WarmStartOpts};
use metric_proj::solver::schedule::Assignment;
use metric_proj::runtime::DEFAULT_ARTIFACTS_DIR;
use metric_proj::solver::{
    dykstra_parallel, dykstra_serial, dykstra_xla, nearness, recover, OnInterrupt, SolveError,
    SolveOpts, Strategy, SweepBackend, SweepPolicy,
};
use metric_proj::telemetry::{self, JsonlRecorder, ProgressRecorder, Recorder, Tee};
use metric_proj::util::parallel::available_cores;
use metric_proj::util::timer::time;
use std::path::Path;

/// Process-wide recorder behind [`telemetry::warn`]: the CLI prints
/// library notices to stderr (embedders who install nothing stay silent
/// unless `METRIC_PROJ_LOG` is set).
struct StderrWarnRecorder;

impl Recorder for StderrWarnRecorder {
    fn record(&self, ev: &telemetry::Event) {
        if let telemetry::Event::Warn { msg } = ev {
            eprintln!("warning: {msg}");
        }
    }
}

fn main() -> Result<()> {
    telemetry::set_global(Box::new(StderrWarnRecorder));
    let args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    match args.command.as_str() {
        "info" => cmd_info(),
        "solve" => cmd_solve(&args),
        "nearness" => cmd_nearness(&args),
        "cross-check" => cmd_cross_check(&args),
        "warm-ablation" => cmd_warm_ablation(&args),
        "generate" => cmd_generate(&args),
        "table1" => cmd_table1(&args),
        "fig6" => cmd_fig6(&args),
        "fig7" => cmd_fig7(&args),
        "report" => cmd_report(&args),
        "bench-gate" => cmd_bench_gate(&args),
        // Hidden: the shard coordinator re-enters its own binary with
        // this subcommand to run one worker process (see ShardStore).
        "shard-worker" => cmd_shard_worker(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command `{other}`")
        }
    }
}

fn print_help() {
    println!(
        "metric-proj — parallel projection methods for metric-constrained optimization\n\
         commands: info | solve | nearness | cross-check | warm-ablation | generate | table1 | fig6 | fig7 | report | bench-gate\n\
         see rust/src/main.rs header or README.md for options"
    );
}

fn parse_dataset(args: &Args, default: Dataset) -> Result<Dataset> {
    match args.get("dataset") {
        None => Ok(default),
        Some(s) => Dataset::parse(s)
            .with_context(|| format!("unknown dataset `{s}` (try ca-GrQc, power, ...)")),
    }
}

fn parse_assignment(args: &Args) -> Result<Assignment> {
    match args.get("assignment").unwrap_or("rr") {
        "rr" | "round-robin" => Ok(Assignment::RoundRobin),
        "rot" | "rotated" => Ok(Assignment::Rotated),
        other => bail!("--assignment must be rr|rot, got `{other}`"),
    }
}

fn parse_strategy(args: &Args) -> Result<Strategy> {
    let sweep_every = args.get_or("sweep-every", 8usize).map_err(|e| anyhow::anyhow!(e))?;
    let forget_after = args.get_or("forget-after", 3usize).map_err(|e| anyhow::anyhow!(e))?;
    let s = args.get("strategy").unwrap_or("full");
    Strategy::parse(s, sweep_every, forget_after)
        .with_context(|| format!("--strategy must be full|active, got `{s}`"))
}

fn parse_algorithm(args: &Args) -> Result<metric_proj::solver::Algorithm> {
    let s = args.get("algorithm").unwrap_or("dykstra");
    metric_proj::solver::Algorithm::parse(s)
        .with_context(|| format!("--algorithm must be dykstra|prox-mm|prox-sd, got `{s}`"))
}

fn parse_sweep_backend(args: &Args) -> Result<SweepBackend> {
    let s = args.get("sweep-backend").unwrap_or("screened");
    SweepBackend::parse(s)
        .with_context(|| format!("--sweep-backend must be scalar|screened|engine, got `{s}`"))
}

/// Storage flags shared by the solve commands: `--store
/// mem|disk|shard`, `--store-dir <dir>` (default `store`),
/// `--store-budget-mb <MiB>` (default 64) — the out-of-core tile store
/// for `X` — `--workers <N>` (default 2) shard worker processes for the
/// shard backend, plus the robustness knobs: `--store-retries <N>`
/// bounds the per-operation retry budget for transient block-I/O
/// failures, and `--fault-plan <key=value,...>` (or env
/// `METRIC_PROJ_FAULTS`) arms deterministic fault injection at the disk
/// store's block layer for drills and tests.
fn parse_store_cfg(args: &Args) -> Result<StoreCfg> {
    let kind_str = args.get("store").unwrap_or("mem");
    let kind = StoreKind::parse(kind_str)
        .with_context(|| format!("--store must be mem|disk|shard, got `{kind_str}`"))?;
    let budget_mb =
        args.get_or("store-budget-mb", 64usize).map_err(|e| anyhow::anyhow!(e))?.max(1);
    let workers = args.get_or("workers", 2usize).map_err(|e| anyhow::anyhow!(e))?;
    if kind == StoreKind::Shard && workers == 0 {
        bail!("--workers must be at least 1");
    }
    // The coordinator spawns shard workers by re-entering its own
    // binary with the hidden `shard-worker` subcommand.
    let worker_exe = if kind == StoreKind::Shard {
        Some(std::env::current_exe().context("resolving the worker executable")?)
    } else {
        None
    };
    let spec = match args.get("fault-plan") {
        Some(s) => Some(s.to_string()),
        None => std::env::var("METRIC_PROJ_FAULTS").ok(),
    };
    let faults = match spec {
        Some(s) => {
            let plan = FaultPlan::parse(&s)
                .map_err(|e| anyhow::anyhow!("--fault-plan/METRIC_PROJ_FAULTS: {e}"))?;
            eprintln!("warning: fault injection armed ({s})");
            Some(std::sync::Arc::new(plan))
        }
        None => None,
    };
    Ok(StoreCfg {
        kind,
        dir: args.get("store-dir").unwrap_or("store").into(),
        budget_bytes: budget_mb << 20,
        faults,
        retries: args
            .get_or("store-retries", DEFAULT_STORE_RETRIES)
            .map_err(|e| anyhow::anyhow!(e))?,
        workers,
        worker_exe,
    })
}

fn parse_sweep_policy(args: &Args) -> Result<Option<SweepPolicy>> {
    match args.get("sweep-policy") {
        None => Ok(None),
        Some(s) => {
            let sweep_every =
                args.get_or("sweep-every", 8usize).map_err(|e| anyhow::anyhow!(e))?;
            SweepPolicy::parse(s, sweep_every)
                .map(Some)
                .with_context(|| format!("--sweep-policy must be fixed|adaptive, got `{s}`"))
        }
    }
}

/// Print the storage line for a non-resident solve (silent for mem).
fn print_store_cfg(cfg: &StoreCfg) {
    match cfg.kind {
        StoreKind::Mem => {}
        StoreKind::Disk => println!(
            "store     : disk ({}, cache budget {} MiB split over the X and streamed-W planes)",
            cfg.x_path().display(),
            cfg.budget_bytes >> 20
        ),
        StoreKind::Shard => println!(
            "store     : shard ({} x {} worker processes over unix sockets)",
            cfg.x_path().display(),
            cfg.workers
        ),
    }
}

/// Print the tile-store I/O counters when the solve ran out of core.
fn print_store_io(stats: Option<metric_proj::matrix::store::StoreStats>) {
    if let Some(stats) = stats {
        if stats.shard_requests > 0 {
            println!(
                "shard I/O : {} requests, {:.2} MiB sent, {:.2} MiB received, \
                 {:.1} ms barrier wait",
                stats.shard_requests,
                stats.shard_bytes_out as f64 / (1u64 << 20) as f64,
                stats.shard_bytes_in as f64 / (1u64 << 20) as f64,
                stats.barrier_wait_us as f64 / 1000.0
            );
            return;
        }
        println!(
            "store I/O : {} block loads ({} W-plane), {} evictions ({} write-backs), \
             {} prefetched, peak cache {:.2} MiB",
            stats.loads,
            stats.w_loads,
            stats.evictions,
            stats.writebacks,
            stats.prefetched,
            stats.peak_resident_bytes as f64 / (1u64 << 20) as f64
        );
        if stats.entry_loads > 0 {
            println!(
                "entry I/O : {} entries gathered via entry-granular leases, \
                 {} footprint blocks skipped",
                stats.entry_loads, stats.blocks_skipped
            );
        }
        if stats.retries > 0 {
            println!("resilience: {} transient store faults absorbed by retries", stats.retries);
        }
    }
}

/// Sweep `--store-dir` for leftovers of crashed runs (temp files,
/// orphaned spill planes, and dead per-shard locks whose owner holds no
/// live pid) before a disk or shard solve opens the store; prints what
/// it removed.
fn clean_store_dir(cfg: &StoreCfg) -> Result<()> {
    if cfg.kind == StoreKind::Mem {
        return Ok(());
    }
    let removed = clean_stale_artifacts(&cfg.dir)
        .with_context(|| format!("cleaning stale artifacts in `{}`", cfg.dir.display()))?;
    for p in removed {
        println!("store     : removed stale artifact {}", p.display());
    }
    Ok(())
}

/// Print the screen hit rate when the run had discovery sweeps.
fn print_sweep_screen(screened: u64, projected: u64) {
    if screened > 0 {
        println!(
            "sweep screen: {projected} of {screened} screened triplets projected \
             ({:.2}% hit rate)",
            100.0 * projected as f64 / screened as f64
        );
    }
}

/// Checkpoint flags shared by `solve` and `nearness`:
/// `--checkpoint <path>` (with optional `--checkpoint-every N`) writes
/// states, `--resume <path>` / `--warm-start <path>` read one.
struct CheckpointCli {
    save_path: Option<String>,
    every: usize,
    loaded: Option<SolverState>,
    warm: bool,
    /// Whether at least one state actually reached the file.
    written: std::cell::Cell<bool>,
}

impl CheckpointCli {
    fn parse(args: &Args) -> Result<CheckpointCli> {
        let save_path = args.get("checkpoint").map(str::to_string);
        let mut every =
            args.get_or("checkpoint-every", 0usize).map_err(|e| anyhow::anyhow!(e))?;
        if save_path.is_none() && every > 0 {
            bail!("--checkpoint-every needs --checkpoint <path>");
        }
        if save_path.is_some() && every == 0 {
            every = usize::MAX; // final state only
        }
        let resume = args.get("resume");
        let warm = args.get("warm-start");
        if resume.is_some() && warm.is_some() {
            bail!("--resume and --warm-start are mutually exclusive");
        }
        let loaded = match resume.or(warm) {
            Some(p) => Some(
                SolverState::load_path(Path::new(p))
                    .with_context(|| format!("loading checkpoint `{p}`"))?,
            ),
            None => None,
        };
        Ok(CheckpointCli {
            save_path,
            every,
            loaded,
            warm: warm.is_some(),
            written: std::cell::Cell::new(false),
        })
    }

    fn in_use(&self) -> bool {
        self.save_path.is_some() || self.loaded.is_some()
    }

    /// Sink that (re)writes the checkpoint file on every emission.
    fn sink(&self) -> impl FnMut(&SolverState) + '_ {
        move |st: &SolverState| {
            if let Some(p) = &self.save_path {
                match st.save_path(Path::new(p)) {
                    Ok(()) => self.written.set(true),
                    Err(e) => eprintln!("warning: failed to write checkpoint `{p}`: {e}"),
                }
            }
        }
    }

    /// Truthful end-of-run report: only claim a file exists if a write
    /// actually succeeded.
    fn report(&self) {
        if let Some(p) = &self.save_path {
            if self.written.get() {
                println!("checkpoint: final state written to {p}");
            } else {
                eprintln!("checkpoint: NO state was written to {p} (see warnings above)");
            }
        }
    }
}

/// Robustness flags shared by the solve commands: `--on-interrupt
/// ignore|checkpoint` (checkpoint mode installs the SIGINT/SIGTERM
/// handlers and needs `--checkpoint`), `--watchdog-stall <K>` /
/// `--watchdog-dump <path>`, and `--recover-attempts <N>` for the
/// auto-resume harness around store failures.
struct RobustCli {
    on_interrupt: OnInterrupt,
    watchdog_stall: usize,
    watchdog_dump: String,
    recover_attempts: usize,
}

impl RobustCli {
    fn parse(args: &Args, ck: &CheckpointCli) -> Result<RobustCli> {
        let s = args.get("on-interrupt").unwrap_or("ignore");
        let on_interrupt = OnInterrupt::parse(s)
            .with_context(|| format!("--on-interrupt must be ignore|checkpoint, got `{s}`"))?;
        if on_interrupt == OnInterrupt::Checkpoint {
            if ck.save_path.is_none() {
                bail!("--on-interrupt checkpoint needs --checkpoint <path>");
            }
            metric_proj::util::interrupt::install();
        }
        let recover_attempts =
            args.get_or("recover-attempts", 0usize).map_err(|e| anyhow::anyhow!(e))?;
        if recover_attempts > 0 && ck.save_path.is_none() {
            bail!("--recover-attempts needs --checkpoint <path> to resume from");
        }
        Ok(RobustCli {
            on_interrupt,
            watchdog_stall: args
                .get_or("watchdog-stall", 0usize)
                .map_err(|e| anyhow::anyhow!(e))?,
            watchdog_dump: args
                .get("watchdog-dump")
                .unwrap_or("watchdog_dump.json")
                .to_string(),
            recover_attempts,
        })
    }

    /// Map a typed solve failure onto CLI behavior: an honored interrupt
    /// is a clean exit (the work is checkpointed, not lost), a watchdog
    /// trip writes its diagnostic dump before failing, and store
    /// failures propagate naming the last good checkpoint.
    fn conclude(&self, err: SolveError) -> Result<()> {
        match err {
            SolveError::Interrupted { pass, checkpointed } => {
                println!(
                    "interrupted: stopped cleanly after pass {pass}{}",
                    if checkpointed { " (state checkpointed)" } else { "" }
                );
                Ok(())
            }
            SolveError::Watchdog { pass, report } => {
                let path = Path::new(&self.watchdog_dump);
                std::fs::write(path, &report)
                    .with_context(|| format!("writing watchdog dump `{}`", path.display()))?;
                bail!(
                    "watchdog tripped at pass {pass} (stall or divergence); \
                     diagnostic dump written to {}",
                    path.display()
                )
            }
            SolveError::Other(e) => Err(e),
            err => Err(anyhow::Error::from(err)),
        }
    }
}

/// Telemetry flags shared by `solve` and `nearness`: `--trace-out
/// <path>` streams structured JSONL events, `--progress` prints one
/// stderr line per pass. Both may be combined (a [`Tee`] fans out).
struct TraceCli {
    jsonl: Option<JsonlRecorder>,
    progress: Option<ProgressRecorder>,
}

impl TraceCli {
    fn parse(args: &Args) -> Result<TraceCli> {
        let jsonl = match args.get("trace-out") {
            Some(p) => Some(JsonlRecorder::create(Path::new(p))?),
            None => None,
        };
        let progress = if args.has_flag("progress") { Some(ProgressRecorder::new()) } else { None };
        Ok(TraceCli { jsonl, progress })
    }

    /// The recorder to hand the solver (disabled when no flag was given,
    /// which pins the untraced path).
    fn recorder(&self) -> Tee<'_> {
        let mut recs: Vec<&dyn Recorder> = Vec::new();
        if let Some(j) = &self.jsonl {
            recs.push(j);
        }
        if let Some(p) = &self.progress {
            recs.push(p);
        }
        Tee::new(recs)
    }

    /// Flush the trace file, surfacing any latched I/O error.
    fn finish(self) -> Result<()> {
        if let Some(j) = self.jsonl {
            let path = j.path().display().to_string();
            j.finish()?;
            println!("trace     : events written to {path}");
        }
        Ok(())
    }
}

/// FNV-1a over the solution plane's bits — the cheap cross-run equality
/// anchor: two solves print the same value iff their iterates are
/// bitwise identical, which is how the CI shard matrix diffs a sharded
/// solve against its resident reference.
fn solution_fnv(x: &[f64]) -> u64 {
    use metric_proj::util::hash::{fnv1a64_f64s, Fnv1a};
    fnv1a64_f64s(Fnv1a::new().finish(), x)
}

/// Print the work accounting shared by `solve` and `nearness`.
fn print_work(metric_visits: u64, active_triplets: usize, passes: usize, full_per_pass: u128) {
    let full_total = full_per_pass as f64 * passes.max(1) as f64;
    println!(
        "metric visits: {:.3e} ({:.1}% of a full-sweep run)",
        metric_visits as f64,
        100.0 * metric_visits as f64 / full_total.max(1.0)
    );
    println!(
        "active set : {} triplets ({:.1}% of C(n,3))",
        active_triplets,
        100.0 * active_triplets as f64 / (full_per_pass as f64 / 3.0).max(1.0)
    );
}

fn eval_config(args: &Args) -> Result<EvalConfig> {
    let mut cfg = EvalConfig::default();
    if let Some(s) = args.get("scale") {
        cfg.scale = Scale::parse(s).with_context(|| format!("bad --scale `{s}`"))?;
    }
    cfg.passes = args.get_or("passes", cfg.passes).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(b) = args.get("tile") {
        let b: usize = b.parse().map_err(|_| anyhow::anyhow!("--tile: bad value"))?;
        cfg.tile = metric_proj::eval::TilePolicy::Fixed(b);
    }
    cfg.seed = args.get_or("seed", cfg.seed).map_err(|e| anyhow::anyhow!(e))?;
    if let Some(cores) = args.get_list("cores").map_err(|e| anyhow::anyhow!(e))? {
        cfg.cores = cores;
    }
    cfg.assignment = parse_assignment(args)?;
    if let Some(s) = args.get("timing") {
        cfg.timing = metric_proj::eval::TimingMode::parse(s)
            .with_context(|| format!("--timing must be real|sim, got `{s}`"))?;
    }
    Ok(cfg)
}

fn cmd_info() -> Result<()> {
    println!("cores available : {}", available_cores());
    match metric_proj::runtime::PjrtRuntime::cpu(DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => {
            println!("pjrt platform   : {}", rt.platform());
            println!("pjrt devices    : {}", rt.device_count());
            println!("artifacts dir   : {}", rt.artifacts_dir().display());
        }
        Err(e) => println!("pjrt            : unavailable ({e})"),
    }
    for d in Dataset::ALL {
        println!(
            "dataset {:<11}: paper n = {:>6}, small-scale n = {}",
            d.name(),
            d.paper_n(),
            Scale::Small.n_for(d)
        );
    }
    Ok(())
}

fn build_instance_cli(args: &Args) -> Result<(CcLpInstance, String)> {
    let d = parse_dataset(args, Dataset::CaGrQc)?;
    let n = args.get_or("n", 300usize).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_or("seed", 42u64).map_err(|e| anyhow::anyhow!(e))?;
    let g = d.load_or_generate(std::path::Path::new("data"), n, seed);
    let inst = metric_proj::instance::construction::build_cc_instance(
        &g,
        metric_proj::instance::construction::ConstructionParams::default(),
        available_cores(),
    );
    Ok((inst, format!("{} (lcc n={}, m={})", d.name(), g.n(), g.m())))
}

fn cmd_solve(args: &Args) -> Result<()> {
    let algorithm = parse_algorithm(args)?;
    if algorithm.is_proximal() {
        bail!(
            "--algorithm {} is implemented for the nearness problem only \
             (the CC-LP objective has slack variables the proximal penalty \
             does not model); use `nearness --algorithm {}` or drop the flag",
            algorithm.name(),
            algorithm.name()
        );
    }
    let (inst, desc) = build_instance_cli(args)?;
    let ck = CheckpointCli::parse(args)?;
    let robust = RobustCli::parse(args, &ck)?;
    let opts = SolveOpts {
        gamma: args.get_or("gamma", 5.0).map_err(|e| anyhow::anyhow!(e))?,
        max_passes: args.get_or("passes", 20usize).map_err(|e| anyhow::anyhow!(e))?,
        threads: args.get_or("threads", available_cores()).map_err(|e| anyhow::anyhow!(e))?,
        tile: args.get_or("tile", 40usize).map_err(|e| anyhow::anyhow!(e))?,
        check_every: args.get_or("check-every", 0usize).map_err(|e| anyhow::anyhow!(e))?,
        track_pass_times: true,
        assignment: parse_assignment(args)?,
        strategy: parse_strategy(args)?,
        sweep_backend: parse_sweep_backend(args)?,
        sweep_policy: parse_sweep_policy(args)?,
        checkpoint_every: ck.every,
        on_interrupt: robust.on_interrupt,
        watchdog_stall: robust.watchdog_stall,
        ..Default::default()
    };
    let store_cfg = parse_store_cfg(args)?;
    let engine = args.get("engine").unwrap_or("cpu");
    if opts.strategy.is_active() && (args.has_flag("serial") || engine != "cpu") {
        bail!(
            "--strategy active runs on the parallel CPU engine only \
             (drop --serial / use --engine cpu)"
        );
    }
    if ck.in_use() && engine != "cpu" {
        bail!("--checkpoint/--resume/--warm-start run on the CPU engine only");
    }
    if store_cfg.kind != StoreKind::Mem && (args.has_flag("serial") || engine != "cpu") {
        bail!(
            "--store {} runs on the parallel CPU engine only \
             (drop --serial / use --engine cpu)",
            store_cfg.kind.name()
        );
    }
    let start: Option<SolverState> = match ck.loaded.clone() {
        Some(st) if ck.warm => {
            let warmed = checkpoint::warm_start_cc(&st, &inst, &opts, &WarmStartOpts::default())?;
            println!(
                "warm start: carried {} metric duals into {} active triplets",
                warmed.metric_duals.len(),
                warmed.active.len()
            );
            Some(warmed)
        }
        Some(st) => {
            println!(
                "resume    : from pass {} ({} metric duals carried)",
                st.pass,
                st.metric_duals.len()
            );
            Some(st)
        }
        None => None,
    };
    println!("instance  : {desc}");
    println!("constraints: {:.3e}", inst.n_constraints() as f64);
    print_store_cfg(&store_cfg);
    clean_store_dir(&store_cfg)?;
    println!(
        "solver    : {} threads={} tile={} passes={} strategy={:?} sweep-backend={}{}",
        if args.has_flag("serial") { "serial" } else { "parallel" },
        opts.threads,
        opts.tile,
        opts.max_passes,
        opts.strategy,
        opts.sweep_backend.name(),
        match opts.sweep_policy {
            Some(p) => format!(" sweep-policy={p:?}"),
            None => String::new(),
        }
    );
    let trace = TraceCli::parse(args)?;
    let (res, secs) = {
        let rec = trace.recorder();
        match engine {
            "cpu" => {
                let mut sink = ck.sink();
                let ckpath = ck.save_path.clone();
                time(|| {
                    recover::run_with_recovery(
                        robust.recover_attempts,
                        ckpath.as_deref().map(Path::new),
                        &rec,
                        |recovered| {
                            let from = recovered.or(start.as_ref());
                            if args.has_flag("serial") {
                                dykstra_serial::solve_traced(&inst, &opts, from, &mut sink, &rec)
                            } else {
                                dykstra_parallel::solve_traced(
                                    &inst,
                                    &opts,
                                    &store_cfg,
                                    from,
                                    &mut sink,
                                    &rec,
                                )
                            }
                        },
                    )
                })
            }
            "xla" => {
                let eng = metric_proj::runtime::engine::XlaEngine::load(DEFAULT_ARTIFACTS_DIR)
                    .context("loading XLA engine (run `make artifacts`)")?;
                time(|| dykstra_xla::solve_traced(&inst, &opts, &eng, &rec))
            }
            other => bail!("--engine must be cpu|xla, got `{other}`"),
        }
    };
    let sol = match res {
        Ok(sol) => sol,
        Err(err) => {
            trace.finish()?;
            ck.report();
            return robust.conclude(err);
        }
    };
    trace.finish()?;
    ck.report();
    let r = &sol.residuals;
    println!(
        "passes    : {} ({secs:.2}s total, {:.3}s/pass pass-time)",
        sol.passes,
        sol.pass_times.iter().sum::<f64>() / sol.passes.max(1) as f64
    );
    println!("violation : {:.3e}", r.max_violation);
    println!("rel gap   : {:.3e}", r.rel_gap);
    println!("LP objective (lower bound on CC): {:.4}", r.lp_objective);
    println!("nnz metric duals: {}", sol.nnz_duals);
    print_work(sol.metric_visits, sol.active_triplets, sol.passes, inst.n_metric_constraints());
    print_sweep_screen(sol.sweep_screened, sol.sweep_projected);
    print_store_io(sol.store_stats);
    println!("solution fnv : {:#018x}", solution_fnv(sol.x.as_slice()));

    if args.has_flag("round") {
        let labels_t = threshold::round(&sol.x, 0.5);
        let obj_t = cc_objective(&inst, &labels_t);
        let (labels_p, obj_p) = pivot::round_best(&sol.x, 20, 7, |l| cc_objective(&inst, l));
        let k = |l: &[usize]| l.iter().max().map(|m| m + 1).unwrap_or(0);
        println!("rounding  : threshold obj={obj_t:.4} ({} clusters)", k(&labels_t));
        println!("          : pivot     obj={obj_p:.4} ({} clusters)", k(&labels_p));
        let best = obj_t.min(obj_p);
        if r.lp_objective > 1e-9 {
            println!("          : approx ratio vs LP bound = {:.3}", best / r.lp_objective);
        }
    }
    Ok(())
}

fn cmd_nearness(args: &Args) -> Result<()> {
    let n = args.get_or("n", 200usize).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_or("seed", 42u64).map_err(|e| anyhow::anyhow!(e))?;
    let inst =
        metric_proj::instance::metric_nearness::MetricNearnessInstance::random(n, 2.0, seed);
    let ck = CheckpointCli::parse(args)?;
    let robust = RobustCli::parse(args, &ck)?;
    let opts = nearness::NearnessOpts {
        max_passes: args.get_or("passes", 50usize).map_err(|e| anyhow::anyhow!(e))?,
        threads: args.get_or("threads", available_cores()).map_err(|e| anyhow::anyhow!(e))?,
        tile: args.get_or("tile", 40usize).map_err(|e| anyhow::anyhow!(e))?,
        strategy: parse_strategy(args)?,
        sweep_backend: parse_sweep_backend(args)?,
        sweep_policy: parse_sweep_policy(args)?,
        checkpoint_every: ck.every,
        on_interrupt: robust.on_interrupt,
        watchdog_stall: robust.watchdog_stall,
        algorithm: parse_algorithm(args)?,
        ..Default::default()
    };
    let start: Option<SolverState> = match ck.loaded.clone() {
        Some(st) if ck.warm => {
            let warmed =
                checkpoint::warm_start_nearness(&st, &inst, &WarmStartOpts::default())?;
            println!(
                "warm start: carried {} metric duals into {} active triplets",
                warmed.metric_duals.len(),
                warmed.active.len()
            );
            Some(warmed)
        }
        Some(st) => {
            println!("resume    : from pass {}", st.pass);
            Some(st)
        }
        None => None,
    };
    let store_cfg = parse_store_cfg(args)?;
    print_store_cfg(&store_cfg);
    clean_store_dir(&store_cfg)?;
    let trace = TraceCli::parse(args)?;
    let (res, secs) = {
        let rec = trace.recorder();
        let mut sink = ck.sink();
        let ckpath = ck.save_path.clone();
        time(|| {
            recover::run_with_recovery(
                robust.recover_attempts,
                ckpath.as_deref().map(Path::new),
                &rec,
                |recovered| {
                    nearness::solve_traced(
                        &inst,
                        &opts,
                        &store_cfg,
                        recovered.or(start.as_ref()),
                        &mut sink,
                        &rec,
                    )
                },
            )
        })
    };
    let sol = match res {
        Ok(sol) => sol,
        Err(err) => {
            trace.finish()?;
            ck.report();
            return robust.conclude(err);
        }
    };
    trace.finish()?;
    ck.report();
    println!(
        "metric nearness n={n} ({}): passes={} time={secs:.2}s",
        opts.algorithm.name(),
        sol.passes
    );
    println!("objective ||X-D||_W^2 = {:.4}", sol.objective);
    println!("max violation = {:.3e}", sol.max_violation);
    let full_per_pass = metric_proj::solver::schedule::n_triplets(n) as u128 * 3;
    print_work(sol.metric_visits, sol.active_triplets, sol.passes, full_per_pass);
    print_sweep_screen(sol.sweep_screened, sol.sweep_projected);
    print_store_io(sol.store_stats);
    println!("solution fnv : {:#018x}", solution_fnv(sol.x.as_slice()));
    Ok(())
}

/// `cross-check` — the cross-family differential oracle: run Dykstra and
/// both proximal members over a seeded instance sweep, compare converged
/// objectives and feasibility within the documented bands, and emit the
/// machine-readable verdict table. `--self-test` additionally proves the
/// oracle's sensitivity by driving the MM solver over a deliberately
/// broken triangle operator and demanding a MISMATCH verdict. Exits
/// nonzero on any mismatch (or on a self-test that fails to trip).
fn cmd_cross_check(args: &Args) -> Result<()> {
    use metric_proj::eval::cross_check::{self, Band, CaseSpec, WeightKind};
    use metric_proj::solver::proximal::{self, operator, ProxTuning};
    use metric_proj::solver::Algorithm;

    let ns = args
        .get_list("ns")
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or_else(|| vec![8, 12, 16]);
    let seed = args.get_or("seed", 42u64).map_err(|e| anyhow::anyhow!(e))?;
    let threads =
        args.get_or("threads", available_cores().min(4)).map_err(|e| anyhow::anyhow!(e))?;
    let specs = cross_check::default_sweep(seed, &ns);
    println!(
        "# cross-family oracle — {} cases (ns={ns:?} x unit/uniform/spiky weights, \
         base seed {seed}), {threads} thread(s)",
        specs.len()
    );
    let report = cross_check::run_sweep(&specs, threads);
    print!("{}", report.render_table());
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().to_string())
            .with_context(|| format!("writing verdict table `{out}`"))?;
        println!("verdicts  : written to {out}");
    }

    if args.has_flag("self-test") {
        // Negative control: the same MM solver over a sign-flipped T'T
        // must land visibly outside the band.
        let spec = CaseSpec { n: 10, seed, weights: WeightKind::Unit, hi: 2.0 };
        let inst = spec.build();
        let dyk = nearness::solve(
            &inst,
            &nearness::NearnessOpts {
                max_passes: 5000,
                check_every: 10,
                tol_violation: 1e-10,
                threads,
                ..Default::default()
            },
        );
        let band = Band::for_algorithm(Algorithm::ProxMm);
        let tuning = ProxTuning::default();
        let broken = operator::BrokenOperator(operator::WaveOperator::new(inst.n, 8, threads));
        let verdict = match proximal::solve_nearness_with(
            &inst,
            Algorithm::ProxMm,
            band.solve_tol,
            threads,
            &tuning,
            &broken,
            &metric_proj::telemetry::NullRecorder,
        ) {
            Ok(sol) => cross_check::judge(
                "self-test/broken-operator".to_string(),
                Algorithm::ProxMm,
                dyk.objective,
                sol.objective,
                sol.max_violation,
                band,
            ),
            // A divergence error is an equally valid detection.
            Err(e) => {
                println!("self-test : broken operator made the solver fail typed ({e}) — ok");
                cross_check::judge(
                    "self-test/broken-operator".to_string(),
                    Algorithm::ProxMm,
                    dyk.objective,
                    f64::NAN,
                    f64::INFINITY,
                    band,
                )
            }
        };
        if verdict.pass {
            bail!(
                "oracle self-test FAILED: a sign-flipped T'T kernel passed the band \
                 (rel_gap {:.3e}, viol {:.3e}) — the tolerances are too loose",
                verdict.rel_gap,
                verdict.max_violation
            );
        }
        println!(
            "self-test : broken kernel flagged (rel_gap {:.3e}, viol {:.3e}) — oracle is live",
            verdict.rel_gap, verdict.max_violation
        );
    }

    if !report.all_pass() {
        bail!(
            "cross-family oracle found {} mismatch(es) — see the table above",
            report.failures().len()
        );
    }
    println!("oracle    : all {} verdicts within tolerance", report.verdicts.len());
    Ok(())
}

fn cmd_warm_ablation(args: &Args) -> Result<()> {
    let n = args.get_or("n", 120usize).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_or("seed", 42u64).map_err(|e| anyhow::anyhow!(e))?;
    let frac = args.get_or("perturb-frac", 0.1f64).map_err(|e| anyhow::anyhow!(e))?;
    let rel = args.get_or("perturb-rel", 0.2f64).map_err(|e| anyhow::anyhow!(e))?;
    let tol = args.get_or("tol", 1e-6f64).map_err(|e| anyhow::anyhow!(e))?;
    let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, seed);
    let perturbed = inst.perturb_weights(frac, rel, seed ^ 0x9E37);
    let opts = SolveOpts {
        max_passes: args.get_or("passes", 10_000usize).map_err(|e| anyhow::anyhow!(e))?,
        check_every: args.get_or("check-every", 5usize).map_err(|e| anyhow::anyhow!(e))?,
        tol_violation: tol,
        tol_gap: 1e30, // violation-driven stop for a clean pass comparison
        threads: args.get_or("threads", available_cores()).map_err(|e| anyhow::anyhow!(e))?,
        tile: args.get_or("tile", 40usize).map_err(|e| anyhow::anyhow!(e))?,
        strategy: parse_strategy(args)?,
        sweep_backend: parse_sweep_backend(args)?,
        sweep_policy: parse_sweep_policy(args)?,
        ..Default::default()
    };
    println!(
        "# warm-start ablation — n={n}, {:.0}% of weights perturbed by up to ±{:.0}%, \
         tol={tol:.0e}, strategy={:?}",
        frac * 100.0,
        rel * 100.0,
        opts.strategy
    );
    let ab = eval::warm_start_ablation(&inst, &perturbed, &opts, &WarmStartOpts::default())?;
    for row in [&ab.base, &ab.cold, &ab.warm] {
        println!(
            "{:<5} passes={:<6} metric visits={:.3e} violation={:.2e} lp={:.4}",
            row.label, row.passes, row.metric_visits as f64, row.max_violation,
            row.lp_objective
        );
    }
    println!(
        "warm start saved {} passes ({:.1}% of cold)",
        ab.passes_saved(),
        100.0 * ab.passes_saved() as f64 / ab.cold.passes.max(1) as f64
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let d = parse_dataset(args, Dataset::Power)?;
    let n = args.get_or("n", 500usize).map_err(|e| anyhow::anyhow!(e))?;
    let seed = args.get_or("seed", 42u64).map_err(|e| anyhow::anyhow!(e))?;
    let out = args.get("out").unwrap_or("graph.txt");
    let g = d.generate(n, seed);
    metric_proj::graph::io::write_edge_list(&g, std::path::Path::new(out))?;
    println!("wrote {} ({} nodes, {} edges, analogue of {})", out, g.n(), g.m(), d.name());
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let cfg = eval_config(args)?;
    println!(
        "# Table I reproduction — scale={:?}, passes={}, tile={:?}, cores={:?}, timing={:?} (machine: {} cores)",
        cfg.scale,
        cfg.passes,
        cfg.tile,
        cfg.cores,
        cfg.timing,
        available_cores()
    );
    let rows = eval::table1(&cfg, &Dataset::ALL, |r| {
        println!(
            "{:<11} n={:<6} cores={:<3} time={:>9.2}s speedup={:.2}",
            r.dataset, r.n, r.cores, r.time_s, r.speedup
        );
    });
    println!("\n{}", eval::render_table1(&rows));
    Ok(())
}

fn cmd_fig6(args: &Args) -> Result<()> {
    let cfg = eval_config(args)?;
    let d = parse_dataset(args, Dataset::CaHepPh)?;
    // paper: 1 core, then 8..40 step 4 — clamp to machine
    let avail = available_cores();
    let default_cores: Vec<usize> =
        std::iter::once(2).chain((4..=avail).step_by(4)).filter(|&c| c <= avail).collect();
    let cores = args.get_list("cores").map_err(|e| anyhow::anyhow!(e))?.unwrap_or(default_cores);
    println!("# Fig 6 reproduction — {} speedup vs cores (tile={:?})", d.name(), cfg.tile);
    eval::fig6(&cfg, d, &cores, |c, t, s| {
        println!("cores={c:<3} time={t:>9.2}s speedup={s:.2}");
    });
    Ok(())
}

fn cmd_fig7(args: &Args) -> Result<()> {
    let cfg = eval_config(args)?;
    let d = parse_dataset(args, Dataset::CaGrQc)?;
    let cores = args
        .get_or("cores-fixed", 16usize.min(available_cores()))
        .map_err(|e| anyhow::anyhow!(e))?;
    let tiles = args
        .get_list("tiles")
        .map_err(|e| anyhow::anyhow!(e))?
        .unwrap_or_else(|| (1..=10).map(|i| i * 5).collect());
    println!("# Fig 7 reproduction — {} speedup vs tile size ({} cores)", d.name(), cores);
    eval::fig7(&cfg, d, cores, &tiles, |b, t, s| {
        println!("tile={b:<3} time={t:>9.2}s speedup={s:.2}");
    });
    Ok(())
}

/// `report --trace a.jsonl[,b.jsonl...]` — summarize solver traces.
fn cmd_report(args: &Args) -> Result<()> {
    let traces = args
        .get("trace")
        .context("report needs --trace <file[,file...]> (a --trace-out capture)")?;
    let paths: Vec<&str> = traces.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if paths.is_empty() {
        bail!("--trace: no paths given");
    }
    print!("{}", metric_proj::telemetry::report::render_files(&paths)?);
    Ok(())
}

/// Hidden `shard-worker --connect <socket>` — one shard worker process,
/// spawned by a `--store shard` coordinator from this same binary. It
/// connects back, receives its slice over INIT, and serves leases until
/// shutdown (or coordinator EOF).
fn cmd_shard_worker(args: &Args) -> Result<()> {
    let sock = args
        .get("connect")
        .context("shard-worker needs --connect <socket path>")?;
    metric_proj::matrix::store::shard::worker_main(Path::new(sock))
        .with_context(|| format!("shard worker serving `{sock}`"))?;
    Ok(())
}

/// `bench-gate --fresh rows.json[,...]` — compare fresh bench rows
/// against the committed baseline, failing the process on regression.
fn cmd_bench_gate(args: &Args) -> Result<()> {
    use metric_proj::eval::regression::{self, BaselineFile};
    let baseline_path = args.get("baseline").unwrap_or("bench/baseline.json");
    let fresh_arg = args
        .get("fresh")
        .context("bench-gate needs --fresh <rows.json[,rows2.json...]> (bench row output)")?;
    let tol = args
        .get_or("tolerance", regression::DEFAULT_TOLERANCE)
        .map_err(|e| anyhow::anyhow!(e))?;
    if !(0.0..1.0).contains(&tol) {
        bail!("--tolerance must be in [0, 1), got {tol}");
    }
    let baseline = BaselineFile::load(Path::new(baseline_path))?;
    let mut fresh = BaselineFile::default();
    for p in fresh_arg.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        fresh.merge(&BaselineFile::load(Path::new(p))?);
    }
    if baseline.rows.is_empty() {
        println!(
            "bench gate: baseline {baseline_path} has no rows yet (bootstrap) — \
             run `cargo bench --bench sweep -- --commit-baseline` to seed it"
        );
    }
    let report = regression::gate(&baseline, &fresh, tol);
    print!("{}", report.render());
    if !report.passed() {
        bail!(
            "bench gate failed: {} regression(s), {} missing cell(s) vs {baseline_path}",
            report.failures.len(),
            report.missing.len()
        );
    }
    Ok(())
}
