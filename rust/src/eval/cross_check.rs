//! Cross-family differential-testing oracle.
//!
//! The crate ships two algorithm families that solve the *same*
//! mathematical problem by unrelated means: the Dykstra drivers
//! (exact projection by cyclic constraint projection) and the proximal
//! family ([`crate::solver::proximal`], penalized Newton-free descent).
//! They share no fixed-point math, no dual storage, and no stopping
//! logic — so running both on the same instance and comparing the
//! converged objectives and constraint residuals is a differential test
//! of everything underneath: the triangle operator, the wave schedule,
//! the projection kernels, the violation scan.
//!
//! The tolerance model is deliberate and documented (see
//! `docs/ARCHITECTURE.md`, "Why agreement is within tolerance"):
//! Dykstra converges to the exact projection; a proximal run stops at a
//! finite penalty, so its objective sits *near* (and its iterate is
//! feasible only to `tol_violation`). The oracle therefore checks
//!
//! * `|obj_prox − obj_dyk| ≤ rel_obj_tol · max(1, obj_dyk)`, and
//! * `max_violation_prox ≤ viol_tol`,
//!
//! with per-member bands measured in the f64 prototype behind the
//! solvers (EXPERIMENTS.md, "Cross-family oracle"): MM converges to
//! ~1e-4 relative agreement, band 5e-3; SD to ~9e-3, band 2e-2. The
//! bands are loose enough for platform jitter but ~4 orders of
//! magnitude tighter than what a broken kernel produces (a single
//! flipped sign in `T'T` lands ~30× off in relative objective —
//! `tests/cross_family.rs` pins this margin with
//! [`crate::solver::proximal::operator::BrokenOperator`]).
//!
//! [`run_sweep`] drives a seeded instance sweep (sizes × weight
//! structures), [`judge`] applies the band to any pair of solutions
//! (public so negative tests can inject deliberately wrong ones), and
//! [`Report::to_json`] emits the machine-readable verdict table the
//! nightly CI oracle job archives.

use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::matrix::PackedSym;
use crate::solver::error::SolveError;
use crate::solver::nearness::{self, NearnessOpts};
use crate::solver::Algorithm;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// How the instance weights are drawn.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightKind {
    /// All weights 1 (the classic nearness setting).
    Unit,
    /// I.i.d. uniform in `[0.5, 2]` — smooth anisotropy.
    Uniform,
    /// Mostly 1 with a ~10% fraction boosted ×25 — near-hard pairs,
    /// the regime where a wrong weighted kernel shows first.
    Spiky,
}

impl WeightKind {
    pub fn name(self) -> &'static str {
        match self {
            WeightKind::Unit => "unit",
            WeightKind::Uniform => "uniform",
            WeightKind::Spiky => "spiky",
        }
    }

    pub fn parse(s: &str) -> Option<WeightKind> {
        match s {
            "unit" => Some(WeightKind::Unit),
            "uniform" => Some(WeightKind::Uniform),
            "spiky" => Some(WeightKind::Spiky),
            _ => None,
        }
    }
}

/// One seeded instance of the sweep.
#[derive(Clone, Copy, Debug)]
pub struct CaseSpec {
    /// Number of points.
    pub n: usize,
    /// Instance seed (distances and weights both derive from it).
    pub seed: u64,
    /// Weight structure.
    pub weights: WeightKind,
    /// Upper bound of the uniform dissimilarity draw.
    pub hi: f64,
}

impl CaseSpec {
    /// Materialize the instance (deterministic in the spec).
    pub fn build(&self) -> MetricNearnessInstance {
        let mut inst = MetricNearnessInstance::random(self.n, self.hi, self.seed);
        let mut rng = Rng::new(self.seed ^ 0x57e1_64f5);
        inst.w = match self.weights {
            WeightKind::Unit => PackedSym::filled(self.n, 1.0),
            WeightKind::Uniform => PackedSym::from_fn(self.n, |_, _| rng.f64_in(0.5, 2.0)),
            WeightKind::Spiky => PackedSym::from_fn(self.n, |_, _| {
                if rng.f64_in(0.0, 1.0) < 0.1 {
                    25.0
                } else {
                    1.0
                }
            }),
        };
        inst
    }

    fn label(&self) -> String {
        format!("n={}/w={}/seed={}", self.n, self.weights.name(), self.seed)
    }
}

/// The default nightly sweep: sizes × weight structures, one seed per
/// cell derived from `base_seed` so re-runs are reproducible and
/// distinct bases give distinct instances.
pub fn default_sweep(base_seed: u64, ns: &[usize]) -> Vec<CaseSpec> {
    let mut specs = Vec::new();
    for (i, &n) in ns.iter().enumerate() {
        for (j, weights) in
            [WeightKind::Unit, WeightKind::Uniform, WeightKind::Spiky].into_iter().enumerate()
        {
            specs.push(CaseSpec {
                n,
                seed: base_seed.wrapping_add(1000 * i as u64 + 100 * j as u64),
                weights,
                hi: 2.0,
            });
        }
    }
    specs
}

/// The per-member agreement band (see the module docs for where the
/// numbers come from).
#[derive(Clone, Copy, Debug)]
pub struct Band {
    /// `|obj_prox − obj_dyk| ≤ rel_obj_tol · max(1, obj_dyk)`.
    pub rel_obj_tol: f64,
    /// Feasibility the proximal iterate must reach.
    pub viol_tol: f64,
    /// `tol_violation` the proximal solver is *run* with (tighter than
    /// `viol_tol`, so the check has slack over the stopping rule).
    pub solve_tol: f64,
}

impl Band {
    /// The validated band for an algorithm member.
    pub fn for_algorithm(a: Algorithm) -> Band {
        match a {
            Algorithm::ProxMm => Band { rel_obj_tol: 5e-3, viol_tol: 1e-6, solve_tol: 1e-7 },
            Algorithm::ProxSd => Band { rel_obj_tol: 2e-2, viol_tol: 1e-5, solve_tol: 1e-6 },
            // Dykstra vs itself: the reference band is only used when
            // judging injected solutions in tests.
            Algorithm::Dykstra => Band { rel_obj_tol: 1e-9, viol_tol: 1e-6, solve_tol: 1e-7 },
        }
    }
}

/// One judged comparison.
#[derive(Clone, Debug)]
pub struct Verdict {
    /// Case label, e.g. `n=16/w=spiky/seed=7100`.
    pub case: String,
    /// Which proximal member was compared against Dykstra.
    pub algorithm: Algorithm,
    /// Converged Dykstra objective (the reference).
    pub obj_dykstra: f64,
    /// Converged proximal objective.
    pub obj_prox: f64,
    /// `|obj_prox − obj_dyk| / max(1, obj_dyk)`.
    pub rel_gap: f64,
    /// Proximal max triangle violation.
    pub max_violation: f64,
    /// The band that was applied.
    pub band: Band,
    /// Whether both checks passed.
    pub pass: bool,
}

/// Apply a [`Band`] to a pair of converged objectives + the proximal
/// feasibility. Public (and solver-free) so negative tests can judge
/// deliberately wrong solutions without re-running anything.
pub fn judge(
    case: String,
    algorithm: Algorithm,
    obj_dykstra: f64,
    obj_prox: f64,
    max_violation: f64,
    band: Band,
) -> Verdict {
    let scale = obj_dykstra.abs().max(1.0);
    let rel_gap = (obj_prox - obj_dykstra).abs() / scale;
    let feasible = max_violation <= band.viol_tol;
    let close = rel_gap <= band.rel_obj_tol;
    Verdict {
        case,
        algorithm,
        obj_dykstra,
        obj_prox,
        rel_gap,
        max_violation,
        band,
        pass: feasible && close && obj_prox.is_finite(),
    }
}

/// The sweep's verdict table.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub verdicts: Vec<Verdict>,
}

impl Report {
    /// True iff every verdict passed.
    pub fn all_pass(&self) -> bool {
        self.verdicts.iter().all(|v| v.pass)
    }

    /// Failing verdicts (for error messages).
    pub fn failures(&self) -> Vec<&Verdict> {
        self.verdicts.iter().filter(|v| !v.pass).collect()
    }

    /// Machine-readable verdict table (the nightly CI artifact).
    pub fn to_json(&self) -> Json {
        let rows = self
            .verdicts
            .iter()
            .map(|v| {
                Json::Obj(vec![
                    ("case".to_string(), Json::Str(v.case.clone())),
                    ("algorithm".to_string(), Json::Str(v.algorithm.name().to_string())),
                    ("obj_dykstra".to_string(), json::num(v.obj_dykstra)),
                    ("obj_prox".to_string(), json::num(v.obj_prox)),
                    ("rel_gap".to_string(), json::num(v.rel_gap)),
                    ("max_violation".to_string(), json::num(v.max_violation)),
                    ("rel_obj_tol".to_string(), json::num(v.band.rel_obj_tol)),
                    ("viol_tol".to_string(), json::num(v.band.viol_tol)),
                    ("pass".to_string(), Json::Bool(v.pass)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("all_pass".to_string(), Json::Bool(self.all_pass())),
            ("cases".to_string(), json::unum(self.verdicts.len() as u64)),
            ("verdicts".to_string(), Json::Arr(rows)),
        ])
    }

    /// Fixed-width human table (one row per verdict).
    pub fn render_table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:<8} {:>12} {:>12} {:>10} {:>10}  verdict\n",
            "case", "member", "obj_dykstra", "obj_prox", "rel_gap", "max_viol"
        ));
        for v in &self.verdicts {
            out.push_str(&format!(
                "{:<28} {:<8} {:>12.6} {:>12.6} {:>10.2e} {:>10.2e}  {}\n",
                v.case,
                v.algorithm.name(),
                v.obj_dykstra,
                v.obj_prox,
                v.rel_gap,
                v.max_violation,
                if v.pass { "ok" } else { "MISMATCH" }
            ));
        }
        out
    }
}

/// Dykstra reference options: converge hard so the reference is the
/// exact projection for all practical purposes.
fn dykstra_opts(threads: usize) -> NearnessOpts {
    NearnessOpts {
        max_passes: 5000,
        check_every: 10,
        tol_violation: 1e-10,
        threads,
        ..Default::default()
    }
}

/// Run both proximal members and Dykstra on one case; returns the two
/// verdicts (MM and SD).
pub fn run_case(spec: &CaseSpec, threads: usize) -> Result<Vec<Verdict>, SolveError> {
    let inst = spec.build();
    let dyk = nearness::solve(&inst, &dykstra_opts(threads));
    let mut verdicts = Vec::with_capacity(2);
    for algorithm in [Algorithm::ProxMm, Algorithm::ProxSd] {
        let band = Band::for_algorithm(algorithm);
        let prox = nearness::solve_stored(
            &inst,
            &NearnessOpts {
                algorithm,
                threads,
                tol_violation: band.solve_tol,
                ..Default::default()
            },
            &crate::matrix::store::StoreCfg::mem(),
            None,
            &mut |_| {},
        )
        .map_err(SolveError::Other)?;
        verdicts.push(judge(
            spec.label(),
            algorithm,
            dyk.objective,
            prox.objective,
            prox.max_violation,
            band,
        ));
    }
    Ok(verdicts)
}

/// Run the whole sweep; solver errors become failing verdicts (the
/// oracle must go red, not crash, when a member diverges).
pub fn run_sweep(specs: &[CaseSpec], threads: usize) -> Report {
    let mut report = Report::default();
    for spec in specs {
        match run_case(spec, threads) {
            Ok(vs) => report.verdicts.extend(vs),
            Err(e) => {
                for algorithm in [Algorithm::ProxMm, Algorithm::ProxSd] {
                    report.verdicts.push(Verdict {
                        case: format!("{} [solver error: {e}]", spec.label()),
                        algorithm,
                        obj_dykstra: f64::NAN,
                        obj_prox: f64::NAN,
                        rel_gap: f64::INFINITY,
                        max_violation: f64::INFINITY,
                        band: Band::for_algorithm(algorithm),
                        pass: false,
                    });
                }
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_sweep_is_deterministic_and_distinct() {
        let a = default_sweep(7, &[8, 10]);
        let b = default_sweep(7, &[8, 10]);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.build().d, y.build().d);
            assert_eq!(x.build().w, y.build().w);
        }
        let mut seeds: Vec<u64> = a.iter().map(|s| s.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 6, "sweep seeds collide");
    }

    #[test]
    fn weight_kinds_shape_the_weights() {
        let unit = CaseSpec { n: 10, seed: 3, weights: WeightKind::Unit, hi: 2.0 }.build();
        assert!(unit.w.as_slice().iter().all(|&w| w == 1.0));
        let spiky = CaseSpec { n: 14, seed: 3, weights: WeightKind::Spiky, hi: 2.0 }.build();
        let boosted = spiky.w.as_slice().iter().filter(|&&w| w == 25.0).count();
        assert!(boosted > 0, "no boosted weights at n=14");
        assert!(spiky.w.as_slice().iter().all(|&w| w == 1.0 || w == 25.0));
        spiky.validate().unwrap();
        let uniform =
            CaseSpec { n: 10, seed: 3, weights: WeightKind::Uniform, hi: 2.0 }.build();
        assert!(uniform.w.as_slice().iter().all(|&w| (0.5..=2.0).contains(&w)));
    }

    #[test]
    fn judge_applies_both_checks() {
        let band = Band { rel_obj_tol: 1e-2, viol_tol: 1e-6, solve_tol: 1e-7 };
        let ok = judge("c".into(), Algorithm::ProxMm, 10.0, 10.05, 1e-8, band);
        assert!(ok.pass, "{ok:?}");
        let far = judge("c".into(), Algorithm::ProxMm, 10.0, 11.0, 1e-8, band);
        assert!(!far.pass);
        let infeasible = judge("c".into(), Algorithm::ProxMm, 10.0, 10.0, 1e-3, band);
        assert!(!infeasible.pass);
        let nan = judge("c".into(), Algorithm::ProxMm, 10.0, f64::NAN, 1e-8, band);
        assert!(!nan.pass);
    }

    #[test]
    fn report_json_and_table_render() {
        let band = Band::for_algorithm(Algorithm::ProxMm);
        let report = Report {
            verdicts: vec![
                judge("a".into(), Algorithm::ProxMm, 1.0, 1.001, 1e-8, band),
                judge("b".into(), Algorithm::ProxSd, 1.0, 2.0, 1e-2, band),
            ],
        };
        assert!(!report.all_pass());
        assert_eq!(report.failures().len(), 1);
        let j = report.to_json();
        assert_eq!(j.get("all_pass").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("cases").and_then(Json::as_u64), Some(2));
        let rows = j.get("verdicts").and_then(Json::as_arr).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("pass").and_then(Json::as_bool), Some(true));
        // roundtrips through the parser (the CI job reads it back)
        let reparsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(reparsed.get("cases").and_then(Json::as_u64), Some(2));
        let table = report.render_table();
        assert!(table.contains("MISMATCH"));
        assert!(table.contains("ok"));
    }

    #[test]
    fn oracle_passes_on_a_small_case() {
        let spec = CaseSpec { n: 10, seed: 11, weights: WeightKind::Uniform, hi: 2.0 };
        let verdicts = run_case(&spec, 2).unwrap();
        assert_eq!(verdicts.len(), 2);
        for v in &verdicts {
            assert!(v.pass, "{}", Report { verdicts: verdicts.clone() }.render_table());
        }
    }
}
