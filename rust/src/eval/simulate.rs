//! Simulated parallel timing for single-core environments.
//!
//! The paper's evaluation is wall-clock speedup on 64–192-core Xeons; this
//! container exposes **one** core, so real thread timing cannot show
//! speedup. Following the substitution rule (DESIGN.md §5), we *simulate
//! the machine, not the algorithm*: the full parallel schedule is executed
//! single-threaded with per-tile instrumentation, and the p-core pass time
//! is reconstructed as
//!
//! ```text
//! T_pass(p) = Σ_waves [ max_tid Σ_{tiles of tid} t(tile) + t_barrier ]
//!           + T_pair / p
//! ```
//!
//! which captures everything the schedule determines — load (im)balance
//! under the `r mod p` assignment, barrier counts (~2n/b per pass), and
//! the cache behaviour of tile size `b` (the per-tile times are *real
//! measured* times of the actual projection code on the actual data).
//!
//! Fidelity gap, documented in EXPERIMENTS.md: shared-resource contention
//! (memory bandwidth, last-level cache) between p real cores is not
//! modeled, so simulated speedups are upper bounds; the paper's 8-core
//! speedup of ~4.7 (vs an ideal 8) is largely that contention plus a
//! shared machine.

use crate::instance::CcLpInstance;
use crate::solver::duals::DualStore;
use crate::solver::dykstra_parallel::run_pair_phase;
use crate::solver::schedule::{Assignment, Schedule};
use crate::solver::CcState;
use crate::util::shared::SharedMut;

/// Default per-wave barrier cost (seconds): a pthread-style barrier
/// wake-up on a multi-socket Xeon. Tunable via `simulate_with_barrier`.
pub const DEFAULT_BARRIER_COST: f64 = 3e-6;

/// Per-tile measured times, accumulated over the instrumented passes.
pub struct Instrumented {
    /// `wave_tile_secs[w][r]` = total seconds spent in tile `r` of wave `w`.
    pub wave_tile_secs: Vec<Vec<f64>>,
    /// Total seconds of the (perfectly parallel) pair phase.
    pub pair_secs: f64,
    /// Passes instrumented.
    pub passes: usize,
}

/// Execute `passes` full passes of the parallel schedule single-threaded,
/// timing every tile. The constraint visit order equals the parallel
/// solver's per-wave order, so the measured work per tile is authentic
/// (including the dual-store sparsity evolving across passes).
pub fn instrument(inst: &CcLpInstance, schedule: &Schedule, passes: usize) -> Instrumented {
    let b = schedule.tile_size();
    let mut state = CcState::new(inst, 5.0, true);
    let mut store = DualStore::new();
    let mut wave_tile_secs: Vec<Vec<f64>> =
        schedule.waves().iter().map(|w| vec![0.0; w.len()]).collect();
    let mut pair_secs = 0.0;
    for _ in 0..passes {
        store.begin_pass();
        {
            let x = SharedMut::new(state.x.as_mut_slice());
            let winv = state.winv.as_slice();
            let col_starts = state.col_starts.as_slice();
            for (w, wave) in schedule.waves().iter().enumerate() {
                for (r, tile) in wave.iter().enumerate() {
                    let t0 = std::time::Instant::now();
                    // SAFETY: single thread; identical visit order to the
                    // parallel solver's per-tile processing.
                    unsafe {
                        crate::solver::hot_loop::process_tile(
                            &x, winv, col_starts, tile, b, &mut store,
                        )
                    };
                    wave_tile_secs[w][r] += t0.elapsed().as_secs_f64();
                }
            }
        }
        let t0 = std::time::Instant::now();
        run_pair_phase(&mut state, 1);
        pair_secs += t0.elapsed().as_secs_f64();
    }
    Instrumented { wave_tile_secs, pair_secs, passes }
}

impl Instrumented {
    /// Reconstruct the total time of the instrumented passes on `p` cores.
    pub fn simulate(&self, p: usize, assignment: Assignment) -> f64 {
        self.simulate_with_barrier(p, assignment, DEFAULT_BARRIER_COST)
    }

    /// As [`simulate`](Self::simulate) with an explicit barrier cost.
    pub fn simulate_with_barrier(
        &self,
        p: usize,
        assignment: Assignment,
        barrier_cost: f64,
    ) -> f64 {
        let p = p.max(1);
        let mut total = 0.0;
        let mut loads = vec![0.0f64; p];
        for (w, wave) in self.wave_tile_secs.iter().enumerate() {
            loads[..p].fill(0.0);
            for (r, &secs) in wave.iter().enumerate() {
                loads[assignment.worker_of(r, w, p)] += secs;
            }
            let critical = loads.iter().cloned().fold(0.0, f64::max);
            total += critical;
            if p > 1 {
                total += barrier_cost * self.passes as f64;
            }
        }
        total + self.pair_secs / p as f64
    }

    /// Total single-threaded metric-phase seconds (p = 1, no barriers).
    pub fn serial_equivalent(&self) -> f64 {
        self.wave_tile_secs.iter().flatten().sum::<f64>() + self.pair_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize, b: usize) -> (CcLpInstance, Schedule) {
        (CcLpInstance::random(n, 0.5, 0.8, 1.6, 3), Schedule::new(n, b))
    }

    #[test]
    fn simulated_time_decreases_with_cores() {
        let (inst, schedule) = setup(60, 5);
        let ins = instrument(&inst, &schedule, 2);
        let t1 = ins.simulate(1, Assignment::RoundRobin);
        let t4 = ins.simulate(4, Assignment::RoundRobin);
        let t16 = ins.simulate(16, Assignment::RoundRobin);
        assert!(t4 < t1, "t4={t4} !< t1={t1}");
        assert!(t16 < t4, "t16={t16} !< t4={t4}");
    }

    #[test]
    fn speedup_bounded_by_p_and_positive() {
        let (inst, schedule) = setup(50, 4);
        let ins = instrument(&inst, &schedule, 1);
        let t1 = ins.simulate_with_barrier(1, Assignment::RoundRobin, 0.0);
        for p in [2usize, 4, 8] {
            let tp = ins.simulate_with_barrier(p, Assignment::RoundRobin, 0.0);
            let speedup = t1 / tp;
            assert!(speedup > 1.0 && speedup <= p as f64 + 1e-9, "p={p} speedup={speedup}");
        }
    }

    #[test]
    fn p1_simulation_matches_serial_equivalent() {
        let (inst, schedule) = setup(40, 6);
        let ins = instrument(&inst, &schedule, 1);
        let t1 = ins.simulate_with_barrier(1, Assignment::RoundRobin, 0.0);
        assert!((t1 - ins.serial_equivalent()).abs() < 1e-12);
    }

    #[test]
    fn barrier_cost_penalizes_many_waves() {
        let (inst, schedule) = setup(40, 1); // many waves with b = 1
        let ins = instrument(&inst, &schedule, 1);
        let cheap = ins.simulate_with_barrier(4, Assignment::RoundRobin, 0.0);
        let costly = ins.simulate_with_barrier(4, Assignment::RoundRobin, 1e-3);
        // ~2n waves x 1ms barrier dominates this tiny problem
        assert!(costly > cheap + 0.05, "cheap={cheap} costly={costly}");
    }

    #[test]
    fn rotated_assignment_helps_or_ties_tiled() {
        let (inst, schedule) = setup(80, 10);
        let ins = instrument(&inst, &schedule, 1);
        let rr = ins.simulate_with_barrier(8, Assignment::RoundRobin, 0.0);
        let rot = ins.simulate_with_barrier(8, Assignment::Rotated, 0.0);
        assert!(rot <= rr * 1.05, "rotated much worse: rr={rr} rot={rot}");
    }

    #[test]
    fn instrumented_state_converges_like_solver() {
        // The instrumentation must not change the algorithm: after enough
        // instrumented passes the iterate is metric-feasible.
        let (inst, schedule) = setup(12, 3);
        let mut state = CcState::new(&inst, 5.0, true);
        let mut store = DualStore::new();
        // quick inline re-run (instrument() hides state): 200 passes
        let b = schedule.tile_size();
        for _ in 0..200 {
            store.begin_pass();
            {
                let x = SharedMut::new(state.x.as_mut_slice());
                let winv = state.winv.as_slice();
                let cs = state.col_starts.as_slice();
                for wave in schedule.waves() {
                    for tile in wave {
                        // SAFETY: single thread.
                        unsafe {
                            crate::solver::hot_loop::process_tile(
                                &x, winv, cs, tile, b, &mut store,
                            )
                        };
                    }
                }
            }
            run_pair_phase(&mut state, 1);
        }
        let r = crate::solver::termination::compute_residuals(&state, 1);
        assert!(r.max_violation < 1e-2, "violation {}", r.max_violation);
    }
}
