//! Evaluation harness: regenerates every table and figure of the paper's
//! §IV on this machine (DESIGN.md §4 maps each experiment to the modules
//! it exercises). Shared by the CLI (`metric-proj table1|fig6|fig7`) and
//! the cargo benches.

pub mod cross_check;
pub mod regression;
pub mod simulate;

use crate::graph::datasets::Dataset;
use crate::instance::construction::{build_cc_instance, ConstructionParams};
use crate::instance::CcLpInstance;
use crate::matrix::store::{StoreCfg, StoreKind};
use crate::solver::checkpoint::{self, SolverState, WarmStartOpts};
use crate::solver::schedule::{Assignment, Schedule};
use crate::solver::{dykstra_parallel, dykstra_serial, SolveOpts, Strategy};
use crate::util::parallel::available_cores;

/// How parallel pass times are obtained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimingMode {
    /// Real threads and wall clock (needs a multi-core machine).
    Real,
    /// Instrumented single-thread execution folded through the machine
    /// model of [`simulate`] — the only honest option on 1 core.
    Simulated,
}

impl TimingMode {
    pub fn parse(s: &str) -> Option<TimingMode> {
        match s.to_ascii_lowercase().as_str() {
            "real" => Some(TimingMode::Real),
            "sim" | "simulated" => Some(TimingMode::Simulated),
            _ => None,
        }
    }

    /// Real when the machine can actually run threads in parallel.
    pub fn auto() -> TimingMode {
        if available_cores() > 1 {
            TimingMode::Real
        } else {
            TimingMode::Simulated
        }
    }
}

/// Scaled problem sizes: Table I at paper scale takes days on one core in
/// Julia; we default to n that regenerate the table's *shape* in minutes
/// and keep the paper's size ordering across datasets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Tiny (CI): n ~ 100-530 (paper n / 34).
    Smoke,
    /// Default: n ~ 520-2240 (paper n / 8; minutes for the full table).
    Small,
    /// Paper-sized n (hours+; only sensible on a large machine).
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s.to_ascii_lowercase().as_str() {
            "smoke" => Some(Scale::Smoke),
            "small" => Some(Scale::Small),
            "paper" | "full" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// Target LCC size for a dataset at this scale. Scaling preserves the
    /// paper's ordering ca-GrQc < power < ca-HepTh < ca-HepPh < ca-AstroPh.
    pub fn n_for(self, d: Dataset) -> usize {
        match self {
            Scale::Paper => d.paper_n(),
            // paper_n / 14 and / 34 keep the relative sizes intact.
            Scale::Small => (d.paper_n() / 8).max(200),
            Scale::Smoke => (d.paper_n() / 34).max(100),
        }
    }
}

/// One row of Table I.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub dataset: &'static str,
    pub n: usize,
    pub constraints: u128,
    pub cores: usize,
    pub time_s: f64,
    pub speedup: f64,
}

/// Tile-size policy for scaled runs.
///
/// The paper uses `b = 40` at `n = 4158..17903`, i.e. `n/b ≈ 104..448`
/// tiles per grid dimension — plenty of tiles per wave for up to 64
/// workers. At scaled-down `n`, a *fixed* b = 40 leaves so few tiles per
/// wave that the wave critical path (the single biggest tile) caps the
/// speedup regardless of p; preserving the paper's **n/b ratio** preserves
/// its parallelism shape, which is what Table I measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TilePolicy {
    /// Use exactly this tile size (paper-faithful at paper scale).
    Fixed(usize),
    /// b = max(4, n / 104): the paper's ca-GrQc ratio (4158 / 40).
    PaperRatio,
}

impl TilePolicy {
    /// Resolve to a concrete tile size for problem size `n`.
    pub fn tile_for(self, n: usize) -> usize {
        match self {
            TilePolicy::Fixed(b) => b,
            TilePolicy::PaperRatio => (n / 104).max(4),
        }
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub scale: Scale,
    pub passes: usize,
    pub tile: TilePolicy,
    pub cores: Vec<usize>,
    pub seed: u64,
    pub assignment: Assignment,
    pub timing: TimingMode,
}

impl Default for EvalConfig {
    fn default() -> Self {
        let timing = TimingMode::auto();
        // Paper's core counts; in simulated mode they need no clamping.
        let cores: Vec<usize> = match timing {
            TimingMode::Simulated => vec![8, 16, 32, 64],
            TimingMode::Real => {
                let avail = available_cores();
                [8usize, 16, 32, 64].iter().copied().filter(|&c| c <= avail).collect()
            }
        };
        EvalConfig {
            scale: Scale::Small,
            passes: 20, // the paper times 20 iterations
            // Paper's Table I is b = 40; at scaled n the harness keeps
            // the paper's n/b ratio instead (see TilePolicy).
            tile: TilePolicy::PaperRatio,
            cores,
            seed: 42,
            assignment: Assignment::RoundRobin,
            timing,
        }
    }
}

/// Build the CC-LP instance for a dataset at the configured scale,
/// exactly as §IV-B: generate/load graph -> LCC -> Jaccard construction.
pub fn build_instance(d: Dataset, cfg: &EvalConfig) -> CcLpInstance {
    let n_target = cfg.scale.n_for(d);
    let g = d.load_or_generate(std::path::Path::new("data"), n_target, cfg.seed);
    build_cc_instance(&g, ConstructionParams::default(), available_cores())
}

/// Seconds to run `passes` full Dykstra passes (pass time only: instance
/// setup and the final residual computation are excluded, matching §IV-D's
/// "time it takes to complete a fixed number of iterations").
pub fn time_parallel(inst: &CcLpInstance, cores: usize, tile: usize, passes: usize,
                     assignment: Assignment) -> f64 {
    let opts = SolveOpts {
        max_passes: passes,
        threads: cores,
        tile,
        check_every: 0,
        track_pass_times: true,
        assignment,
        ..Default::default()
    };
    let sol = dykstra_parallel::solve(inst, &opts);
    sol.pass_times.iter().sum()
}

/// Serial baseline ([37]'s ordering) timing.
pub fn time_serial(inst: &CcLpInstance, passes: usize) -> f64 {
    let opts = SolveOpts {
        max_passes: passes,
        check_every: 0,
        track_pass_times: true,
        ..Default::default()
    };
    let sol = dykstra_serial::solve(inst, &opts);
    sol.pass_times.iter().sum()
}

/// Regenerate Table I. `emit` receives each row as it completes so long
/// runs stream progress.
pub fn table1(cfg: &EvalConfig, datasets: &[Dataset], mut emit: impl FnMut(&Table1Row)) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for &d in datasets {
        let inst = build_instance(d, cfg);
        let constraints = inst.n_constraints();
        let serial = time_serial(&inst, cfg.passes);
        let row = Table1Row {
            dataset: d.name(),
            n: inst.n,
            constraints,
            cores: 1,
            time_s: serial,
            speedup: 1.0,
        };
        emit(&row);
        rows.push(row);
        for (c, t) in times_for_cores(&inst, cfg, cfg.tile.tile_for(inst.n), &cfg.cores) {
            let row = Table1Row {
                dataset: d.name(),
                n: inst.n,
                constraints,
                cores: c,
                time_s: t,
                speedup: serial / t,
            };
            emit(&row);
            rows.push(row);
        }
    }
    rows
}

/// Fig 6: speedup vs core count on one dataset (paper: ca-HepPh, b=40,
/// cores 1 then 8..40 step 4).
pub fn fig6(cfg: &EvalConfig, dataset: Dataset, core_counts: &[usize],
            mut emit: impl FnMut(usize, f64, f64)) -> Vec<(usize, f64, f64)> {
    let inst = build_instance(dataset, cfg);
    let serial = time_serial(&inst, cfg.passes);
    emit(1, serial, 1.0);
    let mut out = vec![(1, serial, 1.0)];
    let cores: Vec<usize> = core_counts.iter().copied().filter(|&c| c > 1).collect();
    for (c, t) in times_for_cores(&inst, cfg, cfg.tile.tile_for(inst.n), &cores) {
        emit(c, t, serial / t);
        out.push((c, t, serial / t));
    }
    out
}

/// Fig 7: speedup vs tile size at fixed cores (paper: ca-GrQc, 16 cores,
/// b in 5..=50 step 5).
pub fn fig7(cfg: &EvalConfig, dataset: Dataset, cores: usize, tiles: &[usize],
            mut emit: impl FnMut(usize, f64, f64)) -> Vec<(usize, f64, f64)> {
    let inst = build_instance(dataset, cfg);
    let serial = time_serial(&inst, cfg.passes);
    let mut out = Vec::new();
    for &b in tiles {
        let t = times_for_cores(&inst, cfg, b, &[cores])[0].1;
        emit(b, t, serial / t);
        out.push((b, t, serial / t));
    }
    out
}

/// Parallel pass times for a list of core counts, honoring the timing
/// mode. Simulated mode instruments ONCE per (instance, tile) and
/// evaluates every core count from the same per-tile measurements.
pub fn times_for_cores(
    inst: &CcLpInstance,
    cfg: &EvalConfig,
    tile: usize,
    cores: &[usize],
) -> Vec<(usize, f64)> {
    match cfg.timing {
        TimingMode::Real => cores
            .iter()
            .map(|&c| (c, time_parallel(inst, c, tile, cfg.passes, cfg.assignment)))
            .collect(),
        TimingMode::Simulated => {
            let schedule = Schedule::new(inst.n, tile);
            let ins = simulate::instrument(inst, &schedule, cfg.passes);
            cores.iter().map(|&c| (c, ins.simulate(c, cfg.assignment))).collect()
        }
    }
}

/// One row of the constraint-visit ablation: how much metric work a
/// strategy spent and where it landed.
#[derive(Clone, Debug)]
pub struct StrategyRow {
    /// Human-readable strategy label for table output.
    pub label: &'static str,
    /// The strategy this row measured.
    pub strategy: Strategy,
    /// Passes executed.
    pub passes: usize,
    /// Total metric-constraint visits over the solve.
    pub metric_visits: u64,
    /// Average metric-constraint visits per pass.
    pub visits_per_pass: f64,
    /// Active triplets at the end (= C(n,3) for the full strategy).
    pub active_triplets: usize,
    /// Final exact max constraint violation.
    pub max_violation: f64,
    /// Final LP objective (the CC lower bound).
    pub lp_objective: f64,
    /// Triplets examined by discovery sweeps (0 for the full strategy).
    pub sweep_screened: u64,
    /// Of those, triplets that actually needed a projection.
    pub sweep_projected: u64,
    /// Peak resident-set estimate for the solve's packed state in MiB —
    /// the memory column next to the visits/sec work column. The
    /// resident backend keeps eight packed `O(n²)` arrays (`x`, `f`,
    /// `winv`, `d`, `w`, and the three pair/box dual lanes); the disk
    /// backend streams `x` **and** `winv` through bounded block caches,
    /// leaving six packed arrays plus the configured cache budget — see
    /// [`cc_resident_mb_est_stored`]. Metric duals and the active set
    /// are sparse and excluded.
    pub resident_mb_est: f64,
}

impl StrategyRow {
    /// Fraction of screened sweep triplets that needed a projection —
    /// the number that explains *why* screening wins (None when the
    /// strategy ran no sweeps).
    pub fn screen_hit_rate(&self) -> Option<f64> {
        if self.sweep_screened > 0 {
            Some(self.sweep_projected as f64 / self.sweep_screened as f64)
        } else {
            None
        }
    }
}

/// Peak resident-set estimate of a resident CC-LP solve in MiB (the
/// eight packed `f64` arrays of [`crate::solver::CcState`]).
pub fn cc_resident_mb_est(n: usize) -> f64 {
    let m = n * n.saturating_sub(1) / 2;
    (8 * m * 8) as f64 / (1u64 << 20) as f64
}

/// Peak resident-set estimate of a CC-LP solve in MiB under a given `X`
/// storage backend. The resident backend keeps eight packed `O(n²)`
/// arrays; the disk backend streams `x` **and** the inverse weights
/// through two bounded block caches, leaving six packed arrays resident
/// plus the configured budget (capped at the two planes' total) — this
/// is what keeps the memory column honest for weighted instances, whose
/// `W` used to be counted as free.
pub fn cc_resident_mb_est_stored(n: usize, cfg: &StoreCfg) -> f64 {
    let m = n * n.saturating_sub(1) / 2;
    let bytes = match cfg.kind {
        StoreKind::Mem => 8 * m * 8,
        StoreKind::Disk => 6 * m * 8 + cfg.budget_bytes.min(2 * m * 8),
    };
    bytes as f64 / (1u64 << 20) as f64
}

/// Solve `inst` once per strategy with otherwise-identical options —
/// convergence-vs-work data for the [A4] ablation bench and for plotting
/// (each [`crate::solver::Solution`] carries the same counters). Runs on
/// the in-memory store; use [`strategy_ablation_stored`] to pick the
/// backend.
pub fn strategy_ablation(
    inst: &CcLpInstance,
    base: &SolveOpts,
    strategies: &[(&'static str, Strategy)],
) -> Vec<StrategyRow> {
    strategy_ablation_stored(inst, base, &StoreCfg::mem(), strategies)
        .expect("in-memory ablation cannot fail")
}

/// [`strategy_ablation`] with an explicit `X` storage backend. Disk
/// rows get a per-row subdirectory under the configured store dir
/// (removed afterwards), so several strategies can stream from disk in
/// one ablation without tripping the store-overwrite guard; their
/// `resident_mb_est` reflects the streamed `x`/`winv` planes.
pub fn strategy_ablation_stored(
    inst: &CcLpInstance,
    base: &SolveOpts,
    store: &StoreCfg,
    strategies: &[(&'static str, Strategy)],
) -> anyhow::Result<Vec<StrategyRow>> {
    let mut rows = Vec::with_capacity(strategies.len());
    for (idx, &(label, strategy)) in strategies.iter().enumerate() {
        let cfg = match store.kind {
            StoreKind::Mem => store.clone(),
            StoreKind::Disk => {
                StoreCfg { dir: store.dir.join(format!("ablation_{idx}")), ..store.clone() }
            }
        };
        let sol = dykstra_parallel::solve_stored(
            inst,
            &SolveOpts { strategy, ..*base },
            &cfg,
            None,
            &mut |_| {},
        )?;
        if store.kind == StoreKind::Disk {
            let _ = std::fs::remove_dir_all(&cfg.dir);
        }
        rows.push(StrategyRow {
            label,
            strategy,
            passes: sol.passes,
            metric_visits: sol.metric_visits,
            visits_per_pass: sol.metric_visits as f64 / sol.passes.max(1) as f64,
            active_triplets: sol.active_triplets,
            max_violation: sol.residuals.max_violation,
            lp_objective: sol.residuals.lp_objective,
            sweep_screened: sol.sweep_screened,
            sweep_projected: sol.sweep_projected,
            resident_mb_est: cc_resident_mb_est_stored(inst.n, &cfg),
        });
    }
    Ok(rows)
}

/// One run of the warm-start ablation.
#[derive(Clone, Debug)]
pub struct WarmStartRow {
    pub label: &'static str,
    /// Passes to reach the configured tolerance.
    pub passes: usize,
    /// Total metric-constraint visits spent.
    pub metric_visits: u64,
    pub max_violation: f64,
    pub lp_objective: f64,
}

/// Cold vs. warm passes-to-tolerance on a perturbed instance.
#[derive(Clone, Debug)]
pub struct WarmStartAblation {
    /// The solve of the base instance that produced the checkpoint.
    pub base: WarmStartRow,
    /// Cold solve of the perturbed instance.
    pub cold: WarmStartRow,
    /// Warm-started solve of the perturbed instance.
    pub warm: WarmStartRow,
}

impl WarmStartAblation {
    /// Passes saved by warm starting (negative if it lost).
    pub fn passes_saved(&self) -> i64 {
        self.cold.passes as i64 - self.warm.passes as i64
    }
}

fn warm_row(label: &'static str, sol: &crate::solver::Solution) -> WarmStartRow {
    WarmStartRow {
        label,
        passes: sol.passes,
        metric_visits: sol.metric_visits,
        max_violation: sol.residuals.max_violation,
        lp_objective: sol.residuals.lp_objective,
    }
}

/// The ROADMAP warm-start scenario, measured end to end: solve `base` to
/// the configured tolerance (checkpointing the final state), then solve
/// `perturbed` (same `n` and targets, updated weights) twice — cold, and
/// warm-started via [`checkpoint::warm_start_cc`] — all with identical
/// options. `opts` must have `check_every > 0` so passes-to-tolerance is
/// observable; the strategy is honored, so an active-set `opts` also
/// exercises the seeded-set / deferred-sweep path.
pub fn warm_start_ablation(
    base: &CcLpInstance,
    perturbed: &CcLpInstance,
    opts: &SolveOpts,
    wopts: &WarmStartOpts,
) -> anyhow::Result<WarmStartAblation> {
    anyhow::ensure!(
        opts.check_every > 0,
        "warm_start_ablation needs convergence checks on (set check_every > 0)"
    );
    // usize::MAX emits no periodic snapshots — only the final state.
    let save_final = SolveOpts { checkpoint_every: usize::MAX, ..*opts };
    let mut last: Option<SolverState> = None;
    let base_sol =
        dykstra_parallel::solve_checkpointed(base, &save_final, None, &mut |s| {
            last = Some(s.clone())
        })?;
    let ckpt = last.expect("final checkpoint emitted");
    let cold_sol = dykstra_parallel::solve(perturbed, opts);
    let seed = checkpoint::warm_start_cc(&ckpt, perturbed, opts, wopts)?;
    let warm_sol = dykstra_parallel::resume(perturbed, opts, &seed)?;
    Ok(WarmStartAblation {
        base: warm_row("base", &base_sol),
        cold: warm_row("cold", &cold_sol),
        warm: warm_row("warm", &warm_sol),
    })
}

/// Render rows in the paper's Table I layout (markdown).
pub fn render_table1(rows: &[Table1Row]) -> String {
    let mut s = String::from(
        "| Graph | # constraints | # Cores | Time (s) | Speedup |\n|---|---|---|---|---|\n",
    );
    let mut last = "";
    for r in rows {
        let (name, cons) = if r.dataset == last {
            (String::new(), String::new())
        } else {
            last = r.dataset;
            (format!("{} (n={})", r.dataset, r.n), format!("{:.1e}", r.constraints as f64))
        };
        s.push_str(&format!(
            "| {name} | {cons} | {} | {:.2} | {:.2} |\n",
            r.cores, r.time_s, r.speedup
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_preserves_order() {
        for scale in [Scale::Smoke, Scale::Small, Scale::Paper] {
            let ns: Vec<usize> = Dataset::ALL.iter().map(|&d| scale.n_for(d)).collect();
            let mut sorted = ns.clone();
            sorted.sort_unstable();
            assert_eq!(ns, sorted, "{scale:?} broke Table I ordering");
        }
        assert_eq!(Scale::Paper.n_for(Dataset::CaAstroPh), 17903);
    }

    #[test]
    fn scale_parse() {
        assert_eq!(Scale::parse("small"), Some(Scale::Small));
        assert_eq!(Scale::parse("PAPER"), Some(Scale::Paper));
        assert_eq!(Scale::parse("full"), Some(Scale::Paper));
        assert_eq!(Scale::parse("nope"), None);
    }

    #[test]
    fn timing_runs_and_speedup_sane() {
        // micro smoke: n ~ 100, 1 pass, 2 cores
        let cfg = EvalConfig {
            scale: Scale::Smoke,
            passes: 1,
            tile: TilePolicy::Fixed(10),
            cores: vec![2],
            seed: 1,
            assignment: Assignment::RoundRobin,
            timing: TimingMode::Simulated,
        };
        let inst = build_instance(Dataset::CaGrQc, &cfg);
        assert!(inst.n >= 100);
        let ts = time_serial(&inst, 1);
        let tp = time_parallel(&inst, 2, 10, 1, Assignment::RoundRobin);
        assert!(ts > 0.0 && tp > 0.0);
        // don't assert speedup in CI-sized runs; just that both complete
    }

    #[test]
    fn strategy_ablation_reports_less_work_for_active() {
        let inst = CcLpInstance::random(24, 0.5, 0.8, 1.6, 3);
        let base = SolveOpts { max_passes: 30, threads: 2, tile: 4, ..Default::default() };
        let rows = strategy_ablation(
            &inst,
            &base,
            &[
                ("full", Strategy::Full),
                ("active", Strategy::Active { sweep_every: 5, forget_after: 2 }),
            ],
        );
        assert_eq!(rows.len(), 2);
        assert!(
            rows[1].metric_visits < rows[0].metric_visits,
            "active {} !< full {}",
            rows[1].metric_visits,
            rows[0].metric_visits
        );
        assert!(rows[1].visits_per_pass < rows[0].visits_per_pass);
        // same pass budget, so the full row visits exactly 3·C(n,3)/pass
        let per_pass = crate::solver::schedule::n_triplets(24) * 3;
        assert_eq!(rows[0].metric_visits, 30 * per_pass);
        // hit-rate instrumentation: the full strategy has no sweeps, the
        // active one screens C(n,3) per sweep and projects a subset.
        assert_eq!(rows[0].screen_hit_rate(), None);
        let hit = rows[1].screen_hit_rate().expect("active rows ran sweeps");
        assert!(rows[1].sweep_screened % crate::solver::schedule::n_triplets(24) == 0);
        assert!(rows[1].sweep_projected <= rows[1].sweep_screened);
        assert!((0.0..=1.0).contains(&hit));
    }

    #[test]
    fn stored_ablation_matches_mem_and_reports_honest_memory() {
        let inst = CcLpInstance::random(22, 0.5, 0.8, 1.6, 5);
        let base = SolveOpts { max_passes: 8, threads: 2, tile: 4, ..Default::default() };
        let strategies: &[(&'static str, Strategy)] = &[
            ("full", Strategy::Full),
            ("active", Strategy::Active { sweep_every: 3, forget_after: 1 }),
        ];
        let mem_rows = strategy_ablation(&inst, &base, strategies);
        let dir = std::env::temp_dir()
            .join(format!("metric_proj_ablation_{}", std::process::id()));
        let disk_rows =
            strategy_ablation_stored(&inst, &base, &StoreCfg::disk(&dir, 1 << 11), strategies)
                .expect("disk ablation");
        let _ = std::fs::remove_dir_all(&dir);
        for (m, d) in mem_rows.iter().zip(&disk_rows) {
            assert_eq!(m.metric_visits, d.metric_visits, "{}", m.label);
            assert_eq!(m.max_violation, d.max_violation, "{}", m.label);
            assert_eq!(m.lp_objective, d.lp_objective, "{}", m.label);
            assert!(
                d.resident_mb_est < m.resident_mb_est,
                "{}: a streamed-x/W row must report a smaller resident set",
                m.label
            );
        }
    }

    #[test]
    fn warm_start_ablation_saves_passes_on_a_perturbed_instance() {
        let base = CcLpInstance::random(40, 0.5, 0.8, 1.6, 21);
        let perturbed = base.perturb_weights(0.1, 0.2, 22);
        let opts = SolveOpts {
            max_passes: 4000,
            check_every: 2,
            tol_violation: 1e-7,
            tol_gap: 1e30, // violation-driven stop
            threads: 2,
            tile: 8,
            strategy: Strategy::Active { sweep_every: 4, forget_after: 2 },
            ..Default::default()
        };
        let ab = warm_start_ablation(&base, &perturbed, &opts, &WarmStartOpts::default())
            .unwrap();
        assert!(ab.base.passes < 4000, "base failed to converge");
        assert!(ab.cold.passes < 4000, "cold failed to converge");
        assert!(ab.warm.passes < 4000, "warm failed to converge");
        assert!(
            ab.warm.passes < ab.cold.passes,
            "warm start must save passes: warm {} vs cold {}",
            ab.warm.passes,
            ab.cold.passes
        );
        assert!(ab.passes_saved() > 0);
        assert!(ab.warm.metric_visits < ab.cold.metric_visits);
        assert!(ab.warm.max_violation <= 1e-7);
    }

    #[test]
    fn render_table_has_all_rows() {
        let rows = vec![
            Table1Row { dataset: "x", n: 10, constraints: 360, cores: 1, time_s: 1.0, speedup: 1.0 },
            Table1Row { dataset: "x", n: 10, constraints: 360, cores: 8, time_s: 0.25, speedup: 4.0 },
        ];
        let s = render_table1(&rows);
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains("4.00"));
    }
}
