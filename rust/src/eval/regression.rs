//! Machine-normalized perf baselines and the bench regression gate.
//!
//! The benches (`cargo bench --bench sweep` / `--bench ablations`) emit
//! one [`BaselineRow`] per measured cell — a `(bench, n, cell, store)`
//! key carrying throughput, screen hit rate, store I/O, and the peak
//! resident-set figure. Raw wall-clock throughput is useless across
//! machines, so every emitting process first runs [`calibrate`]: a fixed
//! arithmetic workload shaped like the projection hot loop, measured in
//! ns/op. Throughput is then stored as *triplet-visits per calibration
//! unit* ([`normalize`]) — a machine that runs the calibration loop 2×
//! faster is expected to sweep ~2× faster too, and the normalized number
//! cancels that out to first order.
//!
//! `bench/baseline.json` at the repo root is the committed history: the
//! benches merge into it under `--commit-baseline`, and the CI gate
//! (`metric-proj bench-gate`) compares a fresh nightly run against it
//! with a relative tolerance band, failing the job when any committed
//! cell degrades beyond the band (or vanishes from the fresh run). See
//! `bench/README.md` and `docs/OBSERVABILITY.md`.

use crate::util::json::{self, Json};
use anyhow::{bail, Context, Result};
use std::fmt::Write as _;
use std::time::Instant;

/// Schema version of `bench/baseline.json`.
pub const BASELINE_VERSION: u64 = 1;

/// Default relative tolerance band of the gate (25% — wide enough for
/// shared CI runners, tight enough to catch a real 2× regression).
pub const DEFAULT_TOLERANCE: f64 = 0.25;

/// One measured perf cell, keyed by `(bench, n, cell, store)`.
#[derive(Clone, Debug, PartialEq)]
pub struct BaselineRow {
    /// Emitting bench (`sweep`, `ablations`).
    pub bench: String,
    /// Problem size.
    pub n: u64,
    /// Strategy/backend label within the bench (e.g. `screened`,
    /// `active s=8 k=3`).
    pub cell: String,
    /// `X` storage backend (`mem` / `disk` / `shard`).
    pub store: String,
    /// Triplet-visits per calibration unit ([`normalize`]d throughput;
    /// higher is better).
    pub visits_per_unit: f64,
    /// Screen hit rate in `[0, 1]` (0 when the cell runs no sweeps).
    pub hit_rate: f64,
    /// Tile-store block loads (0 for in-memory cells).
    pub store_loads: u64,
    /// Peak resident bytes for the cell's `X` path.
    pub peak_resident_bytes: u64,
    /// Entries gathered through entry-granular leases (0 for in-memory
    /// cells and whole-tile paths).
    pub entry_loads: u64,
    /// Footprint blocks entry leases skipped — the gate fails when this
    /// *shrinks* past tolerance (the lease stopped saving I/O).
    pub blocks_skipped: u64,
    /// Bytes moved over the coordinator↔worker sockets (0 for
    /// non-sharded cells). Deterministic for a fixed schedule, so it is
    /// gated like store loads: growth past tolerance means the lease
    /// pattern got chattier.
    pub shard_bytes: u64,
    /// Microseconds the coordinator spent waiting at shard barriers.
    /// Wall-clock — noisy on shared runners — so it is recorded for the
    /// report but never gated.
    pub barrier_wait_us: u64,
}

impl BaselineRow {
    /// The unique key a fresh row is matched on.
    pub fn key(&self) -> String {
        format!("{}/n={}/{}/{}", self.bench, self.n, self.cell, self.store)
    }

    fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("bench".into(), Json::Str(self.bench.clone())),
            ("n".into(), json::unum(self.n)),
            ("cell".into(), Json::Str(self.cell.clone())),
            ("store".into(), Json::Str(self.store.clone())),
            ("visits_per_unit".into(), json::num(self.visits_per_unit)),
            ("hit_rate".into(), json::num(self.hit_rate)),
            ("store_loads".into(), json::unum(self.store_loads)),
            ("peak_resident_bytes".into(), json::unum(self.peak_resident_bytes)),
            ("entry_loads".into(), json::unum(self.entry_loads)),
            ("blocks_skipped".into(), json::unum(self.blocks_skipped)),
            ("shard_bytes".into(), json::unum(self.shard_bytes)),
            ("barrier_wait_us".into(), json::unum(self.barrier_wait_us)),
        ])
    }

    fn from_json(j: &Json) -> Result<BaselineRow> {
        let str_field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .with_context(|| format!("baseline row: missing string field `{k}`"))
        };
        let u64_field = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(Json::as_u64)
                .with_context(|| format!("baseline row: missing counter field `{k}`"))
        };
        let f64_field = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .with_context(|| format!("baseline row: missing number field `{k}`"))
        };
        Ok(BaselineRow {
            bench: str_field("bench")?,
            n: u64_field("n")?,
            cell: str_field("cell")?,
            store: str_field("store")?,
            visits_per_unit: f64_field("visits_per_unit")?,
            hit_rate: f64_field("hit_rate")?,
            store_loads: u64_field("store_loads")?,
            peak_resident_bytes: u64_field("peak_resident_bytes")?,
            // Entry-lease counters postdate the schema's first rows:
            // absent means "measured before entry leases existed" = 0.
            entry_loads: j.get("entry_loads").and_then(Json::as_u64).unwrap_or(0),
            blocks_skipped: j.get("blocks_skipped").and_then(Json::as_u64).unwrap_or(0),
            // Shard columns postdate the schema's first rows too.
            shard_bytes: j.get("shard_bytes").and_then(Json::as_u64).unwrap_or(0),
            barrier_wait_us: j.get("barrier_wait_us").and_then(Json::as_u64).unwrap_or(0),
        })
    }
}

/// A baseline (or fresh-run) row set plus its schema version.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BaselineFile {
    /// Rows keyed by [`BaselineRow::key`]; order preserved.
    pub rows: Vec<BaselineRow>,
}

impl BaselineFile {
    /// Serialize (pretty enough to diff in review: one row per line).
    pub fn to_json_string(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"version\": {BASELINE_VERSION},");
        out.push_str("  \"rows\": [");
        for (i, row) in self.rows.iter().enumerate() {
            out.push_str(if i == 0 { "\n    " } else { ",\n    " });
            row.to_json().write(&mut out);
        }
        out.push_str(if self.rows.is_empty() { "]\n}\n" } else { "\n  ]\n}\n" });
        out
    }

    /// Parse a serialized baseline, rejecting unknown schema versions.
    pub fn parse(text: &str) -> Result<BaselineFile> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("baseline JSON: {e}"))?;
        let version = j
            .get("version")
            .and_then(Json::as_u64)
            .context("baseline JSON: missing `version`")?;
        if version != BASELINE_VERSION {
            bail!("baseline schema version {version} (this build reads {BASELINE_VERSION})");
        }
        let rows = j
            .get("rows")
            .and_then(Json::as_arr)
            .context("baseline JSON: missing `rows` array")?
            .iter()
            .map(BaselineRow::from_json)
            .collect::<Result<Vec<_>>>()?;
        Ok(BaselineFile { rows })
    }

    /// Load from disk.
    pub fn load(path: &std::path::Path) -> Result<BaselineFile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing baseline {}", path.display()))
    }

    /// Write to disk.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing baseline {}", path.display()))
    }

    /// Merge `fresh` in: rows with a known key replace the old
    /// measurement, new keys append (the `--commit-baseline` operation).
    pub fn merge(&mut self, fresh: &BaselineFile) {
        for row in &fresh.rows {
            match self.rows.iter_mut().find(|r| r.key() == row.key()) {
                Some(slot) => *slot = row.clone(),
                None => self.rows.push(row.clone()),
            }
        }
    }

    /// Look a row up by key.
    pub fn find(&self, key: &str) -> Option<&BaselineRow> {
        self.rows.iter().find(|r| r.key() == key)
    }
}

/// ns/op of the fixed calibration workload on this machine.
///
/// The loop is shaped like the solver's triple-projection hot path —
/// fused multiply-adds, a compare, and a data-dependent accumulate over
/// values kept live through [`std::hint::black_box`] — so its speed
/// tracks the speed the sweeps actually run at. Best of three trials,
/// ~10⁷ ops each (a few ms total).
pub fn calibrate() -> f64 {
    const OPS: u64 = 8_000_000;
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        let mut x = std::hint::black_box(1.000_000_1f64);
        let mut acc = 0.0f64;
        for i in 0..OPS {
            // fma-shaped update + branchy clamp, like visit_triplet
            x = x * 1.000_000_01 + 1.0e-9;
            if x > 2.0 {
                x -= 1.0;
            }
            acc += x * ((i & 7) as f64 + 1.0);
        }
        std::hint::black_box(acc);
        let ns = t0.elapsed().as_nanos() as f64 / OPS as f64;
        best = best.min(ns);
    }
    best
}

/// Convert a raw visits/second into visits per calibration unit, given
/// this machine's [`calibrate`] figure. One "unit" is the time the
/// calibration loop takes for 10⁹ ops (≈1 s on a ~1 ns/op machine), so
/// the numbers stay in a human scale.
pub fn normalize(raw_per_sec: f64, calib_ns_per_op: f64) -> f64 {
    raw_per_sec * calib_ns_per_op
}

/// Bench-side row emission: write `rows` as a gate-comparable rows file
/// at `rows_path` and, when `commit` is set (the bench saw
/// `--commit-baseline`), merge them into the committed baseline at
/// `baseline_path` — creating it when absent, replacing matching cells
/// otherwise.
pub fn emit_rows(
    rows: Vec<BaselineRow>,
    rows_path: &std::path::Path,
    commit: bool,
    baseline_path: &std::path::Path,
) -> Result<()> {
    let fresh = BaselineFile { rows };
    fresh.save(rows_path)?;
    println!("wrote {} bench row(s) to {}", fresh.rows.len(), rows_path.display());
    if commit {
        let mut baseline = if baseline_path.exists() {
            BaselineFile::load(baseline_path)?
        } else {
            BaselineFile::default()
        };
        baseline.merge(&fresh);
        baseline.save(baseline_path)?;
        println!(
            "committed {} cell(s) into baseline {} ({} total)",
            fresh.rows.len(),
            baseline_path.display(),
            baseline.rows.len()
        );
    }
    Ok(())
}

/// The gate's verdict on one fresh run vs the committed baseline.
#[derive(Clone, Debug, Default)]
pub struct GateReport {
    /// Baseline rows with a matching fresh measurement.
    pub checked: usize,
    /// Human-readable failure lines (regression beyond tolerance).
    pub failures: Vec<String>,
    /// Baseline keys the fresh run did not measure (coverage loss —
    /// also a failure).
    pub missing: Vec<String>,
    /// Fresh keys not yet in the baseline (informational; commit them
    /// with `--commit-baseline`).
    pub added: Vec<String>,
}

impl GateReport {
    /// True when no committed cell regressed or vanished.
    pub fn passed(&self) -> bool {
        self.failures.is_empty() && self.missing.is_empty()
    }

    /// The gate's stdout block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "bench gate: {} baseline cell{} checked",
            self.checked,
            if self.checked == 1 { "" } else { "s" }
        );
        for f in &self.failures {
            let _ = writeln!(out, "  FAIL {f}");
        }
        for m in &self.missing {
            let _ = writeln!(out, "  MISSING {m} (baseline cell not measured by the fresh run)");
        }
        for a in &self.added {
            let _ = writeln!(out, "  new {a} (not in baseline; commit with --commit-baseline)");
        }
        let _ = writeln!(out, "bench gate: {}", if self.passed() { "PASS" } else { "FAIL" });
        out
    }
}

/// Compare a fresh run against the committed baseline with a relative
/// tolerance band `tol` (e.g. 0.25 = 25%).
///
/// Per matched cell: normalized throughput may not drop below
/// `(1 - tol)×` baseline; the screen hit rate may not drift more than
/// `tol` absolutely (it is a deterministic algorithm property — drift
/// means behavior changed); store loads and peak resident bytes may not
/// grow beyond `(1 + tol)×` baseline. Improvements always pass — refresh
/// the baseline to ratchet them in. An empty baseline passes trivially
/// (the bootstrap state before the first `--commit-baseline`).
pub fn gate(baseline: &BaselineFile, fresh: &BaselineFile, tol: f64) -> GateReport {
    let mut report = GateReport::default();
    for base in &baseline.rows {
        let key = base.key();
        let Some(new) = fresh.find(&key) else {
            report.missing.push(key);
            continue;
        };
        report.checked += 1;
        if new.visits_per_unit < base.visits_per_unit * (1.0 - tol) {
            report.failures.push(format!(
                "{key}: throughput {:.3e} < {:.3e} visits/unit (-{:.1}%, tolerance {:.0}%)",
                new.visits_per_unit,
                base.visits_per_unit,
                100.0 * (1.0 - new.visits_per_unit / base.visits_per_unit),
                100.0 * tol
            ));
        }
        if (new.hit_rate - base.hit_rate).abs() > tol {
            report.failures.push(format!(
                "{key}: screen hit rate {:.4} drifted from {:.4} (> {:.2} absolute)",
                new.hit_rate, base.hit_rate, tol
            ));
        }
        if base.store_loads > 0 && new.store_loads as f64 > base.store_loads as f64 * (1.0 + tol)
        {
            report.failures.push(format!(
                "{key}: store loads {} > {} (+{:.1}%, tolerance {:.0}%)",
                new.store_loads,
                base.store_loads,
                100.0 * (new.store_loads as f64 / base.store_loads as f64 - 1.0),
                100.0 * tol
            ));
        }
        if base.peak_resident_bytes > 0
            && new.peak_resident_bytes as f64 > base.peak_resident_bytes as f64 * (1.0 + tol)
        {
            report.failures.push(format!(
                "{key}: peak resident {} B > {} B (+{:.1}%, tolerance {:.0}%)",
                new.peak_resident_bytes,
                base.peak_resident_bytes,
                100.0 * (new.peak_resident_bytes as f64 / base.peak_resident_bytes as f64
                    - 1.0),
                100.0 * tol
            ));
        }
        // Entry-lease counters: gathering more entries than the baseline
        // means cheap passes got less sparse (or fell back to wider
        // leases); skipping fewer blocks means the lease stopped saving
        // I/O. Both directions are regressions of the active-set I/O
        // model, gated like store loads.
        if base.entry_loads > 0 && new.entry_loads as f64 > base.entry_loads as f64 * (1.0 + tol)
        {
            report.failures.push(format!(
                "{key}: entry loads {} > {} (+{:.1}%, tolerance {:.0}%)",
                new.entry_loads,
                base.entry_loads,
                100.0 * (new.entry_loads as f64 / base.entry_loads as f64 - 1.0),
                100.0 * tol
            ));
        }
        if base.blocks_skipped > 0
            && (new.blocks_skipped as f64) < base.blocks_skipped as f64 * (1.0 - tol)
        {
            report.failures.push(format!(
                "{key}: blocks skipped {} < {} (-{:.1}%, tolerance {:.0}%) — entry leases \
                 are saving less I/O",
                new.blocks_skipped,
                base.blocks_skipped,
                100.0 * (1.0 - new.blocks_skipped as f64 / base.blocks_skipped as f64),
                100.0 * tol
            ));
        }
        // Socket traffic of a sharded cell is schedule-deterministic, so
        // it gates like store loads. Barrier wait is wall-clock and is
        // deliberately NOT gated — it only informs the report. Rows with
        // a zero baseline (pre-shard history, or non-sharded cells) stay
        // disarmed.
        if base.shard_bytes > 0 && new.shard_bytes as f64 > base.shard_bytes as f64 * (1.0 + tol)
        {
            report.failures.push(format!(
                "{key}: shard socket bytes {} > {} (+{:.1}%, tolerance {:.0}%)",
                new.shard_bytes,
                base.shard_bytes,
                100.0 * (new.shard_bytes as f64 / base.shard_bytes as f64 - 1.0),
                100.0 * tol
            ));
        }
    }
    for row in &fresh.rows {
        let key = row.key();
        if baseline.find(&key).is_none() {
            report.added.push(key);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(cell: &str, vpu: f64, hit: f64, loads: u64, peak: u64) -> BaselineRow {
        BaselineRow {
            bench: "sweep".into(),
            n: 120,
            cell: cell.into(),
            store: if loads > 0 { "disk".into() } else { "mem".into() },
            visits_per_unit: vpu,
            hit_rate: hit,
            store_loads: loads,
            peak_resident_bytes: peak,
            entry_loads: 0,
            blocks_skipped: 0,
            shard_bytes: 0,
            barrier_wait_us: 0,
        }
    }

    fn entry_row(entry_loads: u64, blocks_skipped: u64) -> BaselineRow {
        BaselineRow { entry_loads, blocks_skipped, ..row("cheap-pass", 1e8, 0.0, 10, 4096) }
    }

    fn shard_row(shard_bytes: u64, barrier_wait_us: u64) -> BaselineRow {
        BaselineRow {
            shard_bytes,
            barrier_wait_us,
            store: "shard".into(),
            ..row("sharded w=2", 1e8, 0.0, 0, 4096)
        }
    }

    #[test]
    fn baseline_json_roundtrips() {
        let file = BaselineFile {
            rows: vec![row("screened", 1.25e8, 0.013, 0, 230_400), row("scalar", 2.0e7, 0.013, 42, 65_536)],
        };
        let text = file.to_json_string();
        let back = BaselineFile::parse(&text).unwrap();
        assert_eq!(back, file);
        // one row per line keeps diffs reviewable
        assert_eq!(text.lines().filter(|l| l.contains("\"bench\"")).count(), 2);
    }

    #[test]
    fn empty_baseline_roundtrips_and_passes() {
        let empty = BaselineFile::default();
        let back = BaselineFile::parse(&empty.to_json_string()).unwrap();
        assert_eq!(back, empty);
        let fresh = BaselineFile { rows: vec![row("screened", 1e8, 0.0, 0, 100)] };
        let rep = gate(&empty, &fresh, DEFAULT_TOLERANCE);
        assert!(rep.passed());
        assert_eq!(rep.checked, 0);
        assert_eq!(rep.added.len(), 1);
    }

    #[test]
    fn unknown_version_rejected() {
        assert!(BaselineFile::parse("{\"version\": 99, \"rows\": []}").is_err());
        assert!(BaselineFile::parse("{\"rows\": []}").is_err());
        assert!(BaselineFile::parse("not json").is_err());
    }

    #[test]
    fn merge_replaces_matching_keys_and_appends_new() {
        let mut base = BaselineFile { rows: vec![row("screened", 1e8, 0.01, 0, 100)] };
        let fresh = BaselineFile {
            rows: vec![row("screened", 2e8, 0.01, 0, 100), row("scalar", 3e7, 0.01, 0, 100)],
        };
        base.merge(&fresh);
        assert_eq!(base.rows.len(), 2);
        assert_eq!(base.find("sweep/n=120/screened/mem").unwrap().visits_per_unit, 2e8);
        assert_eq!(base.find("sweep/n=120/scalar/mem").unwrap().visits_per_unit, 3e7);
    }

    #[test]
    fn identical_run_passes() {
        let base = BaselineFile { rows: vec![row("screened", 1e8, 0.013, 10, 4096)] };
        let rep = gate(&base, &base.clone(), DEFAULT_TOLERANCE);
        assert!(rep.passed(), "{}", rep.render());
        assert_eq!(rep.checked, 1);
    }

    #[test]
    fn improvements_pass() {
        let base = BaselineFile { rows: vec![row("screened", 1e8, 0.013, 10, 4096)] };
        let fresh = BaselineFile { rows: vec![row("screened", 3e8, 0.013, 8, 2048)] };
        assert!(gate(&base, &fresh, DEFAULT_TOLERANCE).passed());
    }

    #[test]
    fn degraded_throughput_fails_the_gate() {
        // The ISSUE's required negative test: a committed cell degraded
        // beyond tolerance must fail.
        let base = BaselineFile { rows: vec![row("screened", 1.0e8, 0.013, 0, 4096)] };
        let fresh = BaselineFile { rows: vec![row("screened", 0.5e8, 0.013, 0, 4096)] };
        let rep = gate(&base, &fresh, DEFAULT_TOLERANCE);
        assert!(!rep.passed());
        assert_eq!(rep.failures.len(), 1);
        assert!(rep.failures[0].contains("throughput"), "{}", rep.failures[0]);
        assert!(rep.render().contains("FAIL"));
        // …while a drop inside the band passes.
        let ok = BaselineFile { rows: vec![row("screened", 0.8e8, 0.013, 0, 4096)] };
        assert!(gate(&base, &ok, DEFAULT_TOLERANCE).passed());
    }

    #[test]
    fn regressions_in_other_columns_fail_too() {
        let base = BaselineFile { rows: vec![row("disked", 1e8, 0.010, 100, 1 << 20)] };
        let drift = BaselineFile { rows: vec![row("disked", 1e8, 0.500, 100, 1 << 20)] };
        assert!(!gate(&base, &drift, DEFAULT_TOLERANCE).passed());
        let loads = BaselineFile { rows: vec![row("disked", 1e8, 0.010, 200, 1 << 20)] };
        assert!(!gate(&base, &loads, DEFAULT_TOLERANCE).passed());
        let bloat = BaselineFile { rows: vec![row("disked", 1e8, 0.010, 100, 1 << 22)] };
        assert!(!gate(&base, &bloat, DEFAULT_TOLERANCE).passed());
    }

    #[test]
    fn entry_lease_counters_gate_both_directions() {
        let base = BaselineFile { rows: vec![entry_row(100, 50)] };
        // Identical counters pass.
        assert!(gate(&base, &base.clone(), DEFAULT_TOLERANCE).passed());
        // Gathering more entries than tolerated fails.
        let more = BaselineFile { rows: vec![entry_row(200, 50)] };
        let rep = gate(&base, &more, DEFAULT_TOLERANCE);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("entry loads"), "{}", rep.failures[0]);
        // Skipping fewer blocks (lease saving less I/O) fails.
        let fewer = BaselineFile { rows: vec![entry_row(100, 10)] };
        let rep = gate(&base, &fewer, DEFAULT_TOLERANCE);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("blocks skipped"), "{}", rep.failures[0]);
        // Improvements (fewer entries, more skips) pass.
        let better = BaselineFile { rows: vec![entry_row(40, 90)] };
        assert!(gate(&base, &better, DEFAULT_TOLERANCE).passed());
        // Old rows without the counters (parsed as 0) never arm the rule.
        let legacy = BaselineFile { rows: vec![entry_row(0, 0)] };
        let fresh = BaselineFile { rows: vec![entry_row(500, 0)] };
        assert!(gate(&legacy, &fresh, DEFAULT_TOLERANCE).passed());
    }

    #[test]
    fn rows_parse_without_entry_lease_counters() {
        // A baseline committed before the counters existed still loads.
        let text = "{\n  \"version\": 1,\n  \"rows\": [\n    {\"bench\": \"sweep\", \
                    \"n\": 120, \"cell\": \"screened\", \"store\": \"mem\", \
                    \"visits_per_unit\": 1.0, \"hit_rate\": 0.5, \"store_loads\": 3, \
                    \"peak_resident_bytes\": 64}\n  ]\n}\n";
        let file = BaselineFile::parse(text).unwrap();
        assert_eq!(file.rows[0].entry_loads, 0);
        assert_eq!(file.rows[0].blocks_skipped, 0);
        assert_eq!(file.rows[0].shard_bytes, 0);
        assert_eq!(file.rows[0].barrier_wait_us, 0);
    }

    #[test]
    fn shard_bytes_gate_but_barrier_wait_never_does() {
        let base = BaselineFile { rows: vec![shard_row(1 << 20, 500)] };
        // Identical traffic passes.
        assert!(gate(&base, &base.clone(), DEFAULT_TOLERANCE).passed());
        // Socket traffic growing past the band fails (chattier leases).
        let chatty = BaselineFile { rows: vec![shard_row(1 << 21, 500)] };
        let rep = gate(&base, &chatty, DEFAULT_TOLERANCE);
        assert!(!rep.passed());
        assert!(rep.failures[0].contains("shard socket bytes"), "{}", rep.failures[0]);
        // Barrier wait is wall-clock noise: a 100x swing never fails.
        let slow_barrier = BaselineFile { rows: vec![shard_row(1 << 20, 50_000)] };
        assert!(gate(&base, &slow_barrier, DEFAULT_TOLERANCE).passed());
        // Zero-baseline rows (pre-shard history) stay disarmed.
        let legacy = BaselineFile { rows: vec![shard_row(0, 0)] };
        let fresh = BaselineFile { rows: vec![shard_row(1 << 30, 0)] };
        assert!(gate(&legacy, &fresh, DEFAULT_TOLERANCE).passed());
    }

    #[test]
    fn missing_cell_fails_the_gate() {
        let base = BaselineFile { rows: vec![row("screened", 1e8, 0.013, 0, 4096)] };
        let rep = gate(&base, &BaselineFile::default(), DEFAULT_TOLERANCE);
        assert!(!rep.passed());
        assert_eq!(rep.missing.len(), 1);
    }

    #[test]
    fn calibration_is_positive_and_normalization_scales() {
        let ns = calibrate();
        assert!(ns.is_finite() && ns > 0.0, "calibrate() -> {ns}");
        // a machine 2x slower (2x the ns/op) credits 2x the units
        assert!((normalize(100.0, 2.0) - 2.0 * normalize(100.0, 1.0)).abs() < 1e-12);
    }
}
