//! Minimal command-line argument parsing (no `clap` in the offline build).
//!
//! Grammar: `metric-proj <command> [--key value]... [--flag]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut options = HashMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key value` or bare `--flag`
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        options.insert(key.to_string(), it.next().unwrap());
                    }
                    _ => flags.push(key.to_string()),
                }
            } else {
                return Err(format!("unexpected positional argument: {arg}"));
            }
        }
        Ok(Args { command, options, flags })
    }

    /// Parse from the process arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// Typed option with default.
    pub fn get_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: cannot parse `{v}`")),
        }
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Result<Option<Vec<usize>>, String> {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|_| format!("--{key}: bad item `{s}`")))
                .collect::<Result<Vec<_>, _>>()
                .map(Some),
        }
    }

    /// Boolean flag.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn basic_parse() {
        let a = parse("solve --n 100 --threads 8 --verbose");
        assert_eq!(a.command, "solve");
        assert_eq!(a.get("n"), Some("100"));
        assert_eq!(a.get_or("threads", 1usize).unwrap(), 8);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("solve");
        assert_eq!(a.get_or("tile", 40usize).unwrap(), 40);
        assert_eq!(a.get("dataset"), None);
    }

    #[test]
    fn lists_parse() {
        let a = parse("table1 --cores 1,8,16");
        assert_eq!(a.get_list("cores").unwrap(), Some(vec![1, 8, 16]));
        assert_eq!(a.get_list("tiles").unwrap(), None);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("solve --n abc");
        assert!(a.get_or("n", 1usize).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(["solve".into(), "oops".into()]).is_err());
    }

    #[test]
    fn flag_before_option() {
        let a = parse("run --fast --n 5");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_or("n", 0usize).unwrap(), 5);
    }

    #[test]
    fn checkpoint_flags_parse() {
        // The grammar main.rs uses for the checkpoint subsystem.
        let a = parse("solve --checkpoint state.ckpt --checkpoint-every 10");
        assert_eq!(a.get("checkpoint"), Some("state.ckpt"));
        assert_eq!(a.get_or("checkpoint-every", 0usize).unwrap(), 10);
        let b = parse("solve --resume state.ckpt");
        assert_eq!(b.get("resume"), Some("state.ckpt"));
        assert_eq!(b.get("warm-start"), None);
        let c = parse("nearness --warm-start old.ckpt --n 200");
        assert_eq!(c.get("warm-start"), Some("old.ckpt"));
        assert_eq!(c.get_or("n", 0usize).unwrap(), 200);
    }

    #[test]
    fn strategy_flags_parse() {
        // The grammar main.rs uses for the active-set strategy.
        let a = parse("solve --strategy active --sweep-every 6 --forget-after 2");
        assert_eq!(a.get("strategy"), Some("active"));
        assert_eq!(a.get_or("sweep-every", 8usize).unwrap(), 6);
        assert_eq!(a.get_or("forget-after", 3usize).unwrap(), 2);
        // defaults apply when the options are absent
        let b = parse("solve --strategy full");
        assert_eq!(b.get_or("sweep-every", 8usize).unwrap(), 8);
    }

    #[test]
    fn store_flags_parse() {
        // The grammar main.rs uses for the out-of-core tile store (the
        // nearness and — since PR 5 — solve commands both accept it).
        let a = parse("nearness --store disk --store-dir /tmp/run1 --store-budget-mb 128");
        assert_eq!(a.get("store"), Some("disk"));
        assert_eq!(a.get("store-dir"), Some("/tmp/run1"));
        assert_eq!(a.get_or("store-budget-mb", 64usize).unwrap(), 128);
        // defaults apply when absent
        let b = parse("nearness --n 200");
        assert_eq!(b.get("store"), None);
        assert_eq!(b.get_or("store-budget-mb", 64usize).unwrap(), 64);
        // the CC-LP driver takes the same flags, combined with strategy
        let c = parse(
            "solve --store disk --store-dir /tmp/cc --store-budget-mb 8 --strategy active",
        );
        assert_eq!(c.get("store"), Some("disk"));
        assert_eq!(c.get("store-dir"), Some("/tmp/cc"));
        assert_eq!(c.get_or("store-budget-mb", 64usize).unwrap(), 8);
        assert_eq!(c.get("strategy"), Some("active"));
    }

    #[test]
    fn shard_flags_parse() {
        // The grammar main.rs uses for the multi-process shard store.
        let a = parse("solve --store shard --store-dir /tmp/sh --workers 4");
        assert_eq!(a.get("store"), Some("shard"));
        assert_eq!(a.get("store-dir"), Some("/tmp/sh"));
        assert_eq!(a.get_or("workers", 2usize).unwrap(), 4);
        // the worker count defaults when absent
        let b = parse("nearness --store shard --store-dir /tmp/sh");
        assert_eq!(b.get_or("workers", 2usize).unwrap(), 2);
        // the hidden worker subcommand the coordinator re-enters with
        let c = parse("shard-worker --connect /tmp/sh/shard.sock");
        assert_eq!(c.command, "shard-worker");
        assert_eq!(c.get("connect"), Some("/tmp/sh/shard.sock"));
    }

    #[test]
    fn telemetry_flags_parse() {
        // The grammar main.rs uses for the telemetry layer: trace capture
        // on solve/nearness, the trace summarizer, and the perf gate.
        let a = parse("solve --n 300 --strategy active --trace-out run.jsonl --progress");
        assert_eq!(a.get("trace-out"), Some("run.jsonl"));
        assert!(a.has_flag("progress"));
        // both default to off (NullRecorder: zero-cost path)
        let b = parse("solve --n 300");
        assert_eq!(b.get("trace-out"), None);
        assert!(!b.has_flag("progress"));
        // `report` takes a comma-separated list of trace files
        let c = parse("report --trace a.jsonl,b.jsonl");
        assert_eq!(c.get("trace"), Some("a.jsonl,b.jsonl"));
        // `bench-gate` compares fresh rows against the committed baseline
        let d = parse("bench-gate --fresh rows.json --baseline bench/baseline.json --tolerance 0.25");
        assert_eq!(d.get("fresh"), Some("rows.json"));
        assert_eq!(d.get("baseline"), Some("bench/baseline.json"));
        assert_eq!(d.get_or("tolerance", 0.5f64).unwrap(), 0.25);
    }

    #[test]
    fn sweep_engine_flags_parse() {
        // The grammar main.rs uses for the screen-then-project engine.
        let a = parse(
            "solve --strategy active --sweep-backend screened --sweep-policy adaptive",
        );
        assert_eq!(a.get("sweep-backend"), Some("screened"));
        assert_eq!(a.get("sweep-policy"), Some("adaptive"));
        let b = parse("nearness --sweep-backend engine --sweep-policy fixed --sweep-every 4");
        assert_eq!(b.get("sweep-backend"), Some("engine"));
        assert_eq!(b.get("sweep-policy"), Some("fixed"));
        assert_eq!(b.get_or("sweep-every", 8usize).unwrap(), 4);
        // both default to absent (screened backend / strategy cadence)
        let c = parse("solve --strategy active");
        assert_eq!(c.get("sweep-backend"), None);
        assert_eq!(c.get("sweep-policy"), None);
    }
}
