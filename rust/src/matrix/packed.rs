//! Packed strict-lower-triangular symmetric matrix, column-major.
//!
//! For an `n x n` symmetric matrix with ignored diagonal we store
//! `n*(n-1)/2` entries. Column `i` (0-based) holds rows `j = i+1 .. n-1`
//! contiguously, so `idx(i, j) = col_start[i] + (j - i - 1)` for `i < j`.
//! This is exactly the `X` layout of the paper (column-major, §III-C), and
//! the tiled cube iteration maximizes locality for walks down a column.

/// Packed symmetric pairwise matrix over `f64` (strict lower triangle).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedSym {
    n: usize,
    /// `col_start[i]` = offset of entry (i+1, i); has n entries (last col empty).
    col_start: Vec<usize>,
    data: Vec<f64>,
}

/// Number of stored entries for dimension `n`.
#[inline]
pub fn n_pairs(n: usize) -> usize {
    n * (n - 1) / 2
}

impl PackedSym {
    /// Zero-filled matrix of dimension `n` (n >= 1).
    pub fn zeros(n: usize) -> Self {
        Self::filled(n, 0.0)
    }

    /// Constant-filled matrix of dimension `n`.
    pub fn filled(n: usize, v: f64) -> Self {
        assert!(n >= 1, "PackedSym needs n >= 1");
        let mut col_start = Vec::with_capacity(n);
        let mut acc = 0usize;
        for i in 0..n {
            col_start.push(acc);
            acc += n - 1 - i;
        }
        PackedSym { n, col_start, data: vec![v; acc] }
    }

    /// Build from a function of the pair `(i, j)` with `i < j`.
    pub fn from_fn(n: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = m.idx(i, j);
                m.data[idx] = f(i, j);
            }
        }
        m
    }

    /// Dimension `n`.
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of stored pairs.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True iff no pairs are stored (n == 1).
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Linear index of unordered pair `{i, j}`, any order, `i != j`.
    #[inline]
    pub fn idx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i != j && i < self.n && j < self.n);
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        self.col_start[lo] + (hi - lo - 1)
    }

    /// Linear index when the caller guarantees `i < j` (hot path).
    #[inline(always)]
    pub fn idx_ord(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < j && j < self.n);
        // SAFETY of logic: col_start has n entries and i < j < n.
        unsafe { *self.col_start.get_unchecked(i) + (j - i - 1) }
    }

    /// Get entry `{i, j}`.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[self.idx(i, j)]
    }

    /// Set entry `{i, j}`.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        let idx = self.idx(i, j);
        self.data[idx] = v;
    }

    /// Raw packed storage (column-major lower triangle).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw packed storage.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Column-start offsets (for hot loops that precompute bases).
    #[inline]
    pub fn col_starts(&self) -> &[usize] {
        &self.col_start
    }

    /// Iterate `(i, j, value)` over all stored pairs, column-major order.
    pub fn iter_pairs(&self) -> impl Iterator<Item = (usize, usize, f64)> + '_ {
        (0..self.n).flat_map(move |i| {
            ((i + 1)..self.n).map(move |j| (i, j, self.data[self.idx_ord(i, j)]))
        })
    }

    /// Elementwise `self - other` as a new matrix (dimensions must match).
    pub fn sub(&self, other: &PackedSym) -> PackedSym {
        assert_eq!(self.n, other.n);
        let mut out = self.clone();
        for (a, b) in out.data.iter_mut().zip(other.data.iter()) {
            *a -= b;
        }
        out
    }

    /// Weighted squared Frobenius-style norm over pairs: `sum w_ij * v_ij^2`.
    pub fn weighted_sq_norm(&self, w: &PackedSym) -> f64 {
        assert_eq!(self.n, w.n);
        self.data
            .iter()
            .zip(w.data.iter())
            .map(|(v, wi)| wi * v * v)
            .sum()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }
}

/// Decode a linear pair index back to `(i, j)` with `i < j` (O(1) closed form).
///
/// Inverse of `PackedSym::idx_ord`. Used by the pair-constraint phase to map
/// flat work indices to pairs without a lookup table.
pub fn pair_of_index(n: usize, idx: usize) -> (usize, usize) {
    debug_assert!(idx < n_pairs(n));
    // Solve for the column i: idx - col_start[i] in [0, n-1-i).
    // col_start[i] = i*n - i*(i+1)/2 - ... derive via quadratic formula on
    // f(i) = i*(2n - i - 1)/2 <= idx.
    let nf = n as f64;
    let t = 2.0 * nf - 1.0;
    let mut i = ((t - (t * t - 8.0 * idx as f64).sqrt()) / 2.0).floor() as usize;
    // Guard against floating point off-by-one at boundaries.
    let cs = |i: usize| i * (2 * n - i - 1) / 2;
    while i > 0 && cs(i) > idx {
        i -= 1;
    }
    while cs(i + 1) <= idx {
        i += 1;
    }
    let j = i + 1 + (idx - cs(i));
    (i, j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn sizes() {
        assert_eq!(PackedSym::zeros(1).len(), 0);
        assert_eq!(PackedSym::zeros(2).len(), 1);
        assert_eq!(PackedSym::zeros(5).len(), 10);
        assert_eq!(n_pairs(100), 4950);
    }

    #[test]
    fn idx_bijective_and_column_major() {
        let n = 17;
        let m = PackedSym::zeros(n);
        let mut seen = vec![false; m.len()];
        let mut prev = None;
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = m.idx(i, j);
                assert!(!seen[idx], "idx collision at ({i},{j})");
                seen[idx] = true;
                // Column-major: consecutive j in the same column are adjacent.
                if let Some((pi, pidx)) = prev {
                    if pi == i {
                        assert_eq!(idx, pidx + 1usize);
                    }
                }
                prev = Some((i, idx));
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn idx_symmetric_in_arguments() {
        let m = PackedSym::zeros(9);
        for i in 0..9 {
            for j in 0..9 {
                if i != j {
                    assert_eq!(m.idx(i, j), m.idx(j, i));
                }
            }
        }
    }

    #[test]
    fn get_set_roundtrip() {
        let mut m = PackedSym::zeros(6);
        m.set(2, 4, 3.5);
        m.set(4, 1, -1.0); // unordered args
        assert_eq!(m.get(2, 4), 3.5);
        assert_eq!(m.get(4, 2), 3.5);
        assert_eq!(m.get(1, 4), -1.0);
        assert_eq!(m.get(0, 1), 0.0);
    }

    #[test]
    fn from_fn_matches_get() {
        let m = PackedSym::from_fn(8, |i, j| (i * 10 + j) as f64);
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_eq!(m.get(i, j), (i * 10 + j) as f64);
            }
        }
    }

    #[test]
    fn pair_of_index_inverts_idx() {
        for n in [2usize, 3, 5, 17, 101] {
            let m = PackedSym::zeros(n);
            for i in 0..n {
                for j in (i + 1)..n {
                    assert_eq!(pair_of_index(n, m.idx(i, j)), (i, j), "n={n}");
                }
            }
        }
    }

    #[test]
    fn pair_of_index_property() {
        check("pair_of_index random n", 0xC0FFEE, 32, |rng, _| {
            let n = rng.usize_in(2, 500);
            let m = PackedSym::zeros(n);
            for _ in 0..64 {
                let idx = rng.usize_in(0, m.len().max(1));
                let (i, j) = pair_of_index(n, idx);
                prop_assert!(i < j && j < n, "bad pair ({i},{j}) for n={n}");
                prop_assert!(m.idx(i, j) == idx, "roundtrip failed n={n} idx={idx}");
            }
            Ok(())
        });
    }

    #[test]
    fn weighted_norm_and_sub() {
        let a = PackedSym::from_fn(4, |i, j| (i + j) as f64);
        let b = PackedSym::from_fn(4, |_, _| 1.0);
        let d = a.sub(&b);
        for i in 0..4 {
            for j in (i + 1)..4 {
                assert_eq!(d.get(i, j), (i + j) as f64 - 1.0);
            }
        }
        let w = PackedSym::filled(4, 2.0);
        let expect: f64 = d.iter_pairs().map(|(_, _, v)| 2.0 * v * v).sum();
        assert!((d.weighted_sq_norm(&w) - expect).abs() < 1e-12);
    }

    #[test]
    fn iter_pairs_order_is_column_major() {
        let m = PackedSym::from_fn(5, |i, j| (i * 5 + j) as f64);
        let pairs: Vec<(usize, usize)> = m.iter_pairs().map(|(i, j, _)| (i, j)).collect();
        let mut expect = Vec::new();
        for i in 0..5 {
            for j in (i + 1)..5 {
                expect.push((i, j));
            }
        }
        assert_eq!(pairs, expect);
    }

    #[test]
    fn max_abs_works() {
        let mut m = PackedSym::zeros(4);
        m.set(0, 3, -7.25);
        m.set(1, 2, 3.0);
        assert_eq!(m.max_abs(), 7.25);
    }

    #[test]
    fn random_get_set_fuzz() {
        let mut rng = Rng::new(99);
        let n = 40;
        let mut m = PackedSym::zeros(n);
        let mut mirror = std::collections::HashMap::new();
        for _ in 0..5000 {
            let i = rng.usize_in(0, n);
            let mut j = rng.usize_in(0, n);
            if i == j {
                j = (j + 1) % n;
            }
            let v = rng.f64_in(-10.0, 10.0);
            m.set(i, j, v);
            let key = (i.min(j), i.max(j));
            mirror.insert(key, v);
        }
        for ((i, j), v) in mirror {
            assert_eq!(m.get(i, j), v);
        }
    }
}
