//! Dense symmetric pairwise storage.
//!
//! All pairwise quantities in metric-constrained optimization (distances
//! `X`, weights `W`, targets `D`, slacks `F`) are symmetric with an
//! irrelevant diagonal, so we store only the strict lower triangle,
//! **column-major** — the layout the paper's tiled schedule (§III-C) is
//! designed around: for a fixed column `i`, the entries `x_{ij}` for
//! consecutive `j` are contiguous.
//!
//! [`store`] abstracts *where* the packed entries live: resident
//! ([`store::MemStore`], the classic path) or on disk as `(i, k)` tile
//! blocks with a bounded working set ([`store::DiskStore`]), leased tile
//! by tile to the solvers.

pub mod packed;
pub mod store;

pub use packed::PackedSym;
pub use store::{DiskStore, MemStore, StoreCfg, StoreKind, TileScratch, TileStore};
