//! Dense symmetric pairwise storage.
//!
//! All pairwise quantities in metric-constrained optimization (distances
//! `X`, weights `W`, targets `D`, slacks `F`) are symmetric with an
//! irrelevant diagonal, so we store only the strict lower triangle,
//! **column-major** — the layout the paper's tiled schedule (§III-C) is
//! designed around: for a fixed column `i`, the entries `x_{ij}` for
//! consecutive `j` are contiguous.

pub mod packed;

pub use packed::PackedSym;
