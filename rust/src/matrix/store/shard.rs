//! Multi-process tile sharding: the packed `x` / `winv` planes split
//! across worker processes behind [`TileStore`] leases.
//!
//! A sharded solve is one **coordinator** (this process — it owns the
//! wave schedule, the pass loop, termination, checkpoints, telemetry)
//! and `N` **workers**, each holding one [`ShardPartition`] slice of
//! both planes resident and answering gather/scatter requests over a
//! Unix-domain socket speaking the [`super::protocol`] frames. Workers
//! never compute: every projection runs on the coordinator inside the
//! lease callback, on bytes the worker copied verbatim — which is why a
//! sharded solve is **bitwise identical** to the resident one (pinned by
//! `tests/shard_equivalence.rs`), the same argument that made
//! [`super::DiskStore`] safe.
//!
//! The partition is column-granular ([`ShardPartition`]), so every
//! per-column segment a tile lease gathers lives wholly inside one
//! shard: a lease costs one `READ`/`WRITE` round-trip per shard its
//! footprint touches, never a split segment.
//!
//! # Persistence and resume
//!
//! Workers persist nothing per-lease. At each checkpoint the
//! coordinator chains a `STAMP` through the shards: worker `k` writes
//! its slice to `x.tiles.shard<k>` (atomic `.tmp` + rename; 72-byte
//! header + raw slice) and folds the slice into the running FNV-1a
//! state seeded by worker `k - 1`'s result. Because FNV-1a chains, the
//! final value equals the hash of the whole plane in packed order —
//! **independent of the partition** — so it doubles as checkpoint v2's
//! external-x `x_fnv` and a resume may use a *different* `--workers`
//! count: the coordinator re-reads all shard files itself
//! ([`ShardStore::open_with`]), re-partitions, and hands out fresh
//! slices. `SNAPSHOT` copies each shard file to a `.ckpt` sibling, which
//! the resume path promotes when the live files are torn (a crash
//! mid-`STAMP` chain), mirroring the disk store's snapshot discipline.
//!
//! # Locking and failure
//!
//! Each worker holds a [`StoreLock`] on **its own** shard file
//! (`x.tiles.shard<k>.lock`, holding the worker's pid) — per-shard lock
//! paths, so a coordinator restart never refuses its own workers the
//! way a single `x.tiles.lock` would, and a SIGKILLed worker leaves a
//! dead-pid lock that the next open breaks as stale. Socket failures
//! latch the store exactly like disk I/O failures: leases park, the
//! driver's per-pass [`ShardStore::health`] poll (which doubles as the
//! liveness heartbeat — one `BARRIER` round-trip per worker, timed into
//! [`StoreStats::barrier_wait_us`]) unwinds the solve with a typed
//! error, and `--recover-attempts` re-opens from the shard files, which
//! still hold the last checkpoint state.

use super::disk::{
    bytes_to_f64s, f64s_to_bytes, lock_is_live, packed_col_starts, sibling, snapshot_sibling,
    RetryNote, StoreError, StoreLock, StoreStats,
};
use super::protocol::{read_frame, write_frame, Request, Response, PROTOCOL_VERSION};
use super::{Seg, StoreCfg, TileScratch, TileStore};
use crate::matrix::packed::n_pairs;
use crate::solver::schedule::{ShardPartition, Tile};
use crate::solver::tiling::for_each_tile_col;
use crate::util::hash::{fnv1a64, fnv1a64_f64s, Fnv1a};
use crate::util::shared::SharedMut;
use std::fs::File;
use std::io::Write as _;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Shard file magic: identifies one shard's slice of a sharded store.
pub const SHARD_MAGIC: [u8; 8] = *b"MPROJSHD";

/// Current shard-file format version.
pub const SHARD_VERSION: u32 = 1;

const SHARD_HEADER_LEN: usize = 72;

/// How long the coordinator waits for all spawned workers to connect.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(10);

/// Per-request read timeout on coordinator sockets: a worker that goes
/// silent this long counts as dead.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(60);

/// Bounded patience at drop: shutdown ack + child reap.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(2);

/// Entries per `READ`/`WRITE` chunk of a pair-range lease, bounding the
/// frame size (and the worker's transient copy) to 512 KiB of payload.
const PAIR_CHUNK: usize = 1 << 16;

/// Sanity cap on the shard count read back from a shard file header.
const MAX_SHARDS: u32 = 4096;

/// Path of shard `k`'s data file: the logical store path (`x.tiles`)
/// with `.shard<k>` appended.
pub fn shard_data_path(x_path: &Path, shard: usize) -> PathBuf {
    sibling(x_path, &format!(".shard{shard}"))
}

fn shard_header_bytes(
    n: u64,
    shard: u32,
    n_shards: u32,
    entry_lo: u64,
    entry_hi: u64,
    pass: u64,
    slice_fnv: u64,
) -> [u8; SHARD_HEADER_LEN] {
    let mut h = [0u8; SHARD_HEADER_LEN];
    h[..8].copy_from_slice(&SHARD_MAGIC);
    h[8..12].copy_from_slice(&SHARD_VERSION.to_le_bytes());
    h[16..24].copy_from_slice(&n.to_le_bytes());
    h[24..28].copy_from_slice(&shard.to_le_bytes());
    h[28..32].copy_from_slice(&n_shards.to_le_bytes());
    h[32..40].copy_from_slice(&entry_lo.to_le_bytes());
    h[40..48].copy_from_slice(&entry_hi.to_le_bytes());
    h[48..56].copy_from_slice(&pass.to_le_bytes());
    h[56..64].copy_from_slice(&slice_fnv.to_le_bytes());
    let sum = fnv1a64(&h[..64]);
    h[64..72].copy_from_slice(&sum.to_le_bytes());
    h
}

/// Parsed shard-file header.
#[derive(Clone, Copy, Debug)]
struct ShardHeader {
    n: u64,
    shard: u32,
    n_shards: u32,
    entry_lo: u64,
    entry_hi: u64,
    pass: u64,
    slice_fnv: u64,
}

fn parse_shard_header(h: &[u8]) -> Result<ShardHeader, StoreError> {
    if h.len() < SHARD_HEADER_LEN {
        return Err(StoreError::Corrupt("shard file shorter than its header".into()));
    }
    if h[..8] != SHARD_MAGIC {
        return Err(StoreError::BadMagic);
    }
    let version = u32::from_le_bytes(h[8..12].try_into().unwrap());
    if version != SHARD_VERSION {
        return Err(StoreError::UnsupportedVersion(version));
    }
    let sum = u64::from_le_bytes(h[64..72].try_into().unwrap());
    if sum != fnv1a64(&h[..64]) {
        return Err(StoreError::Corrupt("shard header checksum mismatch".into()));
    }
    Ok(ShardHeader {
        n: u64::from_le_bytes(h[16..24].try_into().unwrap()),
        shard: u32::from_le_bytes(h[24..28].try_into().unwrap()),
        n_shards: u32::from_le_bytes(h[28..32].try_into().unwrap()),
        entry_lo: u64::from_le_bytes(h[32..40].try_into().unwrap()),
        entry_hi: u64::from_le_bytes(h[40..48].try_into().unwrap()),
        pass: u64::from_le_bytes(h[48..56].try_into().unwrap()),
        slice_fnv: u64::from_le_bytes(h[56..64].try_into().unwrap()),
    })
}

fn read_shard_file(path: &Path) -> Result<(ShardHeader, Vec<f64>), StoreError> {
    let bytes = std::fs::read(path)?;
    let header = parse_shard_header(&bytes)?;
    let want = (header.entry_hi - header.entry_lo) as usize * 8;
    let data = &bytes[SHARD_HEADER_LEN..];
    if data.len() != want {
        return Err(StoreError::Corrupt(format!(
            "shard file {} holds {} data bytes, header promises {want}",
            path.display(),
            data.len()
        )));
    }
    if fnv1a64(data) != header.slice_fnv {
        return Err(StoreError::Corrupt(format!(
            "shard file {} slice checksum mismatch (torn write?)",
            path.display()
        )));
    }
    Ok((header, bytes_to_f64s(data)))
}

/// Reassemble the full packed plane from the on-disk shard files of a
/// previous run (whatever worker count wrote them — shard 0's header
/// names it). Verifies per-file integrity, cross-shard consistency
/// (same `n`, same shard count, same pass, exact partition geometry),
/// and that no shard is still live-locked by another process. Returns
/// `(plane, pass, plane_fnv)`; the fnv is recomputed from the bytes, so
/// it is simultaneously the stamp and the content fingerprint.
fn read_shard_plane(x_path: &Path, n: usize) -> Result<(Vec<f64>, u64, u64), StoreError> {
    let first = shard_data_path(x_path, 0);
    if !first.exists() {
        return Err(StoreError::Mismatch(format!(
            "no shard files at {} (missing {})",
            x_path.display(),
            first.display()
        )));
    }
    for_each_live_shard_lock(x_path, |k, lock| {
        Err(StoreError::Locked(format!(
            "shard {k} of {} is held by a live process ({})",
            x_path.display(),
            lock.display()
        )))
    })?;
    let bytes = std::fs::read(&first)?;
    let h0 = parse_shard_header(&bytes)?;
    if h0.n != n as u64 {
        return Err(StoreError::Mismatch(format!(
            "shard store is for n = {}, this solve needs n = {n}",
            h0.n
        )));
    }
    if h0.n_shards == 0 || h0.n_shards > MAX_SHARDS {
        return Err(StoreError::Corrupt(format!("implausible shard count {}", h0.n_shards)));
    }
    let on_disk = h0.n_shards as usize;
    let part = ShardPartition::new(n, on_disk);
    let total = n_pairs(n);
    let mut plane = vec![0.0f64; total];
    for k in 0..on_disk {
        let path = shard_data_path(x_path, k);
        let (h, data) = read_shard_file(&path)?;
        let (lo, hi) = part.entry_range(k);
        if h.n != n as u64
            || h.n_shards != h0.n_shards
            || h.shard != k as u32
            || h.pass != h0.pass
            || h.entry_lo != lo as u64
            || h.entry_hi != hi as u64
        {
            return Err(StoreError::Corrupt(format!(
                "shard file {} disagrees with its siblings (shard {} of {}, pass {}, \
                 entries [{}, {}); expected shard {k} of {}, pass {}, entries [{lo}, {hi}))",
                path.display(),
                h.shard,
                h.n_shards,
                h.pass,
                h.entry_lo,
                h.entry_hi,
                h0.n_shards,
                h0.pass,
            )));
        }
        plane[lo..hi].copy_from_slice(&data);
    }
    let fnv = fnv1a64_f64s(Fnv1a::new().finish(), &plane);
    Ok((plane, h0.pass, fnv))
}

/// Visit every live per-shard lock beside `x_path` (scanning the parent
/// directory for `x.tiles.shard<k>.lock` siblings). The visitor may
/// short-circuit by returning an error.
fn for_each_live_shard_lock(
    x_path: &Path,
    mut f: impl FnMut(usize, &Path) -> Result<(), StoreError>,
) -> Result<(), StoreError> {
    for k in 0..MAX_SHARDS as usize {
        let data = shard_data_path(x_path, k);
        let lock = sibling(&data, ".lock");
        if !data.exists() && !lock.exists() {
            break;
        }
        if lock_is_live(&lock) {
            f(k, &lock)?;
        }
    }
    Ok(())
}

/// Promote every `x.tiles.shard<k>.ckpt` snapshot over its live shard
/// file (the sharded analog of the disk store's snapshot promotion; the
/// resume path calls this when the live shard set is torn, e.g. a crash
/// mid-`STAMP` chain left headers disagreeing). Returns how many files
/// were promoted.
pub fn promote_shard_snapshots(x_path: &Path) -> std::io::Result<usize> {
    let mut promoted = 0usize;
    for k in 0..MAX_SHARDS as usize {
        let data = shard_data_path(x_path, k);
        let snap = snapshot_sibling(&data);
        if !data.exists() && !snap.exists() {
            break;
        }
        if snap.exists() {
            std::fs::copy(&snap, &data)?;
            promoted += 1;
        }
    }
    Ok(promoted)
}

/// Whether any shard files exist beside `x_path` (fresh-create refusal,
/// the shard analog of checking for `x.tiles` itself).
pub fn shard_files_exist(x_path: &Path) -> bool {
    shard_data_path(x_path, 0).exists()
}

// ---------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------

/// One worker's resident state: its slice of both planes plus the
/// per-shard persistence paths and lock.
struct WorkerState {
    n: u64,
    shard: u32,
    n_shards: u32,
    entry_lo: usize,
    entry_hi: usize,
    x: Vec<f64>,
    winv: Vec<f64>,
    data_path: PathBuf,
    _lock: StoreLock,
}

impl WorkerState {
    fn init(req: Request) -> Result<WorkerState, StoreError> {
        let Request::Init { version, n, shard, n_shards, x_path, x, winv } = req else {
            return Err(StoreError::Mismatch("first frame must be INIT".into()));
        };
        if version != PROTOCOL_VERSION {
            return Err(StoreError::UnsupportedVersion(version));
        }
        if n_shards == 0 || shard >= n_shards {
            return Err(StoreError::Mismatch(format!("shard {shard} of {n_shards} workers")));
        }
        let part = ShardPartition::new(n as usize, n_shards as usize);
        let (entry_lo, entry_hi) = part.entry_range(shard as usize);
        if x.len() != entry_hi - entry_lo || winv.len() != x.len() {
            return Err(StoreError::Mismatch(format!(
                "shard {shard} slice holds {} entries, partition expects {}",
                x.len(),
                entry_hi - entry_lo
            )));
        }
        let data_path = shard_data_path(&x_path, shard as usize);
        if let Some(dir) = data_path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let lock = StoreLock::acquire(&data_path)?;
        Ok(WorkerState { n, shard, n_shards, entry_lo, entry_hi, x, winv, data_path, _lock: lock })
    }

    /// Validate that `ranges` lie inside this shard's slice and count
    /// their total entries.
    fn check_ranges(&self, ranges: &[(u64, u64)]) -> Result<usize, StoreError> {
        let mut total = 0usize;
        for &(off, len) in ranges {
            let end = off.checked_add(len).ok_or_else(|| {
                StoreError::Mismatch(format!("range ({off}, {len}) overflows"))
            })?;
            if off < self.entry_lo as u64 || end > self.entry_hi as u64 {
                return Err(StoreError::Mismatch(format!(
                    "range [{off}, {end}) outside shard {} slice [{}, {})",
                    self.shard, self.entry_lo, self.entry_hi
                )));
            }
            total += len as usize;
        }
        Ok(total)
    }

    fn gather(&self, ranges: &[(u64, u64)]) -> Result<(Vec<f64>, Vec<f64>), StoreError> {
        let total = self.check_ranges(ranges)?;
        let mut x = Vec::with_capacity(total);
        let mut winv = Vec::with_capacity(total);
        for &(off, len) in ranges {
            let lo = off as usize - self.entry_lo;
            let hi = lo + len as usize;
            x.extend_from_slice(&self.x[lo..hi]);
            winv.extend_from_slice(&self.winv[lo..hi]);
        }
        Ok((x, winv))
    }

    fn scatter(&mut self, ranges: &[(u64, u64)], data: &[f64]) -> Result<(), StoreError> {
        let total = self.check_ranges(ranges)?;
        if data.len() != total {
            return Err(StoreError::Mismatch(format!(
                "scatter payload holds {} entries, ranges cover {total}",
                data.len()
            )));
        }
        let mut pos = 0usize;
        for &(off, len) in ranges {
            let lo = off as usize - self.entry_lo;
            self.x[lo..lo + len as usize].copy_from_slice(&data[pos..pos + len as usize]);
            pos += len as usize;
        }
        Ok(())
    }

    /// Persist the slice to the shard file: header + raw entries, staged
    /// to `.tmp` and renamed (so `clean_stale_artifacts`'s `.tmp` rule
    /// sweeps a torn write and a reader never sees half a file).
    fn persist(&self, pass: u64) -> Result<(), StoreError> {
        let bytes = f64s_to_bytes(&self.x);
        let header = shard_header_bytes(
            self.n,
            self.shard,
            self.n_shards,
            self.entry_lo as u64,
            self.entry_hi as u64,
            pass,
            fnv1a64(&bytes),
        );
        let tmp = sibling(&self.data_path, ".tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(&header)?;
            f.write_all(&bytes)?;
            f.flush()?;
        }
        std::fs::rename(&tmp, &self.data_path)?;
        Ok(())
    }

    fn snapshot(&self) -> Result<(), StoreError> {
        let dest = snapshot_sibling(&self.data_path);
        let tmp = sibling(&dest, ".tmp");
        std::fs::copy(&self.data_path, &tmp)?;
        std::fs::rename(&tmp, &dest)?;
        Ok(())
    }

    /// Handle one post-init request; returns the response and whether to
    /// exit the serve loop.
    fn handle(&mut self, req: Request) -> (Response, bool) {
        let resp = match req {
            Request::Init { .. } => {
                Response::Err { error: StoreError::Mismatch("duplicate INIT".into()) }
            }
            Request::Read { ranges } => match self.gather(&ranges) {
                Ok((x, winv)) => Response::Read { x, winv },
                Err(error) => Response::Err { error },
            },
            Request::Write { ranges, x } => match self.scatter(&ranges, &x) {
                Ok(()) => Response::WriteAck,
                Err(error) => Response::Err { error },
            },
            Request::Stamp { pass, seed } => match self.persist(pass) {
                Ok(()) => Response::Stamp { chain: fnv1a64_f64s(seed, &self.x) },
                Err(error) => Response::Err { error },
            },
            Request::Fingerprint { seed } => {
                Response::Fingerprint { chain: fnv1a64_f64s(seed, &self.x) }
            }
            Request::Snapshot => match self.snapshot() {
                Ok(()) => Response::SnapshotAck,
                Err(error) => Response::Err { error },
            },
            Request::Barrier { pass } => Response::Barrier { pass },
            Request::Shutdown => return (Response::ShutdownAck, true),
        };
        (resp, false)
    }
}

/// Serve one coordinator connection until shutdown or EOF. EOF (the
/// coordinator died or dropped us) is a clean exit: the worker holds no
/// state the shard files don't already hold as of the last `STAMP`, and
/// exiting releases the per-shard lock.
fn serve(mut stream: UnixStream) {
    let mut state = match read_frame(&mut stream) {
        Ok(body) => match Request::decode(&body).and_then(WorkerState::init) {
            Ok(state) => {
                let ack = Response::InitAck { pid: std::process::id() };
                if write_frame(&mut stream, &ack.encode()).is_err() {
                    return;
                }
                state
            }
            Err(error) => {
                let _ = write_frame(&mut stream, &Response::Err { error }.encode());
                return;
            }
        },
        Err(_) => return,
    };
    loop {
        let body = match read_frame(&mut stream) {
            Ok(body) => body,
            Err(_) => return,
        };
        let (resp, done) = match Request::decode(&body) {
            Ok(req) => state.handle(req),
            Err(error) => (Response::Err { error }, false),
        };
        if write_frame(&mut stream, &resp.encode()).is_err() || done {
            return;
        }
    }
}

/// Process-mode worker entry point (the hidden `shard-worker` CLI
/// subcommand): connect to the coordinator's listening socket and serve
/// until shutdown.
pub fn worker_main(connect: &Path) -> Result<(), StoreError> {
    let stream = UnixStream::connect(connect)?;
    serve(stream);
    Ok(())
}

// ---------------------------------------------------------------------
// Coordinator side
// ---------------------------------------------------------------------

/// One worker connection: the request/response socket (a full
/// round-trip runs under the mutex, so concurrent wave workers on the
/// coordinator never interleave frames) plus the handle to reap at
/// drop.
struct ShardConn {
    stream: Mutex<UnixStream>,
    child: Option<Child>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Worker pid from `INIT_ACK` (the coordinator's own pid for
    /// in-process worker threads).
    pid: u32,
}

/// Coordinator-side [`TileStore`] over `N` shard workers.
pub struct ShardStore {
    n: usize,
    total: usize,
    col_starts: Vec<usize>,
    part: ShardPartition,
    path: PathBuf,
    conns: Vec<ShardConn>,
    /// `(pass, x_fnv)` of the last [`ShardStore::flush_and_stamp`] (or
    /// as read back at [`ShardStore::open_with`]).
    stamp: Mutex<(u64, u64)>,
    stats: Mutex<StoreStats>,
    failed: AtomicBool,
    first_err: Mutex<Option<StoreError>>,
    barrier_seq: AtomicU64,
}

/// A tile footprint's segments grouped per owning shard, with the wire
/// ranges and the matching arena spans.
struct ShardGroup {
    shard: usize,
    ranges: Vec<(u64, u64)>,
    /// `(arena_start, len)` per range.
    spans: Vec<(usize, usize)>,
}

fn unexpected(op: &str, resp: &Response) -> StoreError {
    StoreError::Corrupt(format!("unexpected worker response to {op}: {resp:?}"))
}

fn worker_io(shard: usize, context: &str, e: std::io::Error) -> StoreError {
    StoreError::Io(std::io::Error::new(
        e.kind(),
        format!("shard worker {shard} ({context}): {e}"),
    ))
}

impl ShardStore {
    /// Create a fresh sharded store: materialize the plane from
    /// `src(c, r)` (transient `O(n²)`, like any fresh create), partition
    /// it over `cfg.workers` workers, and hand each its slice.
    pub fn create_with(
        cfg: &StoreCfg,
        n: usize,
        winv: Vec<f64>,
        src: &mut dyn FnMut(usize, usize) -> f64,
    ) -> Result<ShardStore, StoreError> {
        let col_starts = packed_col_starts(n);
        let mut x = vec![0.0f64; n_pairs(n)];
        for c in 0..n.saturating_sub(1) {
            let base = col_starts[c];
            for r in (c + 1)..n {
                x[base + (r - c - 1)] = src(c, r);
            }
        }
        Self::boot(cfg, n, x, winv, (0, 0))
    }

    /// Re-open a sharded store from its on-disk shard files (external-x
    /// resume): reassemble the plane (verifying every header, checksum,
    /// and the cross-shard geometry), then re-partition for the
    /// *current* `cfg.workers` — the chained fingerprint is
    /// partition-independent, so resuming with a different worker count
    /// is exact. The returned store's [`ShardStore::stamp`] carries the
    /// files' pass and the recomputed plane fingerprint.
    pub fn open_with(cfg: &StoreCfg, n: usize, winv: Vec<f64>) -> Result<ShardStore, StoreError> {
        let (x, pass, fnv) = read_shard_plane(&cfg.x_path(), n)?;
        Self::boot(cfg, n, x, winv, (pass, fnv))
    }

    fn boot(
        cfg: &StoreCfg,
        n: usize,
        x: Vec<f64>,
        winv: Vec<f64>,
        stamp: (u64, u64),
    ) -> Result<ShardStore, StoreError> {
        let total = n_pairs(n);
        if x.len() != total || winv.len() != total {
            return Err(StoreError::Mismatch(format!(
                "plane slices hold {} / {} entries, n = {n} needs {total}",
                x.len(),
                winv.len()
            )));
        }
        let workers = cfg.workers.max(1);
        let part = ShardPartition::new(n, workers);
        let path = cfg.x_path();
        std::fs::create_dir_all(&cfg.dir)?;
        let mut conns = match &cfg.worker_exe {
            Some(exe) => spawn_process_workers(exe, &cfg.dir, workers)?,
            None => spawn_thread_workers(workers)?,
        };
        let mut stats = StoreStats::default();
        for (k, conn) in conns.iter_mut().enumerate() {
            let (lo, hi) = part.entry_range(k);
            let req = Request::Init {
                version: PROTOCOL_VERSION,
                n: n as u64,
                shard: k as u32,
                n_shards: workers as u32,
                x_path: path.clone(),
                x: x[lo..hi].to_vec(),
                winv: winv[lo..hi].to_vec(),
            };
            let stream = conn.stream.get_mut().unwrap_or_else(|p| p.into_inner());
            let resp = roundtrip(stream, &req, k, &mut stats)?;
            match resp {
                Response::InitAck { pid } => conn.pid = pid,
                Response::Err { error } => return Err(error),
                other => return Err(unexpected("INIT", &other)),
            }
        }
        Ok(ShardStore {
            n,
            total,
            col_starts: packed_col_starts(n),
            part,
            path,
            conns,
            stamp: Mutex::new(stamp),
            stats: Mutex::new(stats),
            failed: AtomicBool::new(false),
            first_err: Mutex::new(None),
            barrier_seq: AtomicU64::new(0),
        })
    }

    /// The partition in force (tests and diagnostics).
    pub fn partition(&self) -> &ShardPartition {
        &self.part
    }

    /// Worker pids in shard order (the kill-recovery test picks its
    /// victim here via the per-shard lock files; this accessor serves
    /// diagnostics).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.conns.iter().map(|c| c.pid).collect()
    }

    /// The `(pass, x_fnv)` stamp of the last
    /// [`ShardStore::flush_and_stamp`] (or as read back at open).
    pub fn stamp(&self) -> (u64, u64) {
        *self.stamp.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Cache/transport counters so far.
    pub fn stats(&self) -> StoreStats {
        *self.stats.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Workers hold their slices resident and `STAMP` persists
    /// synchronously, so there is nothing to flush.
    pub fn flush(&self) -> Result<(), StoreError> {
        self.health_latch_only()
    }

    /// Chain a `STAMP` through the shards: worker `k` persists its
    /// slice stamped with `pass` and folds it into the FNV state seeded
    /// by worker `k - 1`. The final state equals the FNV of the whole
    /// plane in packed order — checkpoint v2's external `x_fnv`.
    pub fn flush_and_stamp(&self, pass: u64) -> Result<u64, StoreError> {
        let mut chain = Fnv1a::new().finish();
        for k in 0..self.part.n_shards() {
            match self.request(k, &Request::Stamp { pass, seed: chain })? {
                Response::Stamp { chain: next } => chain = next,
                other => return Err(unexpected("STAMP", &other)),
            }
        }
        *self.stamp.lock().unwrap_or_else(|p| p.into_inner()) = (pass, chain);
        Ok(chain)
    }

    /// Recompute the plane fingerprint (chained per-shard FNV) without
    /// persisting anything.
    pub fn data_fingerprint(&self) -> Result<u64, StoreError> {
        let mut chain = Fnv1a::new().finish();
        for k in 0..self.part.n_shards() {
            match self.request(k, &Request::Fingerprint { seed: chain })? {
                Response::Fingerprint { chain: next } => chain = next,
                other => return Err(unexpected("FINGERPRINT", &other)),
            }
        }
        Ok(chain)
    }

    /// Have every worker copy its (just stamped) shard file to the
    /// `.ckpt` sibling — the recovery artifact the resume path promotes
    /// over torn live files.
    pub fn snapshot(&self) -> Result<(), StoreError> {
        for k in 0..self.part.n_shards() {
            match self.request(k, &Request::Snapshot)? {
                Response::SnapshotAck => {}
                other => return Err(unexpected("SNAPSHOT", &other)),
            }
        }
        Ok(())
    }

    /// Materialize the full packed plane (final extraction; `O(n²)`
    /// resident, streamed shard by shard in bounded chunks).
    pub fn read_full(&self) -> Result<Vec<f64>, StoreError> {
        let mut out = vec![0.0f64; self.total];
        for k in 0..self.part.n_shards() {
            let (lo, hi) = self.part.entry_range(k);
            let mut pos = lo;
            while pos < hi {
                let take = (hi - pos).min(PAIR_CHUNK);
                match self.request(k, &Request::Read { ranges: vec![(pos as u64, take as u64)] })? {
                    Response::Read { x, .. } => {
                        if x.len() != take {
                            return Err(StoreError::Corrupt(format!(
                                "shard {k} returned {} entries for a {take}-entry read",
                                x.len()
                            )));
                        }
                        out[pos..pos + take].copy_from_slice(&x);
                    }
                    other => return Err(unexpected("READ", &other)),
                }
                pos += take;
            }
        }
        Ok(out)
    }

    /// Per-pass health poll, which doubles as the worker **liveness
    /// heartbeat**: one `BARRIER` round-trip per worker (a SIGKILLed
    /// worker surfaces here as a socket error at the latest), with the
    /// blocked time accounted to [`StoreStats::barrier_wait_us`]. Then
    /// the first-error latch is taken exactly like the disk store's.
    pub fn health(&self) -> Result<(), StoreError> {
        if !self.is_failed() {
            let seq = self.barrier_seq.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            for k in 0..self.part.n_shards() {
                match self.request(k, &Request::Barrier { pass: seq }) {
                    Ok(Response::Barrier { pass }) if pass == seq => {}
                    Ok(other) => {
                        self.latch(unexpected("BARRIER", &other));
                        break;
                    }
                    Err(e) => {
                        self.latch(e);
                        break;
                    }
                }
            }
            let waited = t0.elapsed().as_micros() as u64;
            self.stats.lock().unwrap_or_else(|p| p.into_inner()).barrier_wait_us += waited;
        }
        self.health_latch_only()
    }

    fn health_latch_only(&self) -> Result<(), StoreError> {
        if !self.is_failed() {
            return Ok(());
        }
        let mut first = self.first_err.lock().unwrap_or_else(|p| p.into_inner());
        Err(first.take().unwrap_or_else(|| {
            StoreError::Corrupt("sharded store already failed earlier in this solve".into())
        }))
    }

    /// Whether a permanent failure has been latched (leases are no-ops).
    pub fn is_failed(&self) -> bool {
        self.failed.load(Ordering::Acquire)
    }

    /// Park a lease-path failure in the latch (first error wins).
    fn latch(&self, e: StoreError) {
        let mut first = self.first_err.lock().unwrap_or_else(|p| p.into_inner());
        if first.is_none() {
            *first = Some(e);
        }
        self.failed.store(true, Ordering::Release);
    }

    /// No retry loop on the socket path (a dead worker cannot heal), so
    /// there are never buffered retry notes.
    pub fn drain_retries(&self) -> Vec<RetryNote> {
        Vec::new()
    }

    /// One request/response round-trip with shard `k`, serialized on
    /// the connection mutex, accounted into the transport counters (the
    /// stats lock is taken only after the socket I/O, so requests to
    /// *different* shards never serialize on it).
    fn request(&self, k: usize, req: &Request) -> Result<Response, StoreError> {
        let mut local = StoreStats::default();
        let resp = {
            let mut stream = self.conns[k].stream.lock().unwrap_or_else(|p| p.into_inner());
            roundtrip(&mut stream, req, k, &mut local)
        };
        {
            let mut stats = self.stats.lock().unwrap_or_else(|p| p.into_inner());
            stats.shard_requests += local.shard_requests;
            stats.shard_bytes_out += local.shard_bytes_out;
            stats.shard_bytes_in += local.shard_bytes_in;
        }
        if let Ok(Response::Err { error }) = resp {
            return Err(error);
        }
        resp
    }

    /// Stage `tile`'s footprint into `scratch` (arena + address table +
    /// segment list), one `READ` per shard the footprint touches.
    fn gather_tile(&self, tile: &Tile, scratch: &mut TileScratch) -> Result<(), StoreError> {
        let n = self.n;
        if scratch.cols.len() < n {
            scratch.cols.resize(n, 0);
        }
        scratch.segs.clear();
        let mut arena_len = 0usize;
        {
            let scratch = &mut *scratch;
            for_each_tile_col(tile, |c, lo, hi| {
                // Non-negative by construction — see `DiskStore::gather_tile`.
                debug_assert!(arena_len >= lo - c - 1, "arena base underflow for {tile:?}");
                scratch.cols[c] = arena_len - (lo - c - 1);
                scratch.segs.push(Seg { col: c, row_lo: lo, row_hi: hi, start: arena_len });
                arena_len += hi - lo;
            });
        }
        scratch.x.clear();
        scratch.x.resize(arena_len, 0.0);
        scratch.winv.clear();
        scratch.winv.resize(arena_len, 0.0);
        for group in self.group_segs(&scratch.segs) {
            let want: usize = group.spans.iter().map(|&(_, len)| len).sum();
            match self.request(group.shard, &Request::Read { ranges: group.ranges })? {
                Response::Read { x, winv } => {
                    if x.len() != want || winv.len() != want {
                        return Err(StoreError::Corrupt(format!(
                            "shard {} returned {} / {} entries, lease asked for {want}",
                            group.shard,
                            x.len(),
                            winv.len()
                        )));
                    }
                    let mut pos = 0usize;
                    for &(start, len) in &group.spans {
                        scratch.x[start..start + len].copy_from_slice(&x[pos..pos + len]);
                        scratch.winv[start..start + len].copy_from_slice(&winv[pos..pos + len]);
                        pos += len;
                    }
                }
                other => return Err(unexpected("READ", &other)),
            }
        }
        Ok(())
    }

    /// Write the whole gathered footprint back, one `WRITE` per shard.
    fn scatter_tile(&self, scratch: &TileScratch) -> Result<(), StoreError> {
        for group in self.group_segs(&scratch.segs) {
            let mut payload = Vec::with_capacity(group.spans.iter().map(|&(_, l)| l).sum());
            for &(start, len) in &group.spans {
                payload.extend_from_slice(&scratch.x[start..start + len]);
            }
            match self.request(group.shard, &Request::Write { ranges: group.ranges, x: payload })? {
                Response::WriteAck => {}
                other => return Err(unexpected("WRITE", &other)),
            }
        }
        Ok(())
    }

    /// Group a footprint's per-column segments by owning shard. The
    /// partition is column-granular, so each segment maps to exactly one
    /// shard, and segments arrive in ascending column order, so each
    /// shard's ranges are ascending too.
    fn group_segs(&self, segs: &[Seg]) -> Vec<ShardGroup> {
        let mut groups: Vec<ShardGroup> = Vec::new();
        for seg in segs {
            let len = seg.row_hi - seg.row_lo;
            if len == 0 {
                continue;
            }
            let shard = self.part.shard_of_col(seg.col);
            let off = (self.col_starts[seg.col] + (seg.row_lo - seg.col - 1)) as u64;
            match groups.last_mut() {
                Some(g) if g.shard == shard => {
                    g.ranges.push((off, len as u64));
                    g.spans.push((seg.start, len));
                }
                _ => groups.push(ShardGroup {
                    shard,
                    ranges: vec![(off, len as u64)],
                    spans: vec![(seg.start, len)],
                }),
            }
        }
        groups
    }
}

fn roundtrip(
    stream: &mut UnixStream,
    req: &Request,
    shard: usize,
    stats: &mut StoreStats,
) -> Result<Response, StoreError> {
    let body = req.encode();
    stats.shard_requests += 1;
    stats.shard_bytes_out += body.len() as u64 + 4;
    write_frame(stream, &body).map_err(|e| worker_io(shard, "send", e))?;
    let resp_body = read_frame(stream).map_err(|e| worker_io(shard, "receive", e))?;
    stats.shard_bytes_in += resp_body.len() as u64 + 4;
    Response::decode(&resp_body)
}

impl TileStore for ShardStore {
    fn n(&self) -> usize {
        self.n
    }

    fn n_pairs(&self) -> usize {
        self.total
    }

    unsafe fn with_tile(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        // A latched store parks every lease (waves are barrier-
        // synchronized; the driver's per-pass `health()` unwinds).
        if self.is_failed() {
            return;
        }
        if let Err(e) = self.gather_tile(tile, scratch) {
            self.latch(e);
            return;
        }
        {
            let view = SharedMut::new(scratch.x.as_mut_slice());
            f(&view, &scratch.cols, &scratch.winv);
        }
        if let Err(e) = self.scatter_tile(scratch) {
            self.latch(e);
        }
    }

    unsafe fn with_tile_read(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        if self.is_failed() {
            return;
        }
        if let Err(e) = self.gather_tile(tile, scratch) {
            self.latch(e);
            return;
        }
        let view = SharedMut::new(scratch.x.as_mut_slice());
        f(&view, &scratch.cols, &scratch.winv);
    }

    unsafe fn with_pair_range(
        &self,
        lo: usize,
        hi: usize,
        write: bool,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(usize, &mut [f64], &[f64]),
    ) {
        if lo >= hi || self.is_failed() {
            return;
        }
        debug_assert!(hi <= self.total);
        let walk = (|| -> Result<(), StoreError> {
            let mut g = lo;
            while g < hi {
                let shard = self.part.shard_of_entry(g);
                let (_, shard_hi) = self.part.entry_range(shard);
                let seg_hi = hi.min(shard_hi);
                let mut pos = g;
                while pos < seg_hi {
                    let take = (seg_hi - pos).min(PAIR_CHUNK);
                    let ranges = vec![(pos as u64, take as u64)];
                    match self.request(shard, &Request::Read { ranges: ranges.clone() })? {
                        Response::Read { x, winv } => {
                            if x.len() != take || winv.len() != take {
                                return Err(StoreError::Corrupt(format!(
                                    "shard {shard} returned {} entries for a {take}-entry range",
                                    x.len()
                                )));
                            }
                            scratch.x.clear();
                            scratch.x.extend_from_slice(&x);
                            scratch.winv.clear();
                            scratch.winv.extend_from_slice(&winv);
                        }
                        other => return Err(unexpected("READ", &other)),
                    }
                    f(pos, &mut scratch.x, &scratch.winv);
                    if write {
                        let payload = scratch.x.clone();
                        match self.request(shard, &Request::Write { ranges, x: payload })? {
                            Response::WriteAck => {}
                            other => return Err(unexpected("WRITE", &other)),
                        }
                    }
                    pos += take;
                }
                g = seg_hi;
            }
            Ok(())
        })();
        if let Err(e) = walk {
            self.latch(e);
        }
    }
}

impl Drop for ShardConn {
    /// Best-effort clean shutdown with bounded patience: ask the worker
    /// to exit, close the socket (a wedged worker then sees EOF), and
    /// reap the child / join the thread. Dropping the conns — whether
    /// from a completed solve or a failed boot — never hangs and never
    /// leaks a worker process.
    fn drop(&mut self) {
        {
            let mut stream = self.stream.lock().unwrap_or_else(|p| p.into_inner());
            let _ = stream.set_read_timeout(Some(SHUTDOWN_GRACE));
            let _ = write_frame(&mut *stream, &Request::Shutdown.encode());
            let _ = read_frame(&mut *stream);
            let _ = stream.shutdown(std::net::Shutdown::Both);
        }
        if let Some(mut child) = self.child.take() {
            let deadline = Instant::now() + SHUTDOWN_GRACE;
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(20));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        if let Some(handle) = self.thread.take() {
            let _ = handle.join();
        }
    }
}

fn spawn_thread_workers(workers: usize) -> Result<Vec<ShardConn>, StoreError> {
    let mut conns = Vec::with_capacity(workers);
    for k in 0..workers {
        let (coord, worker) = UnixStream::pair()?;
        coord.set_read_timeout(Some(REQUEST_TIMEOUT))?;
        let thread = std::thread::Builder::new()
            .name(format!("shard-worker-{k}"))
            .spawn(move || serve(worker))
            .map_err(StoreError::Io)?;
        conns.push(ShardConn {
            stream: Mutex::new(coord),
            child: None,
            thread: Some(thread),
            pid: std::process::id(),
        });
    }
    Ok(conns)
}

fn spawn_process_workers(
    exe: &Path,
    dir: &Path,
    workers: usize,
) -> Result<Vec<ShardConn>, StoreError> {
    let sock = dir.join("shard.sock");
    let _ = std::fs::remove_file(&sock);
    let listener = UnixListener::bind(&sock)?;
    listener.set_nonblocking(true)?;
    let mut children: Vec<Child> = Vec::with_capacity(workers);
    let spawn_all = (|| -> Result<(), StoreError> {
        for _ in 0..workers {
            let child = Command::new(exe)
                .arg("shard-worker")
                .arg("--connect")
                .arg(&sock)
                .stdin(Stdio::null())
                .spawn()?;
            children.push(child);
        }
        Ok(())
    })();
    if let Err(e) = spawn_all {
        reap(&mut children);
        let _ = std::fs::remove_file(&sock);
        return Err(e);
    }
    let mut streams: Vec<UnixStream> = Vec::with_capacity(workers);
    let deadline = Instant::now() + ACCEPT_TIMEOUT;
    while streams.len() < workers {
        match listener.accept() {
            Ok((stream, _)) => {
                let ready = (|| -> std::io::Result<()> {
                    stream.set_nonblocking(false)?;
                    stream.set_read_timeout(Some(REQUEST_TIMEOUT))
                })();
                if let Err(e) = ready {
                    reap(&mut children);
                    let _ = std::fs::remove_file(&sock);
                    return Err(e.into());
                }
                streams.push(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                let died = children
                    .iter_mut()
                    .any(|c| matches!(c.try_wait(), Ok(Some(_))));
                if died || Instant::now() > deadline {
                    reap(&mut children);
                    let _ = std::fs::remove_file(&sock);
                    return Err(StoreError::Io(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        if died {
                            "a shard worker exited before connecting"
                        } else {
                            "timed out waiting for shard workers to connect"
                        },
                    )));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                reap(&mut children);
                let _ = std::fs::remove_file(&sock);
                return Err(e.into());
            }
        }
    }
    let _ = std::fs::remove_file(&sock);
    // Identity is assigned by INIT, not by accept order, so pairing the
    // k-th accepted stream with the k-th spawned child is only for
    // reaping — a mismatch is harmless.
    Ok(streams
        .into_iter()
        .zip(children)
        .map(|(stream, child)| ShardConn {
            stream: Mutex::new(stream),
            child: Some(child),
            thread: None,
            pid: 0,
        })
        .collect())
}

fn reap(children: &mut Vec<Child>) {
    for child in children.iter_mut() {
        let _ = child.kill();
        let _ = child.wait();
    }
    children.clear();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::schedule::Schedule;

    fn test_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("metric_proj_shard_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn cfg(dir: &Path, workers: usize) -> StoreCfg {
        StoreCfg::shard(dir, workers)
    }

    /// Deterministic test plane: entry of pair (c, r).
    fn val(c: usize, r: usize) -> f64 {
        (c as f64) * 1000.0 + (r as f64) + 0.25
    }

    fn make_store(dir: &Path, n: usize, workers: usize) -> ShardStore {
        let winv: Vec<f64> = (0..n_pairs(n)).map(|g| 1.0 + (g % 7) as f64).collect();
        ShardStore::create_with(&cfg(dir, workers), n, winv, &mut |c, r| val(c, r)).unwrap()
    }

    fn expected_plane(n: usize) -> Vec<f64> {
        let cs = packed_col_starts(n);
        let mut x = vec![0.0; n_pairs(n)];
        for c in 0..n.saturating_sub(1) {
            for r in (c + 1)..n {
                x[cs[c] + (r - c - 1)] = val(c, r);
            }
        }
        x
    }

    #[test]
    fn create_and_read_full_roundtrips() {
        let dir = test_dir("roundtrip");
        for workers in [1usize, 2, 3] {
            let store = make_store(&dir, 12, workers);
            assert_eq!(store.read_full().unwrap(), expected_plane(12));
            assert!(store.stats().shard_requests > 0);
            drop(store);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tile_lease_gathers_and_scatters_across_shards() {
        let dir = test_dir("lease");
        let n = 14;
        let store = make_store(&dir, n, 3);
        let schedule = Schedule::new(n, 4);
        let cs = packed_col_starts(n);
        let mut scratch = TileScratch::default();
        // Add 1.0 to every entry, tile by tile (each pair touched once
        // per covering tile footprint — use one fixed tile instead).
        let tile = schedule.waves()[0][0];
        // SAFETY: single-threaded test, exclusive tile ownership.
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |x, cols, winv| {
                for_each_tile_col(&tile, |c, lo, hi| {
                    for r in lo..hi {
                        let idx = cols[c] + (r - c - 1);
                        // SAFETY: exclusive access in this test.
                        let got = unsafe { x.get(idx) };
                        assert_eq!(got, val(c, r), "gathered ({c},{r})");
                        assert!(winv[idx] >= 1.0);
                        // SAFETY: exclusive access in this test.
                        unsafe { x.add(idx, 1.0) };
                    }
                });
            });
        }
        store.health().unwrap();
        let full = store.read_full().unwrap();
        let mut want = expected_plane(n);
        for_each_tile_col(&tile, |c, lo, hi| {
            for r in lo..hi {
                want[cs[c] + (r - c - 1)] += 1.0;
            }
        });
        assert_eq!(full, want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pair_range_lease_walks_ascending_across_shard_boundaries() {
        let dir = test_dir("pairrange");
        let n = 13;
        let store = make_store(&dir, n, 4);
        let total = n_pairs(n);
        let mut scratch = TileScratch::default();
        let mut seen = vec![false; total];
        let mut last = 0usize;
        // SAFETY: single-threaded, whole-range ownership.
        unsafe {
            store.with_pair_range(0, total, true, &mut scratch, &mut |g, x, winv| {
                assert!(g >= last, "segments must ascend");
                last = g;
                assert_eq!(x.len(), winv.len());
                for (i, v) in x.iter_mut().enumerate() {
                    assert!(!seen[g + i], "entry {} handed twice", g + i);
                    seen[g + i] = true;
                    *v *= 2.0;
                }
            });
        }
        store.health().unwrap();
        assert!(seen.iter().all(|&s| s));
        let want: Vec<f64> = expected_plane(n).iter().map(|v| v * 2.0).collect();
        assert_eq!(store.read_full().unwrap(), want);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stamp_is_partition_independent_and_resume_is_exact() {
        let dir = test_dir("resume");
        let n = 11;
        let plane = expected_plane(n);
        let fnv_direct = fnv1a64_f64s(Fnv1a::new().finish(), &plane);
        let store = make_store(&dir, n, 3);
        let fnv = store.flush_and_stamp(7).unwrap();
        assert_eq!(fnv, fnv_direct, "chained stamp equals the one-shot plane hash");
        assert_eq!(store.stamp(), (7, fnv));
        assert_eq!(store.data_fingerprint().unwrap(), fnv);
        drop(store);
        // Reopen with a *different* worker count.
        let winv: Vec<f64> = (0..n_pairs(n)).map(|g| 1.0 + (g % 7) as f64).collect();
        let reopened = ShardStore::open_with(&cfg(&dir, 2), n, winv).unwrap();
        assert_eq!(reopened.stamp(), (7, fnv));
        assert_eq!(reopened.read_full().unwrap(), plane);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_promotes_over_a_torn_shard_file() {
        let dir = test_dir("promote");
        let n = 10;
        let store = make_store(&dir, n, 2);
        let fnv = store.flush_and_stamp(3).unwrap();
        store.snapshot().unwrap();
        drop(store);
        // Tear one live shard file (truncate past the header).
        let victim = shard_data_path(&cfg(&dir, 2).x_path(), 1);
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 4]).unwrap();
        let winv: Vec<f64> = (0..n_pairs(n)).map(|g| 1.0 + (g % 7) as f64).collect();
        let x_path = cfg(&dir, 2).x_path();
        assert!(matches!(
            ShardStore::open_with(&cfg(&dir, 2), n, winv.clone()),
            Err(StoreError::Corrupt(_))
        ));
        assert_eq!(promote_shard_snapshots(&x_path).unwrap(), 2);
        let healed = ShardStore::open_with(&cfg(&dir, 2), n, winv).unwrap();
        assert_eq!(healed.stamp(), (3, fnv));
        assert_eq!(healed.read_full().unwrap(), expected_plane(n));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn live_per_shard_lock_refuses_reopen() {
        let dir = test_dir("locked");
        let n = 9;
        let store = make_store(&dir, n, 2);
        store.flush_and_stamp(1).unwrap();
        // Workers are live (in-process threads hold the per-shard
        // locks), so a second coordinator must be refused.
        let winv: Vec<f64> = (0..n_pairs(n)).map(|_| 1.0).collect();
        assert!(matches!(
            ShardStore::open_with(&cfg(&dir, 2), n, winv),
            Err(StoreError::Locked(_))
        ));
        drop(store);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn worker_rejects_out_of_partition_ranges() {
        let dir = test_dir("reject");
        let n = 9;
        let store = make_store(&dir, n, 2);
        let (lo, _) = store.partition().entry_range(1);
        // Ask shard 0 for shard 1's first entry.
        let err = store
            .request(0, &Request::Read { ranges: vec![(lo as u64, 1)] })
            .unwrap_err();
        assert!(matches!(err, StoreError::Mismatch(_)), "{err}");
        // The store itself is not latched by a caller-level misuse probe;
        // the lease paths would latch it.
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_dead_pid_shard_lock_is_broken_on_reopen() {
        let dir = test_dir("stale");
        let n = 8;
        let store = make_store(&dir, n, 2);
        let fnv = store.flush_and_stamp(2).unwrap();
        drop(store);
        // Simulate a SIGKILLed worker: a leftover lock naming a dead pid.
        let lock = sibling(&shard_data_path(&cfg(&dir, 2).x_path(), 0), ".lock");
        std::fs::write(&lock, "999999999").unwrap();
        let winv: Vec<f64> = (0..n_pairs(n)).map(|_| 1.0).collect();
        let reopened = ShardStore::open_with(&cfg(&dir, 3), n, winv).unwrap();
        assert_eq!(reopened.stamp(), (2, fnv));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
