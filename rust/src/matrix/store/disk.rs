//! The file-backed [`TileStore`]: `X` on disk as `(i, k)` tile blocks,
//! behind a bounded LRU block cache — plus a second, **read-only plane**
//! streaming the packed inverse weights `1/w` from a sibling spill file
//! (`<x file>.w`, same format and block layout), so weighted instances
//! keep nothing `O(n²)` resident either. The `w` spill is derived data:
//! it is (re)written from the caller's weights at [`DiskStore::create`]
//! *and* [`DiskStore::open`], never trusted across runs, and removed on
//! drop.
//!
//! # File format (`x.tiles`, all integers little-endian)
//!
//! ```text
//! 0   magic      b"MPROJTIL"
//! 8   version    u32  (currently 1)
//! 12  reserved   u32  (0)
//! 16  n          u64  problem dimension
//! 24  block      u64  block side length of the layout
//! 32  entries    u64  total stored pairs (= n(n-1)/2)
//! 40  pass       u64  solver pass stamped at the last flush (0 = fresh)
//! 48  x_fnv      u64  FNV-1a of the block-checksum table at the last
//!                     stamp (the store fingerprint)
//! 56  hdr_fnv    u64  FNV-1a over bytes 0..56
//! 64  checksums  u64 × n_blocks   per-block FNV-1a, in block order
//! ..  data       f64 × entries    blocks in block order (layout offsets)
//! ```
//!
//! [`DiskStore::open`] validates the header, the exact file size
//! (truncation), and **every** block checksum, so a corrupted or
//! truncated store is rejected before a solve starts — mirroring the
//! checkpoint format's guarantees. Block writes re-stamp the block's
//! checksum; [`DiskStore::flush_and_stamp`] additionally records the
//! solver pass and a store fingerprint in the header, which is what
//! lets a checkpoint *reference* the store instead of re-serializing `x`
//! (see [`crate::solver::checkpoint`]).
//!
//! # Caching
//!
//! Blocks are cached in memory up to a byte budget with exact LRU
//! eviction and write-back of dirty blocks. All gather/scatter copying
//! happens under one lock; the projection work between them runs on
//! worker-private arenas, so workers only serialize on the (short) copy
//! phases. A background thread warms the cache for
//! [`TileStore::prefetch`] hints — loads only, so results are
//! unaffected.
//!
//! # Failure model
//!
//! Nothing mid-solve panics. Every block read is verified against a
//! **resident checksum table** (`sums`, maintained by every write), so a
//! torn or bit-flipped read is caught at the block it happened in, not
//! at the next `open`. Transient failures — `EIO`, a read that fails its
//! checksum — are retried with exponential backoff up to
//! [`StoreTuning::retries`] times, counted in [`StoreStats::retries`]
//! and described by [`RetryNote`]s (drained per pass into the
//! `store_retry` telemetry event). A failure that survives its retry
//! budget (or is non-retryable, like `ENOSPC`) is **latched**: the store
//! remembers the first error, every subsequent lease becomes a no-op,
//! and the driver's per-pass [`DiskStore::health`] poll unwinds the
//! solve with the typed error — barrier-synchronized waves cannot unwind
//! mid-wave, so leases park instead of panicking and the pass loop does
//! the unwinding. Deterministic fault injection for all of this lives in
//! [`super::faults`].
//!
//! A sibling `<x file>.lock` file (holding the owner's pid) makes two
//! concurrent solves on one store a typed [`StoreError::Locked`] instead
//! of silent corruption; stale locks from dead processes are broken
//! automatically, and [`clean_stale_artifacts`] sweeps leftover `*.tmp`
//! files and orphaned spill planes from crashed runs.

use super::faults::FaultPlan;
use super::layout::BlockLayout;
use super::{Seg, TileScratch, TileStore};
use crate::matrix::packed::n_pairs;
use crate::solver::schedule::Tile;
use crate::solver::tiling::for_each_tile_col;
use crate::util::hash::{fnv1a64, Fnv1a};
use crate::util::shared::SharedMut;
use std::fmt;
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard};

/// File magic: identifies a metric-proj tile store.
pub const STORE_MAGIC: [u8; 8] = *b"MPROJTIL";

/// Current tile-file format version.
pub const STORE_VERSION: u32 = 1;

const HEADER_LEN: u64 = 64;

/// Why a tile store could not be created, opened, or flushed.
#[derive(Debug)]
pub enum StoreError {
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// The file does not start with the tile-store magic.
    BadMagic,
    /// The file carries a version this build cannot read.
    UnsupportedVersion(u32),
    /// Truncated or internally inconsistent bytes (size, header
    /// checksum, block checksums).
    Corrupt(String),
    /// The file is well-formed but does not match the caller's problem
    /// (wrong `n`, wrong stamp, ...).
    Mismatch(String),
    /// Another live process (or another handle in this one) holds the
    /// store's lockfile.
    Locked(String),
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "tile store I/O error: {e}"),
            StoreError::BadMagic => write!(f, "not a metric-proj tile store (bad magic)"),
            StoreError::UnsupportedVersion(v) => {
                write!(f, "unsupported tile store version {v} (this build reads {STORE_VERSION})")
            }
            StoreError::Corrupt(msg) => write!(f, "corrupt tile store: {msg}"),
            StoreError::Mismatch(msg) => write!(f, "tile store mismatch: {msg}"),
            StoreError::Locked(msg) => write!(f, "tile store locked: {msg}"),
        }
    }
}

impl std::error::Error for StoreError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            StoreError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

fn corrupt(msg: impl Into<String>) -> StoreError {
    StoreError::Corrupt(msg.into())
}

/// Whether a retry can plausibly heal this failure: transient `EIO` yes,
/// a read-side checksum mismatch yes (a re-read of intact bytes heals a
/// torn read), `ENOSPC` and every structural error no.
fn retryable(e: &StoreError) -> bool {
    match e {
        StoreError::Io(io) => io.raw_os_error() != Some(28 /* ENOSPC */),
        StoreError::Corrupt(_) => true,
        _ => false,
    }
}

/// Default bounded retry budget per block operation.
pub const DEFAULT_STORE_RETRIES: u32 = 4;

/// Robustness knobs threaded from [`super::StoreCfg`] into each cache
/// plane: the (optional) deterministic fault plan and the per-operation
/// retry budget.
#[derive(Clone, Debug)]
pub struct StoreTuning {
    /// Deterministic fault injection at the block read/write layer
    /// (tests, the nightly fault-matrix CI job); `None` in production.
    pub faults: Option<Arc<FaultPlan>>,
    /// Transient failures are retried up to this many times per
    /// operation, with exponential backoff, before latching the store.
    pub retries: u32,
}

impl Default for StoreTuning {
    fn default() -> Self {
        StoreTuning { faults: None, retries: DEFAULT_STORE_RETRIES }
    }
}

/// One healed transient failure, recorded for the `store_retry`
/// telemetry event (see [`DiskStore::drain_retries`]).
#[derive(Clone, Debug)]
pub struct RetryNote {
    /// Which cache plane faulted (`"x"` or `"w"`).
    pub plane: &'static str,
    /// `"read"` or `"write"`.
    pub op: &'static str,
    /// Block index the operation targeted.
    pub block: usize,
    /// 1-based retry attempt that this note records.
    pub attempt: u32,
    /// Rendered error the retry healed.
    pub error: String,
}

/// The store's error latch. Leases run under barrier-synchronized waves
/// and cannot unwind mid-wave, so the first permanent failure is parked
/// here, every later lease becomes a no-op, and the driver's per-pass
/// [`DiskStore::health`] poll turns it into a typed unwind.
#[derive(Default)]
struct StoreHealth {
    failed: AtomicBool,
    first: Mutex<Option<StoreError>>,
}

/// Exclusive-ownership guard over a store file: a sibling
/// `<x file>.lock` holding the owner's pid, created with `create_new`
/// for atomicity. Stale locks (dead pid) are broken; live ones refuse
/// the open with [`StoreError::Locked`]. Removed on drop.
///
/// Shard workers reuse this guard on their *per-shard* data file
/// (`x.tiles.shard<k>.lock`), so a multi-process sharded solve holds one
/// lock per shard instead of fighting over a single `x.tiles.lock`.
pub(crate) struct StoreLock {
    path: PathBuf,
}

impl StoreLock {
    pub(crate) fn acquire(store_path: &Path) -> Result<StoreLock, StoreError> {
        let path = sibling(store_path, ".lock");
        for _ in 0..2 {
            match OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = f.write_all(std::process::id().to_string().as_bytes());
                    let _ = f.flush();
                    return Ok(StoreLock { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    if lock_is_live(&path) {
                        let pid = std::fs::read_to_string(&path).unwrap_or_default();
                        return Err(StoreError::Locked(format!(
                            "{} is held by live process {}",
                            path.display(),
                            pid.trim()
                        )));
                    }
                    // Stale lock from a crashed run: break it and retry.
                    let _ = std::fs::remove_file(&path);
                }
                Err(e) => return Err(e.into()),
            }
        }
        Err(StoreError::Locked(format!("could not acquire {}", path.display())))
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Whether `lock_path` names a lockfile owned by a live process. A
/// missing or unreadable pid counts as dead (the lock is stale).
pub(crate) fn lock_is_live(lock_path: &Path) -> bool {
    std::fs::read_to_string(lock_path)
        .ok()
        .and_then(|s| s.trim().parse::<u32>().ok())
        .is_some_and(pid_alive)
}

#[cfg(unix)]
fn pid_alive(pid: u32) -> bool {
    Path::new(&format!("/proc/{pid}")).exists()
}

#[cfg(not(unix))]
fn pid_alive(_pid: u32) -> bool {
    // No portable liveness probe: treat every recorded pid as live
    // (refusing a possibly-stale lock is safer than breaking a live one).
    true
}

/// Remove leftovers a crashed solve can strand in a store directory:
/// `*.tmp` staging files (atomic-rename writes that never renamed) and
/// orphaned derived artifacts — `*.w` spill planes and `*.lock` files
/// whose owning store has no live lock. Live-locked stores keep all
/// their siblings; `*.ckpt` snapshots are always kept (they are the
/// crash-recovery artifact). The rules are shard-aware by construction:
/// a sharded store's locks are *per shard* (`x.tiles.shard<k>.lock`,
/// each holding its worker's pid), so a restarting coordinator sweeps
/// only the locks of dead workers and never refuses — or breaks — its
/// own live ones, and the shard data files themselves (no recognized
/// suffix) are never swept. Returns the removed paths; a missing `dir`
/// is an empty sweep, not an error.
pub fn clean_stale_artifacts(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e),
    };
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        paths.push(entry?.path());
    }
    let mut removed = Vec::new();
    for path in paths {
        let Some(name) = path.file_name().map(|n| n.to_string_lossy().into_owned()) else {
            continue;
        };
        let stale = if name.ends_with(".tmp") {
            true
        } else if let Some(owner) = name.strip_suffix(".w") {
            !lock_is_live(&sibling(&path.with_file_name(owner), ".lock"))
        } else if name.ends_with(".lock") {
            !lock_is_live(&path)
        } else {
            false
        };
        if stale && std::fs::remove_file(&path).is_ok() {
            removed.push(path);
        }
    }
    Ok(removed)
}

/// Cache counters, for diagnostics, benches, and the eviction-churn
/// assertions in `tests/store_equivalence.rs`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Blocks read from the `X` file into the cache.
    pub loads: u64,
    /// Blocks evicted from the `X` cache.
    pub evictions: u64,
    /// Evicted dirty blocks written back to the `X` file.
    pub writebacks: u64,
    /// Blocks loaded by the background prefetcher (both planes).
    pub prefetched: u64,
    /// High-water mark of resident cache bytes, summed over the `X` and
    /// streamed-`W` planes (sum of per-plane peaks — an upper bound on
    /// the combined instantaneous peak).
    pub peak_resident_bytes: u64,
    /// Blocks read into the streamed-`W` plane's cache.
    pub w_loads: u64,
    /// Blocks evicted from the streamed-`W` plane (never dirty).
    pub w_evictions: u64,
    /// Entries gathered through entry-granular leases
    /// ([`super::TileStore::with_entries`]) — the active-set I/O
    /// footprint, as opposed to whole-tile gathers.
    pub entry_loads: u64,
    /// Tile-footprint blocks an entry-granular lease did **not** have to
    /// touch (whole-tile footprint blocks minus blocks intersecting the
    /// requested entries) — the I/O the lease avoided.
    pub blocks_skipped: u64,
    /// Transient block-I/O failures healed by the bounded retry loop
    /// (both planes) — nonzero means the store survived real faults.
    pub retries: u64,
    /// Protocol round-trips a sharded store issued to its workers
    /// (reads, writes, stamps, barriers — every request frame).
    pub shard_requests: u64,
    /// Payload bytes a sharded store received from its workers (gathered
    /// `x`/`winv` entries, fingerprints, acks).
    pub shard_bytes_in: u64,
    /// Payload bytes a sharded store sent to its workers (scatter
    /// write-backs, requests, init slices).
    pub shard_bytes_out: u64,
    /// Microseconds the coordinator spent blocked in end-of-pass barrier
    /// / heartbeat exchanges with its shard workers.
    pub barrier_wait_us: u64,
}

struct CachedBlock {
    data: Vec<f64>,
    tick: u64,
    dirty: bool,
}

struct Cache {
    file: File,
    blocks: Vec<Option<CachedBlock>>,
    tick: u64,
    resident_entries: usize,
    budget_entries: usize,
    /// Header stamp: (solver pass, store fingerprint) at the last
    /// `flush_and_stamp` (or as read at `open`).
    stamp: (u64, u64),
    stats: StoreStats,
    /// Resident mirror of the on-disk block-checksum table: every write
    /// updates it, every read is verified against it — a flipped bit in
    /// a block read is caught at the block, not at the next `open`.
    sums: Vec<u64>,
    /// Plane name for diagnostics (`"x"` / `"w"`).
    plane: &'static str,
    tuning: StoreTuning,
    /// Healed transient failures since the last drain (bounded; the
    /// count in `stats.retries` is exact even if notes are dropped).
    retry_notes: Vec<RetryNote>,
}

/// Cap on buffered [`RetryNote`]s per plane between drains, so a
/// fault-heavy pass cannot grow memory without bound.
const MAX_RETRY_NOTES: usize = 1024;

impl Cache {
    /// Make block `idx` resident (LRU-touching it) and return nothing;
    /// the caller re-borrows `self.blocks[idx]`.
    fn load_block(&mut self, lay: &BlockLayout, idx: usize) -> Result<(), StoreError> {
        self.tick += 1;
        let tick = self.tick;
        if let Some(b) = self.blocks[idx].as_mut() {
            b.tick = tick;
            return Ok(());
        }
        let data = self.fetch_block(lay, idx)?;
        self.resident_entries += data.len();
        self.stats.loads += 1;
        let bytes = (self.resident_entries * 8) as u64;
        if bytes > self.stats.peak_resident_bytes {
            self.stats.peak_resident_bytes = bytes;
        }
        self.blocks[idx] = Some(CachedBlock { data, tick, dirty: false });
        self.evict_to_budget(lay, idx)
    }

    /// Read and checksum-verify block `idx` (without caching it),
    /// retrying transient failures with exponential backoff.
    fn fetch_block(&mut self, lay: &BlockLayout, idx: usize) -> Result<Vec<f64>, StoreError> {
        let mut attempt = 0u32;
        loop {
            match self.try_fetch_block(lay, idx) {
                Ok(data) => return Ok(data),
                Err(e) if retryable(&e) && attempt < self.tuning.retries => {
                    attempt += 1;
                    self.note_retry("read", idx, attempt, &e);
                    backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// One un-retried read attempt: fault-plan hooks, the raw read, and
    /// checksum verification against the resident table.
    fn try_fetch_block(&mut self, lay: &BlockLayout, idx: usize) -> Result<Vec<f64>, StoreError> {
        let mut data = match &self.tuning.faults {
            Some(plan) => {
                let op = plan.next_op();
                plan.pace(op);
                if let Some(e) = plan.read_error(op) {
                    return Err(e.into());
                }
                let mut data = read_block(&mut self.file, lay, idx)?;
                plan.corrupt_read(op, &mut data);
                data
            }
            None => read_block(&mut self.file, lay, idx)?,
        };
        let want = self.sums[idx];
        if fnv_f64s(&data) != want {
            data.clear();
            return Err(corrupt(format!(
                "checksum mismatch reading block {idx} of the {} plane",
                self.plane
            )));
        }
        Ok(data)
    }

    /// Write block `idx` back (re-stamping its checksum-table entry and
    /// the resident mirror), retrying transient failures.
    fn put_block(&mut self, lay: &BlockLayout, idx: usize, data: &[f64]) -> Result<(), StoreError> {
        let mut attempt = 0u32;
        loop {
            match self.try_put_block(lay, idx, data) {
                Ok(()) => return Ok(()),
                Err(e) if retryable(&e) && attempt < self.tuning.retries => {
                    attempt += 1;
                    self.note_retry("write", idx, attempt, &e);
                    backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    fn try_put_block(
        &mut self,
        lay: &BlockLayout,
        idx: usize,
        data: &[f64],
    ) -> Result<(), StoreError> {
        if let Some(plan) = &self.tuning.faults {
            let op = plan.next_op();
            plan.pace(op);
            if let Some(e) = plan.write_error(op) {
                return Err(e.into());
            }
        }
        let sum = write_block(&mut self.file, lay, idx, data)?;
        self.sums[idx] = sum;
        Ok(())
    }

    fn note_retry(&mut self, op: &'static str, block: usize, attempt: u32, e: &StoreError) {
        self.stats.retries += 1;
        if self.retry_notes.len() < MAX_RETRY_NOTES {
            self.retry_notes.push(RetryNote {
                plane: self.plane,
                op,
                block,
                attempt,
                error: e.to_string(),
            });
        }
    }

    /// Evict least-recently-used blocks (never `keep`) until the budget
    /// holds, writing dirty victims back to the file.
    fn evict_to_budget(&mut self, lay: &BlockLayout, keep: usize) -> Result<(), StoreError> {
        while self.resident_entries > self.budget_entries {
            let mut victim: Option<(usize, u64)> = None;
            for (i, slot) in self.blocks.iter().enumerate() {
                if i == keep {
                    continue;
                }
                if let Some(b) = slot {
                    match victim {
                        Some((_, t)) if b.tick >= t => {}
                        _ => victim = Some((i, b.tick)),
                    }
                }
            }
            let Some((vi, _)) = victim else { break };
            let b = self.blocks[vi].take().expect("victim is resident");
            self.resident_entries -= b.data.len();
            self.stats.evictions += 1;
            if b.dirty {
                self.put_block(lay, vi, &b.data)?;
                self.stats.writebacks += 1;
            }
        }
        Ok(())
    }

    /// Write every dirty block back to the file (blocks stay resident).
    fn flush_dirty(&mut self, lay: &BlockLayout) -> Result<(), StoreError> {
        for idx in 0..self.blocks.len() {
            let dirty = self.blocks[idx].as_ref().is_some_and(|b| b.dirty);
            if dirty {
                let data = {
                    let b = self.blocks[idx].as_mut().expect("checked resident");
                    b.dirty = false;
                    std::mem::take(&mut b.data)
                };
                let res = self.put_block(lay, idx, &data);
                self.blocks[idx].as_mut().expect("still resident").data = data;
                res?;
                self.stats.writebacks += 1;
            }
        }
        self.file.flush()?;
        Ok(())
    }
}

/// Exponential backoff before retry `attempt` (1-based): 0.5 ms, 1 ms,
/// 2 ms, ... capped at ~64 ms.
fn backoff(attempt: u32) {
    std::thread::sleep(std::time::Duration::from_micros(250u64 << attempt.min(8)));
}

/// Allocation-free FNV-1a over a block's f64s — bit-identical to
/// `fnv1a64(&f64s_to_bytes(data))`, which is what the on-disk checksum
/// table stores.
fn fnv_f64s(data: &[f64]) -> u64 {
    let mut h = Fnv1a::new();
    for &v in data {
        h.update(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

/// File-backed tile store (see the [module docs](self) for the format).
pub struct DiskStore {
    layout: Arc<BlockLayout>,
    cache: Arc<Mutex<Cache>>,
    /// Read-only block cache over the sibling `w` spill file streaming
    /// the packed inverse weights. It shares the `X` plane's layout, so
    /// block indices and in-block offsets coincide and the gathered
    /// `winv` arena mirrors the `x` arena exactly.
    wcache: Arc<Mutex<Cache>>,
    /// Global packed column offsets (lease addressing and range walks).
    col_starts: Vec<usize>,
    path: PathBuf,
    w_path: PathBuf,
    prefetch_tx: Option<Mutex<mpsc::Sender<PrefetchMsg>>>,
    prefetch_join: Option<std::thread::JoinHandle<()>>,
    /// First-error latch; see the module docs' failure model.
    health: StoreHealth,
    /// Held for the store's lifetime; removed on drop.
    _lock: StoreLock,
}

enum PrefetchMsg {
    Tile(Tile),
    Stop,
}

impl DiskStore {
    /// Create a fresh store at `path` (parent directories are created),
    /// dimension `n`, block side `block`, cache budget `budget_bytes`,
    /// initialized entry by entry from `src(c, r)` (`c < r`). `winv`
    /// must hold the `n(n-1)/2` packed inverse weights.
    pub fn create(
        path: &Path,
        n: usize,
        block: usize,
        budget_bytes: usize,
        winv: Vec<f64>,
        src: &mut dyn FnMut(usize, usize) -> f64,
    ) -> Result<DiskStore, StoreError> {
        DiskStore::create_with(path, n, block, budget_bytes, winv, src, StoreTuning::default())
    }

    /// [`DiskStore::create`] with explicit robustness tuning (fault plan
    /// and retry budget).
    pub fn create_with(
        path: &Path,
        n: usize,
        block: usize,
        budget_bytes: usize,
        winv: Vec<f64>,
        src: &mut dyn FnMut(usize, usize) -> f64,
        tuning: StoreTuning,
    ) -> Result<DiskStore, StoreError> {
        if winv.len() != n_pairs(n) {
            return Err(StoreError::Mismatch(format!(
                "winv has {} entries, expected {}",
                winv.len(),
                n_pairs(n)
            )));
        }
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let lock = StoreLock::acquire(path)?;
        let layout = BlockLayout::new(n, block.max(1));
        let (file, sums) = write_store_file(path, &layout, src)?;
        let col_starts = packed_col_starts(n);
        let w_path = w_sibling(path);
        let cs = col_starts.clone();
        let (wfile, wsums) =
            write_store_file(&w_path, &layout, &mut |c, r| winv[cs[c] + (r - c - 1)])?;
        Ok(DiskStore::assemble(
            layout,
            file,
            wfile,
            budget_bytes,
            (0, 0),
            col_starts,
            path,
            w_path,
            sums,
            wsums,
            tuning,
            lock,
        ))
    }

    /// Open an existing store, validating the header, the exact file
    /// size, and every block checksum. `winv` must match the problem's
    /// `n(n-1)/2` packed inverse weights.
    pub fn open(
        path: &Path,
        budget_bytes: usize,
        winv: Vec<f64>,
    ) -> Result<DiskStore, StoreError> {
        DiskStore::open_with(path, budget_bytes, winv, StoreTuning::default())
    }

    /// [`DiskStore::open`] with explicit robustness tuning (fault plan
    /// and retry budget).
    pub fn open_with(
        path: &Path,
        budget_bytes: usize,
        winv: Vec<f64>,
        tuning: StoreTuning,
    ) -> Result<DiskStore, StoreError> {
        let lock = StoreLock::acquire(path)?;
        let mut file = OpenOptions::new().read(true).write(true).open(path)?;
        let mut header = [0u8; HEADER_LEN as usize];
        file.read_exact(&mut header).map_err(|_| corrupt("truncated header"))?;
        if header[..8] != STORE_MAGIC {
            return Err(StoreError::BadMagic);
        }
        let version = u32::from_le_bytes(header[8..12].try_into().expect("4 bytes"));
        if version != STORE_VERSION {
            return Err(StoreError::UnsupportedVersion(version));
        }
        let stored_sum = u64::from_le_bytes(header[56..64].try_into().expect("8 bytes"));
        if fnv1a64(&header[..56]) != stored_sum {
            return Err(corrupt("header checksum mismatch"));
        }
        let n = u64::from_le_bytes(header[16..24].try_into().expect("8 bytes"));
        let block = u64::from_le_bytes(header[24..32].try_into().expect("8 bytes"));
        let entries = u64::from_le_bytes(header[32..40].try_into().expect("8 bytes"));
        let pass = u64::from_le_bytes(header[40..48].try_into().expect("8 bytes"));
        let x_fnv = u64::from_le_bytes(header[48..56].try_into().expect("8 bytes"));
        if n < 1 || n > 1 << 20 || block < 1 {
            return Err(corrupt(format!("implausible geometry n={n} block={block}")));
        }
        let (n, block) = (n as usize, block as usize);
        if winv.len() != n_pairs(n) {
            return Err(StoreError::Mismatch(format!(
                "winv has {} entries, store has n = {n}",
                winv.len()
            )));
        }
        let layout = BlockLayout::new(n, block);
        if entries != layout.total_entries() {
            return Err(corrupt(format!(
                "entry count {entries} does not match n = {n} (expected {})",
                layout.total_entries()
            )));
        }
        let n_blocks = layout.n_blocks();
        let expect_len = data_start(&layout) + entries * 8;
        let actual_len = file.metadata()?.len();
        if actual_len != expect_len {
            return Err(corrupt(format!(
                "file is {actual_len} bytes, expected {expect_len} (truncated or padded)"
            )));
        }
        // Read the checksum table (kept resident as the read-verify
        // mirror), then verify every block.
        let mut table = vec![0u8; n_blocks * 8];
        file.read_exact(&mut table).map_err(|_| corrupt("truncated checksum table"))?;
        let sums: Vec<u64> = table
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")))
            .collect();
        for (idx, &want) in sums.iter().enumerate() {
            let len = layout.block_len(idx);
            let mut bytes = vec![0u8; len * 8];
            file.read_exact(&mut bytes)
                .map_err(|_| corrupt(format!("truncated data for block {idx}")))?;
            if fnv1a64(&bytes) != want {
                return Err(corrupt(format!("checksum mismatch in block {idx}")));
            }
        }
        // The W spill is derived data: recreate it fresh from the
        // caller's weights rather than trusting a leftover file.
        let col_starts = packed_col_starts(n);
        let w_path = w_sibling(path);
        let cs = col_starts.clone();
        let (wfile, wsums) =
            write_store_file(&w_path, &layout, &mut |c, r| winv[cs[c] + (r - c - 1)])?;
        Ok(DiskStore::assemble(
            layout,
            file,
            wfile,
            budget_bytes,
            (pass, x_fnv),
            col_starts,
            path,
            w_path,
            sums,
            wsums,
            tuning,
            lock,
        ))
    }

    #[allow(clippy::too_many_arguments)]
    fn assemble(
        layout: BlockLayout,
        file: File,
        wfile: File,
        budget_bytes: usize,
        stamp: (u64, u64),
        col_starts: Vec<usize>,
        path: &Path,
        w_path: PathBuf,
        sums: Vec<u64>,
        wsums: Vec<u64>,
        tuning: StoreTuning,
        lock: StoreLock,
    ) -> DiskStore {
        let n_blocks = layout.n_blocks();
        // The byte budget is split evenly between the X and W planes.
        let plane_budget = (budget_bytes / 2 / 8).max(1);
        let mk_cache = |file: File, stamp: (u64, u64), sums: Vec<u64>, plane: &'static str| Cache {
            file,
            blocks: (0..n_blocks).map(|_| None).collect(),
            tick: 0,
            resident_entries: 0,
            budget_entries: plane_budget,
            stamp,
            stats: StoreStats::default(),
            sums,
            plane,
            tuning: tuning.clone(),
            retry_notes: Vec::new(),
        };
        let layout = Arc::new(layout);
        let cache = Arc::new(Mutex::new(mk_cache(file, stamp, sums, "x")));
        let wcache = Arc::new(Mutex::new(mk_cache(wfile, (0, 0), wsums, "w")));
        let (tx, rx) = mpsc::channel::<PrefetchMsg>();
        let join = {
            let layout = Arc::clone(&layout);
            let cache = Arc::clone(&cache);
            let wcache = Arc::clone(&wcache);
            std::thread::spawn(move || prefetch_loop(&layout, &cache, &wcache, &rx))
        };
        DiskStore {
            layout,
            cache,
            wcache,
            col_starts,
            path: path.to_path_buf(),
            w_path,
            prefetch_tx: Some(Mutex::new(tx)),
            prefetch_join: Some(join),
            health: StoreHealth::default(),
            _lock: lock,
        }
    }

    /// Path of the backing file.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Path of the streamed-`W` sibling spill file (derived data,
    /// recreated on every create/open and removed on drop).
    pub fn w_spill_path(&self) -> &Path {
        &self.w_path
    }

    /// Block side length of the on-disk layout.
    pub fn block(&self) -> usize {
        self.layout.block()
    }

    /// Cache counters so far, combined over the `X` and streamed-`W`
    /// planes (see [`StoreStats`] for which field counts which plane).
    pub fn stats(&self) -> StoreStats {
        let x = self.lock().stats;
        let w = self.wlock().stats;
        StoreStats {
            loads: x.loads,
            evictions: x.evictions,
            writebacks: x.writebacks,
            prefetched: x.prefetched + w.prefetched,
            peak_resident_bytes: x.peak_resident_bytes + w.peak_resident_bytes,
            w_loads: w.loads,
            w_evictions: w.evictions,
            entry_loads: x.entry_loads,
            blocks_skipped: x.blocks_skipped,
            retries: x.retries + w.retries,
            // The socket-transport counters belong to the shard store.
            ..StoreStats::default()
        }
    }

    /// Currently resident cache bytes (both planes).
    pub fn resident_bytes(&self) -> usize {
        (self.lock().resident_entries + self.wlock().resident_entries) * 8
    }

    /// The `(pass, x_fnv)` header stamp of the last
    /// [`DiskStore::flush_and_stamp`] (or as read at open).
    pub fn stamp(&self) -> (u64, u64) {
        self.lock().stamp
    }

    /// Write all dirty blocks back to the file.
    pub fn flush(&self) -> Result<(), StoreError> {
        let mut cache = self.lock();
        cache.flush_dirty(&self.layout)?;
        Ok(())
    }

    /// Flush, fingerprint the store, and stamp the header with
    /// `(pass, fingerprint)`. Returns the fingerprint. This is the
    /// consistency anchor for external-x checkpoints: a resume verifies
    /// the store still matches the checkpoint's stamp exactly.
    ///
    /// The fingerprint hashes the **block-checksum table**, which every
    /// block write maintains incrementally — so stamping costs
    /// `O(n_blocks)`, not an `O(n²)` data scan, per checkpoint. The
    /// table↔data coupling itself is verified by the full read
    /// [`DiskStore::open`] performs once on the (rare) resume path.
    pub fn flush_and_stamp(&self, pass: u64) -> Result<u64, StoreError> {
        let mut cache = self.lock();
        cache.flush_dirty(&self.layout)?;
        // The resident `sums` mirror equals the on-disk table after a
        // flush, so the fingerprint needs no file re-read (which would
        // also re-enter the fault plan for a pure bookkeeping step).
        let x_fnv = fingerprint_of(&cache.sums);
        cache.file.seek(SeekFrom::Start(0))?;
        cache.file.write_all(&header_bytes(&self.layout, pass, x_fnv))?;
        cache.file.flush()?;
        cache.stamp = (pass, x_fnv);
        Ok(x_fnv)
    }

    /// Recompute the store fingerprint (the block-checksum-table hash)
    /// after flushing dirty blocks — what a resume compares against the
    /// checkpoint's stamp.
    pub fn data_fingerprint(&self) -> Result<u64, StoreError> {
        let mut cache = self.lock();
        cache.flush_dirty(&self.layout)?;
        Ok(fingerprint_of(&cache.sums))
    }

    /// Copy the (flushed, stamped) store file to `dest` atomically
    /// (stage to `<dest>.tmp`, then rename), holding the `X`-plane lock
    /// so no write-back interleaves with the copy. Drivers snapshot to
    /// [`snapshot_sibling`] right after each checkpoint's
    /// `flush_and_stamp`, which is what makes an external-`x` checkpoint
    /// recoverable after the live store drifts past it or dies mid-pass.
    pub fn snapshot_to(&self, dest: &Path) -> Result<(), StoreError> {
        let _guard = self.lock();
        let tmp = sibling(dest, ".tmp");
        std::fs::copy(&self.path, &tmp)?;
        std::fs::rename(&tmp, dest)?;
        Ok(())
    }

    /// [`DiskStore::snapshot_to`] the store's default snapshot path
    /// ([`snapshot_sibling`] of the store file).
    pub fn snapshot(&self) -> Result<(), StoreError> {
        self.snapshot_to(&snapshot_sibling(&self.path))
    }

    /// First-error latch poll — the per-pass health check drivers run
    /// between phases. Returns the first latched error (taking it; later
    /// polls report a generic already-failed error) or `Ok` while the
    /// store is healthy.
    pub fn health(&self) -> Result<(), StoreError> {
        if !self.is_failed() {
            return Ok(());
        }
        let mut first = self.health.first.lock().unwrap_or_else(|p| p.into_inner());
        Err(first
            .take()
            .unwrap_or_else(|| corrupt("tile store already failed earlier in this solve")))
    }

    /// Whether a permanent failure has been latched (leases are no-ops).
    pub fn is_failed(&self) -> bool {
        self.health.failed.load(Ordering::Acquire)
    }

    /// Park a lease-path failure in the latch (first error wins).
    fn latch(&self, e: StoreError) {
        let mut first = self.health.first.lock().unwrap_or_else(|p| p.into_inner());
        if first.is_none() {
            *first = Some(e);
        }
        self.health.failed.store(true, Ordering::Release);
    }

    /// Take the retry notes buffered since the last drain (both planes).
    /// Drivers drain once per pass and emit them as one `store_retry`
    /// telemetry event, so the buffer stays small.
    pub fn drain_retries(&self) -> Vec<RetryNote> {
        let mut notes = std::mem::take(&mut self.lock().retry_notes);
        notes.append(&mut self.wlock().retry_notes);
        notes
    }

    /// Materialize the full packed array in global column-major order
    /// (for final solution extraction and tests; resident `O(n²)`).
    pub fn read_full(&self) -> Result<Vec<f64>, StoreError> {
        let mut out = vec![0.0f64; n_pairs(self.layout.n())];
        let mut guard = self.lock();
        let cache = &mut *guard;
        let lay = self.layout.as_ref();
        let mut coords = Vec::with_capacity(lay.n_blocks());
        lay.for_each_block(|cb, rb, idx| coords.push((cb, rb, idx)));
        for (cb, rb, idx) in coords {
            let cached: Option<Vec<f64>> = cache.blocks[idx].as_ref().map(|b| b.data.clone());
            let data = match cached {
                Some(d) => d,
                None => cache.fetch_block(lay, idx)?,
            };
            let mut pos = 0usize;
            lay.for_each_block_col(cb, rb, |c, lo, hi, _base| {
                let g = self.col_starts[c] + (lo - c - 1);
                out[g..g + (hi - lo)].copy_from_slice(&data[pos..pos + (hi - lo)]);
                pos += hi - lo;
            });
        }
        Ok(out)
    }

    /// Lock a cache plane, recovering from poison: the caches hold plain
    /// data (no invariants a panicking copy loop can break mid-flight
    /// that the checksum table won't catch), and cascading one worker's
    /// panic into every other worker is exactly what the failure model
    /// forbids.
    fn lock(&self) -> MutexGuard<'_, Cache> {
        self.cache.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn wlock(&self) -> MutexGuard<'_, Cache> {
        self.wcache.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Stage `tile`'s footprint into `scratch` (arena + address table +
    /// segment list), loading blocks through the caches under their
    /// locks — one plane at a time, never nested.
    fn gather_tile(&self, tile: &Tile, scratch: &mut TileScratch) -> Result<(), StoreError> {
        let lay = &self.layout;
        let n = lay.n();
        if scratch.cols.len() < n {
            scratch.cols.resize(n, 0);
        }
        scratch.x.clear();
        scratch.winv.clear();
        scratch.segs.clear();
        {
            let mut cache = self.lock();
            let scratch = &mut *scratch;
            let mut res = Ok(());
            for_each_tile_col(tile, |c, lo, hi| {
                if res.is_err() {
                    return;
                }
                let start = scratch.x.len();
                // Non-negative by construction: the first footprint column
                // starts at offset 0 with `lo == c + 1`, and every later
                // column's start exceeds its `lo - c - 1` shift (the first
                // column's span alone is longer).
                debug_assert!(start >= lo - c - 1, "arena base underflow for {tile:?}");
                scratch.cols[c] = start - (lo - c - 1);
                scratch.segs.push(Seg { col: c, row_lo: lo, row_hi: hi, start });
                res = copy_col_span(&mut cache, lay, c, lo, hi, &mut scratch.x);
            });
            res?;
        }
        // Second plane: replay the recorded segments against the W
        // spill. Same layout, same append order -> the winv arena
        // mirrors the x arena offset for offset.
        {
            let mut wc = self.wlock();
            let scratch = &mut *scratch;
            for seg in &scratch.segs {
                copy_col_span(&mut wc, lay, seg.col, seg.row_lo, seg.row_hi, &mut scratch.winv)?;
            }
        }
        Ok(())
    }
}

/// Append rows `[lo, hi)` of column `c` to `out`, loading the covering
/// blocks through `cache` (the caller holds the plane's lock).
fn copy_col_span(
    cache: &mut Cache,
    lay: &BlockLayout,
    c: usize,
    lo: usize,
    hi: usize,
    out: &mut Vec<f64>,
) -> Result<(), StoreError> {
    let n = lay.n();
    let cb = lay.block_of(c);
    let mut r = lo;
    while r < hi {
        let rb = lay.block_of(r);
        let take_hi = hi.min(((rb + 1) * lay.block()).min(n));
        let idx = lay.block_index(cb, rb);
        cache.load_block(lay, idx)?;
        let (base, blo) = lay.block_col_base(cb, rb, c);
        let data = &cache.blocks[idx].as_ref().expect("just loaded").data;
        out.extend_from_slice(&data[base + (r - blo)..base + (take_hi - blo)]);
        r = take_hi;
    }
    Ok(())
}

/// Copy rows `[lo, hi)` of column `c` into the pre-sized `out`, loading
/// the covering blocks through `cache` (the caller holds the plane's
/// lock). Every loaded-or-resident block index is recorded once in
/// `touched` (the entry lease's block-skip accounting).
fn copy_col_span_into(
    cache: &mut Cache,
    lay: &BlockLayout,
    c: usize,
    lo: usize,
    hi: usize,
    out: &mut [f64],
    touched: &mut Vec<usize>,
) -> Result<(), StoreError> {
    debug_assert_eq!(out.len(), hi - lo);
    let n = lay.n();
    let cb = lay.block_of(c);
    let mut r = lo;
    let mut pos = 0usize;
    while r < hi {
        let rb = lay.block_of(r);
        let take_hi = hi.min(((rb + 1) * lay.block()).min(n));
        let idx = lay.block_index(cb, rb);
        if !touched.contains(&idx) {
            touched.push(idx);
        }
        cache.load_block(lay, idx)?;
        let (base, blo) = lay.block_col_base(cb, rb, c);
        let data = &cache.blocks[idx].as_ref().expect("just loaded").data;
        out[pos..pos + (take_hi - r)]
            .copy_from_slice(&data[base + (r - blo)..base + (take_hi - blo)]);
        pos += take_hi - r;
        r = take_hi;
    }
    Ok(())
}

impl Drop for DiskStore {
    fn drop(&mut self) {
        if let Some(tx) = self.prefetch_tx.take() {
            let _ = tx.lock().unwrap_or_else(|p| p.into_inner()).send(PrefetchMsg::Stop);
        }
        if let Some(join) = self.prefetch_join.take() {
            let _ = join.join();
        }
        // Best-effort durability for un-flushed writes.
        let _ = self.lock().flush_dirty(&self.layout);
        // The W spill is derived data, recreated on every create/open —
        // don't leave it behind.
        let _ = std::fs::remove_file(&self.w_path);
    }
}

impl TileStore for DiskStore {
    fn n(&self) -> usize {
        self.layout.n()
    }

    fn n_pairs(&self) -> usize {
        self.layout.total_entries() as usize
    }

    unsafe fn with_tile(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        // A latched store parks every lease: waves are barrier-
        // synchronized, so the pass runs to its end on no-op leases and
        // the driver's per-pass `health()` poll unwinds the solve.
        if self.is_failed() {
            return;
        }
        let lay = &self.layout;
        let n = lay.n();
        // Gather: per-column segments of the tile footprint, copied from
        // the cached blocks under the lock.
        if let Err(e) = self.gather_tile(tile, scratch) {
            self.latch(e);
            return;
        }
        // Compute on the private arena — no lock held.
        {
            let view = SharedMut::new(scratch.x.as_mut_slice());
            f(&view, &scratch.cols, &scratch.winv);
        }
        // Scatter: write the whole footprint back (it equals the set of
        // pairs this tile may touch — disjoint from every concurrent
        // lease by the wave invariant, which `tiling` tests pin) and
        // mark the blocks dirty.
        let scatter = (|| -> Result<(), StoreError> {
            let mut cache = self.lock();
            for seg in &scratch.segs {
                let cb = lay.block_of(seg.col);
                let mut r = seg.row_lo;
                let mut pos = seg.start;
                while r < seg.row_hi {
                    let rb = lay.block_of(r);
                    let take_hi = seg.row_hi.min(((rb + 1) * lay.block()).min(n));
                    let idx = lay.block_index(cb, rb);
                    cache.load_block(lay, idx)?;
                    let (base, blo) = lay.block_col_base(cb, rb, seg.col);
                    let block = cache.blocks[idx].as_mut().expect("just loaded");
                    let dst = &mut block.data[base + (r - blo)..base + (take_hi - blo)];
                    dst.copy_from_slice(&scratch.x[pos..pos + (take_hi - r)]);
                    block.dirty = true;
                    pos += take_hi - r;
                    r = take_hi;
                }
            }
            Ok(())
        })();
        if let Err(e) = scatter {
            self.latch(e);
        }
    }

    unsafe fn with_tile_read(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        if self.is_failed() {
            return;
        }
        // Gather only — no scatter, no dirty marks: a read-only scan
        // must not turn the whole store dirty.
        if let Err(e) = self.gather_tile(tile, scratch) {
            self.latch(e);
            return;
        }
        let view = SharedMut::new(scratch.x.as_mut_slice());
        f(&view, &scratch.cols, &scratch.winv);
    }

    unsafe fn with_entries(
        &self,
        tile: &Tile,
        each_pair: &mut dyn FnMut(&mut dyn FnMut(usize, usize)),
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        if self.is_failed() {
            return;
        }
        let lay = &self.layout;
        let n = lay.n();
        if scratch.cols.len() < n {
            scratch.cols.resize(n, 0);
        }
        scratch.x.clear();
        scratch.winv.clear();
        scratch.segs.clear();
        scratch.pairs.clear();
        {
            let pairs = &mut scratch.pairs;
            each_pair(&mut |c, r| {
                debug_assert!(c < r && r < n, "entry lease pair ({c}, {r}) out of range");
                pairs.push((c as u32, r as u32));
            });
        }
        scratch.pairs.sort_unstable();
        scratch.pairs.dedup();
        // Footprint-shaped arena: the same `cols[]` address table and
        // arena length `with_tile` would build (so the kernel's
        // `cols[c] + (r - c - 1)` addressing is untouched), but
        // zero-filled — only the requested entries are gathered into it,
        // and only blocks intersecting them are faulted. Also count the
        // footprint's block set, so we can report how many blocks the
        // entry lease skipped.
        let footprint_blocks;
        {
            let mut arena_len = 0usize;
            let mut foot_idx: Vec<usize> = Vec::new();
            let cols = &mut scratch.cols;
            for_each_tile_col(tile, |c, lo, hi| {
                // Non-negative by construction — see `gather_tile`.
                debug_assert!(arena_len >= lo - c - 1, "arena base underflow for {tile:?}");
                cols[c] = arena_len - (lo - c - 1);
                let cb = lay.block_of(c);
                let mut r = lo;
                while r < hi {
                    let rb = lay.block_of(r);
                    let take_hi = hi.min(((rb + 1) * lay.block()).min(n));
                    let idx = lay.block_index(cb, rb);
                    if !foot_idx.contains(&idx) {
                        foot_idx.push(idx);
                    }
                    r = take_hi;
                }
                arena_len += hi - lo;
            });
            footprint_blocks = foot_idx.len() as u64;
            scratch.x.resize(arena_len, 0.0);
            scratch.winv.resize(arena_len, 0.0);
        }
        let TileScratch { x, winv, cols, segs, pairs } = &mut *scratch;
        // Coalesce the sorted pairs into per-column runs of consecutive
        // rows — each run is one contiguous arena segment, gathered and
        // scattered like a (shorter) `gather_tile` segment.
        let mut i = 0usize;
        while i < pairs.len() {
            let c = pairs[i].0;
            let mut j = i + 1;
            while j < pairs.len() && pairs[j].0 == c && pairs[j].1 == pairs[j - 1].1 + 1 {
                j += 1;
            }
            let cc = c as usize;
            let (lo, hi) = (pairs[i].1 as usize, pairs[j - 1].1 as usize + 1);
            segs.push(Seg { col: cc, row_lo: lo, row_hi: hi, start: cols[cc] + (lo - cc - 1) });
            i = j;
        }
        // Gather only the blocks the requested entries live in, one plane
        // locked at a time; account the entry-lease counters on the X
        // plane.
        let gather = (|| -> Result<(), StoreError> {
            {
                let mut cache = self.lock();
                let mut touched: Vec<usize> = Vec::new();
                for seg in segs.iter() {
                    copy_col_span_into(
                        &mut cache,
                        lay,
                        seg.col,
                        seg.row_lo,
                        seg.row_hi,
                        &mut x[seg.start..seg.start + (seg.row_hi - seg.row_lo)],
                        &mut touched,
                    )?;
                }
                cache.stats.entry_loads += pairs.len() as u64;
                cache.stats.blocks_skipped +=
                    footprint_blocks.saturating_sub(touched.len() as u64);
            }
            let mut wc = self.wlock();
            let mut wtouched: Vec<usize> = Vec::new();
            for seg in segs.iter() {
                copy_col_span_into(
                    &mut wc,
                    lay,
                    seg.col,
                    seg.row_lo,
                    seg.row_hi,
                    &mut winv[seg.start..seg.start + (seg.row_hi - seg.row_lo)],
                    &mut wtouched,
                )?;
            }
            Ok(())
        })();
        if let Err(e) = gather {
            self.latch(e);
            return;
        }
        // Compute on the private arena — no lock held.
        {
            let view = SharedMut::new(x.as_mut_slice());
            f(&view, cols, winv);
        }
        // Scatter only the requested segments back, dirtying only their
        // blocks (same block walk as the `with_tile` scatter).
        let scatter = (|| -> Result<(), StoreError> {
            let mut cache = self.lock();
            for seg in segs.iter() {
                let cb = lay.block_of(seg.col);
                let mut r = seg.row_lo;
                let mut pos = seg.start;
                while r < seg.row_hi {
                    let rb = lay.block_of(r);
                    let take_hi = seg.row_hi.min(((rb + 1) * lay.block()).min(n));
                    let idx = lay.block_index(cb, rb);
                    cache.load_block(lay, idx)?;
                    let (base, blo) = lay.block_col_base(cb, rb, seg.col);
                    let block = cache.blocks[idx].as_mut().expect("just loaded");
                    block.data[base + (r - blo)..base + (take_hi - blo)]
                        .copy_from_slice(&x[pos..pos + (take_hi - r)]);
                    block.dirty = true;
                    pos += take_hi - r;
                    r = take_hi;
                }
            }
            Ok(())
        })();
        if let Err(e) = scatter {
            self.latch(e);
        }
    }

    unsafe fn with_pair_range(
        &self,
        lo: usize,
        hi: usize,
        write: bool,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(usize, &mut [f64], &[f64]),
    ) {
        if lo >= hi || self.is_failed() {
            return;
        }
        let lay = &self.layout;
        let n = lay.n();
        debug_assert!(hi as u64 <= lay.total_entries());
        let walk = (|| -> Result<(), StoreError> {
            // Column containing `lo`: col_starts is strictly increasing
            // over the nonempty columns, so binary search lands on (or
            // just past) the owning column.
            let mut c = match self.col_starts.binary_search(&lo) {
                Ok(c) => c,
                Err(ins) => ins - 1,
            };
            let mut g = lo;
            while g < hi {
                let c_start = self.col_starts[c];
                let c_end = c_start + (n - 1 - c);
                debug_assert!(g >= c_start && g < c_end, "range walk lost its column");
                let seg_hi = c_end.min(hi);
                let cb = lay.block_of(c);
                let mut r = c + 1 + (g - c_start);
                let r_hi = c + 1 + (seg_hi - c_start);
                while r < r_hi {
                    let rb = lay.block_of(r);
                    let take_hi = r_hi.min(((rb + 1) * lay.block()).min(n));
                    let len = take_hi - r;
                    let idx = lay.block_index(cb, rb);
                    let (base, blo) = lay.block_col_base(cb, rb, c);
                    // Gather the piece — one plane locked at a time.
                    scratch.x.clear();
                    scratch.winv.clear();
                    {
                        let mut cache = self.lock();
                        cache.load_block(lay, idx)?;
                        let data = &cache.blocks[idx].as_ref().expect("just loaded").data;
                        scratch
                            .x
                            .extend_from_slice(&data[base + (r - blo)..base + (take_hi - blo)]);
                    }
                    {
                        let mut wc = self.wlock();
                        wc.load_block(lay, idx)?;
                        let data = &wc.blocks[idx].as_ref().expect("just loaded").data;
                        scratch
                            .winv
                            .extend_from_slice(&data[base + (r - blo)..base + (take_hi - blo)]);
                    }
                    // Compute on the private piece — no lock held.
                    f(g, &mut scratch.x, &scratch.winv);
                    if write {
                        // The block may have been (cleanly) evicted while
                        // the callback ran; reload and write the piece
                        // back.
                        let mut cache = self.lock();
                        cache.load_block(lay, idx)?;
                        let block = cache.blocks[idx].as_mut().expect("just loaded");
                        block.data[base + (r - blo)..base + (take_hi - blo)]
                            .copy_from_slice(&scratch.x);
                        block.dirty = true;
                    }
                    g += len;
                    r = take_hi;
                }
                c += 1;
            }
            Ok(())
        })();
        if let Err(e) = walk {
            self.latch(e);
        }
    }

    fn prefetch(&self, tile: &Tile) {
        if self.is_failed() {
            return;
        }
        if let Some(tx) = &self.prefetch_tx {
            let _ = tx
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .send(PrefetchMsg::Tile(*tile));
        }
    }
}

/// Background cache warmer: loads the blocks of hinted tiles into both
/// planes. Loads only — never writes entries — so it cannot change
/// results; I/O failures are ignored (the foreground gather will surface
/// them).
fn prefetch_loop(
    lay: &BlockLayout,
    cache: &Mutex<Cache>,
    wcache: &Mutex<Cache>,
    rx: &mpsc::Receiver<PrefetchMsg>,
) {
    while let Ok(PrefetchMsg::Tile(tile)) = rx.recv() {
        let mut blocks: Vec<usize> = Vec::new();
        for_each_tile_col(&tile, |c, lo, hi| {
            let cb = lay.block_of(c);
            let mut rb = lay.block_of(lo);
            while rb <= lay.block_of(hi - 1) {
                let idx = lay.block_index(cb, rb);
                if !blocks.contains(&idx) {
                    blocks.push(idx);
                }
                rb += 1;
            }
        });
        for idx in blocks {
            for plane in [cache, wcache] {
                // Lock per block so foreground gathers interleave freely.
                let Ok(mut guard) = plane.lock() else { return };
                let fresh = guard.blocks[idx].is_none();
                if guard.load_block(lay, idx).is_ok() && fresh {
                    guard.stats.prefetched += 1;
                }
            }
        }
    }
}

fn data_start(lay: &BlockLayout) -> u64 {
    HEADER_LEN + lay.n_blocks() as u64 * 8
}

/// `path` with `suffix` appended to the file name (appended, not a
/// replaced extension, so distinct stores never collide on a sibling).
pub(crate) fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(suffix);
    PathBuf::from(name)
}

/// Path of the streamed-`W` spill sibling of a store file.
fn w_sibling(path: &Path) -> PathBuf {
    sibling(path, ".w")
}

/// Path of a store file's recovery snapshot (written by
/// [`DiskStore::snapshot_to`] after each checkpoint): the store file
/// name plus `.ckpt`. A resume whose live store fails verification
/// promotes this snapshot back over the live file.
pub fn snapshot_sibling(path: &Path) -> PathBuf {
    sibling(path, ".ckpt")
}

/// Global packed column offsets for dimension `n` (column `c` starts at
/// `sum_{i<c} (n - 1 - i)`).
pub(crate) fn packed_col_starts(n: usize) -> Vec<usize> {
    let mut col_starts = Vec::with_capacity(n);
    let mut acc = 0usize;
    for i in 0..n {
        col_starts.push(acc);
        acc += n - 1 - i;
    }
    col_starts
}

/// Write a fresh store file at `path` (truncating any existing one):
/// header with a zero stamp, reserved checksum table, blocks streamed
/// from `src(c, r)` one buffer at a time (never materializing the full
/// matrix), then the filled-in table. Returns the open read-write handle
/// and the block checksums (the cache's resident read-verify mirror).
fn write_store_file(
    path: &Path,
    layout: &BlockLayout,
    src: &mut dyn FnMut(usize, usize) -> f64,
) -> Result<(File, Vec<u64>), StoreError> {
    let mut file =
        OpenOptions::new().read(true).write(true).create(true).truncate(true).open(path)?;
    file.write_all(&header_bytes(layout, 0, 0))?;
    let n_blocks = layout.n_blocks();
    file.write_all(&vec![0u8; n_blocks * 8])?;
    let mut coords = Vec::with_capacity(n_blocks);
    layout.for_each_block(|cb, rb, _idx| coords.push((cb, rb)));
    let mut sums = Vec::with_capacity(n_blocks);
    let mut buf: Vec<f64> = Vec::new();
    for &(cb, rb) in &coords {
        buf.clear();
        layout.for_each_block_col(cb, rb, |c, lo, hi, _base| {
            for r in lo..hi {
                buf.push(src(c, r));
            }
        });
        let bytes = f64s_to_bytes(&buf);
        sums.push(fnv1a64(&bytes));
        file.write_all(&bytes)?;
    }
    file.seek(SeekFrom::Start(HEADER_LEN))?;
    for sum in &sums {
        file.write_all(&sum.to_le_bytes())?;
    }
    file.flush()?;
    Ok((file, sums))
}

fn header_bytes(lay: &BlockLayout, pass: u64, x_fnv: u64) -> [u8; HEADER_LEN as usize] {
    let mut h = [0u8; HEADER_LEN as usize];
    h[..8].copy_from_slice(&STORE_MAGIC);
    h[8..12].copy_from_slice(&STORE_VERSION.to_le_bytes());
    h[16..24].copy_from_slice(&(lay.n() as u64).to_le_bytes());
    h[24..32].copy_from_slice(&(lay.block() as u64).to_le_bytes());
    h[32..40].copy_from_slice(&lay.total_entries().to_le_bytes());
    h[40..48].copy_from_slice(&pass.to_le_bytes());
    h[48..56].copy_from_slice(&x_fnv.to_le_bytes());
    let sum = fnv1a64(&h[..56]);
    h[56..64].copy_from_slice(&sum.to_le_bytes());
    h
}

fn block_file_offset(lay: &BlockLayout, idx: usize) -> u64 {
    data_start(lay) + lay.block_offset(idx) * 8
}

fn read_block(file: &mut File, lay: &BlockLayout, idx: usize) -> std::io::Result<Vec<f64>> {
    let len = lay.block_len(idx);
    let mut bytes = vec![0u8; len * 8];
    file.seek(SeekFrom::Start(block_file_offset(lay, idx)))?;
    file.read_exact(&mut bytes)?;
    Ok(bytes_to_f64s(&bytes))
}

/// Write a block's data and re-stamp its checksum table entry. Returns
/// the block checksum, which the caller mirrors into its resident table.
fn write_block(
    file: &mut File,
    lay: &BlockLayout,
    idx: usize,
    data: &[f64],
) -> std::io::Result<u64> {
    debug_assert_eq!(data.len(), lay.block_len(idx));
    let bytes = f64s_to_bytes(data);
    file.seek(SeekFrom::Start(block_file_offset(lay, idx)))?;
    file.write_all(&bytes)?;
    let sum = fnv1a64(&bytes);
    file.seek(SeekFrom::Start(HEADER_LEN + idx as u64 * 8))?;
    file.write_all(&sum.to_le_bytes())?;
    Ok(sum)
}

/// FNV-1a over the block checksums in block order — the store
/// fingerprint, bit-identical to hashing the on-disk checksum table
/// (which [`write_block`] keeps in lockstep with the resident mirror);
/// the table↔data coupling is what [`DiskStore::open`]'s full
/// verification pins down.
fn fingerprint_of(sums: &[u64]) -> u64 {
    let mut h = Fnv1a::new();
    for sum in sums {
        h.update(&sum.to_le_bytes());
    }
    h.finish()
}

pub(crate) fn f64s_to_bytes(data: &[f64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 8);
    for &v in data {
        out.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    out
}

pub(crate) fn bytes_to_f64s(bytes: &[u8]) -> Vec<f64> {
    bytes
        .chunks_exact(8)
        .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().expect("8 bytes"))))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::PackedSym;
    use crate::solver::schedule::Schedule;
    use crate::solver::tiling::for_each_triplet;
    use crate::util::rng::Rng;

    fn tmp_path(tag: &str) -> PathBuf {
        let pid = std::process::id();
        std::env::temp_dir().join(format!("metric_proj_store_{tag}_{pid}"))
    }

    fn make(tag: &str, n: usize, block: usize, budget: usize, seed: u64) -> (DiskStore, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let d = PackedSym::from_fn(n, |_, _| rng.f64_in(-3.0, 3.0));
        let winv = vec![1.0; d.len()];
        let path = tmp_path(tag);
        let src = d.clone();
        let store = DiskStore::create(&path, n, block, budget, winv, &mut |c, r| {
            src.get(c, r)
        })
        .expect("create");
        (store, d.as_slice().to_vec())
    }

    #[test]
    fn create_read_full_roundtrips() {
        for (n, b) in [(6usize, 2usize), (13, 3), (20, 7), (9, 40)] {
            let (store, want) = make(&format!("rt{n}_{b}"), n, b, 1 << 20, n as u64);
            assert_eq!(store.read_full().expect("read_full"), want, "n={n} b={b}");
            let path = store.path().to_path_buf();
            drop(store);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    #[allow(unused_unsafe)]
    fn leases_see_and_mutate_the_right_entries_under_churn() {
        // Tiny budget forces load/evict/write-back churn while a serial
        // walk mutates every pair through leases; the result must equal
        // the same walk over a flat array.
        let (n, b) = (17usize, 4usize);
        let (store, mut flat) = make("churn", n, b, 64 * 8, 7);
        let m = PackedSym::zeros(n);
        let schedule = Schedule::new(n, b);
        let mut scratch = TileScratch::default();
        for pass in 0..2 {
            for wave in schedule.waves() {
                for tile in wave {
                    // SAFETY: single thread owns every tile.
                    unsafe {
                        store.with_tile(tile, &mut scratch, &mut |x, cols, winv| {
                            for_each_triplet(tile, b, |i, j, k| {
                                for (a, bb) in [(i, j), (i, k), (j, k)] {
                                    let p = cols[a] + (bb - a - 1);
                                    // SAFETY: in-bounds lease addressing.
                                    assert_eq!(
                                        unsafe { x.get(p) },
                                        flat[m.idx(a, bb)],
                                        "pass={pass} pair ({a},{bb})"
                                    );
                                    assert_eq!(winv[p], 1.0);
                                }
                                let p = cols[i] + (j - i - 1);
                                // SAFETY: in-bounds, single thread.
                                unsafe {
                                    let v = x.get(p) * 0.5 + (i + j + k) as f64 * 0.001;
                                    x.set(p, v);
                                    flat[m.idx(i, j)] = v;
                                }
                            });
                        });
                    }
                }
            }
        }
        assert_eq!(store.read_full().expect("read_full"), flat);
        let stats = store.stats();
        assert!(stats.evictions > 0, "budget was too generous to exercise eviction");
        assert!(stats.writebacks > 0, "dirty blocks must be written back");
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn pair_range_streams_and_mutates_under_churn() {
        // Mutate every packed entry through ascending pair-range leases
        // with a budget that forces churn; compare against the same walk
        // over a flat array, and check the streamed W plane hands back
        // the weighted values exactly.
        let (n, b) = (19usize, 4usize);
        let mut rng = Rng::new(33);
        let d = PackedSym::from_fn(n, |_, _| rng.f64_in(-2.0, 2.0));
        let winv: Vec<f64> = (0..d.len()).map(|_| rng.f64_in(0.25, 4.0)).collect();
        let path = tmp_path("pair_range");
        let src = d.clone();
        let store =
            DiskStore::create(&path, n, b, 96 * 8, winv.clone(), &mut |c, r| src.get(c, r))
                .expect("create");
        let m = d.len();
        let mut flat: Vec<f64> = d.as_slice().to_vec();
        let mut scratch = TileScratch::default();
        // Three disjoint chunks, like the pair phase's chunk split.
        for (lo, hi) in [(0usize, m / 3), (m / 3, 2 * m / 3), (2 * m / 3, m)] {
            // SAFETY: single thread owns every range.
            unsafe {
                store.with_pair_range(lo, hi, true, &mut scratch, &mut |g, xs, wv| {
                    for (t, v) in xs.iter_mut().enumerate() {
                        let e = g + t;
                        assert_eq!(*v, flat[e], "entry {e} before write");
                        assert_eq!(wv[t], winv[e], "winv {e} must stream exactly");
                        *v = *v * 0.5 + wv[t];
                        flat[e] = flat[e] * 0.5 + winv[e];
                    }
                });
            }
        }
        assert_eq!(store.read_full().expect("read_full"), flat);
        let stats = store.stats();
        assert!(stats.w_loads > 0, "the W plane must stream");
        // Read-only ranges keep the store clean: fingerprint unchanged.
        let f1 = store.data_fingerprint().expect("fp");
        // SAFETY: single thread, read-only callback.
        unsafe {
            store.with_pair_range(0, m, false, &mut scratch, &mut |_g, _xs, _wv| {});
        }
        assert_eq!(store.data_fingerprint().expect("fp"), f1);
        let path = store.path().to_path_buf();
        let w_path = store.w_spill_path().to_path_buf();
        drop(store);
        assert!(!w_path.exists(), "drop must remove the W spill");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn tile_leases_stream_weighted_winv() {
        let (n, b) = (13usize, 3usize);
        let mut rng = Rng::new(44);
        let d = PackedSym::from_fn(n, |_, _| rng.f64_in(-1.0, 1.0));
        let winv: Vec<f64> = (0..d.len()).map(|_| rng.f64_in(0.5, 2.0)).collect();
        let path = tmp_path("wtile");
        let src = d.clone();
        let store =
            DiskStore::create(&path, n, b, 1 << 20, winv.clone(), &mut |c, r| src.get(c, r))
                .expect("create");
        let schedule = Schedule::new(n, b);
        let m = PackedSym::zeros(n);
        let mut scratch = TileScratch::default();
        for wave in schedule.waves() {
            for tile in wave {
                // SAFETY: single thread owns every tile; reads only.
                unsafe {
                    store.with_tile_read(tile, &mut scratch, &mut |x, cols, wv| {
                        for_each_triplet(tile, b, |i, j, k| {
                            for (a, bb) in [(i, j), (i, k), (j, k)] {
                                let p = cols[a] + (bb - a - 1);
                                // SAFETY: in-bounds lease addressing.
                                assert_eq!(unsafe { x.get(p) }, d.get(a, bb));
                                assert_eq!(wv[p], winv[m.idx(a, bb)]);
                            }
                        });
                    });
                }
            }
        }
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn open_validates_and_rejects_corruption() {
        let (store, want) = make("corrupt", 12, 3, 1 << 20, 3);
        let path = store.path().to_path_buf();
        store.flush_and_stamp(5).expect("stamp");
        drop(store);
        let winv = vec![1.0; want.len()];

        // Clean reopen works and carries the stamp.
        let reopened = DiskStore::open(&path, 1 << 20, winv.clone()).expect("reopen");
        assert_eq!(reopened.stamp().0, 5);
        assert_eq!(reopened.read_full().expect("read_full"), want);
        drop(reopened);

        let bytes = std::fs::read(&path).expect("read file");
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&path, &bad).expect("write");
        assert!(matches!(
            DiskStore::open(&path, 1 << 20, winv.clone()),
            Err(StoreError::BadMagic)
        ));
        // Unsupported version (header checksum re-stamped so the version
        // check is what fires).
        let mut bad = bytes.clone();
        bad[8..12].copy_from_slice(&9u32.to_le_bytes());
        let sum = fnv1a64(&bad[..56]);
        bad[56..64].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bad).expect("write");
        assert!(matches!(
            DiskStore::open(&path, 1 << 20, winv.clone()),
            Err(StoreError::UnsupportedVersion(9))
        ));
        // Header bitflip.
        let mut bad = bytes.clone();
        bad[17] ^= 0x10;
        std::fs::write(&path, &bad).expect("write");
        assert!(DiskStore::open(&path, 1 << 20, winv.clone()).is_err());
        // Data bitflip (caught by the block checksum).
        let mut bad = bytes.clone();
        let last = bad.len() - 3;
        bad[last] ^= 0x40;
        std::fs::write(&path, &bad).expect("write");
        assert!(matches!(
            DiskStore::open(&path, 1 << 20, winv.clone()),
            Err(StoreError::Corrupt(_))
        ));
        // Truncation at several lengths.
        for cut in [bytes.len() - 1, bytes.len() / 2, 40, 7] {
            std::fs::write(&path, &bytes[..cut]).expect("write");
            assert!(
                DiskStore::open(&path, 1 << 20, winv.clone()).is_err(),
                "accepted a {cut}-byte prefix"
            );
        }
        // Restore and confirm it opens again.
        std::fs::write(&path, &bytes).expect("write");
        assert!(DiskStore::open(&path, 1 << 20, winv.clone()).is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn stamp_and_fingerprint_track_content() {
        let (store, _want) = make("stamp", 10, 3, 1 << 20, 11);
        let f1 = store.flush_and_stamp(3).expect("stamp");
        assert_eq!(store.stamp(), (3, f1));
        assert_eq!(store.data_fingerprint().expect("fp"), f1);
        // Mutate one entry through a lease; the fingerprint must change.
        let schedule = Schedule::new(10, 3);
        let tile = schedule.waves()[0][0];
        let mut scratch = TileScratch::default();
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |x, cols, _| {
                let p = cols[tile.i_lo] + (tile.k_lo - tile.i_lo - 1);
                // SAFETY: in-bounds lease addressing, single thread.
                unsafe { x.set(p, x.get(p) + 1.0) };
            });
        }
        let f2 = store.data_fingerprint().expect("fp");
        assert_ne!(f1, f2, "fingerprint must react to content changes");
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn entry_leases_touch_only_requested_blocks_and_write_back() {
        // A sparse entry request must gather and scatter only the blocks
        // its pairs intersect, skip the rest of the footprint, and count
        // both through the stats so telemetry can surface the saving.
        let (n, b, block) = (40usize, 8usize, 4usize);
        let (store, mut flat) = make("entry", n, block, 1 << 20, 23);
        let m = PackedSym::zeros(n);
        let schedule = Schedule::new(n, b);
        let tile = schedule.waves()[0][0];
        let mut footprint: Vec<(usize, usize)> = Vec::new();
        crate::solver::tiling::for_each_tile_col(&tile, |c, lo, hi| {
            for r in lo..hi {
                footprint.push((c, r));
            }
        });
        let first = footprint[0];
        let last = *footprint.last().unwrap();
        assert_ne!(first, last);
        let mut scratch = TileScratch::default();
        let mut seen = 0usize;
        // SAFETY: single thread owns the tile.
        unsafe {
            store.with_entries(
                &tile,
                // Duplicates are legal; the store dedups before gathering.
                &mut |emit| {
                    for &(c, r) in &[first, last, first, last] {
                        emit(c, r);
                    }
                },
                &mut scratch,
                &mut |x, cols, winv| {
                    for &(c, r) in &[first, last] {
                        let p = cols[c] + (r - c - 1);
                        // SAFETY: in-bounds lease addressing, single thread.
                        unsafe {
                            assert_eq!(x.get(p), flat[m.idx(c, r)], "pair ({c},{r})");
                            x.set(p, 7.25);
                        }
                        assert_eq!(winv[p], 1.0);
                        seen += 1;
                    }
                },
            );
        }
        assert_eq!(seen, 2);
        let stats = store.stats();
        assert_eq!(stats.entry_loads, 2, "deduped request count");
        assert!(
            stats.blocks_skipped > 0,
            "the footprint spans more blocks than two pairs touch"
        );
        let sparse_loads = stats.loads;
        // Write-back covers exactly the requested entries, nothing else.
        flat[m.idx(first.0, first.1)] = 7.25;
        flat[m.idx(last.0, last.1)] = 7.25;
        assert_eq!(store.read_full().expect("read_full"), flat);
        // A whole-tile lease on an identical cold store must load
        // strictly more X blocks than the sparse entry lease did.
        let (tile_store, _) = make("entry_tile", n, block, 1 << 20, 23);
        // SAFETY: single thread, read-only callback.
        unsafe {
            tile_store.with_tile_read(&tile, &mut scratch, &mut |_x, _cols, _wv| {});
        }
        assert!(
            sparse_loads < tile_store.stats().loads,
            "sparse lease loaded {sparse_loads} X blocks, whole tile loaded {}",
            tile_store.stats().loads
        );
        for s in [store, tile_store] {
            let path = s.path().to_path_buf();
            drop(s);
            let _ = std::fs::remove_file(path);
        }
    }

    #[test]
    #[allow(unused_unsafe)]
    fn transient_faults_heal_bitwise_identical() {
        // The same churn-heavy mutation walk as
        // `leases_see_and_mutate_the_right_entries_under_churn`, but
        // under an aggressive transient-fault plan: EIO on reads and
        // writes, bit-flips on reads. With a retry budget, the final
        // content must be bitwise identical to the fault-free walk, and
        // the retry counter must prove faults actually fired.
        let (n, b) = (17usize, 4usize);
        let mut rng = Rng::new(7);
        let d = PackedSym::from_fn(n, |_, _| rng.f64_in(-3.0, 3.0));
        let winv = vec![1.0; d.len()];
        let path = tmp_path("faulty");
        let src = d.clone();
        let plan = FaultPlan::parse("seed=5,read-eio=0.05,write-eio=0.03,bitflip=0.03")
            .expect("plan");
        let tuning = StoreTuning { faults: Some(Arc::new(plan)), retries: 10 };
        let store = DiskStore::create_with(&path, n, b, 64 * 8, winv, &mut |c, r| {
            src.get(c, r)
        }, tuning)
        .expect("create");
        let mut flat = d.as_slice().to_vec();
        let m = PackedSym::zeros(n);
        let schedule = Schedule::new(n, b);
        let mut scratch = TileScratch::default();
        for _pass in 0..2 {
            for wave in schedule.waves() {
                for tile in wave {
                    // SAFETY: single thread owns every tile.
                    unsafe {
                        store.with_tile(tile, &mut scratch, &mut |x, cols, _| {
                            for_each_triplet(tile, b, |i, j, k| {
                                let p = cols[i] + (j - i - 1);
                                // SAFETY: in-bounds, single thread.
                                unsafe {
                                    let v = x.get(p) * 0.5 + (i + j + k) as f64 * 0.001;
                                    x.set(p, v);
                                    flat[m.idx(i, j)] = v;
                                }
                            });
                        });
                    }
                }
            }
        }
        store.health().expect("retries must absorb every transient fault");
        assert_eq!(store.read_full().expect("read_full"), flat);
        let stats = store.stats();
        assert!(stats.retries > 0, "the fault plan must actually have fired");
        let notes = store.drain_retries();
        assert!(!notes.is_empty(), "healed faults must leave retry notes");
        assert!(store.drain_retries().is_empty(), "drain must consume the notes");
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn permanent_failure_latches_and_parks_leases() {
        // A read that fails on every retry must not panic: the lease
        // parks, later leases are no-ops, and `health()` hands back the
        // typed error exactly once.
        let (n, b) = (12usize, 3usize);
        let mut rng = Rng::new(9);
        let d = PackedSym::from_fn(n, |_, _| rng.f64_in(-1.0, 1.0));
        let winv = vec![1.0; d.len()];
        let path = tmp_path("permfault");
        let src = d.clone();
        let plan = FaultPlan::parse("seed=2,read-eio=1.0").expect("plan");
        let tuning = StoreTuning { faults: Some(Arc::new(plan)), retries: 2 };
        let store = DiskStore::create_with(&path, n, b, 1 << 20, winv, &mut |c, r| {
            src.get(c, r)
        }, tuning)
        .expect("create never reads blocks, so it must succeed");
        assert!(store.health().is_ok());
        let schedule = Schedule::new(n, b);
        let tile = schedule.waves()[0][0];
        let mut scratch = TileScratch::default();
        let mut ran = false;
        // SAFETY: single thread owns the tile.
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |_x, _cols, _wv| ran = true);
        }
        assert!(!ran, "a failed gather must not run the kernel");
        assert!(store.is_failed());
        let err = store.health().expect_err("latch must surface the error");
        assert!(matches!(err, StoreError::Io(_)), "got {err}");
        // Later leases park silently; a later poll reports generically.
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |_x, _cols, _wv| ran = true);
        }
        assert!(!ran);
        assert!(store.health().is_err());
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn enospc_is_never_retried() {
        let plan = FaultPlan::parse("seed=1,enospc=1.0").expect("plan");
        let e: StoreError = plan.write_error(0).expect("always fires").into();
        assert!(!retryable(&e), "a full disk does not heal on backoff");
        let eio: StoreError = std::io::Error::from_raw_os_error(5).into();
        assert!(retryable(&eio));
        assert!(retryable(&corrupt("torn read")));
        assert!(!retryable(&StoreError::BadMagic));
        assert!(!retryable(&StoreError::Locked("x".into())));
    }

    #[test]
    fn lockfile_refuses_double_open_and_breaks_stale() {
        let (store, want) = make("lockfile", 10, 3, 1 << 20, 21);
        let path = store.path().to_path_buf();
        let winv = vec![1.0; want.len()];
        // A second open while the first handle is live must refuse.
        assert!(matches!(
            DiskStore::open(&path, 1 << 20, winv.clone()),
            Err(StoreError::Locked(_))
        ));
        store.flush_and_stamp(1).expect("stamp");
        drop(store);
        // A stale lock (dead pid) from a crashed run is broken silently.
        std::fs::write(sibling(&path, ".lock"), b"999999999").expect("plant stale lock");
        let reopened = DiskStore::open(&path, 1 << 20, winv).expect("stale lock must break");
        drop(reopened);
        assert!(!sibling(&path, ".lock").exists(), "drop must release the lock");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn clean_stale_artifacts_sweeps_crash_leftovers() {
        let dir = std::env::temp_dir()
            .join(format!("metric_proj_sweep_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("mkdir");
        // Crashed-run leftovers: a staging tmp, an orphaned spill, a
        // stale lock...
        std::fs::write(dir.join("ck.bin.tmp"), b"partial").expect("write");
        std::fs::write(dir.join("x.tiles.w"), b"orphan spill").expect("write");
        std::fs::write(dir.join("x.tiles.lock"), b"999999999").expect("write");
        // ...plus a live solve's spill (lock held by this process) and
        // artifacts that must always survive.
        std::fs::write(dir.join("y.tiles.w"), b"live spill").expect("write");
        std::fs::write(dir.join("y.tiles.lock"), std::process::id().to_string())
            .expect("write");
        std::fs::write(dir.join("x.tiles"), b"store").expect("write");
        std::fs::write(dir.join("x.tiles.ckpt"), b"snapshot").expect("write");
        let mut removed = clean_stale_artifacts(&dir).expect("sweep");
        removed.sort();
        assert_eq!(
            removed,
            vec![dir.join("ck.bin.tmp"), dir.join("x.tiles.lock"), dir.join("x.tiles.w")]
        );
        assert!(dir.join("y.tiles.w").exists(), "live-locked spill must survive");
        assert!(dir.join("y.tiles.lock").exists());
        assert!(dir.join("x.tiles").exists(), "store files are never swept");
        assert!(dir.join("x.tiles.ckpt").exists(), "snapshots are never swept");
        // A missing directory is an empty sweep, not an error.
        let _ = std::fs::remove_dir_all(&dir);
        assert!(clean_stale_artifacts(&dir).expect("missing dir").is_empty());
    }

    #[test]
    fn clean_stale_artifacts_is_shard_aware() {
        // Per-shard lock paths mean a coordinator restart sweeps only
        // dead workers' locks: live shard locks, shard data files, and
        // shard snapshots all survive the sweep.
        let dir = std::env::temp_dir()
            .join(format!("metric_proj_sweep_shard_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).expect("mkdir");
        std::fs::write(dir.join("x.tiles.shard0"), b"slice 0").expect("write");
        std::fs::write(dir.join("x.tiles.shard1"), b"slice 1").expect("write");
        std::fs::write(dir.join("x.tiles.shard0.ckpt"), b"snapshot 0").expect("write");
        // Shard 0's worker died (stale pid); shard 1's is live (our pid).
        std::fs::write(dir.join("x.tiles.shard0.lock"), b"999999999").expect("write");
        std::fs::write(dir.join("x.tiles.shard1.lock"), std::process::id().to_string())
            .expect("write");
        // A torn shard persist (crash between write and rename).
        std::fs::write(dir.join("x.tiles.shard0.tmp"), b"torn").expect("write");
        let mut removed = clean_stale_artifacts(&dir).expect("sweep");
        removed.sort();
        assert_eq!(
            removed,
            vec![dir.join("x.tiles.shard0.lock"), dir.join("x.tiles.shard0.tmp")]
        );
        assert!(dir.join("x.tiles.shard0").exists(), "shard data is never swept");
        assert!(dir.join("x.tiles.shard1").exists());
        assert!(dir.join("x.tiles.shard0.ckpt").exists(), "shard snapshots survive");
        assert!(dir.join("x.tiles.shard1.lock").exists(), "live worker lock survives");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    #[allow(unused_unsafe)]
    fn snapshot_promotes_over_a_drifted_store() {
        // Snapshot after a stamp, drift the live store past it, then
        // promote the snapshot back: the reopened store must carry the
        // snapshot's stamp and content.
        let (store, want) = make("snap", 11, 3, 1 << 20, 13);
        let path = store.path().to_path_buf();
        let f1 = store.flush_and_stamp(4).expect("stamp");
        let snap = snapshot_sibling(&path);
        store.snapshot_to(&snap).expect("snapshot");
        // Drift: mutate one entry and stamp a later pass.
        let schedule = Schedule::new(11, 3);
        let tile = schedule.waves()[0][0];
        let mut scratch = TileScratch::default();
        unsafe {
            store.with_tile(&tile, &mut scratch, &mut |x, cols, _| {
                let p = cols[tile.i_lo] + (tile.k_lo - tile.i_lo - 1);
                // SAFETY: in-bounds lease addressing, single thread.
                unsafe { x.set(p, x.get(p) + 1.0) };
            });
        }
        store.flush_and_stamp(5).expect("stamp");
        drop(store);
        std::fs::copy(&snap, &path).expect("promote");
        let winv = vec![1.0; want.len()];
        let reopened = DiskStore::open(&path, 1 << 20, winv).expect("reopen");
        assert_eq!(reopened.stamp(), (4, f1), "promotion restores the snapshot stamp");
        assert_eq!(reopened.read_full().expect("read_full"), want);
        drop(reopened);
        let _ = std::fs::remove_file(&snap);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn prefetch_warms_the_cache_without_changing_content() {
        let (store, want) = make("prefetch", 14, 3, 1 << 20, 17);
        let schedule = Schedule::new(14, 3);
        for wave in schedule.waves() {
            for tile in wave {
                store.prefetch(tile);
            }
        }
        // Drain: drop joins the prefetcher; poll until it has loaded
        // something or give up quickly (the assertion below is on
        // content, which must hold either way).
        for _ in 0..50 {
            if store.stats().prefetched > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(store.read_full().expect("read_full"), want);
        let path = store.path().to_path_buf();
        drop(store);
        let _ = std::fs::remove_file(path);
    }
}
