//! Wire protocol between a sharded solve's coordinator and its worker
//! processes (see [`super::shard`]).
//!
//! Every message is one **frame**: a little-endian `u32` body length
//! followed by the body, whose first byte is the opcode. Payloads are
//! fixed-width little-endian integers, length-prefixed byte strings, and
//! raw `f64` bit patterns — no general-purpose serialization, so the
//! bytes a worker returns for an entry are exactly the bytes it holds
//! and a sharded gather stays bit-identical to a resident read.
//!
//! The conversation is strictly request/response over a per-worker
//! Unix-domain socket (the coordinator never pipelines), so a frame
//! boundary is also a turn boundary: after writing a request the
//! coordinator reads exactly one response, and a worker that encounters
//! a store error answers with an [`Response::Err`] frame carrying a
//! typed [`StoreError`] instead of dying silently.
//!
//! Offsets in [`Request::Read`] / [`Request::Write`] are **global packed
//! column-major entry indices** — the same addressing every kernel and
//! [`super::TileStore`] lease uses — and must lie inside the worker's
//! own partition range; the worker rejects anything else as a
//! [`StoreError::Mismatch`].

use super::disk::{bytes_to_f64s, f64s_to_bytes, StoreError};
use std::io::{Read, Write};
use std::path::PathBuf;

/// Protocol version, checked at [`Request::Init`].
pub const PROTOCOL_VERSION: u32 = 1;

/// Upper bound on a frame body (1 GiB): a length prefix beyond this is
/// treated as stream corruption rather than honored as an allocation.
pub const MAX_FRAME_LEN: u32 = 1 << 30;

/// Coordinator → worker messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Hand the worker its identity and its resident slice. `x_path` is
    /// the *logical* store file (`<dir>/x.tiles`); the worker derives
    /// its own artifacts (`x.tiles.shard<k>`, per-shard lock) from it.
    /// The partition geometry is recomputed worker-side from
    /// `(n, n_shards, shard)`, so both ends agree by construction.
    Init {
        /// Protocol version of the coordinator.
        version: u32,
        /// Problem dimension.
        n: u64,
        /// This worker's shard index.
        shard: u32,
        /// Total shard count.
        n_shards: u32,
        /// Logical store path the shard artifacts are siblings of.
        x_path: PathBuf,
        /// The shard's slice of the packed `x` plane.
        x: Vec<f64>,
        /// The shard's slice of the packed inverse-weight plane.
        winv: Vec<f64>,
    },
    /// Gather the listed `(global_offset, len)` ranges of both planes.
    Read {
        /// Ascending, non-overlapping, inside the worker's partition.
        ranges: Vec<(u64, u64)>,
    },
    /// Scatter `x` back over the listed ranges (concatenated in range
    /// order). `winv` is read-only and never written.
    Write {
        /// Same contract as [`Request::Read`].
        ranges: Vec<(u64, u64)>,
        /// Concatenated replacement entries, `sum(len)` values.
        x: Vec<f64>,
    },
    /// Persist the shard file stamped with `pass`, then return the
    /// FNV-1a state after folding this shard's slice into `seed` — the
    /// chaining step of the plane-wide fingerprint.
    Stamp {
        /// Solver pass being stamped.
        pass: u64,
        /// Incoming FNV state (previous shard's result).
        seed: u64,
    },
    /// Return the chained FNV state without persisting anything.
    Fingerprint {
        /// Incoming FNV state (previous shard's result).
        seed: u64,
    },
    /// Copy the shard file to its `.ckpt` sibling (atomically).
    Snapshot,
    /// End-of-pass barrier / liveness heartbeat; echoes `pass` back.
    Barrier {
        /// Pass number, echoed in the response.
        pass: u64,
    },
    /// Clean shutdown: the worker acks, releases its lock, and exits.
    Shutdown,
}

/// Worker → coordinator messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Init accepted; `pid` is the worker's OS process id (the
    /// coordinator's own pid for in-process worker threads).
    InitAck {
        /// Worker process id.
        pid: u32,
    },
    /// Gathered entries, concatenated in range order, both planes.
    Read {
        /// Distance entries.
        x: Vec<f64>,
        /// Inverse-weight entries (same layout).
        winv: Vec<f64>,
    },
    /// Scatter applied.
    WriteAck,
    /// Shard file persisted; `chain` is the outgoing FNV state.
    Stamp {
        /// FNV state after this shard's slice.
        chain: u64,
    },
    /// Chained fingerprint without persistence.
    Fingerprint {
        /// FNV state after this shard's slice.
        chain: u64,
    },
    /// Snapshot written.
    SnapshotAck,
    /// Barrier reached; echoes the request's pass.
    Barrier {
        /// Echoed pass number.
        pass: u64,
    },
    /// Shutdown acknowledged (the socket closes right after).
    ShutdownAck,
    /// The request failed worker-side with a typed store error.
    Err {
        /// The re-hydrated error.
        error: StoreError,
    },
}

const OP_INIT: u8 = 0x01;
const OP_READ: u8 = 0x02;
const OP_WRITE: u8 = 0x03;
const OP_STAMP: u8 = 0x04;
const OP_FINGERPRINT: u8 = 0x05;
const OP_SNAPSHOT: u8 = 0x06;
const OP_BARRIER: u8 = 0x07;
const OP_SHUTDOWN: u8 = 0x08;
const OP_INIT_ACK: u8 = 0x81;
const OP_READ_OK: u8 = 0x82;
const OP_WRITE_OK: u8 = 0x83;
const OP_STAMP_OK: u8 = 0x84;
const OP_FINGERPRINT_OK: u8 = 0x85;
const OP_SNAPSHOT_OK: u8 = 0x86;
const OP_BARRIER_OK: u8 = 0x87;
const OP_SHUTDOWN_OK: u8 = 0x88;
const OP_ERR: u8 = 0x7F;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u64(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn put_f64s(out: &mut Vec<u8>, data: &[f64]) {
    put_bytes(out, &f64s_to_bytes(data));
}

fn put_ranges(out: &mut Vec<u8>, ranges: &[(u64, u64)]) {
    put_u64(out, ranges.len() as u64);
    for &(off, len) in ranges {
        put_u64(out, off);
        put_u64(out, len);
    }
}

/// Bounded reader over a frame body.
struct Buf<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Buf<'a> {
    fn new(b: &'a [u8]) -> Buf<'a> {
        Buf { b, pos: 0 }
    }

    fn take(&mut self, len: usize) -> Result<&'a [u8], StoreError> {
        let end = self
            .pos
            .checked_add(len)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| StoreError::Corrupt("truncated protocol frame".into()))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn take_u8(&mut self) -> Result<u8, StoreError> {
        Ok(self.take(1)?[0])
    }

    fn take_u32(&mut self) -> Result<u32, StoreError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn take_u64(&mut self) -> Result<u64, StoreError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn take_bytes(&mut self) -> Result<&'a [u8], StoreError> {
        let len = self.take_u64()?;
        if len > MAX_FRAME_LEN as u64 {
            return Err(StoreError::Corrupt(format!("oversized field ({len} bytes)")));
        }
        self.take(len as usize)
    }

    fn take_f64s(&mut self) -> Result<Vec<f64>, StoreError> {
        let bytes = self.take_bytes()?;
        if bytes.len() % 8 != 0 {
            return Err(StoreError::Corrupt("f64 field not a multiple of 8 bytes".into()));
        }
        Ok(bytes_to_f64s(bytes))
    }

    fn take_ranges(&mut self) -> Result<Vec<(u64, u64)>, StoreError> {
        let count = self.take_u64()?;
        if count > (MAX_FRAME_LEN as u64) / 16 {
            return Err(StoreError::Corrupt(format!("oversized range list ({count})")));
        }
        let mut ranges = Vec::with_capacity(count as usize);
        for _ in 0..count {
            let off = self.take_u64()?;
            let len = self.take_u64()?;
            ranges.push((off, len));
        }
        Ok(ranges)
    }

    fn finish(&self) -> Result<(), StoreError> {
        if self.pos != self.b.len() {
            return Err(StoreError::Corrupt(format!(
                "trailing bytes in protocol frame ({} unread)",
                self.b.len() - self.pos
            )));
        }
        Ok(())
    }
}

/// Write one frame (`u32` length + body) and flush.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    debug_assert!(body.len() as u64 <= MAX_FRAME_LEN as u64);
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one frame body. An EOF *before* the length prefix surfaces as
/// `UnexpectedEof` — callers distinguish a peer that closed cleanly from
/// one that died mid-frame by whether any bytes arrived.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len);
    if len > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("protocol frame length {len} exceeds {MAX_FRAME_LEN}"),
        ));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    Ok(body)
}

impl Request {
    /// Serialize into a frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Request::Init { version, n, shard, n_shards, x_path, x, winv } => {
                out.push(OP_INIT);
                put_u32(&mut out, *version);
                put_u64(&mut out, *n);
                put_u32(&mut out, *shard);
                put_u32(&mut out, *n_shards);
                put_bytes(&mut out, x_path.to_string_lossy().as_bytes());
                put_f64s(&mut out, x);
                put_f64s(&mut out, winv);
            }
            Request::Read { ranges } => {
                out.push(OP_READ);
                put_ranges(&mut out, ranges);
            }
            Request::Write { ranges, x } => {
                out.push(OP_WRITE);
                put_ranges(&mut out, ranges);
                put_f64s(&mut out, x);
            }
            Request::Stamp { pass, seed } => {
                out.push(OP_STAMP);
                put_u64(&mut out, *pass);
                put_u64(&mut out, *seed);
            }
            Request::Fingerprint { seed } => {
                out.push(OP_FINGERPRINT);
                put_u64(&mut out, *seed);
            }
            Request::Snapshot => out.push(OP_SNAPSHOT),
            Request::Barrier { pass } => {
                out.push(OP_BARRIER);
                put_u64(&mut out, *pass);
            }
            Request::Shutdown => out.push(OP_SHUTDOWN),
        }
        out
    }

    /// Parse a frame body.
    pub fn decode(body: &[u8]) -> Result<Request, StoreError> {
        let mut buf = Buf::new(body);
        let req = match buf.take_u8()? {
            OP_INIT => Request::Init {
                version: buf.take_u32()?,
                n: buf.take_u64()?,
                shard: buf.take_u32()?,
                n_shards: buf.take_u32()?,
                x_path: PathBuf::from(String::from_utf8_lossy(buf.take_bytes()?).into_owned()),
                x: buf.take_f64s()?,
                winv: buf.take_f64s()?,
            },
            OP_READ => Request::Read { ranges: buf.take_ranges()? },
            OP_WRITE => {
                Request::Write { ranges: buf.take_ranges()?, x: buf.take_f64s()? }
            }
            OP_STAMP => Request::Stamp { pass: buf.take_u64()?, seed: buf.take_u64()? },
            OP_FINGERPRINT => Request::Fingerprint { seed: buf.take_u64()? },
            OP_SNAPSHOT => Request::Snapshot,
            OP_BARRIER => Request::Barrier { pass: buf.take_u64()? },
            OP_SHUTDOWN => Request::Shutdown,
            op => return Err(StoreError::Corrupt(format!("unknown request opcode {op:#x}"))),
        };
        buf.finish()?;
        Ok(req)
    }
}

/// Error kinds on the wire (one byte + auxiliary word + message).
fn err_body(error: &StoreError) -> Vec<u8> {
    let (kind, aux, msg): (u8, u32, String) = match error {
        StoreError::Io(e) => (0, e.raw_os_error().unwrap_or(0) as u32, e.to_string()),
        StoreError::BadMagic => (1, 0, String::new()),
        StoreError::UnsupportedVersion(v) => (2, *v, String::new()),
        StoreError::Corrupt(m) => (3, 0, m.clone()),
        StoreError::Mismatch(m) => (4, 0, m.clone()),
        StoreError::Locked(m) => (5, 0, m.clone()),
    };
    let mut out = vec![OP_ERR, kind];
    put_u32(&mut out, aux);
    put_bytes(&mut out, msg.as_bytes());
    out
}

fn decode_err(buf: &mut Buf<'_>) -> Result<StoreError, StoreError> {
    let kind = buf.take_u8()?;
    let aux = buf.take_u32()?;
    let msg = String::from_utf8_lossy(buf.take_bytes()?).into_owned();
    Ok(match kind {
        0 => {
            let e = if aux != 0 {
                std::io::Error::from_raw_os_error(aux as i32)
            } else {
                std::io::Error::other(msg)
            };
            StoreError::Io(e)
        }
        1 => StoreError::BadMagic,
        2 => StoreError::UnsupportedVersion(aux),
        3 => StoreError::Corrupt(msg),
        4 => StoreError::Mismatch(msg),
        5 => StoreError::Locked(msg),
        k => return Err(StoreError::Corrupt(format!("unknown error kind {k}"))),
    })
}

impl Response {
    /// Serialize into a frame body.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Response::InitAck { pid } => {
                out.push(OP_INIT_ACK);
                put_u32(&mut out, *pid);
            }
            Response::Read { x, winv } => {
                out.push(OP_READ_OK);
                put_f64s(&mut out, x);
                put_f64s(&mut out, winv);
            }
            Response::WriteAck => out.push(OP_WRITE_OK),
            Response::Stamp { chain } => {
                out.push(OP_STAMP_OK);
                put_u64(&mut out, *chain);
            }
            Response::Fingerprint { chain } => {
                out.push(OP_FINGERPRINT_OK);
                put_u64(&mut out, *chain);
            }
            Response::SnapshotAck => out.push(OP_SNAPSHOT_OK),
            Response::Barrier { pass } => {
                out.push(OP_BARRIER_OK);
                put_u64(&mut out, *pass);
            }
            Response::ShutdownAck => out.push(OP_SHUTDOWN_OK),
            Response::Err { error } => return err_body(error),
        }
        out
    }

    /// Parse a frame body.
    pub fn decode(body: &[u8]) -> Result<Response, StoreError> {
        let mut buf = Buf::new(body);
        let resp = match buf.take_u8()? {
            OP_INIT_ACK => Response::InitAck { pid: buf.take_u32()? },
            OP_READ_OK => Response::Read { x: buf.take_f64s()?, winv: buf.take_f64s()? },
            OP_WRITE_OK => Response::WriteAck,
            OP_STAMP_OK => Response::Stamp { chain: buf.take_u64()? },
            OP_FINGERPRINT_OK => Response::Fingerprint { chain: buf.take_u64()? },
            OP_SNAPSHOT_OK => Response::SnapshotAck,
            OP_BARRIER_OK => Response::Barrier { pass: buf.take_u64()? },
            OP_SHUTDOWN_OK => Response::ShutdownAck,
            OP_ERR => Response::Err { error: decode_err(&mut buf)? },
            op => return Err(StoreError::Corrupt(format!("unknown response opcode {op:#x}"))),
        };
        buf.finish()?;
        Ok(resp)
    }
}

// PartialEq for Response must see through StoreError (which carries
// io::Error and is not PartialEq): compare the rendered form, which is
// what tests and logs observe anyway.
impl PartialEq for StoreError {
    fn eq(&self, other: &Self) -> bool {
        self.to_string() == other.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(req: Request) {
        let body = req.encode();
        assert_eq!(Request::decode(&body).unwrap(), req);
    }

    fn roundtrip_resp(resp: Response) {
        let body = resp.encode();
        assert_eq!(Response::decode(&body).unwrap(), resp);
    }

    #[test]
    fn every_request_roundtrips() {
        roundtrip_req(Request::Init {
            version: PROTOCOL_VERSION,
            n: 17,
            shard: 1,
            n_shards: 4,
            x_path: PathBuf::from("/tmp/store/x.tiles"),
            x: vec![1.5, -2.25, f64::MIN_POSITIVE],
            winv: vec![0.0, 1.0, 4.0],
        });
        roundtrip_req(Request::Read { ranges: vec![(0, 3), (10, 7)] });
        roundtrip_req(Request::Write { ranges: vec![(4, 2)], x: vec![0.5, -0.5] });
        roundtrip_req(Request::Stamp { pass: 9, seed: 0xdead_beef });
        roundtrip_req(Request::Fingerprint { seed: 42 });
        roundtrip_req(Request::Snapshot);
        roundtrip_req(Request::Barrier { pass: 3 });
        roundtrip_req(Request::Shutdown);
    }

    #[test]
    fn every_response_roundtrips() {
        roundtrip_resp(Response::InitAck { pid: 4242 });
        roundtrip_resp(Response::Read { x: vec![1.0, 2.0], winv: vec![3.0, 4.0] });
        roundtrip_resp(Response::WriteAck);
        roundtrip_resp(Response::Stamp { chain: 0xcbf29ce484222325 });
        roundtrip_resp(Response::Fingerprint { chain: 7 });
        roundtrip_resp(Response::SnapshotAck);
        roundtrip_resp(Response::Barrier { pass: 11 });
        roundtrip_resp(Response::ShutdownAck);
        for error in [
            StoreError::BadMagic,
            StoreError::UnsupportedVersion(9),
            StoreError::Corrupt("torn".into()),
            StoreError::Mismatch("wrong n".into()),
            StoreError::Locked("pid 1".into()),
            StoreError::Io(std::io::Error::from_raw_os_error(28)),
        ] {
            roundtrip_resp(Response::Err { error });
        }
    }

    #[test]
    fn f64_payloads_are_bit_exact() {
        let vals = vec![f64::NAN, -0.0, f64::INFINITY, 1.0 + f64::EPSILON];
        let body = Response::Read { x: vals.clone(), winv: vals.clone() }.encode();
        match Response::decode(&body).unwrap() {
            Response::Read { x, winv } => {
                for (a, b) in x.iter().chain(winv.iter()).zip(vals.iter().chain(vals.iter())) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn frames_cross_a_pipe() {
        let mut wire: Vec<u8> = Vec::new();
        let req = Request::Barrier { pass: 5 };
        write_frame(&mut wire, &req.encode()).unwrap();
        let resp = Response::Barrier { pass: 5 };
        write_frame(&mut wire, &resp.encode()).unwrap();
        let mut r = &wire[..];
        assert_eq!(Request::decode(&read_frame(&mut r).unwrap()).unwrap(), req);
        assert_eq!(Response::decode(&read_frame(&mut r).unwrap()).unwrap(), resp);
        assert!(read_frame(&mut r).is_err(), "EOF after the last frame");
    }

    #[test]
    fn truncated_and_trailing_bytes_are_typed_errors() {
        let body = Request::Stamp { pass: 1, seed: 2 }.encode();
        assert!(matches!(
            Request::decode(&body[..body.len() - 1]),
            Err(StoreError::Corrupt(_))
        ));
        let mut long = body.clone();
        long.push(0);
        assert!(matches!(Request::decode(&long), Err(StoreError::Corrupt(_))));
        assert!(matches!(Request::decode(&[0xEE]), Err(StoreError::Corrupt(_))));
    }

    #[test]
    fn oversized_frame_length_is_refused() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME_LEN + 1).to_le_bytes());
        wire.extend_from_slice(&[0u8; 16]);
        let mut r = &wire[..];
        let err = read_frame(&mut r).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }
}
