//! The resident [`TileStore`]: a pass-through over the classic packed
//! array. Leasing a tile costs nothing — the callback receives the
//! global `x` view, the global `col_starts`, and the global `winv`, so
//! every kernel runs exactly as it did before the store abstraction
//! existed (same pointers, same indices, same numbers).

use super::{TileScratch, TileStore};
use crate::solver::schedule::Tile;
use crate::util::shared::SharedMut;

/// Borrowed in-memory store over the caller's packed arrays.
///
/// Constructed fresh for each solver phase from the phase's exclusive
/// borrow of `x` (mirroring how the drivers built their [`SharedMut`]
/// views before), so the aliasing discipline is unchanged.
pub struct MemStore<'a> {
    x: SharedMut<'a, f64>,
    col_starts: &'a [usize],
    winv: &'a [f64],
    n: usize,
    m: usize,
}

impl<'a> MemStore<'a> {
    /// Wrap the packed distance slice (`n(n-1)/2` entries), its column
    /// offsets, and the matching inverse weights.
    pub fn new(x: &'a mut [f64], col_starts: &'a [usize], winv: &'a [f64]) -> MemStore<'a> {
        let n = col_starts.len();
        let m = x.len();
        debug_assert_eq!(m, n * n.saturating_sub(1) / 2);
        debug_assert_eq!(winv.len(), m);
        MemStore { x: SharedMut::new(x), col_starts, winv, n, m }
    }
}

impl TileStore for MemStore<'_> {
    fn n(&self) -> usize {
        self.n
    }

    fn n_pairs(&self) -> usize {
        self.m
    }

    unsafe fn with_tile(
        &self,
        _tile: &Tile,
        _scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        f(&self.x, self.col_starts, self.winv);
    }

    unsafe fn with_entries(
        &self,
        _tile: &Tile,
        _each_pair: &mut dyn FnMut(&mut dyn FnMut(usize, usize)),
        _scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        // Zero cost, same as `with_tile`: the enumerator is never even
        // invoked — the resident array already holds every entry.
        f(&self.x, self.col_starts, self.winv);
    }

    unsafe fn with_pair_range(
        &self,
        lo: usize,
        hi: usize,
        _write: bool,
        _scratch: &mut TileScratch,
        f: &mut dyn FnMut(usize, &mut [f64], &[f64]),
    ) {
        debug_assert!(lo <= hi && hi <= self.m);
        // SAFETY: the caller guarantees disjoint ranges across threads
        // (the lease contract), so reborrowing the chunk is race-free.
        // Writes land in the backing directly, which also means a
        // `write = false` caller must honor its read-only promise.
        let xs = unsafe { self.x.slice_mut(lo, hi) };
        f(lo, xs, &self.winv[lo..hi]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::PackedSym;
    use crate::solver::schedule::Schedule;
    use crate::solver::tiling::for_each_tile_col;

    #[test]
    #[allow(unused_unsafe)]
    fn lease_is_the_global_view() {
        let n = 9;
        let d = PackedSym::from_fn(n, |i, j| (i * 10 + j) as f64);
        let mut x: Vec<f64> = d.as_slice().to_vec();
        let winv = vec![1.0; x.len()];
        let cs = d.col_starts().to_vec();
        let store = MemStore::new(x.as_mut_slice(), &cs, &winv);
        assert_eq!(store.n(), n);
        assert_eq!(store.n_pairs(), n * (n - 1) / 2);
        let schedule = Schedule::new(n, 3);
        let mut scratch = TileScratch::default();
        for wave in schedule.waves() {
            for tile in wave {
                // SAFETY: single thread owns every tile.
                unsafe {
                    store.with_tile(tile, &mut scratch, &mut |xv, cols, wv| {
                        for_each_tile_col(tile, |c, lo, hi| {
                            for r in lo..hi {
                                let p = cols[c] + (r - c - 1);
                                // SAFETY: in-bounds lease addressing.
                                assert_eq!(unsafe { xv.get(p) }, d.get(c, r));
                                assert_eq!(wv[p], 1.0);
                            }
                        });
                    });
                }
            }
        }
    }

    #[test]
    #[allow(unused_unsafe)]
    fn pair_range_lease_is_the_global_chunk() {
        let n = 8;
        let mut x: Vec<f64> = (0..n * (n - 1) / 2).map(|e| e as f64).collect();
        let winv: Vec<f64> = (0..x.len()).map(|e| 1.0 + e as f64).collect();
        let cs: Vec<usize> = PackedSym::zeros(n).col_starts().to_vec();
        let m = x.len();
        {
            let store = MemStore::new(x.as_mut_slice(), &cs, &winv);
            let mut scratch = TileScratch::default();
            let mut calls = 0usize;
            // SAFETY: single thread owns the whole range.
            unsafe {
                store.with_pair_range(3, m - 2, true, &mut scratch, &mut |g, xs, wv| {
                    calls += 1;
                    assert_eq!(g, 3, "mem lease is one global chunk");
                    assert_eq!(xs.len(), m - 5);
                    for (t, v) in xs.iter_mut().enumerate() {
                        assert_eq!(*v, (g + t) as f64);
                        assert_eq!(wv[t], 1.0 + (g + t) as f64);
                        *v += 100.0;
                    }
                });
            }
            assert_eq!(calls, 1);
        }
        for (e, v) in x.iter().enumerate() {
            let expect =
                if (3..m - 2).contains(&e) { e as f64 + 100.0 } else { e as f64 };
            assert_eq!(*v, expect, "entry {e}");
        }
    }

    #[test]
    #[allow(unused_unsafe)]
    fn writes_through_the_lease_are_durable() {
        let n = 6;
        let mut x = vec![0.0f64; n * (n - 1) / 2];
        let winv = vec![1.0; x.len()];
        let cs: Vec<usize> = {
            let m = PackedSym::zeros(n);
            m.col_starts().to_vec()
        };
        let schedule = Schedule::new(n, 2);
        {
            let store = MemStore::new(x.as_mut_slice(), &cs, &winv);
            let mut scratch = TileScratch::default();
            let tile = &schedule.waves()[0][0];
            unsafe {
                store.with_tile(tile, &mut scratch, &mut |xv, cols, _| {
                    let p = cols[tile.i_lo] + (tile.k_lo - tile.i_lo - 1);
                    // SAFETY: in-bounds lease addressing, single thread.
                    unsafe { xv.set(p, 7.5) };
                });
            }
        }
        assert!(x.iter().any(|&v| v == 7.5));
    }
}
