//! Seeded, deterministic fault injection for the disk tile store.
//!
//! A [`FaultPlan`] decides, per block-I/O operation, whether to inject a
//! failure: a transient read/write `EIO`, an in-memory checksum bit-flip
//! (a torn or silently-corrupted read, caught by the store's resident
//! checksum table), an `ENOSPC` on write-back (never retried — a full
//! disk does not heal on a 2 ms backoff), or a latency spike. Every
//! decision is a pure hash of `(seed, op index, fault kind)`, so a plan
//! replays the same fault schedule for the same operation sequence —
//! tests and the nightly fault-matrix CI job exercise exact failure
//! paths, not roulette. Plans are parsed from a compact spec string
//! (CLI `--fault-plan` / env `METRIC_PROJ_FAULTS`):
//!
//! ```text
//! seed=42,read-eio=0.02,write-eio=0.01,bitflip=0.005,latency=0.05,latency-ms=5,after=200
//! ```
//!
//! Rates are probabilities in `[0, 1]` drawn independently per
//! operation; `after=N` arms the plan only from the `N`-th operation on,
//! which models a device that works for a while and then degrades —
//! `read-eio=1.0,after=N` is a *permanent* failure (every retry faults
//! again and the retry budget unwinds into a typed error).
//!
//! Faults are injected at exactly one layer: the disk store's block
//! read/write wrappers (`rust/src/matrix/store/disk.rs`). Setup and
//! teardown I/O (header writes, spill creation, open-time verification)
//! is not in scope — the plan drills the *steady-state* solve loop,
//! which is where hours-long out-of-core runs live.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Errno for a transient I/O failure.
const EIO: i32 = 5;
/// Errno for "no space left on device".
const ENOSPC: i32 = 28;

/// A deterministic fault-injection plan (see the [module docs](self)).
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Seed mixed into every per-operation draw.
    pub seed: u64,
    /// Probability of a transient `EIO` on a block read.
    pub read_eio: f64,
    /// Probability of a transient `EIO` on a block write.
    pub write_eio: f64,
    /// Probability of flipping one bit of a block as it is read (caught
    /// by the store's checksum verification, then retried).
    pub bitflip: f64,
    /// Probability of `ENOSPC` on a block write (non-retryable).
    pub enospc: f64,
    /// Probability of a latency spike on any block operation.
    pub latency: f64,
    /// Duration of one latency spike, in milliseconds.
    pub latency_ms: u64,
    /// Operations to pass through cleanly before the plan arms.
    pub after: u64,
    /// Global operation counter (shared by every plane of every store
    /// holding this plan).
    ops: AtomicU64,
}

impl FaultPlan {
    /// Parse a `key=value,...` spec string. Keys: `seed`, `read-eio`,
    /// `write-eio`, `bitflip`, `enospc`, `latency` (rates in `[0, 1]`),
    /// `latency-ms`, `after` (integers). Unknown keys are errors.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| format!("fault spec `{part}` is not key=value"))?;
            let rate = |v: &str| -> Result<f64, String> {
                let r: f64 =
                    v.parse().map_err(|_| format!("fault rate `{v}` is not a number"))?;
                if !(0.0..=1.0).contains(&r) {
                    return Err(format!("fault rate `{v}` is outside [0, 1]"));
                }
                Ok(r)
            };
            let int = |v: &str| -> Result<u64, String> {
                v.parse().map_err(|_| format!("fault value `{v}` is not an integer"))
            };
            match key.trim() {
                "seed" => plan.seed = int(value)?,
                "read-eio" => plan.read_eio = rate(value)?,
                "write-eio" => plan.write_eio = rate(value)?,
                "bitflip" => plan.bitflip = rate(value)?,
                "enospc" => plan.enospc = rate(value)?,
                "latency" => plan.latency = rate(value)?,
                "latency-ms" => plan.latency_ms = int(value)?,
                "after" => plan.after = int(value)?,
                other => return Err(format!("unknown fault key `{other}`")),
            }
        }
        if plan.latency > 0.0 && plan.latency_ms == 0 {
            plan.latency_ms = 10;
        }
        Ok(plan)
    }

    /// Claim the next operation id. Each block read/write claims exactly
    /// one id and derives all of its fault draws from it.
    pub fn next_op(&self) -> u64 {
        self.ops.fetch_add(1, Ordering::Relaxed)
    }

    /// Operations drawn so far.
    pub fn ops_seen(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    /// Uniform draw in `[0, 1)` for `(op, salt)` — a pure function of
    /// the plan seed, so schedules replay.
    fn draw(&self, op: u64, salt: u64) -> f64 {
        let h = crate::util::hash::fnv1a64(
            &[self.seed.to_le_bytes(), op.to_le_bytes(), salt.to_le_bytes()].concat(),
        );
        // 53 high bits -> uniform in [0, 1).
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    fn armed(&self, op: u64) -> bool {
        op >= self.after
    }

    /// Sleep out a latency spike, if this operation drew one.
    pub fn pace(&self, op: u64) {
        if self.armed(op) && self.latency > 0.0 && self.draw(op, 1) < self.latency {
            std::thread::sleep(std::time::Duration::from_millis(self.latency_ms));
        }
    }

    /// The injected error for a block read, if any.
    pub fn read_error(&self, op: u64) -> Option<std::io::Error> {
        if self.armed(op) && self.draw(op, 2) < self.read_eio {
            return Some(std::io::Error::from_raw_os_error(EIO));
        }
        None
    }

    /// The injected error for a block write, if any. `ENOSPC` wins over
    /// the transient `EIO` when both are drawn.
    pub fn write_error(&self, op: u64) -> Option<std::io::Error> {
        if !self.armed(op) {
            return None;
        }
        if self.draw(op, 3) < self.enospc {
            return Some(std::io::Error::from_raw_os_error(ENOSPC));
        }
        if self.draw(op, 4) < self.write_eio {
            return Some(std::io::Error::from_raw_os_error(EIO));
        }
        None
    }

    /// Flip one deterministic bit of a just-read block, if this
    /// operation drew a bit-flip. Returns whether a flip happened.
    pub fn corrupt_read(&self, op: u64, data: &mut [f64]) -> bool {
        if data.is_empty() || !self.armed(op) || self.draw(op, 5) >= self.bitflip {
            return false;
        }
        let h = crate::util::hash::fnv1a64(
            &[self.seed.to_le_bytes(), op.to_le_bytes(), 6u64.to_le_bytes()].concat(),
        );
        let entry = (h as usize) % data.len();
        let bit = (h >> 32) % 64;
        data[entry] = f64::from_bits(data[entry].to_bits() ^ (1u64 << bit));
        true
    }

    /// Whether the plan can inject anything at all.
    pub fn is_active(&self) -> bool {
        self.read_eio > 0.0
            || self.write_eio > 0.0
            || self.bitflip > 0.0
            || self.enospc > 0.0
            || self.latency > 0.0
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "seed={}", self.seed)?;
        for (key, rate) in [
            ("read-eio", self.read_eio),
            ("write-eio", self.write_eio),
            ("bitflip", self.bitflip),
            ("enospc", self.enospc),
            ("latency", self.latency),
        ] {
            if rate > 0.0 {
                write!(f, ",{key}={rate}")?;
            }
        }
        if self.latency > 0.0 {
            write!(f, ",latency-ms={}", self.latency_ms)?;
        }
        if self.after > 0 {
            write!(f, ",after={}", self.after)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_through_display() {
        let spec = "seed=42,read-eio=0.02,bitflip=0.005,latency=0.05,latency-ms=5,after=200";
        let plan = FaultPlan::parse(spec).expect("parse");
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.read_eio, 0.02);
        assert_eq!(plan.bitflip, 0.005);
        assert_eq!(plan.latency_ms, 5);
        assert_eq!(plan.after, 200);
        let again = FaultPlan::parse(&plan.to_string()).expect("reparse");
        assert_eq!(again.read_eio, plan.read_eio);
        assert_eq!(again.after, plan.after);
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("read-eio").is_err());
        assert!(FaultPlan::parse("read-eio=2.0").is_err());
        assert!(FaultPlan::parse("read-eio=-0.5").is_err());
        assert!(FaultPlan::parse("warp-core=0.5").is_err());
        assert!(FaultPlan::parse("seed=banana").is_err());
        assert!(FaultPlan::parse("").expect("empty is a no-fault plan").is_active() == false);
    }

    #[test]
    fn draws_are_deterministic_and_rate_shaped() {
        let plan = FaultPlan::parse("seed=7,read-eio=0.25").expect("parse");
        let twin = FaultPlan::parse("seed=7,read-eio=0.25").expect("parse");
        let mut faults = 0usize;
        for op in 0..10_000u64 {
            let a = plan.read_error(op).is_some();
            let b = twin.read_error(op).is_some();
            assert_eq!(a, b, "op {op} must replay identically");
            faults += a as usize;
        }
        // 2500 expected; allow a generous deterministic band.
        assert!((1800..3200).contains(&faults), "rate 0.25 drew {faults}/10000");
    }

    #[test]
    fn after_gates_every_fault_kind() {
        let plan =
            FaultPlan::parse("seed=1,read-eio=1.0,write-eio=1.0,enospc=1.0,bitflip=1.0,after=100")
                .expect("parse");
        let mut data = [1.0f64; 4];
        for op in 0..100u64 {
            assert!(plan.read_error(op).is_none());
            assert!(plan.write_error(op).is_none());
            assert!(!plan.corrupt_read(op, &mut data));
        }
        assert!(plan.read_error(100).is_some());
        assert!(plan.write_error(100).is_some());
        assert_eq!(plan.write_error(100).unwrap().raw_os_error(), Some(ENOSPC));
        assert!(plan.corrupt_read(101, &mut data));
    }

    #[test]
    fn bitflip_changes_exactly_one_bit() {
        let plan = FaultPlan::parse("seed=3,bitflip=1.0").expect("parse");
        let before = [1.5f64, -2.25, 0.0, 99.0];
        let mut after = before;
        assert!(plan.corrupt_read(0, &mut after));
        let diffs: u32 = before
            .iter()
            .zip(after.iter())
            .map(|(a, b)| (a.to_bits() ^ b.to_bits()).count_ones())
            .sum();
        assert_eq!(diffs, 1, "exactly one bit must flip");
    }

    #[test]
    fn next_op_counts_up() {
        let plan = FaultPlan::default();
        assert_eq!(plan.next_op(), 0);
        assert_eq!(plan.next_op(), 1);
        assert_eq!(plan.ops_seen(), 2);
    }
}
