//! Pluggable storage backends for the packed distance matrix `X`.
//!
//! The paper's headline scale (trillions of constraints) works because a
//! projection solver only ever needs `O(n²)` *variables* resident, never
//! the `O(n³)` constraints — and once the duals sparsify, the packed `X`
//! itself becomes the binding memory limit. This module inverts the
//! ownership of that hot-path array: solvers no longer address a flat
//! `&mut [f64]` directly but lease **tile working sets** from a
//! [`TileStore`], so `X` can live wherever the store decides:
//!
//! * [`MemStore`] — the classic resident packed array. Leases are free
//!   pass-throughs (the solver sees the exact same pointer and the exact
//!   same global `col_starts` addressing as before), so the in-memory
//!   path is unchanged.
//! * [`DiskStore`] — `X` on disk, laid out as the same `(i, k)` tile
//!   blocks the wave schedule iterates ([`layout::BlockLayout`]), behind
//!   a bounded LRU block cache with write-back on eviction and
//!   prefetching of the next tile in sweep order. Leases gather the
//!   tile's per-column segments ([`for_each_tile_col`]) into a
//!   worker-local arena and scatter them back afterwards. The packed
//!   inverse weights stream from a second **read-only plane** (a sibling
//!   `w` spill file with the same block layout) instead of staying
//!   resident, so weighted instances pay the same bounded footprint as
//!   unweighted ones.
//!
//! Besides tile leases, stores hand out **pair-range leases**
//! ([`TileStore::with_pair_range`]): ascending contiguous segments of
//! the packed order, which is what the CC-LP pair phase and the
//! elementwise residual scans stream — the last solver phases that used
//! to address the flat array directly.
//!
//! Cheap active-set passes use the finer-grained **entry lease**
//! ([`TileStore::with_entries`]): the caller names exactly the pairs its
//! kernel will touch (a tile bucket's active keys expand to at most
//! three pairs per triplet), and the store only has to materialize
//! those. [`MemStore`] still passes the resident array through at zero
//! cost; [`DiskStore`] gathers from only the blocks intersecting the
//! requested entries — blocks of the tile footprint holding no requested
//! pair are neither read nor written, which is what makes cheap-pass I/O
//! scale with the active set instead of tile geometry
//! ([`StoreStats::entry_loads`] / [`StoreStats::blocks_skipped`] count
//! it).
//!
//! # The lease contract
//!
//! [`TileStore::with_tile`] hands the callback `(x, cols, winv)` such
//! that the entry of pair `{c, r}` (`c < r`) lives at
//! `x[cols[c] + (r - c - 1)]`, and `winv` is indexed identically — the
//! exact addressing every kernel already uses with the global
//! `col_starts`. Because a lease hands the kernels bit-identical values
//! under bit-identical arithmetic (a gather/scatter copies, it never
//! rounds), a disk-backed solve is **bitwise identical** to the
//! in-memory solve (pinned by `tests/store_equivalence.rs`).
//!
//! The safety story is the wave schedule's, unchanged: a worker may only
//! lease a tile it owns for the current wave, so concurrent leases touch
//! disjoint pairs. Stores may still share cache *blocks* between workers
//! (block granularity is coarser than pair granularity); [`DiskStore`]
//! therefore serializes all gather/scatter copying on one lock while the
//! compute between them stays fully parallel on private arenas.
//!
//! [`for_each_tile_col`]: crate::solver::tiling::for_each_tile_col

pub mod disk;
pub mod faults;
pub mod layout;
pub mod mem;
pub mod protocol;
pub mod shard;

pub use disk::{
    clean_stale_artifacts, snapshot_sibling, DiskStore, RetryNote, StoreError, StoreStats,
    StoreTuning, DEFAULT_STORE_RETRIES,
};
pub use faults::FaultPlan;
pub use mem::MemStore;
pub use shard::ShardStore;

use crate::solver::schedule::Tile;
use crate::util::shared::SharedMut;
use std::path::PathBuf;
use std::sync::Arc;

/// One leased per-column segment of a tile footprint (disk gathers).
#[derive(Clone, Copy, Debug)]
pub(crate) struct Seg {
    /// Column index.
    pub col: usize,
    /// First touched row (`> col`).
    pub row_lo: usize,
    /// One past the last touched row.
    pub row_hi: usize,
    /// Arena offset of the segment's first entry.
    pub start: usize,
}

/// Worker-local scratch a store may use to stage a tile's working set.
///
/// Created once per worker ([`TileScratch::default`]) and reused across
/// tiles; [`MemStore`] ignores it entirely, [`DiskStore`] keeps the
/// gathered `x`/`winv` arenas and the per-column address table here.
#[derive(Default)]
pub struct TileScratch {
    /// Gathered distance entries (read-write).
    pub(crate) x: Vec<f64>,
    /// Gathered inverse weights (read-only mirror of `x`'s layout).
    pub(crate) winv: Vec<f64>,
    /// Per-column arena bases in `col_starts` form: the entry of pair
    /// `{c, r}` sits at `cols[c] + (r - c - 1)`. Only columns of the
    /// currently leased tile hold valid values.
    pub(crate) cols: Vec<usize>,
    /// The leased segments, for the write-back scatter.
    pub(crate) segs: Vec<Seg>,
    /// Requested `(col, row)` pairs of an entry lease (disk stores
    /// collect, sort, and coalesce them here; see
    /// [`TileStore::with_entries`]).
    pub(crate) pairs: Vec<(u32, u32)>,
}

/// A storage backend for the packed distance matrix, leased tile by tile.
///
/// Implementations must be [`Sync`]: one store is shared by every worker
/// of a wave-parallel pass.
pub trait TileStore: Sync {
    /// Problem dimension `n` (the matrix stores `n(n-1)/2` pairs).
    fn n(&self) -> usize;

    /// Number of stored pairs (`n(n-1)/2`).
    fn n_pairs(&self) -> usize;

    /// Lease the working set of `tile` and run `f(x, cols, winv)` on it,
    /// where the entry of pair `{c, r}` lives at
    /// `x[cols[c] + (r - c - 1)]` and `winv` mirrors that addressing.
    /// Writes through `x` are durable once `with_tile` returns.
    ///
    /// # Safety
    ///
    /// The caller must own `tile` for the duration (the wave schedule
    /// invariant): no other thread may concurrently lease a tile whose
    /// footprint shares a *pair* with this one. Concurrent leases of
    /// pair-disjoint tiles are always safe, even when they share storage
    /// blocks.
    unsafe fn with_tile(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    );

    /// Like [`TileStore::with_tile`] for callbacks that only **read**:
    /// any writes through `x` are discarded rather than written back.
    /// Residual scans use this so a disk store does not dirty (and
    /// later re-write) every block a read-only pass visits. The default
    /// forwards to [`TileStore::with_tile`], which is correct for
    /// stores whose leases alias the backing directly.
    ///
    /// # Safety
    /// Same contract as [`TileStore::with_tile`].
    unsafe fn with_tile_read(
        &self,
        tile: &Tile,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        // SAFETY: forwarded contract.
        unsafe { self.with_tile(tile, scratch, f) }
    }

    /// Lease exactly the entries a kernel will touch within `tile`'s
    /// footprint, instead of the whole footprint.
    ///
    /// `each_pair` is an enumerator: the store may invoke it (at most
    /// once, strictly **before** `f`, never concurrently with it) with an
    /// `emit(c, r)` sink, and the caller must emit every pair `{c, r}`
    /// (`c < r`, inside `tile`'s footprint) its kernel will read or
    /// write. Duplicates and arbitrary order are fine. The callback `f`
    /// then sees the exact [`TileStore::with_tile`] contract —
    /// `x[cols[c] + (r - c - 1)]`, `winv` mirroring it — but only the
    /// *emitted* entries are guaranteed to hold real values; touching a
    /// non-emitted pair is a contract violation (a disk store hands back
    /// unspecified garbage there, a memory store the live array).
    ///
    /// The default forwards to [`TileStore::with_tile`] (every emitted
    /// entry is in the footprint, so a whole-footprint lease is always
    /// correct). [`MemStore`] overrides it with the same zero-cost
    /// pass-through as `with_tile` without ever calling `each_pair`;
    /// [`DiskStore`] gathers/scatters only the blocks that intersect the
    /// emitted entries and skips the rest of the footprint entirely.
    /// Because every implementation hands the kernel bit-identical
    /// values (gathers copy, they never round), switching a pass from
    /// `with_tile` to `with_entries` cannot change results.
    ///
    /// # Safety
    /// Same contract as [`TileStore::with_tile`].
    unsafe fn with_entries(
        &self,
        tile: &Tile,
        _each_pair: &mut dyn FnMut(&mut dyn FnMut(usize, usize)),
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&SharedMut<'_, f64>, &[usize], &[f64]),
    ) {
        // SAFETY: forwarded contract; the footprint is a superset of any
        // legal entry request.
        unsafe { self.with_tile(tile, scratch, f) }
    }

    /// Lease the packed entries `[lo, hi)` (global column-major packed
    /// order) as a sequence of contiguous segments, ascending: each
    /// `f(g, x, winv)` call receives the global packed index of `x[0]`,
    /// the segment's entries, and the matching inverse weights. Every
    /// entry of the range is handed out exactly once, in ascending
    /// order. With `write = true`, mutations through `x` are durable
    /// once the call returns; with `write = false` the callback must
    /// treat `x` as read-only (a [`MemStore`] lease aliases the live
    /// backing, so writes would leak through; [`DiskStore`] discards
    /// them and keeps its blocks clean).
    ///
    /// This is the lease the CC-LP **pair phase** and the elementwise
    /// residual scans run on: pair updates are independent per entry, so
    /// concurrent calls over disjoint ranges (the classic
    /// [`chunk_range`] partition) are race-free and the disk-backed pass
    /// is bitwise identical to the resident one.
    ///
    /// # Safety
    ///
    /// Concurrent calls must use pairwise-disjoint `[lo, hi)` ranges,
    /// and no tile lease may overlap the range for the duration.
    ///
    /// [`chunk_range`]: crate::util::parallel::chunk_range
    unsafe fn with_pair_range(
        &self,
        lo: usize,
        hi: usize,
        write: bool,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(usize, &mut [f64], &[f64]),
    );

    /// Hint that the caller will lease `tile` soon (the next tile in its
    /// sweep order). Stores may warm their cache asynchronously; values
    /// are never modified, so prefetching cannot change results.
    fn prefetch(&self, _tile: &Tile) {}
}

/// Which [`TileStore`] backend a solve uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum StoreKind {
    /// Resident packed array (the classic path; the default).
    #[default]
    Mem,
    /// File-backed tile blocks with a bounded resident working set.
    Disk,
    /// Plane sharded across worker processes behind Unix-socket leases
    /// ([`ShardStore`]).
    Shard,
}

impl StoreKind {
    /// Parse a CLI name (`mem` / `disk` / `shard`).
    pub fn parse(s: &str) -> Option<StoreKind> {
        match s {
            "mem" | "memory" => Some(StoreKind::Mem),
            "disk" | "file" => Some(StoreKind::Disk),
            "shard" | "sharded" => Some(StoreKind::Shard),
            _ => None,
        }
    }

    /// CLI name of the backend.
    pub fn name(self) -> &'static str {
        match self {
            StoreKind::Mem => "mem",
            StoreKind::Disk => "disk",
            StoreKind::Shard => "shard",
        }
    }
}

/// Storage configuration for a solve (`--store`, `--store-dir`,
/// `--store-budget-mb` on the CLI).
#[derive(Clone, Debug)]
pub struct StoreCfg {
    /// Backend selection.
    pub kind: StoreKind,
    /// Directory holding the store file (disk backend; created on
    /// demand). The tile file itself is `<dir>/x.tiles`.
    pub dir: PathBuf,
    /// Resident block-cache budget in bytes (disk backend; the CLI flag
    /// is in MiB), split evenly between the `X` plane and the streamed
    /// read-only `W` plane (the packed inverse weights live in a sibling
    /// spill file rather than staying resident — see [`DiskStore`]). The
    /// true resident footprint adds one `O(n · b)` gather arena per
    /// worker plus the `O(n)` address tables. Budgets smaller than a
    /// single block still work — the block being copied is exempt from
    /// eviction — they just churn harder.
    pub budget_bytes: usize,
    /// Deterministic fault injection at the disk store's block I/O layer
    /// (`--fault-plan` / `METRIC_PROJ_FAULTS`); `None` in production.
    pub faults: Option<Arc<FaultPlan>>,
    /// Bounded retry budget per block operation (`--store-retries`).
    pub retries: u32,
    /// Number of shard workers (`--workers`; shard backend only).
    pub workers: usize,
    /// How the shard backend runs its workers: `Some(exe)` spawns real
    /// worker *processes* from that binary (the CLI passes its own
    /// `current_exe()`, which re-enters as the hidden `shard-worker`
    /// subcommand); `None` runs the same worker loop on in-process
    /// threads over socketpairs — the embedder/bench/unit-test mode,
    /// byte-for-byte the same protocol.
    pub worker_exe: Option<PathBuf>,
}

impl Default for StoreCfg {
    fn default() -> Self {
        StoreCfg {
            kind: StoreKind::Mem,
            dir: PathBuf::from("store"),
            budget_bytes: 64 << 20,
            faults: None,
            retries: DEFAULT_STORE_RETRIES,
            workers: 2,
            worker_exe: None,
        }
    }
}

impl StoreCfg {
    /// The in-memory configuration (what every plain `solve` call uses).
    pub fn mem() -> StoreCfg {
        StoreCfg::default()
    }

    /// A disk configuration rooted at `dir` with the given cache budget
    /// in bytes.
    pub fn disk(dir: impl Into<PathBuf>, budget_bytes: usize) -> StoreCfg {
        StoreCfg {
            kind: StoreKind::Disk,
            dir: dir.into(),
            budget_bytes,
            ..StoreCfg::default()
        }
    }

    /// A shard configuration rooted at `dir` with `workers` in-process
    /// worker threads (set [`StoreCfg::worker_exe`] afterwards to use
    /// real processes).
    pub fn shard(dir: impl Into<PathBuf>, workers: usize) -> StoreCfg {
        StoreCfg {
            kind: StoreKind::Shard,
            dir: dir.into(),
            workers,
            ..StoreCfg::default()
        }
    }

    /// Path of the tile file this configuration addresses.
    pub fn x_path(&self) -> PathBuf {
        self.dir.join("x.tiles")
    }

    /// The robustness tuning handed to [`DiskStore`] constructors.
    pub fn tuning(&self) -> StoreTuning {
        StoreTuning { faults: self.faults.clone(), retries: self.retries }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_kind_parses() {
        assert_eq!(StoreKind::parse("mem"), Some(StoreKind::Mem));
        assert_eq!(StoreKind::parse("memory"), Some(StoreKind::Mem));
        assert_eq!(StoreKind::parse("disk"), Some(StoreKind::Disk));
        assert_eq!(StoreKind::parse("file"), Some(StoreKind::Disk));
        assert_eq!(StoreKind::parse("shard"), Some(StoreKind::Shard));
        assert_eq!(StoreKind::parse("sharded"), Some(StoreKind::Shard));
        assert_eq!(StoreKind::parse("tape"), None);
        for k in [StoreKind::Mem, StoreKind::Disk, StoreKind::Shard] {
            assert_eq!(StoreKind::parse(k.name()), Some(k));
        }
        assert_eq!(StoreKind::default(), StoreKind::Mem);
    }

    #[test]
    fn cfg_paths_and_budget() {
        let cfg = StoreCfg::disk("/tmp/xyz", 2 << 20);
        assert_eq!(cfg.kind, StoreKind::Disk);
        assert_eq!(cfg.x_path(), PathBuf::from("/tmp/xyz/x.tiles"));
        assert_eq!(cfg.budget_bytes, 2 << 20);
        assert_eq!(StoreCfg::mem().kind, StoreKind::Mem);
        let sh = StoreCfg::shard("/tmp/sh", 4);
        assert_eq!(sh.kind, StoreKind::Shard);
        assert_eq!(sh.workers, 4);
        assert!(sh.worker_exe.is_none());
    }
}
