//! Block decomposition of the packed strict-lower triangle for the
//! on-disk tile store.
//!
//! Columns are grouped into **column blocks** of `block` consecutive
//! columns, rows into **row blocks** of `block` consecutive rows. Block
//! `(cb, rb)` (valid for `rb >= cb`, since stored pairs have
//! `row > col`) holds, for each column `c` of its column range, the
//! contiguous rows `[max(rb·block, c+1), min((rb+1)·block, n))` —
//! column-major within the block, exactly like the packed matrix itself.
//!
//! This is the `(i, k)` blocking of the wave schedule: a solver tile
//! with `i`-block `a` and `k`-block `e` touches only the block row
//! `(a, a..=e)` and the block column `(a..=e, e)` of this grid, and
//! every per-column span of its footprint
//! ([`crate::solver::tiling::for_each_tile_col`]) maps to a short run of
//! consecutive blocks down one block column. Diagonal blocks are
//! triangular; all offsets are precomputed so block I/O is one seek.

/// Immutable geometry of a blocked packed triangle.
#[derive(Clone, Debug)]
pub struct BlockLayout {
    n: usize,
    block: usize,
    /// Number of blocks per side: `ceil(n / block)`.
    nb: usize,
    /// Entry offset of each block in block order, plus one final total
    /// (`offsets.len() == n_blocks() + 1`).
    offsets: Vec<u64>,
}

impl BlockLayout {
    /// Build the layout for dimension `n` and block size `block >= 1`.
    pub fn new(n: usize, block: usize) -> BlockLayout {
        assert!(n >= 1, "BlockLayout needs n >= 1");
        assert!(block >= 1, "BlockLayout needs block >= 1");
        let nb = n.div_ceil(block);
        let mut offsets = Vec::with_capacity(nb * (nb + 1) / 2 + 1);
        let mut acc = 0u64;
        for cb in 0..nb {
            for rb in cb..nb {
                offsets.push(acc);
                let mut cnt = 0u64;
                Self::block_cols(n, block, cb, rb, |_, lo, hi| cnt += (hi - lo) as u64);
                acc += cnt;
            }
        }
        offsets.push(acc);
        debug_assert_eq!(acc as usize, n * (n - 1) / 2);
        BlockLayout { n, block, nb, offsets }
    }

    /// Dimension `n`.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Block side length.
    pub fn block(&self) -> usize {
        self.block
    }

    /// Blocks per side of the grid.
    pub fn blocks_per_side(&self) -> usize {
        self.nb
    }

    /// Total number of blocks (`nb·(nb+1)/2`, including empty ones).
    pub fn n_blocks(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total stored entries (`n(n-1)/2`).
    pub fn total_entries(&self) -> u64 {
        *self.offsets.last().expect("offsets holds a final total")
    }

    /// Linear index of block `(cb, rb)`, `cb <= rb < nb`.
    #[inline]
    pub fn block_index(&self, cb: usize, rb: usize) -> usize {
        debug_assert!(cb <= rb && rb < self.nb);
        cb * self.nb - cb * (cb.saturating_sub(1)) / 2 - cb + rb
    }

    /// Entry offset of block `idx` within the data region.
    #[inline]
    pub fn block_offset(&self, idx: usize) -> u64 {
        self.offsets[idx]
    }

    /// Entry count of block `idx`.
    #[inline]
    pub fn block_len(&self, idx: usize) -> usize {
        (self.offsets[idx + 1] - self.offsets[idx]) as usize
    }

    /// Visit every block as `(cb, rb, idx)` in block order.
    pub fn for_each_block<F: FnMut(usize, usize, usize)>(&self, mut f: F) {
        let mut idx = 0usize;
        for cb in 0..self.nb {
            for rb in cb..self.nb {
                f(cb, rb, idx);
                idx += 1;
            }
        }
    }

    /// Visit the nonempty columns of block `(cb, rb)` as
    /// `(c, row_lo, row_hi, base)`: rows `[row_lo, row_hi)` of column `c`
    /// sit at `[base, base + row_hi - row_lo)` within the block buffer.
    #[inline]
    pub fn for_each_block_col<F: FnMut(usize, usize, usize, usize)>(
        &self,
        cb: usize,
        rb: usize,
        mut f: F,
    ) {
        let mut base = 0usize;
        Self::block_cols(self.n, self.block, cb, rb, |c, lo, hi| {
            f(c, lo, hi, base);
            base += hi - lo;
        });
    }

    /// Block coordinate of a row or column index.
    #[inline]
    pub fn block_of(&self, index: usize) -> usize {
        index / self.block
    }

    /// Where column `c` sits inside block `(cb, rb)`: returns
    /// `(base, row_lo)` such that the block buffer holds rows
    /// `[row_lo, min((rb+1)·block, n))` of column `c` starting at
    /// `base`. `c` must belong to column block `cb`.
    #[inline]
    pub fn block_col_base(&self, cb: usize, rb: usize, c: usize) -> (usize, usize) {
        debug_assert_eq!(self.block_of(c), cb);
        let r_cap = ((rb + 1) * self.block).min(self.n);
        let mut base = 0usize;
        for cc in (cb * self.block)..c {
            let lo = (rb * self.block).max(cc + 1);
            base += r_cap.saturating_sub(lo);
        }
        (base, (rb * self.block).max(c + 1))
    }

    fn block_cols<F: FnMut(usize, usize, usize)>(
        n: usize,
        block: usize,
        cb: usize,
        rb: usize,
        mut f: F,
    ) {
        let c_hi = ((cb + 1) * block).min(n);
        let r_cap = ((rb + 1) * block).min(n);
        for c in (cb * block)..c_hi {
            let lo = (rb * block).max(c + 1);
            if lo < r_cap {
                f(c, lo, r_cap);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::packed::n_pairs;

    #[test]
    fn totals_match_n_pairs() {
        for (n, b) in [(1usize, 1usize), (2, 1), (6, 2), (10, 3), (17, 5), (23, 7), (30, 40)] {
            let lay = BlockLayout::new(n, b);
            assert_eq!(lay.total_entries() as usize, n_pairs(n), "n={n} b={b}");
            assert_eq!(lay.n_blocks(), lay.blocks_per_side() * (lay.blocks_per_side() + 1) / 2);
        }
    }

    #[test]
    fn block_index_is_block_order() {
        for (n, b) in [(10usize, 3usize), (23, 7), (9, 2)] {
            let lay = BlockLayout::new(n, b);
            let mut expect = 0usize;
            lay.for_each_block(|cb, rb, idx| {
                assert_eq!(idx, expect, "n={n} b={b} ({cb},{rb})");
                assert_eq!(lay.block_index(cb, rb), idx, "n={n} b={b} ({cb},{rb})");
                expect += 1;
            });
            assert_eq!(expect, lay.n_blocks());
        }
    }

    #[test]
    fn blocks_partition_every_pair_exactly_once() {
        for (n, b) in [(7usize, 2usize), (14, 3), (19, 4), (12, 12), (11, 40)] {
            let lay = BlockLayout::new(n, b);
            let mut seen = vec![false; n_pairs(n)];
            let m = crate::matrix::PackedSym::zeros(n);
            lay.for_each_block(|cb, rb, idx| {
                let mut within = 0usize;
                lay.for_each_block_col(cb, rb, |c, lo, hi, base| {
                    assert_eq!(base, within, "column bases must be prefix sums");
                    for r in lo..hi {
                        assert!(c < r && r < n);
                        assert_eq!(lay.block_of(c), cb);
                        assert_eq!(lay.block_of(r), rb);
                        let g = m.idx(c, r);
                        assert!(!seen[g], "pair ({c},{r}) covered twice (n={n} b={b})");
                        seen[g] = true;
                    }
                    within += hi - lo;
                });
                assert_eq!(within, lay.block_len(idx), "n={n} b={b} block ({cb},{rb})");
            });
            assert!(seen.iter().all(|&s| s), "n={n} b={b}: uncovered pairs");
        }
    }

    #[test]
    fn block_col_base_matches_enumeration() {
        for (n, b) in [(9usize, 2usize), (14, 3), (23, 7)] {
            let lay = BlockLayout::new(n, b);
            lay.for_each_block(|cb, rb, _| {
                lay.for_each_block_col(cb, rb, |c, lo, _hi, base| {
                    assert_eq!(lay.block_col_base(cb, rb, c), (base, lo), "n={n} b={b}");
                });
            });
        }
    }

    #[test]
    fn offsets_are_contiguous() {
        let lay = BlockLayout::new(20, 6);
        let mut acc = 0u64;
        for idx in 0..lay.n_blocks() {
            assert_eq!(lay.block_offset(idx), acc);
            acc += lay.block_len(idx) as u64;
        }
        assert_eq!(acc, lay.total_entries());
    }
}
