//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them from
//! the Rust hot path.
//!
//! This is the L3↔L1/L2 bridge of the architecture: `make artifacts` runs
//! Python/JAX once (`python/compile/aot.py`), emitting `artifacts/*.hlo.txt`;
//! this module loads them via the `xla` crate
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile` →
//! `execute`). Python never runs at solve time.

pub mod engine;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Where `make artifacts` drops the AOT-compiled HLO-text kernels,
/// relative to the working directory — shared by the CLI and the solver
/// paths that load the engine on demand (e.g. the `engine` sweep
/// backend).
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// A PJRT client plus the artifact directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
}

/// One compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Number of outputs in the (always-tupled) result.
    n_outputs: usize,
    name: String,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, artifacts_dir: artifacts_dir.into() })
    }

    /// Platform name reported by PJRT (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Device count.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Artifact directory.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load and compile `<artifacts_dir>/<name>.hlo.txt`.
    /// `n_outputs` must match the JAX function's output arity (aot.py lowers
    /// with `return_tuple=True`, so results always arrive as one tuple).
    pub fn load(&self, name: &str, n_outputs: usize) -> Result<Executable> {
        let path = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, n_outputs, name: name.to_string() })
    }
}

impl Executable {
    /// Execute with the given input literals; returns the untupled outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let literal = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        let outs = literal.to_tuple().context("untupling result")?;
        anyhow::ensure!(
            outs.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            outs.len()
        );
        Ok(outs)
    }

    /// Artifact name.
    pub fn name(&self) -> &str {
        &self.name
    }
}

/// Build an `f32` literal of shape `[n]` from a slice.
pub fn literal_f32(values: &[f32]) -> xla::Literal {
    xla::Literal::vec1(values)
}

/// Build an `f32` literal of shape `[rows, cols]` from row-major data.
pub fn literal_f32_2d(values: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(values.len() == rows * cols, "shape mismatch");
    Ok(xla::Literal::vec1(values).reshape(&[rows as i64, cols as i64])?)
}

/// Extract an `f32` vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        Path::new("artifacts/project_b1024.hlo.txt").exists()
    }

    #[test]
    fn client_comes_up() {
        let rt = PjrtRuntime::cpu("artifacts").unwrap();
        assert_eq!(rt.platform(), "cpu");
        assert!(rt.device_count() >= 1);
    }

    #[test]
    fn load_missing_artifact_errors() {
        let rt = PjrtRuntime::cpu("artifacts").unwrap();
        assert!(rt.load("no_such_artifact", 1).is_err());
    }

    #[test]
    fn project_artifact_roundtrip() {
        if !artifacts_available() {
            crate::telemetry::warn("skipping: run `make artifacts` first");
            return;
        }
        let rt = PjrtRuntime::cpu("artifacts").unwrap();
        let exe = rt.load("project_b1024", 2).unwrap();
        let b = 1024usize;
        // paper's worked example in lane 0: (3,1,1) unit weights
        let mut x = vec![0.0f32; b * 3];
        let w = vec![1.0f32; b * 3];
        let y = vec![0.0f32; b * 3];
        x[0] = 3.0;
        x[1] = 1.0;
        x[2] = 1.0;
        let outs = exe
            .run(&[
                literal_f32_2d(&x, b, 3).unwrap(),
                literal_f32_2d(&w, b, 3).unwrap(),
                literal_f32_2d(&y, b, 3).unwrap(),
            ])
            .unwrap();
        assert_eq!(outs.len(), 2);
        let xo = to_vec_f32(&outs[0]).unwrap();
        let yo = to_vec_f32(&outs[1]).unwrap();
        assert!((xo[0] - (3.0 - 1.0 / 3.0)).abs() < 1e-5, "xo[0]={}", xo[0]);
        assert!((xo[1] - (1.0 + 1.0 / 3.0)).abs() < 1e-5);
        assert!((yo[0] - 1.0 / 3.0).abs() < 1e-5);
        // untouched lanes stay zero
        assert_eq!(xo[3], 0.0);
    }

    #[test]
    fn objective_artifact_matches_rust_formula() {
        if !artifacts_available() {
            crate::telemetry::warn("skipping: run `make artifacts` first");
            return;
        }
        let rt = PjrtRuntime::cpu("artifacts").unwrap();
        let exe = rt.load("objective_b4096", 1).unwrap();
        let b = 4096usize;
        let x = vec![0.5f32; b];
        let f = vec![0.25f32; b];
        let w = vec![2.0f32; b];
        let d = vec![1.0f32; b];
        let yu = vec![0.1f32; b];
        let yl = vec![0.05f32; b];
        let yb = vec![0.2f32; b];
        let outs = exe
            .run(&[
                literal_f32(&x),
                literal_f32(&f),
                literal_f32(&w),
                literal_f32(&d),
                literal_f32(&yu),
                literal_f32(&yl),
                literal_f32(&yb),
            ])
            .unwrap();
        let terms = to_vec_f32(&outs[0]).unwrap();
        let bf = b as f32;
        assert!((terms[0] - 2.0 * 0.25 * bf).abs() / bf < 1e-5); // c'x
        assert!((terms[1] - 2.0 * (0.25 + 0.0625) * bf).abs() / bf < 1e-4); // x'Wx
        assert!((terms[2] - (0.05 + 0.2) * bf).abs() / bf < 1e-5); // b'yhat
        assert!((terms[3] - 2.0 * 0.5 * bf).abs() / bf < 1e-4); // lp
    }
}
