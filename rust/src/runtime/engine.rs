//! The XLA-backed projection engine: executes the AOT-compiled JAX/Pallas
//! kernels (L1/L2) from the Rust coordinator (L3).
//!
//! A conflict-free wave of the schedule is exactly a data-parallel batch,
//! so the engine's contract mirrors the scalar hot path: give it a batch
//! of triplets (variables, inverse weights, duals) and it returns the
//! post-visit values. Batches are padded with identity lanes (x = 0,
//! w⁻¹ = 1, y = 0 — a satisfied constraint with no dual is a no-op) up to
//! the nearest compiled batch size, and chunked by the largest.
//!
//! Artifacts are f32 (the TPU-faithful dtype); the f64 coordinator state
//! is converted at the boundary. The CPU scalar engine remains the
//! default production path; this engine exists to prove the three-layer
//! composition and for the engine ablation bench.

use super::{literal_f32, literal_f32_2d, to_vec_f32, Executable, PjrtRuntime};
use anyhow::{Context, Result};

/// Compiled batch sizes emitted by python/compile/aot.py.
pub const PROJECT_BATCHES: [usize; 3] = [1024, 4096, 16384];
/// Pair-sweep batch size emitted by aot.py.
pub const PAIR_BATCH: usize = 4096;
/// Objective batch size emitted by aot.py.
pub const OBJECTIVE_BATCH: usize = 4096;

/// Engine holding all compiled executables.
pub struct XlaEngine {
    /// (batch, executable), ascending batch size.
    project: Vec<(usize, Executable)>,
    pair: Executable,
    objective: Executable,
    platform: String,
}

impl XlaEngine {
    /// Load and compile all artifacts from `artifacts_dir`.
    pub fn load(artifacts_dir: &str) -> Result<XlaEngine> {
        let rt = PjrtRuntime::cpu(artifacts_dir)?;
        let mut project = Vec::new();
        for b in PROJECT_BATCHES {
            let exe = rt
                .load(&format!("project_b{b}"), 2)
                .with_context(|| format!("loading project_b{b} (run `make artifacts`)"))?;
            project.push((b, exe));
        }
        // `project_batch` picks the smallest fitting batch by scanning in
        // order, so the list must be non-empty and strictly ascending —
        // validate here instead of trusting the artifact enumeration.
        project.sort_by_key(|&(b, _)| b);
        anyhow::ensure!(
            !project.is_empty(),
            "no project executables compiled (run `make artifacts`)"
        );
        anyhow::ensure!(
            project.windows(2).all(|w| w[0].0 < w[1].0),
            "duplicate project batch sizes in artifacts"
        );
        let pair = rt.load(&format!("pair_b{PAIR_BATCH}"), 5)?;
        let objective = rt.load(&format!("objective_b{OBJECTIVE_BATCH}"), 1)?;
        Ok(XlaEngine { project, pair, objective, platform: rt.platform() })
    }

    /// PJRT platform executing the kernels.
    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Visit the 3 metric constraints of `n_lanes` independent triplets.
    /// `x3`, `winv3`, `y3` are row-major `[n_lanes, 3]`; `x3` and `y3` are
    /// updated in place.
    pub fn project_batch(
        &self,
        x3: &mut Vec<f32>,
        winv3: &[f32],
        y3: &mut Vec<f32>,
    ) -> Result<()> {
        let n_lanes = x3.len() / 3;
        anyhow::ensure!(x3.len() == n_lanes * 3 && winv3.len() == x3.len());
        let sizes: Vec<usize> = self.project.iter().map(|p| p.0).collect();
        let mut done = 0usize;
        while done < n_lanes {
            let remaining = n_lanes - done;
            let idx = pick_batch(&sizes, remaining).ok_or_else(|| {
                anyhow::anyhow!("engine holds no project executables (run `make artifacts`)")
            })?;
            let (b, exe) = &self.project[idx];
            let lanes = remaining.min(*b);
            let (lo, hi) = (done * 3, (done + lanes) * 3);
            // Pad with identity lanes: x=0 satisfies all metric rows, y=0.
            let mut xb = vec![0.0f32; b * 3];
            let mut wb = vec![1.0f32; b * 3];
            let mut yb = vec![0.0f32; b * 3];
            xb[..hi - lo].copy_from_slice(&x3[lo..hi]);
            wb[..hi - lo].copy_from_slice(&winv3[lo..hi]);
            yb[..hi - lo].copy_from_slice(&y3[lo..hi]);
            let outs = exe.run(&[
                literal_f32_2d(&xb, *b, 3)?,
                literal_f32_2d(&wb, *b, 3)?,
                literal_f32_2d(&yb, *b, 3)?,
            ])?;
            let xo = to_vec_f32(&outs[0])?;
            let yo = to_vec_f32(&outs[1])?;
            x3[lo..hi].copy_from_slice(&xo[..hi - lo]);
            y3[lo..hi].copy_from_slice(&yo[..hi - lo]);
            done += lanes;
        }
        Ok(())
    }

    /// Visit the pair (+ box) constraints for a batch of pairs; all arrays
    /// have the same length; `x`, `f`, `yu`, `yl`, `yb` update in place.
    #[allow(clippy::too_many_arguments)]
    pub fn pair_sweep(
        &self,
        x: &mut [f32],
        f: &mut [f32],
        winv: &[f32],
        d: &[f32],
        yu: &mut [f32],
        yl: &mut [f32],
        yb: &mut [f32],
    ) -> Result<()> {
        let m = x.len();
        let mut done = 0usize;
        while done < m {
            let lanes = (m - done).min(PAIR_BATCH);
            let (lo, hi) = (done, done + lanes);
            let pad = |src: &[f32], fill: f32| -> Vec<f32> {
                let mut v = vec![fill; PAIR_BATCH];
                v[..lanes].copy_from_slice(&src[lo..hi]);
                v
            };
            // identity lanes: x=d=0, f=1 (slack), winv=1, duals 0 -> no-op
            let outs = self.pair.run(&[
                literal_f32(&pad(x, 0.0)),
                literal_f32(&pad(f, 1.0)),
                literal_f32(&pad(winv, 1.0)),
                literal_f32(&pad(d, 0.0)),
                literal_f32(&pad(yu, 0.0)),
                literal_f32(&pad(yl, 0.0)),
                literal_f32(&pad(yb, 0.0)),
            ])?;
            let unpack = |lit: &xla::Literal, dst: &mut [f32]| -> Result<()> {
                let v = to_vec_f32(lit)?;
                dst[lo..hi].copy_from_slice(&v[..lanes]);
                Ok(())
            };
            unpack(&outs[0], x)?;
            unpack(&outs[1], f)?;
            unpack(&outs[2], yu)?;
            unpack(&outs[3], yl)?;
            unpack(&outs[4], yb)?;
            done += lanes;
        }
        Ok(())
    }

    /// Accumulate objective terms `[c'x, x'Wx, b'yhat, lp]` over all pairs.
    #[allow(clippy::too_many_arguments)]
    pub fn objective_terms(
        &self,
        x: &[f32],
        f: &[f32],
        w: &[f32],
        d: &[f32],
        yu: &[f32],
        yl: &[f32],
        yb: &[f32],
    ) -> Result<[f64; 4]> {
        let m = x.len();
        let mut acc = [0.0f64; 4];
        let mut done = 0usize;
        while done < m {
            let lanes = (m - done).min(OBJECTIVE_BATCH);
            let (lo, hi) = (done, done + lanes);
            let pad = |src: &[f32]| -> Vec<f32> {
                let mut v = vec![0.0f32; OBJECTIVE_BATCH];
                v[..lanes].copy_from_slice(&src[lo..hi]);
                v
            };
            // zero-weight padding contributes nothing to any term
            let outs = self.objective.run(&[
                literal_f32(&pad(x)),
                literal_f32(&pad(f)),
                literal_f32(&pad(w)),
                literal_f32(&pad(d)),
                literal_f32(&pad(yu)),
                literal_f32(&pad(yl)),
                literal_f32(&pad(yb)),
            ])?;
            let terms = to_vec_f32(&outs[0])?;
            for (a, t) in acc.iter_mut().zip(terms.iter()) {
                *a += *t as f64;
            }
            done += lanes;
        }
        Ok(acc)
    }
}

/// Batch choice for `remaining` lanes over ascending `batches`: index of
/// the smallest compiled batch that fits, else of the largest (which the
/// caller chunks through). `None` iff `batches` is empty — the caller
/// turns that into an error instead of the old `last().unwrap()` panic.
fn pick_batch(batches: &[usize], remaining: usize) -> Option<usize> {
    if batches.is_empty() {
        return None;
    }
    Some(batches.iter().position(|&b| b >= remaining).unwrap_or(batches.len() - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<XlaEngine> {
        if !Path::new("artifacts/project_b1024.hlo.txt").exists() {
            crate::telemetry::warn("skipping: run `make artifacts` first");
            return None;
        }
        Some(XlaEngine::load("artifacts").unwrap())
    }

    #[test]
    fn pick_batch_prefers_smallest_fit_then_chunks() {
        let sizes = [1024usize, 4096, 16384];
        assert_eq!(pick_batch(&sizes, 1), Some(0));
        assert_eq!(pick_batch(&sizes, 1024), Some(0));
        assert_eq!(pick_batch(&sizes, 1025), Some(1));
        assert_eq!(pick_batch(&sizes, 16384), Some(2));
        // Oversized batches chunk through the largest executable.
        assert_eq!(pick_batch(&sizes, 100_000), Some(2));
        // Zero executables is an error at the caller, never a panic.
        assert_eq!(pick_batch(&[], 7), None);
    }

    #[test]
    fn project_batch_odd_sizes_and_padding() {
        let Some(eng) = engine() else { return };
        for lanes in [1usize, 3, 100, 1025] {
            let mut x = vec![0.0f32; lanes * 3];
            let w = vec![1.0f32; lanes * 3];
            let mut y = vec![0.0f32; lanes * 3];
            // violate lane `lanes-1`
            x[(lanes - 1) * 3] = 3.0;
            x[(lanes - 1) * 3 + 1] = 1.0;
            x[(lanes - 1) * 3 + 2] = 1.0;
            eng.project_batch(&mut x, &w, &mut y).unwrap();
            let base = (lanes - 1) * 3;
            assert!((x[base] - (3.0 - 1.0 / 3.0)).abs() < 1e-5, "lanes={lanes}");
            assert!((y[base] - 1.0 / 3.0).abs() < 1e-5);
            if lanes > 1 {
                assert_eq!(x[0], 0.0);
                assert_eq!(y[0], 0.0);
            }
        }
    }

    #[test]
    fn project_batch_matches_rust_engine() {
        let Some(eng) = engine() else { return };
        use crate::solver::projection::visit_metric;
        use crate::util::shared::SharedMut;
        let mut rng = crate::util::rng::Rng::new(17);
        let lanes = 200usize;
        let mut x: Vec<f32> = (0..lanes * 3).map(|_| rng.f64_in(-1.0, 2.0) as f32).collect();
        let w: Vec<f32> = (0..lanes * 3).map(|_| rng.f64_in(0.4, 2.0) as f32).collect();
        let mut y = vec![0.0f32; lanes * 3];
        // rust reference on f64 copies
        let mut xr: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let wr: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let mut yr = vec![[0.0f64; 3]; lanes];
        {
            let xs = SharedMut::new(xr.as_mut_slice());
            for lane in 0..lanes {
                let b = lane * 3;
                for t in 0..3 {
                    let theta =
                        unsafe { visit_metric(&xs, &wr, b, b + 1, b + 2, t, yr[lane][t]) };
                    yr[lane][t] = theta;
                }
            }
        }
        eng.project_batch(&mut x, &w, &mut y).unwrap();
        for i in 0..lanes * 3 {
            assert!(
                (x[i] as f64 - xr[i]).abs() < 1e-4,
                "lane {} differs: xla={} rust={}",
                i / 3,
                x[i],
                xr[i]
            );
        }
    }

    #[test]
    fn pair_sweep_projects_onto_planes() {
        let Some(eng) = engine() else { return };
        let m = 10usize;
        let mut x = vec![2.0f32; m];
        let mut f = vec![0.0f32; m];
        let winv = vec![1.0f32; m];
        let d = vec![1.0f32; m];
        let (mut yu, mut yl, mut yb) = (vec![0.0; m], vec![0.0; m], vec![0.0; m]);
        eng.pair_sweep(&mut x, &mut f, &winv, &d, &mut yu, &mut yl, &mut yb).unwrap();
        for e in 0..m {
            // upper: x - f <= d must now hold (approximately, f32)
            assert!(x[e] - f[e] - d[e] < 1e-5);
            // box: x <= 1
            assert!(x[e] <= 1.0 + 1e-5);
        }
    }

    #[test]
    fn objective_terms_accumulate_over_chunks() {
        let Some(eng) = engine() else { return };
        let m = OBJECTIVE_BATCH + 137; // forces 2 chunks
        let x = vec![0.5f32; m];
        let f = vec![0.25f32; m];
        let w = vec![1.0f32; m];
        let d = vec![0.0f32; m];
        let z = vec![0.0f32; m];
        let acc = eng.objective_terms(&x, &f, &w, &d, &z, &z, &z).unwrap();
        let mf = m as f64;
        assert!((acc[0] - 0.25 * mf).abs() / mf < 1e-5);
        assert!((acc[1] - (0.25 + 0.0625) * mf).abs() / mf < 1e-4);
        assert!((acc[3] - 0.5 * mf).abs() / mf < 1e-4);
    }
}
