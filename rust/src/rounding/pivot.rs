//! LP-pivot rounding (Ailon–Charikar–Newman style KwikCluster on the
//! fractional solution): repeatedly pick a random unclustered pivot `u`
//! and gather every unclustered `v` with `x_uv < 1/2` into its cluster.
//! Solving the LP first and pivoting on the fractional distances is the
//! scheme behind the best known approximation factors for correlation
//! clustering ([2], [11] in the paper).

use crate::matrix::PackedSym;
use crate::util::rng::Rng;

/// One pivot rounding pass with the given RNG seed.
pub fn round(x: &PackedSym, seed: u64) -> Vec<usize> {
    let n = x.n();
    let mut rng = Rng::new(seed);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut label = vec![usize::MAX; n];
    let mut next = 0usize;
    for &u in &order {
        if label[u] != usize::MAX {
            continue;
        }
        label[u] = next;
        for v in 0..n {
            if v != u && label[v] == usize::MAX && x.get(u, v) < 0.5 {
                label[v] = next;
            }
        }
        next += 1;
    }
    label
}

/// Run `trials` pivot roundings and keep the one with the best (lowest)
/// objective according to `score`. Returns (labels, best_score).
pub fn round_best<F>(x: &PackedSym, trials: usize, seed: u64, score: F) -> (Vec<usize>, f64)
where
    F: Fn(&[usize]) -> f64,
{
    assert!(trials >= 1);
    let mut rng = Rng::new(seed);
    let mut best: Option<(Vec<usize>, f64)> = None;
    for _ in 0..trials {
        let labels = round(x, rng.next_u64());
        let s = score(&labels);
        if best.as_ref().map(|(_, bs)| s < *bs).unwrap_or(true) {
            best = Some((labels, s));
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::{cc_objective, CcLpInstance};

    #[test]
    fn ideal_distances_recovered() {
        let x = PackedSym::from_fn(6, |i, j| if (i < 3) == (j < 3) { 0.0 } else { 1.0 });
        let labels = round(&x, 7);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[0], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn deterministic_given_seed() {
        let x = PackedSym::from_fn(10, |i, j| ((i * j) % 3) as f64 / 2.0);
        assert_eq!(round(&x, 42), round(&x, 42));
    }

    #[test]
    fn every_node_labeled() {
        let x = PackedSym::filled(20, 0.7);
        let labels = round(&x, 3);
        assert!(labels.iter().all(|&l| l != usize::MAX));
    }

    #[test]
    fn round_best_improves_or_matches_single() {
        let inst = CcLpInstance::random(12, 0.4, 0.5, 1.5, 5);
        // pretend the LP solution is the target matrix itself
        let x = inst.d.clone();
        let single = cc_objective(&inst, &round(&x, 1));
        let (_, best) = round_best(&x, 20, 1, |l| cc_objective(&inst, l));
        assert!(best <= single + 1e-12);
    }

    #[test]
    fn pivot_respects_half_threshold() {
        // pivot u gathers exactly x_uv < 1/2 among unclustered
        let mut x = PackedSym::filled(3, 1.0);
        x.set(0, 1, 0.4);
        x.set(0, 2, 0.6);
        // force pivot order starting at 0 by trying seeds until order[0]==0
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let mut order: Vec<usize> = (0..3).collect();
            rng.shuffle(&mut order);
            if order[0] == 0 {
                let labels = round(&x, seed);
                assert_eq!(labels[0], labels[1]);
                assert_ne!(labels[0], labels[2]);
                return;
            }
        }
        panic!("no seed found with pivot 0 first");
    }
}
