//! Threshold rounding: cluster = connected component of the graph whose
//! edges are pairs with LP distance below a threshold (1/2 by default).
//! This is the simplest scheme with provable guarantees for special cases
//! and a strong practical baseline.

use crate::matrix::PackedSym;

/// Round distances `x` into a clustering: connect pairs with
/// `x_ij < threshold`, return connected-component labels.
pub fn round(x: &PackedSym, threshold: f64) -> Vec<usize> {
    let n = x.n();
    // Union-find over threshold edges.
    let mut parent: Vec<usize> = (0..n).collect();
    fn find(parent: &mut Vec<usize>, mut a: usize) -> usize {
        while parent[a] != a {
            parent[a] = parent[parent[a]];
            a = parent[a];
        }
        a
    }
    for (i, j, v) in x.iter_pairs() {
        if v < threshold {
            let (ra, rb) = (find(&mut parent, i), find(&mut parent, j));
            if ra != rb {
                parent[ra.max(rb)] = ra.min(rb);
            }
        }
    }
    // Compact labels to 0..k by first occurrence.
    let mut label = vec![usize::MAX; n];
    let mut next = 0;
    let mut out = vec![0usize; n];
    for u in 0..n {
        let r = find(&mut parent, u);
        if label[r] == usize::MAX {
            label[r] = next;
            next += 1;
        }
        out[u] = label[r];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_blocks_split() {
        // distances: 0 within {0,1}, {2,3}; 1 across
        let x = PackedSym::from_fn(4, |i, j| if (i < 2) == (j < 2) { 0.0 } else { 1.0 });
        let labels = round(&x, 0.5);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_ne!(labels[0], labels[2]);
    }

    #[test]
    fn all_far_apart_is_singletons() {
        let x = PackedSym::filled(5, 1.0);
        let labels = round(&x, 0.5);
        let set: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(set.len(), 5);
    }

    #[test]
    fn all_close_is_one_cluster() {
        let x = PackedSym::filled(5, 0.0);
        let labels = round(&x, 0.5);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn transitive_chaining() {
        // 0-1 close, 1-2 close, 0-2 far: threshold rounding chains them.
        let mut x = PackedSym::filled(3, 1.0);
        x.set(0, 1, 0.1);
        x.set(1, 2, 0.1);
        let labels = round(&x, 0.5);
        assert_eq!(labels[0], labels[2]);
    }

    #[test]
    fn labels_compact_and_deterministic() {
        let x = PackedSym::from_fn(6, |i, j| if j == i + 1 { 0.0 } else { 1.0 });
        let a = round(&x, 0.5);
        let b = round(&x, 0.5);
        assert_eq!(a, b);
        let k = a.iter().max().unwrap() + 1;
        for l in 0..k {
            assert!(a.contains(&l), "label {l} skipped");
        }
    }
}
