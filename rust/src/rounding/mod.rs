//! Rounding LP solutions into clusterings — the downstream step that
//! motivates solving the metric-constrained LP (§I, §II-A).

pub mod pivot;
pub mod threshold;
