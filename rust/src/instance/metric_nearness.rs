//! The p = 2 metric nearness problem (paper (1)): given dissimilarities
//! `D` and weights `W`, find the nearest (weighted least-squares) matrix
//! `X` satisfying all triangle inequalities. This is the original setting
//! of Sra–Tropp–Dhillon [36] and is solved by the same projection machinery
//! with no slack variables: Dykstra projects `X0 = D` onto the metric cone.

use crate::matrix::PackedSym;
use crate::util::rng::Rng;

/// Weighted l2 metric nearness instance.
#[derive(Clone, Debug)]
pub struct MetricNearnessInstance {
    pub n: usize,
    /// Input dissimilarities (symmetric, nonnegative).
    pub d: PackedSym,
    /// Positive weights.
    pub w: PackedSym,
}

impl MetricNearnessInstance {
    /// Uniform-weight instance from a dissimilarity matrix.
    pub fn new(d: PackedSym) -> Self {
        let n = d.n();
        crate::instance::assert_size_representable(n);
        MetricNearnessInstance { n, d, w: PackedSym::filled(n, 1.0) }
    }

    /// Random instance: d_ij uniform in [0, hi], unit weights.
    pub fn random(n: usize, hi: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Self::new(PackedSym::from_fn(n, |_, _| rng.f64_in(0.0, hi)))
    }

    /// Weighted squared distance `Σ w_ij (x_ij − d_ij)^2` — the objective.
    pub fn objective(&self, x: &PackedSym) -> f64 {
        x.sub(&self.d).weighted_sq_norm(&self.w)
    }

    /// The perturbed re-solve scenario of the warm-start subsystem: same
    /// dissimilarities, each weight independently rescaled with
    /// probability `frac` by a factor uniform in `[1 - rel, 1 + rel]`.
    pub fn perturb_weights(&self, frac: f64, rel: f64, seed: u64) -> MetricNearnessInstance {
        MetricNearnessInstance {
            n: self.n,
            d: self.d.clone(),
            w: crate::instance::perturbed_weights(&self.w, frac, rel, seed),
        }
    }

    /// Validate: size representable, nonnegative d, positive w.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.n < crate::solver::active::set::MAX_N,
            "instance size n = {} exceeds the solver limit of {} \
             (constraint indices are packed into 20-bit key fields; \
             larger n would silently collide keys and corrupt duals)",
            self.n,
            crate::solver::active::set::MAX_N - 1,
        );
        anyhow::ensure!(self.d.n() == self.n && self.w.n() == self.n, "dim mismatch");
        for (i, j, v) in self.d.iter_pairs() {
            anyhow::ensure!(v >= 0.0 && v.is_finite(), "d[{i},{j}] = {v} negative");
        }
        for (i, j, v) in self.w.iter_pairs() {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "w[{i},{j}] = {v} not positive");
        }
        Ok(())
    }
}

/// Max triangle-inequality violation of `x`: max over ordered triples of
/// `x_ij − x_ik − x_jk` (nonpositive ⇔ x is metric). O(n^3) — for tests
/// and small-instance validation; the solver tracks this incrementally.
pub fn max_triangle_violation(x: &PackedSym) -> f64 {
    let n = x.n();
    let mut worst = f64::NEG_INFINITY;
    for i in 0..n {
        for j in (i + 1)..n {
            for k in (j + 1)..n {
                let (a, b, c) = (x.get(i, j), x.get(i, k), x.get(j, k));
                worst = worst.max(a - b - c).max(b - a - c).max(c - a - b);
            }
        }
    }
    if worst == f64::NEG_INFINITY {
        0.0
    } else {
        worst
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metric_matrix_has_no_violation() {
        // all distances equal 1 -> triangle holds with slack 1
        let x = PackedSym::filled(5, 1.0);
        assert!(max_triangle_violation(&x) <= -1.0 + 1e-12);
    }

    #[test]
    fn violation_detected() {
        let mut x = PackedSym::filled(3, 1.0);
        x.set(0, 1, 5.0); // 5 > 1 + 1
        assert!((max_triangle_violation(&x) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn objective_zero_at_d() {
        let inst = MetricNearnessInstance::random(6, 2.0, 3);
        assert_eq!(inst.objective(&inst.d), 0.0);
    }

    #[test]
    fn random_is_valid() {
        MetricNearnessInstance::random(10, 3.0, 4).validate().unwrap();
    }

    #[test]
    fn validate_rejects_unrepresentable_n() {
        let inst = MetricNearnessInstance {
            n: 1 << 20,
            d: PackedSym::zeros(2),
            w: PackedSym::zeros(2),
        };
        let err = inst.validate().unwrap_err().to_string();
        assert!(err.contains("20-bit key fields"), "{err}");
    }

    #[test]
    fn small_n_no_triples() {
        let x = PackedSym::filled(2, 7.0);
        assert_eq!(max_triangle_violation(&x), 0.0);
    }
}
