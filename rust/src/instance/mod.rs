//! Problem instances for metric-constrained optimization.
//!
//! * [`CcLpInstance`] — the metric-constrained LP relaxation of correlation
//!   clustering, in the metric-nearness form (3) of the paper: dense 0/1
//!   targets `D` and positive weights `W` over all pairs.
//! * [`MetricNearnessInstance`] — the p = 2 metric nearness problem (1).
//! * [`construction`] — §IV-B Jaccard/Wang-et-al. signed instance builder.

pub mod construction;
pub mod metric_nearness;

use crate::matrix::PackedSym;
use crate::util::rng::Rng;

/// Correlation-clustering LP relaxation in metric-nearness form (paper (3)):
///
/// ```text
/// min  Σ_{i<j} w_ij f_ij
/// s.t. x_ij ≤ x_ik + x_jk          for all triples
///      |x_ij − d_ij| ≤ f_ij       for all pairs
/// ```
///
/// with `d_ij ∈ {0, 1}` (1 ⇔ negative/dissimilar edge) and `w_ij > 0`.
#[derive(Clone, Debug)]
pub struct CcLpInstance {
    /// Number of objects (graph nodes).
    pub n: usize,
    /// 0/1 dissimilarity targets.
    pub d: PackedSym,
    /// Positive pair weights.
    pub w: PackedSym,
}

impl CcLpInstance {
    /// Validate invariants (size representable, weights positive,
    /// targets 0/1).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.n < crate::solver::active::set::MAX_N,
            "instance size n = {} exceeds the solver limit of {} \
             (constraint indices are packed into 20-bit key fields; \
             larger n would silently collide keys and corrupt duals)",
            self.n,
            crate::solver::active::set::MAX_N - 1,
        );
        anyhow::ensure!(self.d.n() == self.n && self.w.n() == self.n, "dim mismatch");
        for (i, j, v) in self.d.iter_pairs() {
            anyhow::ensure!(v == 0.0 || v == 1.0, "d[{i},{j}] = {v} not 0/1");
        }
        for (i, j, v) in self.w.iter_pairs() {
            anyhow::ensure!(v > 0.0 && v.is_finite(), "w[{i},{j}] = {v} not positive");
        }
        Ok(())
    }

    /// Number of metric (triangle) constraints: 3·C(n,3).
    pub fn n_metric_constraints(&self) -> u128 {
        let n = self.n as u128;
        n * (n - 1) * (n - 2) / 6 * 3
    }

    /// Total constraints incl. the 2 pair constraints per pair (paper's
    /// Table I counts: 3·C(n,3) + 2·C(n,2)).
    pub fn n_constraints(&self) -> u128 {
        let n = self.n as u128;
        self.n_metric_constraints() + n * (n - 1)
    }

    /// LP objective Σ w_ij |x_ij − d_ij| at a (not necessarily feasible) x.
    pub fn lp_objective(&self, x: &PackedSym) -> f64 {
        assert_eq!(x.n(), self.n);
        let (xd, dd, wd) = (x.as_slice(), self.d.as_slice(), self.w.as_slice());
        xd.iter()
            .zip(dd)
            .zip(wd)
            .map(|((x, d), w)| w * (x - d).abs())
            .sum()
    }

    /// Random dense instance for tests: each pair negative with prob
    /// `p_neg`, weights uniform in `[w_lo, w_hi]`.
    pub fn random(n: usize, p_neg: f64, w_lo: f64, w_hi: f64, seed: u64) -> Self {
        assert_size_representable(n);
        let mut rng = Rng::new(seed);
        let d = PackedSym::from_fn(n, |_, _| f64::from(rng.bool(p_neg)));
        let w = PackedSym::from_fn(n, |_, _| rng.f64_in(w_lo, w_hi));
        CcLpInstance { n, d, w }
    }

    /// Unweighted instance from an explicit signed partition of pairs:
    /// pairs in `neg` get d = 1; everything else d = 0; all weights 1.
    pub fn unweighted(n: usize, neg: &[(usize, usize)]) -> Self {
        assert_size_representable(n);
        let mut d = PackedSym::zeros(n);
        for &(i, j) in neg {
            d.set(i, j, 1.0);
        }
        CcLpInstance { n, d, w: PackedSym::filled(n, 1.0) }
    }

    /// The perturbed re-solve scenario of the warm-start subsystem: the
    /// same graph with each weight independently rescaled with
    /// probability `frac` by a factor uniform in `[1 - rel, 1 + rel]`
    /// (clamped positive). Targets are unchanged.
    pub fn perturb_weights(&self, frac: f64, rel: f64, seed: u64) -> CcLpInstance {
        CcLpInstance {
            n: self.n,
            d: self.d.clone(),
            w: perturbed_weights(&self.w, frac, rel, seed),
        }
    }
}

/// Reject instance sizes whose indices would overflow the solver's
/// 20-bit key fields (see [`crate::solver::active::set::MAX_N`]) before
/// any O(n²) allocation happens.
pub(crate) fn assert_size_representable(n: usize) {
    assert!(
        n < crate::solver::active::set::MAX_N,
        "instance size n = {n} exceeds the solver limit of {} \
         (constraint indices are packed into 20-bit key fields)",
        crate::solver::active::set::MAX_N - 1,
    );
}

/// Shared weight-perturbation kernel (see
/// [`CcLpInstance::perturb_weights`]).
pub(crate) fn perturbed_weights(w: &PackedSym, frac: f64, rel: f64, seed: u64) -> PackedSym {
    let mut rng = Rng::new(seed);
    let mut out = w.clone();
    for v in out.as_mut_slice().iter_mut() {
        if rng.bool(frac) {
            *v *= (1.0 + rng.f64_in(-rel, rel)).max(1e-6);
        }
    }
    out
}

/// Evaluate the integral correlation-clustering objective (disagreements)
/// of a clustering `labels` against an instance.
pub fn cc_objective(inst: &CcLpInstance, labels: &[usize]) -> f64 {
    assert_eq!(labels.len(), inst.n);
    let mut total = 0.0;
    for (i, j, d) in inst.d.iter_pairs() {
        let together = labels[i] == labels[j];
        let w = inst.w.get(i, j);
        // d=0 (positive pair): mistake if apart. d=1 (negative): if together.
        if d == 0.0 && !together {
            total += w;
        } else if d == 1.0 && together {
            total += w;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constraint_counts() {
        let inst = CcLpInstance::random(10, 0.5, 1.0, 2.0, 1);
        // 3*C(10,3) = 360, pairs 2*45 = 90
        assert_eq!(inst.n_metric_constraints(), 360);
        assert_eq!(inst.n_constraints(), 450);
    }

    #[test]
    fn table1_constraint_scale_matches_paper() {
        // Paper Table I: ca-GrQc n=4158 -> 3.6e10; ca-AstroPh n=17903 -> 2.9e12
        let c = |n: usize| CcLpInstance { n, d: PackedSym::zeros(2), w: PackedSym::zeros(2) }
            .n_metric_constraints() as f64;
        assert!((c(4158) / 3.6e10 - 1.0).abs() < 0.05);
        assert!((c(17903) / 2.9e12 - 1.0).abs() < 0.05);
    }

    #[test]
    fn validate_accepts_random() {
        CcLpInstance::random(8, 0.3, 0.5, 1.5, 2).validate().unwrap();
    }

    #[test]
    fn validate_rejects_unrepresentable_n() {
        // Struct literal on purpose: the constructors assert before the
        // O(n²) allocation, so this is the only way to reach validate().
        let inst =
            CcLpInstance { n: 1 << 20, d: PackedSym::zeros(2), w: PackedSym::zeros(2) };
        let err = inst.validate().unwrap_err().to_string();
        assert!(err.contains("20-bit key fields"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_d() {
        let mut inst = CcLpInstance::random(5, 0.3, 1.0, 1.0, 3);
        inst.d.set(0, 1, 0.5);
        assert!(inst.validate().is_err());
    }

    #[test]
    fn validate_rejects_nonpositive_w() {
        let mut inst = CcLpInstance::random(5, 0.3, 1.0, 1.0, 3);
        inst.w.set(2, 3, 0.0);
        assert!(inst.validate().is_err());
    }

    #[test]
    fn lp_objective_zero_at_d() {
        let inst = CcLpInstance::random(7, 0.4, 1.0, 2.0, 4);
        assert_eq!(inst.lp_objective(&inst.d), 0.0);
    }

    #[test]
    fn perturb_weights_touches_a_fraction_and_stays_valid() {
        let inst = CcLpInstance::random(20, 0.5, 0.8, 1.6, 4);
        let pert = inst.perturb_weights(0.1, 0.2, 9);
        pert.validate().unwrap();
        assert_eq!(pert.d, inst.d, "targets must be unchanged");
        let m = inst.w.as_slice().len();
        let changed = inst
            .w
            .as_slice()
            .iter()
            .zip(pert.w.as_slice())
            .filter(|(a, b)| a != b)
            .count();
        assert!(changed > 0, "something must change");
        assert!(changed < m / 2, "~10% selected, got {changed}/{m}");
        for (a, b) in inst.w.as_slice().iter().zip(pert.w.as_slice()) {
            assert!(b / a >= 0.8 - 1e-12 && b / a <= 1.2 + 1e-12, "{a} -> {b}");
        }
        // deterministic in the seed
        assert_eq!(pert.w, inst.perturb_weights(0.1, 0.2, 9).w);
    }

    #[test]
    fn cc_objective_perfect_clustering() {
        // two cliques of 2: pairs (0,1) and (2,3) positive, rest negative
        let neg = [(0, 2), (0, 3), (1, 2), (1, 3)];
        let inst = CcLpInstance::unweighted(4, &neg);
        assert_eq!(cc_objective(&inst, &[0, 0, 1, 1]), 0.0);
        // everything together: 4 negative mistakes
        assert_eq!(cc_objective(&inst, &[0, 0, 0, 0]), 4.0);
        // everything apart: 2 positive mistakes
        assert_eq!(cc_objective(&inst, &[0, 1, 2, 3]), 2.0);
    }
}
