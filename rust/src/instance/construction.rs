//! §IV-B problem construction (Wang et al. [40] with the modification of
//! [37]): given an undirected graph, compute all-pairs Jaccard similarity,
//! map through a non-linear signing function, and offset by ±epsilon so
//! every pair carries a sign and a nonzero weight — a *dense* correlation
//! clustering instance whose LP relaxation is the benchmark problem.

use super::CcLpInstance;
use crate::graph::jaccard::all_pairs_jaccard;
use crate::graph::Graph;
use crate::matrix::PackedSym;

/// Parameters of the signed-instance construction.
#[derive(Clone, Copy, Debug)]
pub struct ConstructionParams {
    /// Jaccard threshold: similarity above ⇒ positive pair (d = 0).
    pub threshold: f64,
    /// Weight offset ε ensuring every pair has nonzero weight.
    pub epsilon: f64,
}

impl Default for ConstructionParams {
    fn default() -> Self {
        // threshold ~ the sparsity regime of the ca-* nets; epsilon small,
        // as in [37]'s modification ("offset these scores by ±ε").
        ConstructionParams { threshold: 0.05, epsilon: 0.01 }
    }
}

/// Non-linear signing function: logit-like map of the Jaccard score `s`
/// against the threshold `t`, f(s) = log((s + δ) / (t + δ)) with δ a small
/// smoothing constant. f > 0 ⇔ s > t; |f| grows smoothly with the margin.
fn sign_score(s: f64, t: f64) -> f64 {
    const DELTA: f64 = 1e-3;
    ((s + DELTA) / (t + DELTA)).ln()
}

/// Build the dense correlation-clustering instance of §IV-B from a graph
/// (callers should pass the largest connected component, as the paper does).
/// `p` = worker threads for the all-pairs Jaccard sweep.
pub fn build_cc_instance(g: &Graph, params: ConstructionParams, p: usize) -> CcLpInstance {
    let n = g.n();
    let jac = all_pairs_jaccard(g, p);
    let mut d = PackedSym::zeros(n);
    let mut w = PackedSym::zeros(n);
    for i in 0..n {
        for j in (i + 1)..n {
            let v = sign_score(jac.get(i, j), params.threshold);
            // v > 0: similar ⇒ positive pair (target distance 0).
            // v ≤ 0: dissimilar ⇒ negative pair (target distance 1).
            d.set(i, j, f64::from(v <= 0.0));
            w.set(i, j, v.abs() + params.epsilon);
        }
    }
    CcLpInstance { n, d, w }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::{erdos_renyi, two_cliques};

    #[test]
    fn instance_is_valid_and_dense() {
        let g = erdos_renyi(30, 0.2, 11);
        let inst = build_cc_instance(&g, ConstructionParams::default(), 2);
        inst.validate().unwrap();
        assert_eq!(inst.n, 30);
    }

    #[test]
    fn cliques_become_positive_pairs() {
        let g = two_cliques(6);
        // threshold 0.1 > 1/12: cross pairs that only share a bridge
        // endpoint stay negative; in-clique pairs (Jaccard >= 1/2) positive.
        let params = ConstructionParams { threshold: 0.1, epsilon: 0.01 };
        let inst = build_cc_instance(&g, params, 1);
        // Within-clique pairs share most of their closed neighborhoods.
        let mut in_pos = 0;
        let mut cross_neg = 0;
        for i in 0..6 {
            for j in (i + 1)..6 {
                if inst.d.get(i, j) == 0.0 {
                    in_pos += 1;
                }
            }
        }
        for i in 0..6 {
            for j in 6..12 {
                if inst.d.get(i, j) == 1.0 {
                    cross_neg += 1;
                }
            }
        }
        assert_eq!(in_pos, 15, "all in-clique pairs should be positive");
        assert!(cross_neg >= 35, "most cross pairs negative, got {cross_neg}");
    }

    #[test]
    fn weights_at_least_epsilon() {
        let g = erdos_renyi(20, 0.15, 3);
        let params = ConstructionParams { threshold: 0.1, epsilon: 0.02 };
        let inst = build_cc_instance(&g, params, 1);
        for (_, _, w) in inst.w.iter_pairs() {
            assert!(w >= 0.02);
        }
    }

    #[test]
    fn sign_score_monotone_and_signed() {
        assert!(sign_score(0.5, 0.1) > 0.0);
        assert!(sign_score(0.01, 0.1) < 0.0);
        assert!(sign_score(0.3, 0.1) < sign_score(0.6, 0.1));
        // exactly at threshold: log(1) = 0 -> negative pair by convention
        assert_eq!(sign_score(0.1, 0.1), 0.0);
    }

    #[test]
    fn deterministic_given_graph() {
        let g = erdos_renyi(25, 0.2, 7);
        let a = build_cc_instance(&g, ConstructionParams::default(), 1);
        let b = build_cc_instance(&g, ConstructionParams::default(), 4);
        assert_eq!(a.d, b.d);
        assert_eq!(a.w, b.w);
    }
}
