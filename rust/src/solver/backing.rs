//! Where the packed distance variables of a solve live — resident
//! vector (the classic path) or a disk-backed tile store with a bounded
//! working set.
//!
//! [`XBacking`] is shared by every store-generic driver: the nearness
//! solvers (full + active) and, since PR 5, the CC-LP solvers (full
//! parallel + active). All of them lease `X` through
//! [`TileStore`] — tile leases for the metric phases, pair-range leases
//! for the CC pair phase and the elementwise residual scans, and (since
//! PR 7) entry-granular leases ([`TileStore::with_entries`]) for the
//! cheap active passes, which name only the pairs their tile bucket
//! touches so the disk backend gathers from just the blocks those pairs
//! intersect — so the numerics are backend-independent bit for bit
//! (pinned by `tests/store_equivalence.rs`).

use super::checkpoint::SolverState;
use super::schedule::Schedule;
use super::CcState;
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::matrix::store::shard::{promote_shard_snapshots, shard_data_path, shard_files_exist};
use crate::matrix::store::{
    snapshot_sibling, DiskStore, MemStore, RetryNote, ShardStore, StoreCfg, StoreError, StoreKind,
    StoreTuning, TileStore,
};
use anyhow::{bail, Context as _};
use std::path::Path;

/// Creating a fresh store must never clobber an existing file: an
/// `x.tiles` on disk may be the only copy of an earlier run's iterate
/// (external-x checkpoints reference it rather than inlining `x`).
pub(crate) fn refuse_store_overwrite(path: &Path) -> anyhow::Result<()> {
    if path.exists() {
        bail!(
            "refusing to overwrite the existing tile store {}: it may back an earlier \
             run's checkpoint. Resume it (--resume <ckpt>), point --store-dir somewhere \
             fresh, or delete the file to discard that state",
            path.display()
        );
    }
    Ok(())
}

/// Check a store's `(pass, fnv)` stamp against an external-x
/// checkpoint's expectation — a store that advanced past (or fell
/// behind) the checkpoint is refused instead of silently resuming from
/// the wrong iterate. Shared by the disk and shard verifiers.
fn check_stamp(stamp: (u64, u64), st: &SolverState, path: &Path) -> anyhow::Result<()> {
    let (pass, fnv) = stamp;
    if pass != st.pass || fnv != st.x_fnv {
        bail!(
            "store {} is stamped (pass {pass}, fnv {fnv:#x}) but the checkpoint expects \
             (pass {}, fnv {:#x}); they are not a consistent pair",
            path.display(),
            st.pass,
            st.x_fnv
        );
    }
    Ok(())
}

/// Check that an opened store and an external-x checkpoint form a
/// consistent pair: the header stamp must match the checkpoint's
/// `(pass, x_fnv)` exactly, and the re-derived content fingerprint must
/// confirm the stamp.
fn verify_stamp(store: &DiskStore, st: &SolverState, path: &Path) -> anyhow::Result<()> {
    check_stamp(store.stamp(), st, path)?;
    let actual = store.data_fingerprint()?;
    if actual != st.x_fnv {
        bail!(
            "store {} content (fnv {actual:#x}) no longer matches its stamp (fnv {:#x}); \
             it cannot resume this checkpoint",
            path.display(),
            st.x_fnv
        );
    }
    Ok(())
}

/// Open a store for an external-x resume, falling back to its `.ckpt`
/// snapshot when the live file is unusable. A solve that died mid-pass
/// leaves the live store drifted past (or torn relative to) the
/// checkpoint it must match; the snapshot taken at the checkpoint's
/// `flush_and_stamp` is the matching copy, so it is promoted over the
/// live file and the open retried. A [`StoreError::Locked`] failure is
/// never promoted over — another live process owns the store.
fn open_verified(
    path: &Path,
    budget_bytes: usize,
    winv: &[f64],
    st: &SolverState,
    tuning: &StoreTuning,
) -> anyhow::Result<DiskStore> {
    let first = match DiskStore::open_with(path, budget_bytes, winv.to_vec(), tuning.clone()) {
        Ok(store) => match verify_stamp(&store, st, path) {
            Ok(()) => return Ok(store),
            // `store` drops here, releasing its lockfile before the
            // snapshot is copied over the live file below.
            Err(e) => e,
        },
        Err(e @ StoreError::Locked(_)) => return Err(anyhow::Error::from(e)),
        Err(e) => anyhow::Error::from(e),
    };
    let snap = snapshot_sibling(path);
    if !snap.exists() {
        return Err(first.context(format!(
            "store {} cannot resume this checkpoint and no snapshot exists beside it",
            path.display()
        )));
    }
    crate::telemetry::warn(&format!(
        "store {} cannot resume this checkpoint ({first}); promoting snapshot {}",
        path.display(),
        snap.display()
    ));
    std::fs::copy(&snap, path)
        .with_context(|| format!("promoting store snapshot {}", snap.display()))?;
    let store = DiskStore::open_with(path, budget_bytes, winv.to_vec(), tuning.clone())?;
    verify_stamp(&store, st, path)?;
    Ok(store)
}

/// Creating a fresh *sharded* store must never clobber existing shard
/// files (the shard analog of [`refuse_store_overwrite`]).
fn refuse_shard_overwrite(x_path: &Path) -> anyhow::Result<()> {
    if shard_files_exist(x_path) {
        bail!(
            "refusing to overwrite the existing shard files beside {} (found {}): they may \
             back an earlier run's checkpoint. Resume it (--resume <ckpt>), point \
             --store-dir somewhere fresh, or delete the files to discard that state",
            x_path.display(),
            shard_data_path(x_path, 0).display()
        );
    }
    Ok(())
}

/// Open a sharded store for an external-x resume, falling back to its
/// per-shard `.ckpt` snapshots when the live shard set is unusable (the
/// shard analog of [`open_verified`]). [`ShardStore::open_with`]
/// recomputes the plane fingerprint from the bytes it reassembles and
/// reports it as the stamp, so a successful [`check_stamp`] *is* the
/// content verification — no second fingerprint pass is needed. A
/// [`StoreError::Locked`] failure (another coordinator's workers are
/// live) is never promoted over.
fn open_verified_shard(
    cfg: &StoreCfg,
    n: usize,
    winv: &[f64],
    st: &SolverState,
) -> anyhow::Result<ShardStore> {
    let path = cfg.x_path();
    let first = match ShardStore::open_with(cfg, n, winv.to_vec()) {
        Ok(store) => match check_stamp(store.stamp(), st, &path) {
            Ok(()) => return Ok(store),
            // `store` drops here, shutting its workers down (and
            // releasing the per-shard locks) before the snapshots are
            // promoted below.
            Err(e) => e,
        },
        Err(e @ StoreError::Locked(_)) => return Err(anyhow::Error::from(e)),
        Err(e) => anyhow::Error::from(e),
    };
    let promoted = promote_shard_snapshots(&path)
        .with_context(|| format!("promoting shard snapshots beside {}", path.display()))?;
    if promoted == 0 {
        return Err(first.context(format!(
            "sharded store {} cannot resume this checkpoint and no shard snapshots exist \
             beside it",
            path.display()
        )));
    }
    crate::telemetry::warn(&format!(
        "sharded store {} cannot resume this checkpoint ({first}); promoted {promoted} \
         shard snapshot(s)",
        path.display()
    ));
    let store = ShardStore::open_with(cfg, n, winv.to_vec())?;
    check_stamp(store.stamp(), st, &path)?;
    Ok(store)
}

/// Where the packed distance variables of a solve live — resident vector
/// (the classic path) or disk-backed tile store with a bounded working
/// set. Shared by the CC-LP and nearness drivers; every phase leases
/// tiles (or pair ranges) through [`TileStore`], so the numerics are
/// backend-independent bit for bit.
pub(crate) enum XBacking {
    /// Resident packed `x`, leased through a fresh [`MemStore`] per
    /// solver phase (the exact aliasing discipline of the classic
    /// drivers).
    Mem {
        /// The packed iterate.
        x: Vec<f64>,
    },
    /// `x` lives in a [`DiskStore`]; only the bounded block caches (the
    /// `X` plane plus the streamed-`W` plane) and one gather arena per
    /// worker stay resident.
    Disk {
        /// The tile store (owns the file handles and caches).
        store: DiskStore,
    },
    /// `x` is partitioned across shard worker processes (or in-process
    /// worker threads) behind a [`ShardStore`]; the coordinator keeps
    /// only per-lease gather arenas resident and every access crosses
    /// the socket protocol.
    Shard {
        /// The coordinator-side store (owns the worker connections).
        store: ShardStore,
    },
}

impl XBacking {
    /// Build the backing for a nearness solve: fresh from `inst.d`, or
    /// seeded from a resume state. An inline-x state seeds either
    /// backend; an external-x state requires the disk backend, whose
    /// file must match the checkpoint's `(pass, x_fnv)` stamp (see
    /// [`verify_stamp`]).
    pub(crate) fn init_nearness(
        inst: &MetricNearnessInstance,
        block: usize,
        cfg: &StoreCfg,
        resume: Option<&SolverState>,
    ) -> anyhow::Result<XBacking> {
        match cfg.kind {
            StoreKind::Mem => {
                if resume.is_some_and(|st| st.x_external) {
                    bail!(
                        "checkpoint references an external x store; resume with the \
                         backend that wrote it (--store disk or --store shard, with \
                         --store-dir <dir>)"
                    );
                }
                let mut x: Vec<f64> = inst.d.as_slice().to_vec();
                if let Some(st) = resume {
                    x.copy_from_slice(&st.x);
                }
                Ok(XBacking::Mem { x })
            }
            StoreKind::Disk => {
                let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
                let path = cfg.x_path();
                let tuning = cfg.tuning();
                match resume {
                    Some(st) if st.x_external => {
                        let store = open_verified(
                            &path,
                            cfg.budget_bytes.max(8),
                            &winv,
                            st,
                            &tuning,
                        )?;
                        Ok(XBacking::Disk { store })
                    }
                    Some(st) => {
                        refuse_store_overwrite(&path)?;
                        let src = &st.x;
                        let cs = inst.d.col_starts();
                        let store = DiskStore::create_with(
                            &path,
                            inst.n,
                            block,
                            cfg.budget_bytes.max(8),
                            winv,
                            &mut |c, r| src[cs[c] + (r - c - 1)],
                            tuning,
                        )?;
                        Ok(XBacking::Disk { store })
                    }
                    None => {
                        refuse_store_overwrite(&path)?;
                        let d = &inst.d;
                        let store = DiskStore::create_with(
                            &path,
                            inst.n,
                            block,
                            cfg.budget_bytes.max(8),
                            winv,
                            &mut |c, r| d.get(c, r),
                            tuning,
                        )?;
                        Ok(XBacking::Disk { store })
                    }
                }
            }
            StoreKind::Shard => {
                let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
                match resume {
                    Some(st) if st.x_external => {
                        let store = open_verified_shard(cfg, inst.n, &winv, st)?;
                        Ok(XBacking::Shard { store })
                    }
                    Some(st) => {
                        refuse_shard_overwrite(&cfg.x_path())?;
                        let src = &st.x;
                        let cs = inst.d.col_starts();
                        let store = ShardStore::create_with(cfg, inst.n, winv, &mut |c, r| {
                            src[cs[c] + (r - c - 1)]
                        })?;
                        Ok(XBacking::Shard { store })
                    }
                    None => {
                        refuse_shard_overwrite(&cfg.x_path())?;
                        let d = &inst.d;
                        let store =
                            ShardStore::create_with(cfg, inst.n, winv, &mut |c, r| d.get(c, r))?;
                        Ok(XBacking::Shard { store })
                    }
                }
            }
        }
    }

    /// Build the backing for a CC-LP solve, taking ownership of the
    /// packed `x` that [`CcState::new`] / `restore_cc_state` produced —
    /// the state's own `x` is left empty and every further access goes
    /// through the backing. On the disk backend the state's `winv` is
    /// taken too (the store streams it from its W spill plane and hands
    /// it back through every lease), so neither `O(n²)` plane stays
    /// resident. A fresh or inline-resumed iterate seeds either backend;
    /// an external-x state requires the disk backend and a store
    /// matching the checkpoint stamp.
    pub(crate) fn init_cc(
        state: &mut CcState,
        block: usize,
        cfg: &StoreCfg,
        resume: Option<&SolverState>,
    ) -> anyhow::Result<XBacking> {
        let x = std::mem::take(&mut state.x);
        match cfg.kind {
            StoreKind::Mem => {
                if resume.is_some_and(|st| st.x_external) {
                    bail!(
                        "checkpoint references an external x store; resume with the \
                         backend that wrote it (--store disk or --store shard, with \
                         --store-dir <dir>)"
                    );
                }
                Ok(XBacking::Mem { x })
            }
            StoreKind::Disk => {
                // The store consumes winv to write its W spill and drops
                // it; the disk drivers read weights back through leases,
                // never through CcState::winv (left empty).
                let winv = std::mem::take(&mut state.winv);
                let path = cfg.x_path();
                let tuning = cfg.tuning();
                match resume {
                    Some(st) if st.x_external => {
                        let store = open_verified(
                            &path,
                            cfg.budget_bytes.max(8),
                            &winv,
                            st,
                            &tuning,
                        )?;
                        Ok(XBacking::Disk { store })
                    }
                    _ => {
                        refuse_store_overwrite(&path)?;
                        let cs = &state.col_starts;
                        let store = DiskStore::create_with(
                            &path,
                            state.n,
                            block,
                            cfg.budget_bytes.max(8),
                            winv,
                            &mut |c, r| x[cs[c] + (r - c - 1)],
                            tuning,
                        )?;
                        Ok(XBacking::Disk { store })
                    }
                }
            }
            StoreKind::Shard => {
                // The shard workers hold winv resident in their slices;
                // the drivers read weights back through leases, never
                // through CcState::winv (left empty), exactly like the
                // disk path.
                let winv = std::mem::take(&mut state.winv);
                match resume {
                    Some(st) if st.x_external => {
                        let store = open_verified_shard(cfg, state.n, &winv, st)?;
                        Ok(XBacking::Shard { store })
                    }
                    _ => {
                        refuse_shard_overwrite(&cfg.x_path())?;
                        let cs = &state.col_starts;
                        let store = ShardStore::create_with(cfg, state.n, winv, &mut |c, r| {
                            x[cs[c] + (r - c - 1)]
                        })?;
                        Ok(XBacking::Shard { store })
                    }
                }
            }
        }
    }

    /// Run one solver phase against the backing's [`TileStore`] view.
    pub(crate) fn with_store<R>(
        &mut self,
        col_starts: &[usize],
        winv: &[f64],
        f: impl FnOnce(&dyn TileStore) -> R,
    ) -> R {
        match self {
            XBacking::Mem { x } => {
                let store = MemStore::new(x.as_mut_slice(), col_starts, winv);
                f(&store)
            }
            XBacking::Disk { store } => f(&*store),
            XBacking::Shard { store } => f(&*store),
        }
    }

    /// Exact max triangle violation of the current iterate (direct scan
    /// for the resident backing, lease-addressed scan for the disk
    /// backing; the values agree exactly).
    pub(crate) fn violation(
        &self,
        col_starts: &[usize],
        n: usize,
        p: usize,
        schedule: &Schedule,
    ) -> f64 {
        match self {
            XBacking::Mem { x } => super::nearness::violation(x, col_starts, n, p),
            XBacking::Disk { store } => {
                super::active::sweep::exact_violation(store, schedule, p)
            }
            XBacking::Shard { store } => {
                super::active::sweep::exact_violation(store, schedule, p)
            }
        }
    }

    /// Materialize the packed iterate (`O(n²)` resident — final
    /// extraction only). Typed so an extraction-time store failure
    /// surfaces as [`SolveError::Store`](super::SolveError::Store) in
    /// the drivers.
    pub(crate) fn extract(&self) -> Result<Vec<f64>, StoreError> {
        match self {
            XBacking::Mem { x } => Ok(x.clone()),
            XBacking::Disk { store } => {
                store.flush()?;
                store.read_full()
            }
            XBacking::Shard { store } => {
                store.flush()?;
                store.read_full()
            }
        }
    }

    /// Cache counters of the disk backing (`None` for the resident
    /// path) — surfaced on `store_stats` of the solutions.
    pub(crate) fn store_stats(&self) -> Option<crate::matrix::store::StoreStats> {
        match self {
            XBacking::Mem { .. } => None,
            XBacking::Disk { store } => Some(store.stats()),
            XBacking::Shard { store } => Some(store.stats()),
        }
    }

    /// Poll the disk backing's first-error latch (always healthy for the
    /// resident path). Drivers call this once per pass: barrier-phased
    /// leases cannot unwind mid-wave, so a failed store parks its leases
    /// and the driver discovers the latched error here.
    pub(crate) fn health(&self) -> Result<(), StoreError> {
        match self {
            XBacking::Mem { .. } => Ok(()),
            XBacking::Disk { store } => store.health(),
            XBacking::Shard { store } => store.health(),
        }
    }

    /// Take the retry notes buffered since the last drain (empty for the
    /// resident path); drivers emit them as a `store_retry` trace event.
    pub(crate) fn drain_retries(&self) -> Vec<RetryNote> {
        match self {
            XBacking::Mem { .. } => Vec::new(),
            XBacking::Disk { store } => store.drain_retries(),
            XBacking::Shard { store } => store.drain_retries(),
        }
    }

    /// Snapshot the (just flushed and stamped) store file beside itself
    /// — the copy [`open_verified`] promotes when a crashed run's live
    /// store can no longer resume its checkpoint. No-op for the resident
    /// path, whose checkpoints inline `x`.
    pub(crate) fn snapshot(&self) -> Result<(), StoreError> {
        match self {
            XBacking::Mem { .. } => Ok(()),
            XBacking::Disk { store } => store.snapshot(),
            XBacking::Shard { store } => store.snapshot(),
        }
    }

    /// Flush-and-stamp the backing at `pass` and snapshot it beside
    /// itself — everything an external-x checkpoint capture needs from
    /// a non-resident backend, in one call. Returns the stamped plane
    /// fingerprint (`None` for the resident path, whose checkpoints
    /// inline `x` instead). The drivers' `capture_*_backed` helpers
    /// branch on the backing once and share this for every external
    /// backend.
    pub(crate) fn stamp_external(&self, pass: u64) -> Result<Option<u64>, StoreError> {
        match self {
            XBacking::Mem { .. } => Ok(None),
            XBacking::Disk { store } => {
                let fnv = store.flush_and_stamp(pass)?;
                store.snapshot()?;
                Ok(Some(fnv))
            }
            XBacking::Shard { store } => {
                let fnv = store.flush_and_stamp(pass)?;
                store.snapshot()?;
                Ok(Some(fnv))
            }
        }
    }
}
