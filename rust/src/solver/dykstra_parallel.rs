//! Parallel Dykstra — the paper's contribution (§III).
//!
//! Each pass walks the wave [`Schedule`]: all tiles of a wave contain
//! mutually conflict-free triplets, so workers project concurrently with
//! **no locks and no atomics**; a barrier separates waves. Tiles are
//! assigned `r mod p` (Fig 3), every worker visits its tiles (and the
//! triplets inside, via the cube order of [`tiling`]) in the same
//! deterministic order each pass, so per-worker [`DualStore`]s give O(1)
//! dual access (§III-D).
//!
//! A corollary worth stating (and tested): because concurrent projections
//! touch disjoint variables, the result of a pass is *bitwise identical*
//! for every worker count `p` — parallelism changes wall-clock only. The
//! constraint *order* (hence the iterate sequence) differs from the serial
//! baseline, which §IV-D discusses; both converge.

use super::backing::XBacking;
use super::checkpoint::{self, CheckRecord, SolverState};
use super::duals::DualStore;
use super::error::SolveError;
use super::projection::{visit_box_upper_val, visit_pair_lower_val, visit_pair_upper_val};
use super::schedule::{next_owned_tile, Assignment, Schedule};
use super::termination::compute_residuals_stored;
use super::watchdog::Watchdog;
use super::{CcState, OnInterrupt, Residuals, Solution, SolveOpts};
use crate::instance::CcLpInstance;
use crate::matrix::store::{MemStore, StoreCfg, TileScratch, TileStore};
use crate::matrix::PackedSym;
use crate::telemetry::{
    self, Counters, Event, NullRecorder, PassKind, PhaseName, PhaseProbe, Recorder,
};
use crate::util::parallel::{chunk_range, scoped_workers};
use crate::util::shared::{PerWorker, SharedMut};

/// Solve the CC-LP instance with the parallel projection method,
/// dispatching on [`super::Strategy`]: full sweeps run here, the active
/// set runs in [`super::active`].
pub fn solve(inst: &CcLpInstance, opts: &SolveOpts) -> Solution {
    solve_checkpointed(inst, opts, None, &mut |_| {})
        .expect("cold parallel solve cannot fail")
}

/// Continue a previously saved solve from its checkpoint, dispatching on
/// [`super::Strategy`] like [`solve`]. With unchanged options this
/// reproduces the uninterrupted run bitwise — and because pass results
/// are bitwise independent of the worker count, `opts.threads` may even
/// differ from the saving run's.
pub fn resume(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    state: &SolverState,
) -> anyhow::Result<Solution> {
    solve_checkpointed(inst, opts, Some(state), &mut |_| {})
}

/// Full-control entry point: optionally resume from a saved state and
/// receive a [`SolverState`] through `on_checkpoint` every
/// [`SolveOpts::checkpoint_every`] passes (plus one for the final
/// state). Dispatches on [`super::Strategy`]. Runs on the in-memory
/// store; use [`solve_stored`] to pick the backend.
pub fn solve_checkpointed(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<Solution> {
    solve_stored(inst, opts, &StoreCfg::mem(), resume_from, on_checkpoint)
}

/// [`solve_checkpointed`] with an explicit `X` storage backend
/// ([`StoreCfg`]): the memory configuration is the classic resident
/// solve; the disk configuration streams `X` (and the instance's
/// inverse weights) through a bounded
/// [`crate::matrix::store::DiskStore`] working set — every phase,
/// including the pair phase and the residual scans, leases its entries
/// from the store, so the CC-LP solve runs at `n` beyond RAM bitwise
/// identically to the resident solve (pinned by
/// `tests/store_equivalence.rs`). With a disk store, checkpoints
/// reference the flushed-and-stamped store file instead of
/// re-serializing `x`. Dispatches on [`super::Strategy`].
pub fn solve_stored(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<Solution> {
    Ok(solve_traced(inst, opts, store_cfg, resume_from, on_checkpoint, &NullRecorder)?)
}

/// [`solve_stored`] with a [`Recorder`] receiving structured trace
/// events (pass boundaries, phase timings with per-worker busy seconds,
/// residual timeline, store I/O snapshots, and a
/// [`crate::telemetry::Counters`] footer). With [`NullRecorder`] — the
/// default behind every other entry point — no instrumentation runs at
/// all and the solve is bitwise identical to an untraced one (pinned by
/// `tests/telemetry.rs`). Dispatches on [`super::Strategy`].
///
/// The traced entry point is also the typed-error boundary: it returns
/// [`SolveError`] so embedders can distinguish store failures (and
/// auto-resume via [`super::recover`]), watchdog trips, and clean
/// interrupt unwinds; the `anyhow` wrappers above convert transparently.
pub fn solve_traced(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<Solution, SolveError> {
    if opts.strategy.is_active() {
        return super::active::solve_cc_traced(
            inst,
            opts,
            store_cfg,
            resume_from,
            on_checkpoint,
            rec,
        );
    }
    let schedule = Schedule::new(inst.n, opts.tile);
    solve_inner(inst, opts, &schedule, store_cfg, resume_from, on_checkpoint, rec)
}

/// Solve with a prebuilt schedule (benchmarks reuse schedules across
/// runs). Full strategy only; [`solve`] handles strategy dispatch.
pub fn solve_with_schedule(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    schedule: &Schedule,
) -> Solution {
    solve_inner(inst, opts, schedule, &StoreCfg::mem(), None, &mut |_| {}, &NullRecorder)
        .expect("cold parallel solve cannot fail")
}

#[allow(clippy::too_many_arguments)]
fn solve_inner(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    schedule: &Schedule,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<Solution, SolveError> {
    assert_eq!(schedule.n(), inst.n, "schedule built for wrong n");
    assert!(
        !opts.strategy.is_active(),
        "solve_with_schedule runs the full strategy only; use solve() for Strategy::Active"
    );
    let p = opts.threads.max(1);
    let triplets_per_pass = schedule.total_triplets();
    let mut state = match resume_from {
        Some(st) => {
            st.validate_cc(inst, opts)?;
            st.restore_cc_state(inst, opts)
        }
        None => CcState::new(inst, opts.gamma, opts.include_box),
    };
    // The backing takes ownership of the packed iterate (state.x is left
    // empty); every phase below leases it back through a TileStore.
    let mut backing = XBacking::init_cc(&mut state, opts.tile, store_cfg, resume_from)?;
    let mut stores = PerWorker::new((0..p).map(|_| DualStore::new()).collect());
    if let Some(st) = resume_from {
        // Redistribute the saved key-sorted duals into each worker's
        // deterministic visit order (valid for ANY worker count).
        let per_worker = st.worker_duals(schedule, opts.assignment, p);
        for (store, entries) in stores.iter_mut().zip(per_worker) {
            store.restore(entries);
        }
    }
    let start_pass = resume_from.map_or(0, |st| st.pass as usize);
    let mut history: Vec<CheckRecord> =
        resume_from.map(|st| st.history.clone()).unwrap_or_default();
    // Cumulative work, carried across resumes (an active-strategy
    // checkpoint's cheap passes keep their true cost).
    let mut triplet_visits: u64 = resume_from.map_or(0, |st| st.triplet_visits);
    let mut pass_times = Vec::new();
    let mut residuals = Residuals::default();
    let mut passes_done = start_pass;
    // passes_done at which `residuals` was measured (MAX = never).
    let mut measured_at = usize::MAX;
    let mut last_saved = usize::MAX;
    let pairs_per_pass = (inst.n * (inst.n - 1) / 2) as u64;
    let mut probe = PhaseProbe::new(rec, p);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);

    for pass in start_pass..opts.max_passes {
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });
        let t0 = std::time::Instant::now();
        let pt = probe.start();
        let ws = probe.workers();
        backing.with_store(&state.col_starts, &state.winv, |store| {
            run_metric_phase_timed(store, schedule, &stores, p, opts.assignment, ws.as_ref())
        });
        probe.finish(pass_no, PhaseName::Metric, pt, triplets_per_pass, ws);
        {
            let CcState { col_starts, winv, f, y_upper, y_lower, y_box, d, include_box, .. } =
                &mut state;
            let ib = *include_box;
            let pt = probe.start();
            let ws = probe.workers();
            backing.with_store(col_starts.as_slice(), winv.as_slice(), |store| {
                run_pair_phase_timed(store, f, y_upper, y_lower, y_box, d, ib, p, ws.as_ref())
            });
            probe.finish(pass_no, PhaseName::Pair, pt, pairs_per_pass, ws);
        }
        // A failed store parks its leases mid-wave (barriers cannot
        // unwind); the latched first error surfaces here, before the
        // un-projected iterate could feed a residual scan or checkpoint.
        backing.health()?;
        emit_retries(&probe, pass_no, backing.drain_retries());
        passes_done = pass + 1;
        triplet_visits += triplets_per_pass;
        if opts.track_pass_times {
            pass_times.push(t0.elapsed().as_secs_f64());
        }
        let mut stop = false;
        if opts.check_every > 0 && passes_done % opts.check_every == 0 {
            let pt = probe.start();
            residuals = backing.with_store(&state.col_starts, &state.winv, |store| {
                compute_residuals_stored(&state, store, schedule, p)
            });
            residuals.stamp_work(triplet_visits, triplets_per_pass as usize);
            probe.finish(pass_no, PhaseName::ResidualScan, pt, triplets_per_pass, None);
            measured_at = passes_done;
            history.push(CheckRecord {
                pass: passes_done as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
            });
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                lp_objective: residuals.lp_objective,
                exact: true,
            });
            watchdog.observe(
                passes_done,
                residuals.max_violation,
                residuals.rel_gap,
                &history,
            )?;
            if residuals.max_violation <= opts.tol_violation
                && residuals.rel_gap.abs() <= opts.tol_gap
            {
                stop = true;
            }
        }
        if opts.checkpoint_every > 0 && (passes_done % opts.checkpoint_every == 0 || stop) {
            let pt = probe.start();
            on_checkpoint(&capture_cc_full_backed(
                &state,
                &mut backing,
                checkpoint::collect_duals(&mut stores),
                passes_done,
                triplet_visits,
                &history,
            )?);
            probe.finish(pass_no, PhaseName::Checkpoint, pt, 0, None);
            last_saved = passes_done;
        }
        if probe.on() {
            if let Some(stats) = backing.store_stats() {
                probe.emit(Event::StoreIo { pass: pass_no, stats });
            }
        }
        probe.emit(Event::PassEnd {
            pass: pass_no,
            secs: t0.elapsed().as_secs_f64(),
            triplet_visits,
            active_triplets: triplets_per_pass,
        });
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted()
        {
            let checkpointed = opts.checkpoint_every > 0;
            if checkpointed && last_saved != passes_done {
                on_checkpoint(&capture_cc_full_backed(
                    &state,
                    &mut backing,
                    checkpoint::collect_duals(&mut stores),
                    passes_done,
                    triplet_visits,
                    &history,
                )?);
            }
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed });
        }
        if stop {
            break;
        }
    }
    if opts.checkpoint_every > 0 && last_saved != passes_done {
        on_checkpoint(&capture_cc_full_backed(
            &state,
            &mut backing,
            checkpoint::collect_duals(&mut stores),
            passes_done,
            triplet_visits,
            &history,
        )?);
    }
    // Re-measure unless the last checkpoint already measured the final
    // iterate — reported residuals always describe the returned x.
    if measured_at != passes_done {
        residuals = backing.with_store(&state.col_starts, &state.winv, |store| {
            compute_residuals_stored(&state, store, schedule, p)
        });
        residuals.stamp_work(triplet_visits, triplets_per_pass as usize);
    }
    let mut stores = stores.into_inner();
    let nnz = stores.iter_mut().map(|s| s.nnz()).sum();
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: triplet_visits * 3,
                active_triplets: triplets_per_pass,
                sweep_screened: 0,
                sweep_projected: 0,
                nnz_duals: nnz as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: backing.store_stats(),
            },
        });
    }
    let x_final = backing.extract()?;
    let mut xm = PackedSym::zeros(inst.n);
    xm.as_mut_slice().copy_from_slice(&x_final);
    Ok(Solution {
        x: xm,
        f: Some(state.f_matrix()),
        passes: passes_done,
        residuals,
        pass_times,
        nnz_duals: nnz,
        metric_visits: triplet_visits * 3,
        active_triplets: triplets_per_pass as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: backing.store_stats(),
    })
}

/// Capture a full-strategy CC-LP checkpoint against either backing:
/// inline `x` for the memory store, a flush-and-stamp reference for the
/// disk store. The disk store is also snapshotted beside itself right
/// after the stamp, so the checkpoint stays resumable even if the live
/// store later drifts past it or dies mid-pass (see
/// `backing::open_verified`).
fn capture_cc_full_backed(
    state: &CcState,
    backing: &mut XBacking,
    metric_duals: Vec<(u64, f64)>,
    passes_done: usize,
    triplet_visits: u64,
    history: &[CheckRecord],
) -> Result<SolverState, SolveError> {
    Ok(match backing {
        XBacking::Mem { x } => SolverState::capture_cc_full(
            state,
            x,
            metric_duals,
            passes_done,
            triplet_visits,
            history,
        ),
        backing @ (XBacking::Disk { .. } | XBacking::Shard { .. }) => {
            let x_fnv = backing
                .stamp_external(passes_done as u64)?
                .expect("external backings always stamp");
            SolverState::capture_cc_full_external(
                state,
                x_fnv,
                metric_duals,
                passes_done,
                triplet_visits,
                history,
            )
        }
    })
}

/// Emit one compact `store_retry` event for the notes a pass drained
/// (shared by every store-generic driver). Notes are drained by the
/// caller unconditionally — the buffer must not grow across passes —
/// but the event only fires when a recorder is listening and something
/// was actually retried.
pub(crate) fn emit_retries(
    probe: &PhaseProbe<'_>,
    pass: u64,
    notes: Vec<crate::matrix::store::RetryNote>,
) {
    let Some(first) = notes.first() else { return };
    if !probe.on() {
        return;
    }
    probe.emit(Event::StoreRetry {
        pass,
        retries: notes.len() as u64,
        detail: format!(
            "{}/{} block {} attempt {}: {}",
            first.plane, first.op, first.block, first.attempt, first.error
        ),
    });
}

/// One wave-parallel sweep over all metric constraints (resident `x`).
/// The drivers now lease `x` through their backing and call
/// [`run_metric_phase_store`] directly; this wrapper remains for tests
/// that pin the sweep against the classic resident pass.
#[cfg_attr(not(test), allow(dead_code))]
pub(crate) fn run_metric_phase(
    state: &mut CcState,
    schedule: &Schedule,
    stores: &PerWorker<DualStore>,
    p: usize,
    assignment: Assignment,
) {
    let store = MemStore::new(state.x.as_mut_slice(), &state.col_starts, &state.winv);
    run_metric_phase_store(&store, schedule, stores, p, assignment);
}

/// One wave-parallel sweep over all metric constraints, leasing each
/// tile's working set from a [`TileStore`] — the same pass for the
/// resident array (free pass-through leases) and the disk-backed store
/// (bounded working set, next-tile prefetch).
#[allow(unused_unsafe)]
pub(crate) fn run_metric_phase_store(
    store: &dyn TileStore,
    schedule: &Schedule,
    stores: &PerWorker<DualStore>,
    p: usize,
    assignment: Assignment,
) {
    run_metric_phase_timed(store, schedule, stores, p, assignment, None)
}

/// [`run_metric_phase_store`] with optional per-worker busy-seconds
/// accumulation: when `worker_secs` is attached, each worker adds the
/// wall time it spent processing tiles (excluding barrier waits) into
/// its slot, once per wave — no locking, no hot-loop instrumentation.
#[allow(unused_unsafe)]
pub(crate) fn run_metric_phase_timed(
    store: &dyn TileStore,
    schedule: &Schedule,
    stores: &PerWorker<DualStore>,
    p: usize,
    assignment: Assignment,
    worker_secs: Option<&PerWorker<f64>>,
) {
    let b = schedule.tile_size();
    scoped_workers(p, |tid, barrier| {
        // SAFETY: slot `tid` is touched by this worker only.
        let duals = unsafe { stores.get_mut(tid) };
        duals.begin_pass();
        let mut scratch = TileScratch::default();
        for (wave_idx, wave) in schedule.waves().iter().enumerate() {
            let tb = telemetry::busy_start(worker_secs);
            // Fig 3: the r-th tile of the wave goes to worker r mod p
            // (optionally rotated per wave for better load balance).
            let mut r = assignment.first_tile(tid, wave_idx, p);
            while r < wave.len() {
                let tile = &wave[r];
                if let Some(next) = next_owned_tile(schedule, assignment, tid, p, wave_idx, r)
                {
                    store.prefetch(next);
                }
                // SAFETY: wave tiles are conflict-free (schedule invariant,
                // tested exhaustively) -> this worker's writes are disjoint,
                // which is the lease contract of `with_tile`.
                unsafe {
                    store.with_tile(tile, &mut scratch, &mut |x, col_starts, winv| {
                        // SAFETY: forwarded from the lease contract.
                        unsafe {
                            super::hot_loop::process_tile(x, winv, col_starts, tile, b, duals)
                        };
                    });
                }
                r += p;
            }
            // SAFETY: busy slot `tid` is touched by this worker only.
            unsafe { telemetry::add_busy(worker_secs, tid, tb) };
            // Wave boundary: all workers must finish before the next wave
            // may touch variables this wave wrote.
            barrier.wait();
        }
    });
}

/// Pair (+ box) constraints: one independent 2-3 constraint block per
/// pair, embarrassingly parallel over contiguous chunks of the resident
/// state (classic entry point, used by the serial-order and XLA drivers
/// and the timing simulator). Implemented as a [`MemStore`] pass through
/// [`run_pair_phase_store`] — bitwise identical to the historic direct
/// loop, since the mem lease hands each worker its exact global chunk.
pub(crate) fn run_pair_phase(state: &mut CcState, p: usize) {
    let CcState { x, col_starts, winv, f, y_upper, y_lower, y_box, d, include_box, .. } = state;
    let store = MemStore::new(x.as_mut_slice(), col_starts.as_slice(), winv.as_slice());
    run_pair_phase_store(&store, f, y_upper, y_lower, y_box, d, *include_box, p);
}

/// Pair (+ box) constraints against a [`TileStore`]: each worker leases
/// its contiguous chunk of the packed order
/// ([`TileStore::with_pair_range`]) and runs the same independent 2-3
/// constraint block per pair. The partition, per-entry visit order, and
/// arithmetic match the classic resident phase exactly — elementwise
/// updates are order-independent across entries — so the disk-backed
/// pair phase is bitwise identical to the resident one. Slacks, pair
/// and box duals, and the targets stay resident (`O(n²)` each); only
/// `x` and the inverse weights stream.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pair_phase_store(
    store: &dyn TileStore,
    f: &mut [f64],
    y_upper: &mut [f64],
    y_lower: &mut [f64],
    y_box: &mut [f64],
    d: &[f64],
    include_box: bool,
    p: usize,
) {
    run_pair_phase_timed(store, f, y_upper, y_lower, y_box, d, include_box, p, None)
}

/// [`run_pair_phase_store`] with optional per-worker busy-seconds
/// accumulation (same contract as
/// [`run_metric_phase_timed`]'s `worker_secs`).
#[allow(unused_unsafe)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_pair_phase_timed(
    store: &dyn TileStore,
    f: &mut [f64],
    y_upper: &mut [f64],
    y_lower: &mut [f64],
    y_box: &mut [f64],
    d: &[f64],
    include_box: bool,
    p: usize,
    worker_secs: Option<&PerWorker<f64>>,
) {
    let m = store.n_pairs();
    debug_assert_eq!(f.len(), m);
    let fs = SharedMut::new(f);
    let yu = SharedMut::new(y_upper);
    let yl = SharedMut::new(y_lower);
    let yb = SharedMut::new(y_box);
    scoped_workers(p, |tid, _| {
        let tb = telemetry::busy_start(worker_secs);
        let (lo, hi) = chunk_range(m, p, tid);
        let mut scratch = TileScratch::default();
        // SAFETY: chunks are disjoint -> the pair-range lease contract
        // holds, and each pair's variables (the leased x entry plus the
        // resident f/y lanes at the same index) are touched by this
        // worker only.
        unsafe {
            store.with_pair_range(lo, hi, true, &mut scratch, &mut |g, xs, wv| {
                for (t, xv) in xs.iter_mut().enumerate() {
                    let e = g + t;
                    let w = wv[t];
                    // SAFETY: e lies inside this worker's chunk and in
                    // bounds of every packed array.
                    unsafe {
                        let de = *d.get_unchecked(e);
                        let mut fv = fs.get(e);
                        let th = visit_pair_upper_val(xv, &mut fv, w, de, yu.get(e));
                        yu.set(e, th);
                        let th = visit_pair_lower_val(xv, &mut fv, w, de, yl.get(e));
                        yl.set(e, th);
                        fs.set(e, fv);
                        if include_box {
                            let th = visit_box_upper_val(xv, w, yb.get(e));
                            yb.set(e, th);
                        }
                    }
                }
            });
        }
        // SAFETY: busy slot `tid` is touched by this worker only.
        unsafe { telemetry::add_busy(worker_secs, tid, tb) };
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::metric_nearness::max_triangle_violation;
    use crate::solver::dykstra_serial;
    use crate::prop_assert;
    use crate::util::proptest::check;

    fn tiny(n: usize, seed: u64) -> CcLpInstance {
        CcLpInstance::random(n, 0.5, 0.8, 1.6, seed)
    }

    #[test]
    fn result_independent_of_thread_count_bitwise() {
        // The schedule's conflict-freeness makes the pass outcome exactly
        // independent of p — the strongest possible correctness signal.
        let inst = tiny(14, 3);
        let base = solve(&inst, &SolveOpts { max_passes: 8, threads: 1, tile: 3, ..Default::default() });
        for p in [2usize, 4, 7] {
            let opts = SolveOpts { max_passes: 8, threads: p, tile: 3, ..Default::default() };
            let sol = solve(&inst, &opts);
            assert_eq!(sol.x, base.x, "p={p} diverged from p=1");
            assert_eq!(sol.f, base.f, "p={p} slacks diverged");
            assert_eq!(sol.nnz_duals, base.nnz_duals, "p={p} dual count diverged");
        }
    }

    #[test]
    fn thread_independence_property() {
        check("parallel bitwise p-independence", 0xAB5EED, 12, |rng, _| {
            let n = rng.usize_in(4, 18);
            let b = rng.usize_in(1, 6);
            let inst = tiny(n, rng.next_u64());
            let mk = |p| SolveOpts { max_passes: 3, threads: p, tile: b, ..Default::default() };
            let s1 = solve(&inst, &mk(1));
            let s3 = solve(&inst, &mk(3));
            prop_assert!(s1.x == s3.x, "n={n} b={b}: p=1 vs p=3 differ");
            Ok(())
        });
    }

    #[test]
    fn converges_to_metric_feasible() {
        let inst = tiny(10, 5);
        let opts = SolveOpts { max_passes: 400, threads: 4, tile: 2, ..Default::default() };
        let sol = solve(&inst, &opts);
        assert!(max_triangle_violation(&sol.x) < 1e-3);
        assert!(sol.residuals.max_violation < 1e-2);
    }

    #[test]
    fn agrees_with_serial_at_convergence() {
        // Different constraint orders converge to the SAME unique QP
        // optimum (the projection onto the feasible set is unique).
        let inst = tiny(9, 11);
        let opts_par =
            SolveOpts { max_passes: 400, threads: 4, tile: 2, ..Default::default() };
        let opts_ser = SolveOpts { max_passes: 400, ..Default::default() };
        let par = solve(&inst, &opts_par);
        let ser = dykstra_serial::solve(&inst, &opts_ser);
        let mut worst: f64 = 0.0;
        for (i, j, v) in par.x.iter_pairs() {
            worst = worst.max((v - ser.x.get(i, j)).abs());
        }
        assert!(worst < 5e-3, "parallel vs serial optimum differ by {worst}");
    }

    #[test]
    fn tile_size_does_not_change_fixed_point() {
        let inst = tiny(10, 21);
        let sols: Vec<_> = [1usize, 2, 5, 40]
            .iter()
            .map(|&b| {
                solve(
                    &inst,
                    &SolveOpts { max_passes: 300, threads: 2, tile: b, ..Default::default() },
                )
            })
            .collect();
        for s in &sols[1..] {
            let mut worst: f64 = 0.0;
            for (i, j, v) in s.x.iter_pairs() {
                worst = worst.max((v - sols[0].x.get(i, j)).abs());
            }
            assert!(worst < 5e-3, "tile size changed the optimum by {worst}");
        }
    }

    #[test]
    fn lp_objective_close_to_serial() {
        let inst = tiny(12, 31);
        let par = solve(
            &inst,
            &SolveOpts { max_passes: 200, threads: 3, tile: 4, ..Default::default() },
        );
        let ser = dykstra_serial::solve(&inst, &SolveOpts { max_passes: 200, ..Default::default() });
        let lp_par = inst.lp_objective(&par.x);
        let lp_ser = inst.lp_objective(&ser.x);
        assert!(
            (lp_par - lp_ser).abs() < 1e-2 * lp_ser.abs().max(1.0),
            "LP objectives differ: {lp_par} vs {lp_ser}"
        );
    }

    #[test]
    fn rotated_assignment_same_result_bitwise() {
        // Assignment policy moves tiles between workers but never changes
        // the wave structure -> identical numerics, different per-worker
        // dual arrays only.
        let inst = tiny(12, 61);
        let rr = solve(
            &inst,
            &SolveOpts {
                max_passes: 6,
                threads: 3,
                tile: 2,
                assignment: Assignment::RoundRobin,
                ..Default::default()
            },
        );
        let rot = solve(
            &inst,
            &SolveOpts {
                max_passes: 6,
                threads: 3,
                tile: 2,
                assignment: Assignment::Rotated,
                ..Default::default()
            },
        );
        assert_eq!(rr.x, rot.x);
        assert_eq!(rr.nnz_duals, rot.nnz_duals);
    }

    #[test]
    fn respects_prebuilt_schedule() {
        let inst = tiny(8, 41);
        let schedule = Schedule::new(8, 2);
        let opts = SolveOpts { max_passes: 5, threads: 2, tile: 2, ..Default::default() };
        let a = solve_with_schedule(&inst, &opts, &schedule);
        let b = solve(&inst, &opts);
        assert_eq!(a.x, b.x);
    }

    #[test]
    #[should_panic(expected = "schedule built for wrong n")]
    fn wrong_schedule_panics() {
        let inst = tiny(8, 41);
        let schedule = Schedule::new(9, 2);
        let _ = solve_with_schedule(&inst, &SolveOpts::default(), &schedule);
    }
}
