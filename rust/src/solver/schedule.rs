//! The paper's parallel execution schedule (§III-B/C).
//!
//! Triplets `(i, j, k)`, `i < j < k`, are grouped into sets `S_{i,k}` (all
//! middle indices `j` for a fixed smallest index `i` and largest index
//! `k`). Arranged on the `(i, k)` grid, any two sets on the same
//! *downward-sloping diagonal* (`i` strictly increasing while `k` strictly
//! decreasing, i.e. constant `i + k`) contain triplets sharing at most one
//! index, so their projections touch disjoint variables (Fig 1/2).
//!
//! §III-C generalizes cells to `b × b` **tiles** of `S_{i,k}` sets for
//! cache efficiency (Fig 4); tiles along one block diagonal are
//! conflict-free by the same argument (DESIGN.md §1 gives the proof we
//! test against). `b = 1` recovers the untiled schedule exactly.
//!
//! A [`Schedule`] is a sequence of **waves**; all tiles in a wave may be
//! processed concurrently, with the `r mod p` worker assignment of Fig 3.

/// A rectangular tile of the `(i, k)` grid: smallest indices
/// `i ∈ [i_lo, i_hi)`, largest indices `k ∈ [k_lo, k_hi)`. The triplets of
/// the tile are `{(i, j, k) : i ∈ I, k ∈ K, i < j < k}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tile {
    pub i_lo: usize,
    pub i_hi: usize,
    pub k_lo: usize,
    pub k_hi: usize,
}

impl Tile {
    /// Number of triplets inside this tile.
    pub fn triplet_count(&self) -> u64 {
        let mut count = 0u64;
        for i in self.i_lo..self.i_hi {
            for k in self.k_lo..self.k_hi {
                if k >= i + 2 {
                    count += (k - i - 1) as u64;
                }
            }
        }
        count
    }

    /// True iff the tile contains at least one valid triplet.
    pub fn is_nonempty(&self) -> bool {
        // smallest i and largest k give the widest j range
        self.i_lo + 2 < self.k_hi && self.i_lo < self.i_hi && self.k_lo < self.k_hi
    }
}

/// Wave-structured schedule over all `C(n, 3)` triplets.
#[derive(Clone, Debug)]
pub struct Schedule {
    n: usize,
    b: usize,
    waves: Vec<Vec<Tile>>,
}

impl Schedule {
    /// Build the tiled schedule for problem size `n` (nodes) and tile size
    /// `b >= 1`. Every triplet `i < j < k < n` is covered by exactly one
    /// tile; tiles within a wave are mutually conflict-free.
    pub fn new(n: usize, b: usize) -> Schedule {
        assert!(b >= 1, "tile size must be >= 1");
        let mut waves: Vec<Vec<Tile>> = Vec::new();
        if n < 3 {
            return Schedule { n, b, waves };
        }
        // i-blocks partition [0, n-2) (largest useful smallest-index is n-3).
        // k-blocks partition [2, n). Block `a` covers i ∈ [a·b, (a+1)·b);
        // block `e` covers k ∈ [2 + e·b, 2 + (e+1)·b). Along a wave,
        // a + e = d is constant: `a` ascending ⇒ i-ranges ascending and
        // k-ranges descending, which is the conflict-free diagonal pattern.
        let i_span = n - 2;
        let k_span = n - 2;
        let na = i_span.div_ceil(b);
        let ne = k_span.div_ceil(b);
        // Iterate d from high to low so the first waves hold the largest k
        // (z = n downwards), matching Fig 1's first double loop direction.
        for d in (0..=(na - 1 + ne - 1)).rev() {
            let a_min = d.saturating_sub(ne - 1);
            let a_max = d.min(na - 1);
            let mut wave = Vec::new();
            for a in a_min..=a_max {
                let e = d - a;
                let tile = Tile {
                    i_lo: a * b,
                    i_hi: ((a + 1) * b).min(i_span),
                    k_lo: 2 + e * b,
                    k_hi: (2 + (e + 1) * b).min(n),
                };
                if tile.is_nonempty() {
                    wave.push(tile);
                }
            }
            if !wave.is_empty() {
                waves.push(wave);
            }
        }
        Schedule { n, b, waves }
    }

    /// Problem size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Tile size.
    pub fn tile_size(&self) -> usize {
        self.b
    }

    /// The waves, in execution order. Tiles within a wave are ordered by
    /// ascending `i_lo` — the index used for the `r mod p` assignment.
    pub fn waves(&self) -> &[Vec<Tile>] {
        &self.waves
    }

    /// Total number of tiles.
    pub fn n_tiles(&self) -> usize {
        self.waves.iter().map(Vec::len).sum()
    }

    /// Total triplets covered (must equal C(n,3)).
    pub fn total_triplets(&self) -> u64 {
        self.waves.iter().flatten().map(Tile::triplet_count).sum()
    }

    /// Per-worker triplet loads under an [`Assignment`] policy — used by
    /// load-balance diagnostics, the ablation bench, and tests.
    pub fn worker_loads(&self, p: usize, policy: Assignment) -> Vec<u64> {
        let mut loads = vec![0u64; p];
        for (wi, wave) in self.waves.iter().enumerate() {
            for (r, tile) in wave.iter().enumerate() {
                loads[policy.worker_of(r, wi, p)] += tile.triplet_count();
            }
        }
        loads
    }
}

/// Maps triplets back to their position in a schedule — the wave and
/// in-wave tile owning them, plus the j-chunk of the cube iteration —
/// so consumers can reconstruct the deterministic visit order without
/// enumerating tiles. Built once per schedule; used by the active-set
/// seeding and the checkpoint dual redistribution, which must agree on
/// this geometry exactly (a drift between them would break bitwise
/// resume equivalence).
pub struct TileRouter {
    b: usize,
    /// (i-block, k-block) -> (wave index, tile index within the wave).
    map: std::collections::HashMap<(usize, usize), (usize, usize)>,
}

impl TileRouter {
    /// Index the schedule's tiles by their block coordinates: tile
    /// `(a, e)` covers `i ∈ [a·b, (a+1)·b)` and `k ∈ [2+e·b, 2+(e+1)·b)`.
    pub fn new(schedule: &Schedule) -> TileRouter {
        let b = schedule.tile_size();
        let mut map = std::collections::HashMap::new();
        for (wi, wave) in schedule.waves().iter().enumerate() {
            for (r, tile) in wave.iter().enumerate() {
                map.insert((tile.i_lo / b, (tile.k_lo - 2) / b), (wi, r));
            }
        }
        TileRouter { b, map }
    }

    /// `(wave_idx, tile_idx_in_wave, j_chunk)` of triplet `(i, j, k)`.
    /// Within a chunk, [`crate::solver::tiling::for_each_triplet`] visits
    /// in ascending `(i, j, k)` — the triplet key's numeric order.
    ///
    /// # Panics
    /// If the triplet lies outside the schedule's `n` (callers validate
    /// keys first).
    pub fn locate(&self, i: usize, j: usize, k: usize) -> (usize, usize, usize) {
        let a = i / self.b;
        let (wi, r) = self.map[&(a, (k - 2) / self.b)];
        // j-chunks of width b start at the tile's j_min = a·b + 1.
        let chunk = (j - (a * self.b + 1)) / self.b;
        (wi, r, chunk)
    }
}

/// Tile-to-worker assignment policy within a wave.
///
/// `RoundRobin` is the paper's Fig 3: the r-th tile of a wave goes to
/// worker `r mod p`. Because tile sizes *decrease* along a diagonal
/// (the j-span shrinks as `i` grows toward `k`), worker 0 systematically
/// receives the largest tile of **every** wave; for tiled schedules with
/// few tiles per wave (`n/b` comparable to `p`) this is measurably
/// imbalanced. `Rotated` fixes it by shifting the round-robin offset by
/// the wave index — still fully deterministic per worker across passes
/// (the §III-D requirement), so the dual stores remain valid. The
/// ablation bench quantifies the difference.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Assignment {
    /// Paper's Fig 3: worker = r mod p.
    #[default]
    RoundRobin,
    /// worker = (r + wave_index) mod p.
    Rotated,
}

impl Assignment {
    /// Worker owning the `r`-th tile of wave `wave_idx` among `p` workers.
    #[inline(always)]
    pub fn worker_of(self, r: usize, wave_idx: usize, p: usize) -> usize {
        match self {
            Assignment::RoundRobin => r % p,
            Assignment::Rotated => (r + wave_idx) % p,
        }
    }

    /// First tile index of wave `wave_idx` owned by `tid` (then step by p).
    #[inline(always)]
    pub fn first_tile(self, tid: usize, wave_idx: usize, p: usize) -> usize {
        match self {
            Assignment::RoundRobin => tid,
            Assignment::Rotated => (tid + p - wave_idx % p) % p,
        }
    }
}

/// The next tile worker `tid` will process after tile `r` of wave
/// `wave_idx` under `assignment` — the prefetch target that keeps a
/// disk-backed tile store ([`crate::matrix::store`]) one tile ahead of a
/// streaming pass.
pub fn next_owned_tile<'a>(
    schedule: &'a Schedule,
    assignment: Assignment,
    tid: usize,
    p: usize,
    wave_idx: usize,
    r: usize,
) -> Option<&'a Tile> {
    let waves = schedule.waves();
    if r + p < waves[wave_idx].len() {
        return Some(&waves[wave_idx][r + p]);
    }
    for (w, wave) in waves.iter().enumerate().skip(wave_idx + 1) {
        let nr = assignment.first_tile(tid, w, p);
        if nr < wave.len() {
            return Some(&wave[nr]);
        }
    }
    None
}

/// C(n, 3) as u64.
pub fn n_triplets(n: usize) -> u64 {
    if n < 3 {
        return 0;
    }
    let n = n as u64;
    n * (n - 1) * (n - 2) / 6
}

/// Partition of the packed column-major `x` plane across shard workers.
///
/// Each shard owns a *contiguous run of columns* `c ∈ [col_bounds[s],
/// col_bounds[s+1])` of the strict upper triangle, i.e. the contiguous
/// packed-entry range `[entry_bounds[s], entry_bounds[s+1])`. Column
/// granularity matters: every per-column segment a tile lease gathers
/// (see `for_each_tile_col`) then lives wholly inside one shard, so a
/// lease's socket traffic is a handful of per-shard range requests, never
/// a split segment. Columns are dealt greedily by pair count (column `c`
/// holds `n - 1 - c` pairs), so shard loads are balanced to within one
/// column. The partition is a pure function of `(n, n_shards)` —
/// coordinator and workers recompute it independently and agree.
///
/// Trailing shards may own zero columns when `n_shards > n - 1`; that is
/// legal (the worker simply idles), so worker counts need not divide the
/// problem size.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPartition {
    n: usize,
    /// `n_shards + 1` ascending column bounds; first 0, last `n - 1`
    /// (the last column of the strict upper triangle is empty and is
    /// never assigned).
    col_bounds: Vec<usize>,
    /// `n_shards + 1` ascending packed-entry bounds; first 0, last
    /// `n·(n-1)/2`.
    entry_bounds: Vec<usize>,
}

impl ShardPartition {
    /// Build the partition of the `n`-node plane over `n_shards >= 1`
    /// workers.
    pub fn new(n: usize, n_shards: usize) -> ShardPartition {
        assert!(n_shards >= 1, "shard partition needs at least one shard");
        let n_cols = n.saturating_sub(1);
        let total: usize = n * n_cols / 2;
        let mut col_bounds = Vec::with_capacity(n_shards + 1);
        let mut entry_bounds = Vec::with_capacity(n_shards + 1);
        col_bounds.push(0);
        entry_bounds.push(0);
        let mut c = 0usize;
        let mut e = 0usize;
        for s in 0..n_shards {
            // Greedy: extend this shard while its pair count stays below
            // the even split of what remains over the shards left.
            let remaining_shards = n_shards - s;
            let target = (total - e).div_ceil(remaining_shards);
            let mut here = 0usize;
            while c < n_cols && (here == 0 || here + (n - 1 - c) <= target) {
                here += n - 1 - c;
                c += 1;
            }
            e += here;
            col_bounds.push(c);
            entry_bounds.push(e);
        }
        debug_assert_eq!(*col_bounds.last().unwrap(), n_cols);
        debug_assert_eq!(*entry_bounds.last().unwrap(), total);
        ShardPartition { n, col_bounds, entry_bounds }
    }

    /// Problem size.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.col_bounds.len() - 1
    }

    /// Column range `[lo, hi)` owned by shard `s` (may be empty).
    pub fn col_range(&self, s: usize) -> (usize, usize) {
        (self.col_bounds[s], self.col_bounds[s + 1])
    }

    /// Packed-entry range `[lo, hi)` owned by shard `s` (may be empty).
    pub fn entry_range(&self, s: usize) -> (usize, usize) {
        (self.entry_bounds[s], self.entry_bounds[s + 1])
    }

    /// Shard owning global packed entry `g`.
    ///
    /// # Panics
    /// If `g` is at or past the total pair count.
    pub fn shard_of_entry(&self, g: usize) -> usize {
        assert!(g < *self.entry_bounds.last().unwrap(), "entry {g} out of range");
        // entry_bounds is ascending but not strictly (empty shards repeat
        // a bound); partition_point finds the first shard whose upper
        // bound exceeds g — the unique nonempty owner.
        self.entry_bounds[1..].partition_point(|&b| b <= g)
    }

    /// Shard owning packed column `c` (the shard whose column range
    /// contains it; empty columns at the tail are unowned).
    ///
    /// # Panics
    /// If `c >= n - 1` (the last column holds no pairs).
    pub fn shard_of_col(&self, c: usize) -> usize {
        assert!(c < *self.col_bounds.last().unwrap(), "column {c} out of range");
        self.col_bounds[1..].partition_point(|&b| b <= c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::stats::load_imbalance;

    /// Exhaustively collect (tile_index_in_wave -> triplets) per wave.
    fn wave_triplets(wave: &[Tile]) -> Vec<Vec<(usize, usize, usize)>> {
        wave.iter()
            .map(|t| {
                let mut v = Vec::new();
                for i in t.i_lo..t.i_hi {
                    for k in t.k_lo..t.k_hi {
                        for j in (i + 1)..k {
                            v.push((i, j, k));
                        }
                    }
                }
                v
            })
            .collect()
    }

    fn shares_two_indices(a: (usize, usize, usize), b: (usize, usize, usize)) -> bool {
        let sa = [a.0, a.1, a.2];
        let sb = [b.0, b.1, b.2];
        let shared = sa.iter().filter(|x| sb.contains(x)).count();
        shared >= 2
    }

    #[test]
    fn covers_all_triplets_exactly_once_small() {
        for n in [3usize, 4, 5, 8, 13, 20] {
            for b in [1usize, 2, 3, 5, 40] {
                let s = Schedule::new(n, b);
                let mut seen = std::collections::HashSet::new();
                for wave in s.waves() {
                    for tri in wave_triplets(wave).into_iter().flatten() {
                        assert!(seen.insert(tri), "duplicate {tri:?} n={n} b={b}");
                    }
                }
                assert_eq!(seen.len() as u64, n_triplets(n), "n={n} b={b}");
            }
        }
    }

    #[test]
    fn triplet_count_formula_matches_enumeration() {
        let s = Schedule::new(15, 4);
        for wave in s.waves() {
            for (tile, tris) in wave.iter().zip(wave_triplets(wave)) {
                assert_eq!(tile.triplet_count() as usize, tris.len());
            }
        }
        assert_eq!(s.total_triplets(), n_triplets(15));
    }

    #[test]
    fn waves_are_conflict_free_exhaustive() {
        // The safety property for SharedMut: two triplets from different
        // tiles of the same wave never share 2+ indices.
        for n in [6usize, 9, 12, 14] {
            for b in [1usize, 2, 3] {
                let s = Schedule::new(n, b);
                for wave in s.waves() {
                    let per_tile = wave_triplets(wave);
                    for a in 0..per_tile.len() {
                        for bb in (a + 1)..per_tile.len() {
                            for &ta in &per_tile[a] {
                                for &tb in &per_tile[bb] {
                                    assert!(
                                        !shares_two_indices(ta, tb),
                                        "conflict {ta:?} vs {tb:?} (n={n} b={b})"
                                    );
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn conflict_freeness_property_random() {
        check("schedule conflict-free", 0xD1A60, 24, |rng, _| {
            let n = rng.usize_in(3, 60);
            let b = rng.usize_in(1, 12);
            let s = Schedule::new(n, b);
            // sample pairs of tiles in random waves
            for _ in 0..50 {
                if s.waves().is_empty() {
                    break;
                }
                let w = &s.waves()[rng.usize_in(0, s.waves().len())];
                if w.len() < 2 {
                    continue;
                }
                let ta = w[rng.usize_in(0, w.len())];
                let tb = w[rng.usize_in(0, w.len())];
                if ta == tb {
                    continue;
                }
                // random triplet from each tile
                let pick = |rng: &mut crate::util::rng::Rng, t: &Tile| loop {
                    let i = rng.usize_in(t.i_lo, t.i_hi);
                    let k = rng.usize_in(t.k_lo, t.k_hi);
                    if k >= i + 2 {
                        let j = rng.usize_in(i + 1, k);
                        return (i, j, k);
                    }
                };
                if !ta.is_nonempty() || !tb.is_nonempty() {
                    continue;
                }
                let x = pick(rng, &ta);
                let y = pick(rng, &tb);
                prop_assert!(!shares_two_indices(x, y), "{x:?} vs {y:?} n={n} b={b}");
            }
            Ok(())
        });
    }

    #[test]
    fn coverage_property_random() {
        check("schedule covers C(n,3)", 0xC0FE3, 24, |rng, _| {
            let n = rng.usize_in(3, 80);
            let b = rng.usize_in(1, 16);
            let s = Schedule::new(n, b);
            prop_assert!(
                s.total_triplets() == n_triplets(n),
                "covered {} != C({n},3) = {} (b={b})",
                s.total_triplets(),
                n_triplets(n)
            );
            Ok(())
        });
    }

    #[test]
    fn untiled_matches_figure2_shape() {
        // n = 12 as in Fig 2: every wave's tiles have strictly increasing
        // i and strictly decreasing k.
        let s = Schedule::new(12, 1);
        for wave in s.waves() {
            for pair in wave.windows(2) {
                assert!(pair[0].i_lo < pair[1].i_lo);
                assert!(pair[0].k_lo > pair[1].k_lo);
            }
        }
    }

    #[test]
    fn degenerate_sizes() {
        assert_eq!(Schedule::new(1, 4).total_triplets(), 0);
        assert_eq!(Schedule::new(2, 4).total_triplets(), 0);
        assert_eq!(Schedule::new(3, 4).total_triplets(), 1);
    }

    #[test]
    fn load_balance_untiled_reasonable() {
        // Fig 3's r mod p assignment on the untiled schedule: waves have
        // ~n/2 sets, so round-robin is well balanced for p << n.
        let s = Schedule::new(300, 1);
        for p in [2usize, 4, 8] {
            let loads: Vec<f64> =
                s.worker_loads(p, Assignment::RoundRobin).iter().map(|&x| x as f64).collect();
            let im = load_imbalance(&loads);
            assert!(im < 0.3, "p={p} imbalance={im}");
            assert_eq!(loads.iter().sum::<f64>() as u64, n_triplets(300));
        }
    }

    #[test]
    fn rotated_assignment_beats_round_robin_when_tiled() {
        // With b=10 and n=300 each wave has <= 30 tiles; worker 0 always
        // getting the wave's largest tile hurts RoundRobin. Rotation fixes.
        let s = Schedule::new(300, 10);
        for p in [4usize, 8] {
            let rr: Vec<f64> =
                s.worker_loads(p, Assignment::RoundRobin).iter().map(|&x| x as f64).collect();
            let rot: Vec<f64> =
                s.worker_loads(p, Assignment::Rotated).iter().map(|&x| x as f64).collect();
            assert!(
                load_imbalance(&rot) < load_imbalance(&rr),
                "p={p}: rotated {} !< round-robin {}",
                load_imbalance(&rot),
                load_imbalance(&rr)
            );
            assert!(load_imbalance(&rot) < 0.1, "p={p} rotated imbalance");
            // both conserve total work
            assert_eq!(rr.iter().sum::<f64>(), rot.iter().sum::<f64>());
        }
    }

    #[test]
    fn assignment_policies_cover_all_tiles() {
        for policy in [Assignment::RoundRobin, Assignment::Rotated] {
            for p in [1usize, 3, 5] {
                // every tile index must be owned by exactly one worker, and
                // first_tile + step-p must enumerate exactly those indices
                for wave_idx in [0usize, 1, 7] {
                    let wave_len = 23;
                    let mut owned = vec![false; wave_len];
                    for tid in 0..p {
                        let mut r = policy.first_tile(tid, wave_idx, p);
                        while r < wave_len {
                            assert_eq!(policy.worker_of(r, wave_idx, p), tid);
                            assert!(!owned[r]);
                            owned[r] = true;
                            r += p;
                        }
                    }
                    assert!(owned.iter().all(|&o| o));
                }
            }
        }
    }

    #[test]
    fn next_owned_tile_walks_each_workers_visit_order() {
        let s = Schedule::new(20, 3);
        for policy in [Assignment::RoundRobin, Assignment::Rotated] {
            for p in [1usize, 3] {
                for tid in 0..p {
                    let mut order = Vec::new();
                    for (wi, wave) in s.waves().iter().enumerate() {
                        let mut r = policy.first_tile(tid, wi, p);
                        while r < wave.len() {
                            order.push((wi, r));
                            r += p;
                        }
                    }
                    for w in order.windows(2) {
                        let ((wi, r), (nwi, nr)) = (w[0], w[1]);
                        let got = next_owned_tile(&s, policy, tid, p, wi, r)
                            .expect("successor exists");
                        assert_eq!(got, &s.waves()[nwi][nr], "p={p} tid={tid}");
                    }
                    if let Some(&(wi, r)) = order.last() {
                        assert!(next_owned_tile(&s, policy, tid, p, wi, r).is_none());
                    }
                }
            }
        }
    }

    #[test]
    fn router_locates_every_triplet_in_its_tile_and_chunk() {
        for (n, b) in [(11usize, 1usize), (16, 3), (20, 7)] {
            let s = Schedule::new(n, b);
            let router = TileRouter::new(&s);
            for i in 0..n {
                for j in (i + 1)..n {
                    for k in (j + 1)..n {
                        let (wi, r, chunk) = router.locate(i, j, k);
                        let tile = &s.waves()[wi][r];
                        assert!(tile.i_lo <= i && i < tile.i_hi, "({i},{j},{k}) n={n} b={b}");
                        assert!(tile.k_lo <= k && k < tile.k_hi, "({i},{j},{k}) n={n} b={b}");
                        // chunk index matches the cube iteration's j-chunks
                        let j_min = tile.i_lo + 1;
                        assert_eq!(chunk, (j - j_min) / b, "({i},{j},{k}) n={n} b={b}");
                    }
                }
            }
        }
    }

    #[test]
    fn b1_tiles_are_single_cells() {
        let s = Schedule::new(10, 1);
        for wave in s.waves() {
            for t in wave {
                assert_eq!(t.i_hi - t.i_lo, 1);
                assert_eq!(t.k_hi - t.k_lo, 1);
            }
        }
    }

    #[test]
    fn shard_partition_covers_plane_exactly() {
        for n in [2usize, 3, 7, 16, 41] {
            for p in [1usize, 2, 3, 4, 8, 50] {
                let part = ShardPartition::new(n, p);
                assert_eq!(part.n_shards(), p);
                let n_pairs = n * (n - 1) / 2;
                // Column and entry ranges tile [0, n-1) and [0, n_pairs)
                // contiguously, and agree with each other.
                let mut c_prev = 0usize;
                let mut e_prev = 0usize;
                for s in 0..p {
                    let (clo, chi) = part.col_range(s);
                    let (elo, ehi) = part.entry_range(s);
                    assert_eq!(clo, c_prev, "n={n} p={p} s={s}");
                    assert_eq!(elo, e_prev, "n={n} p={p} s={s}");
                    assert!(chi >= clo && ehi >= elo);
                    let pairs: usize = (clo..chi).map(|c| n - 1 - c).sum();
                    assert_eq!(ehi - elo, pairs, "n={n} p={p} s={s}");
                    c_prev = chi;
                    e_prev = ehi;
                }
                assert_eq!(c_prev, n - 1);
                assert_eq!(e_prev, n_pairs);
            }
        }
    }

    #[test]
    fn shard_partition_lookup_agrees_with_ranges() {
        let (n, p) = (23usize, 4usize);
        let part = ShardPartition::new(n, p);
        let n_pairs = n * (n - 1) / 2;
        for g in 0..n_pairs {
            let s = part.shard_of_entry(g);
            let (lo, hi) = part.entry_range(s);
            assert!(lo <= g && g < hi, "entry {g} -> shard {s}");
        }
        for c in 0..(n - 1) {
            let s = part.shard_of_col(c);
            let (lo, hi) = part.col_range(s);
            assert!(lo <= c && c < hi, "col {c} -> shard {s}");
        }
    }

    #[test]
    fn shard_partition_is_balanced_within_one_column() {
        // Greedy dealing bounds each shard's load by the even split plus
        // the heaviest column (n - 1 pairs).
        for (n, p) in [(64usize, 2usize), (64, 4), (101, 8)] {
            let part = ShardPartition::new(n, p);
            let total = n * (n - 1) / 2;
            let even = total.div_ceil(p);
            for s in 0..p {
                let (lo, hi) = part.entry_range(s);
                assert!(hi - lo <= even + (n - 1), "n={n} p={p} s={s} load={}", hi - lo);
            }
        }
    }

    #[test]
    fn shard_partition_tolerates_more_shards_than_columns() {
        let part = ShardPartition::new(4, 10);
        // 3 columns, 10 shards: the first shards own one column each, the
        // rest are empty but well-formed.
        let owned: usize =
            (0..10).map(|s| part.col_range(s)).map(|(lo, hi)| hi - lo).sum();
        assert_eq!(owned, 3);
        assert_eq!(part.entry_range(9), (6, 6));
    }
}
