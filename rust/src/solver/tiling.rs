//! Cube iteration inside a tile (§III-C, Fig 5).
//!
//! A tile fixes `b` candidate smallest indices `i` and `b` candidate
//! largest indices `k`. The middle indices `j` span `(i_lo, k_hi - 1)`;
//! we split that span into chunks of length `b`, producing `b × b × b`
//! cubes of `(i, j, k)` values. Within a cube we iterate `i → j → k` so the
//! innermost loop walks entries `x_{jk}` (column `j`) and `x_{ik}` (column
//! `i`) down contiguous column segments of the column-major packed matrix —
//! the access pattern Fig 5 is designed for. Incomplete cubes near the
//! `i < j < k` boundary are simply clipped.

use super::schedule::Tile;

/// Visit every triplet `(i, j, k)` of `tile` in the cube order, calling
/// `f(i, j, k)` for each. The order is deterministic — a requirement for
/// the per-worker dual-variable arrays (§III-D). Defined as the
/// expansion of [`for_each_run`], so the two enumeration orders agree by
/// construction (the screened sweep's bitwise-equivalence argument
/// needs them to match visit for visit).
#[inline]
pub fn for_each_triplet<F: FnMut(usize, usize, usize)>(tile: &Tile, b: usize, mut f: F) {
    for_each_run(tile, b, |i, j, k_lo, k_hi| {
        for k in k_lo..k_hi {
            f(i, j, k);
        }
    });
}

/// Visit every contiguous `k`-run of `tile` in cube order, calling
/// `f(i, j, k_lo, k_hi)` once per nonempty run — [`for_each_triplet`]
/// with the innermost loop hoisted out. A run fixes `(i, j)` and spans
/// `k ∈ [k_lo, k_hi)`; both packed indices `p_ik` and `p_jk` walk
/// contiguous column segments along it, which is what makes a run the
/// natural unit for the vectorized violation screen
/// ([`crate::solver::active::sweep`]).
#[inline]
pub fn for_each_run<F: FnMut(usize, usize, usize, usize)>(tile: &Tile, b: usize, mut f: F) {
    let j_min = tile.i_lo + 1;
    let j_end = tile.k_hi.saturating_sub(1); // j < k <= k_hi - 1
    let mut chunk_lo = j_min;
    while chunk_lo < j_end {
        let chunk_hi = (chunk_lo + b).min(j_end);
        // One b×b×b cube: i-range × j-chunk × k-runs, clipped to i<j<k.
        for i in tile.i_lo..tile.i_hi {
            let j_lo = chunk_lo.max(i + 1);
            for j in j_lo..chunk_hi {
                let k_lo = tile.k_lo.max(j + 1);
                if k_lo < tile.k_hi {
                    f(i, j, k_lo, tile.k_hi);
                }
            }
        }
        chunk_lo = chunk_hi;
    }
}

/// Visit the **pair footprint** of a tile: one `(c, row_lo, row_hi)` call
/// per column `c` whose packed entries a visit of the tile can touch,
/// with the touched rows spanning exactly `[row_lo, row_hi)`.
///
/// A triplet `(i, j, k)` of the tile reads/writes pairs `(i, j)`,
/// `(i, k)`, `(j, k)`. With `i ∈ [i_lo, i_hi)`, `k ∈ [k_lo, k_hi)` and
/// `j` free in between, the union over the tile is, per column:
///
/// * columns `c ∈ [i_lo, i_hi)` (tile `i`-columns): rows `(c, k_hi)` —
///   `x_cj` for every middle `j` plus `x_ck` for the tile's `k`s;
/// * columns `c ∈ [i_hi, k_hi - 1)` (middle `j`-columns): rows
///   `[max(k_lo, c + 1), k_hi)` — only `x_jk` entries.
///
/// Every span is **contiguous** in the column-major packed layout, which
/// is what lets an out-of-core store ([`crate::matrix::store`]) stage a
/// tile's working set as one gather of per-column segments. Callers that
/// need the global flat range of a span can compute
/// `col_starts[c] + (row_lo - c - 1) ..` as usual.
#[inline]
pub fn for_each_tile_col<F: FnMut(usize, usize, usize)>(tile: &Tile, mut f: F) {
    let hi = tile.k_hi.saturating_sub(1);
    for c in tile.i_lo..hi {
        let row_lo = if c < tile.i_hi { c + 1 } else { tile.k_lo.max(c + 1) };
        if row_lo < tile.k_hi {
            f(c, row_lo, tile.k_hi);
        }
    }
}

/// The serial baseline order of [37]: plain lexicographic `(i, j, k)`.
#[inline]
pub fn for_each_triplet_lex<F: FnMut(usize, usize, usize)>(n: usize, mut f: F) {
    for i in 0..n {
        for j in (i + 1)..n {
            for k in (j + 1)..n {
                f(i, j, k);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::schedule::{n_triplets, Schedule};

    #[test]
    fn tile_iteration_matches_tile_definition() {
        let tile = Tile { i_lo: 1, i_hi: 3, k_lo: 5, k_hi: 8 };
        let mut got = Vec::new();
        for_each_triplet(&tile, 2, |i, j, k| got.push((i, j, k)));
        // reference: all (i,j,k), i in [1,3), k in [5,8), i<j<k
        let mut want = Vec::new();
        for i in 1..3 {
            for k in 5..8 {
                for j in (i + 1)..k {
                    want.push((i, j, k));
                }
            }
        }
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn no_duplicates_and_valid_order_invariants() {
        let tile = Tile { i_lo: 0, i_hi: 4, k_lo: 2, k_hi: 9 };
        let mut seen = std::collections::HashSet::new();
        for_each_triplet(&tile, 3, |i, j, k| {
            assert!(i < j && j < k, "bad triplet ({i},{j},{k})");
            assert!(seen.insert((i, j, k)), "dup ({i},{j},{k})");
        });
        assert_eq!(seen.len() as u64, tile.triplet_count());
    }

    #[test]
    fn full_schedule_iteration_covers_cn3() {
        for (n, b) in [(10usize, 1usize), (14, 3), (23, 5), (30, 40)] {
            let s = Schedule::new(n, b);
            let mut seen = std::collections::HashSet::new();
            for wave in s.waves() {
                for tile in wave {
                    for_each_triplet(tile, b, |i, j, k| {
                        assert!(seen.insert((i, j, k)), "dup n={n} b={b}");
                    });
                }
            }
            assert_eq!(seen.len() as u64, n_triplets(n), "n={n} b={b}");
        }
    }

    #[test]
    fn lex_order_is_sorted_and_complete() {
        let mut got = Vec::new();
        for_each_triplet_lex(7, |i, j, k| got.push((i, j, k)));
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(got, sorted, "lex order must be sorted");
        assert_eq!(got.len() as u64, n_triplets(7));
    }

    #[test]
    fn deterministic_iteration_order() {
        let tile = Tile { i_lo: 2, i_hi: 6, k_lo: 7, k_hi: 12 };
        let mut a = Vec::new();
        let mut b_ = Vec::new();
        for_each_triplet(&tile, 4, |i, j, k| a.push((i, j, k)));
        for_each_triplet(&tile, 4, |i, j, k| b_.push((i, j, k)));
        assert_eq!(a, b_);
    }

    #[test]
    fn runs_expand_to_the_triplet_order_exactly() {
        // for_each_run is for_each_triplet with the k loop hoisted: the
        // screened sweep relies on the orders matching visit for visit.
        for (n, b) in [(10usize, 1usize), (14, 3), (19, 4), (23, 7)] {
            let s = Schedule::new(n, b);
            for wave in s.waves() {
                for tile in wave {
                    let mut via_triplets = Vec::new();
                    for_each_triplet(tile, b, |i, j, k| via_triplets.push((i, j, k)));
                    let mut via_runs = Vec::new();
                    for_each_run(tile, b, |i, j, k_lo, k_hi| {
                        assert!(k_lo < k_hi, "empty run emitted n={n} b={b}");
                        assert!(i < j && j < k_lo, "bad run ({i},{j},{k_lo}..{k_hi})");
                        for k in k_lo..k_hi {
                            via_runs.push((i, j, k));
                        }
                    });
                    assert_eq!(via_runs, via_triplets, "n={n} b={b}");
                }
            }
        }
    }

    #[test]
    fn run_length_never_exceeds_tile_size() {
        for (n, b) in [(15usize, 2usize), (30, 5), (12, 40)] {
            let s = Schedule::new(n, b);
            for wave in s.waves() {
                for tile in wave {
                    for_each_run(tile, b, |_, _, k_lo, k_hi| {
                        assert!(k_hi - k_lo <= b.max(tile.k_hi - tile.k_lo));
                        assert!(k_hi - k_lo <= tile.k_hi - tile.k_lo);
                    });
                }
            }
        }
    }

    #[test]
    fn tile_footprint_equals_the_reachable_pair_set() {
        // The safety contract of the out-of-core store, in BOTH
        // directions. Coverage (footprint ⊇ touched pairs) makes a
        // lease's arena sufficient; exactness (footprint ⊆ touched
        // pairs) is what lets the disk store scatter the *whole*
        // footprint back — same-wave reachable sets are disjoint (the
        // wave invariant), so equal footprints are disjoint too, and a
        // blanket write-back can never clobber a concurrent lease.
        for (n, b) in [(8usize, 2usize), (14, 3), (19, 4), (23, 7), (12, 40)] {
            let s = Schedule::new(n, b);
            for wave in s.waves() {
                for tile in wave {
                    let mut cover = std::collections::HashSet::new();
                    let mut seen_cols = std::collections::HashSet::new();
                    for_each_tile_col(tile, |c, lo, hi| {
                        assert!(lo < hi, "empty span emitted n={n} b={b}");
                        assert!(c < lo, "span must sit below the diagonal");
                        assert!(hi <= n, "span exceeds n={n}");
                        assert!(seen_cols.insert(c), "column {c} emitted twice");
                        for r in lo..hi {
                            cover.insert((c, r));
                        }
                    });
                    let mut touched = std::collections::HashSet::new();
                    for_each_triplet(tile, b, |i, j, k| {
                        for (a, bb) in [(i, j), (i, k), (j, k)] {
                            assert!(
                                cover.contains(&(a, bb)),
                                "pair ({a},{bb}) of triplet ({i},{j},{k}) outside \
                                 footprint of {tile:?} (n={n} b={b})"
                            );
                            touched.insert((a, bb));
                        }
                    });
                    assert_eq!(
                        cover, touched,
                        "footprint of {tile:?} exceeds its reachable pairs (n={n} b={b})"
                    );
                }
            }
        }
    }

    #[test]
    fn cube_order_groups_j_chunks() {
        // With b=2 and a wide j span, the first visited j values must all
        // lie in the first chunk before any j from the second chunk.
        let tile = Tile { i_lo: 0, i_hi: 2, k_lo: 8, k_hi: 10 };
        let mut js = Vec::new();
        for_each_triplet(&tile, 2, |_, j, _| js.push(j));
        let first_chunk_max = 1 + 2; // j_min=1, chunk = [1,3)
        let split = js.iter().position(|&j| j >= first_chunk_max).unwrap();
        assert!(js[..split].iter().all(|&j| j < first_chunk_max));
        assert!(js[split..].iter().all(|&j| j >= first_chunk_max));
    }
}
