//! The proximal-distance **MM** driver: minimize
//! `f(x) = ½ Σ w_e (x_e - d_e)²` subject to `Dx ≥ 0` (`D = [T; I]`) by
//! majorize-minimize on the penalized objective
//! `f(x) + ρ/2 · dist²(Dx, ℝ₊)`.
//!
//! Majorizing the distance term at the current iterate `y` (projecting
//! `Dy` onto the nonnegative orthant: `p = max(Ty, 0)`, `q = max(y, 0)`)
//! gives a quadratic surrogate whose minimizer solves the normal
//! equations
//!
//! ```text
//!   (W + ρ (T'T + I)) x  =  W∘d + ρ (T'p + q)
//! ```
//!
//! solved matrix-free by warm-started preconditioned CG
//! ([`super::cg`]), with `ρ` annealed geometrically every outer
//! iteration and the iterate sequence Nesterov-accelerated (without
//! acceleration the fixed-point map's linear rate makes the penalty
//! path stall — measured in the f64 prototype for this module: the
//! plain iteration needs thousands of inner solves per ρ level, the
//! accelerated annealed loop ~300 total to a 1e-7 violation).
//!
//! Stopping is on the **true** max triangle violation (the same scan
//! the Dykstra drivers use, not an operator-derived quantity), so a
//! broken [`MetricOperator`] cannot convince the loop it converged —
//! it converges to a visibly wrong point or never reaches tolerance,
//! and either way the cross-family oracle flags it.

use super::cg::{self, CgScratch};
use super::operator::MetricOperator;
use super::ProxTuning;
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::solver::error::SolveError;
use crate::solver::nearness::{self, NearnessSolution};
use crate::telemetry::{Counters, Event, PassKind, PhaseName, PhaseProbe, Recorder};
use crate::matrix::PackedSym;

pub(crate) fn run(
    inst: &MetricNearnessInstance,
    op: &dyn MetricOperator,
    tol_violation: f64,
    threads: usize,
    tuning: &ProxTuning,
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    let n = inst.n;
    let p = threads.max(1);
    let d = inst.d.as_slice();
    let w = inst.w.as_slice();
    let m = d.len();
    let col_starts = inst.d.col_starts().to_vec();
    let tps = op.sweep_triplets();

    let mut x = d.to_vec();
    let mut x_prev = x.clone();
    let mut y = vec![0.0; m];
    let mut rhs = vec![0.0; m];
    let mut tmp = vec![0.0; m];
    let mut scratch = CgScratch::default();
    let mut rho = tuning.rho_init;
    let mut t_nes = 1.0f64;

    let mut triplet_visits: u64 = 0;
    let mut outers_done = 0usize;
    let mut max_violation = f64::INFINITY;
    let mut measured_at = usize::MAX;
    let mut probe = PhaseProbe::new(rec, p);
    let check_every = tuning.mm_check_every.max(1);

    for outer in 0..tuning.mm_max_outer {
        let t_pass = probe.start();
        let pass_no = (outer + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });

        // Nesterov extrapolation point.
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t_nes * t_nes).sqrt());
        let beta = (t_nes - 1.0) / t_next;
        t_nes = t_next;
        for e in 0..m {
            y[e] = x[e] + beta * (x[e] - x_prev[e]);
        }

        // Majorize at y and solve the normal equations from the warm
        // start x = y. One scatter sweep + (1 + iters) matvec sweeps.
        let pt = probe.start();
        tmp.fill(0.0);
        op.scatter_clamped(&y, true, &mut tmp);
        for e in 0..m {
            rhs[e] = w[e] * d[e] + rho * (tmp[e] + y[e].max(0.0));
        }
        x_prev.copy_from_slice(&x);
        x.copy_from_slice(&y);
        let out = cg::solve(op, w, rho, &rhs, &mut x, tuning.cg_rtol, tuning.cg_max, &mut scratch);
        let solve_visits = (out.iters as u64 + 2) * tps;
        triplet_visits += solve_visits;
        probe.finish(pass_no, PhaseName::Cg, pt, solve_visits, None);

        outers_done = outer + 1;
        let mut stop = false;
        if outers_done % check_every == 0 || outers_done == tuning.mm_max_outer {
            let pt = probe.start();
            max_violation = nearness::violation(&x, &col_starts, n, p);
            probe.finish(pass_no, PhaseName::ResidualScan, pt, tps, None);
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation,
                rel_gap: 0.0,
                lp_objective: 0.0,
                exact: true,
            });
            measured_at = outers_done;
            if !max_violation.is_finite() {
                return Err(SolveError::Other(anyhow::anyhow!(
                    "prox-mm diverged (non-finite iterate) at outer iteration {outers_done}, \
                     rho = {rho:.3e}"
                )));
            }
            if max_violation <= tol_violation {
                stop = true;
            }
        }
        if probe.on() {
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t_pass.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0),
                triplet_visits,
                active_triplets: tps,
            });
        }
        if stop {
            break;
        }
        rho *= tuning.mm_rho_mult;
    }
    if measured_at != outers_done {
        max_violation = nearness::violation(&x, &col_starts, n, p);
    }
    let mut xm = PackedSym::zeros(n);
    xm.as_mut_slice().copy_from_slice(&x);
    let sol = NearnessSolution {
        objective: inst.objective(&xm),
        x: xm,
        max_violation,
        passes: outers_done,
        metric_visits: triplet_visits * 3,
        active_triplets: tps as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: None,
    };
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                ..sol.counters()
            },
        });
    }
    Ok(sol)
}
