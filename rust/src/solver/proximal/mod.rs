//! The **proximal-distance** solver family — the second, fully
//! independent algorithm family for the metric-nearness objective
//! (ROADMAP "second algorithm family"; Keys–Zhou–Lange's
//! proximal-distance framework applied to metric projection).
//!
//! Where every Dykstra driver in this crate projects onto one
//! constraint at a time and converges to the **exact** weighted
//! projection, the proximal family never projects at all: it minimizes
//! the penalized objective `f(x) + ρ/2 · dist²(Dx, ℝ₊)` for an
//! increasing ladder of penalties `ρ`, where `D = [T; I]` stacks the
//! triangle operator ([`operator`]) on the identity. As `ρ → ∞` the
//! penalty path converges to the projection — validated to a relative
//! objective agreement of ~1e-4 against converged Dykstra in the f64
//! prototype behind this module — but any finite run stops at finite
//! `ρ`, so the family agrees with Dykstra *within tolerance*, never
//! bitwise. That near-total independence (different math, different
//! fixed point, different stopping) is the point: the two families
//! cross-check each other in [`crate::eval::cross_check`], and a bug in
//! either one shows up as a tolerance-band mismatch
//! (`tests/cross_family.rs` proves this with a deliberately broken
//! operator).
//!
//! Two members, selected by [`Algorithm`]:
//!
//! * [`Algorithm::ProxMm`] ([`mm`]) — majorize-minimize; each outer
//!   iteration solves `(W + ρ(T'T + I)) x = W∘d + ρ(T'p + q)` with
//!   matrix-free preconditioned CG ([`cg`]), Nesterov-accelerated,
//!   `ρ` annealed per iteration. The accurate member.
//! * [`Algorithm::ProxSd`] ([`sd`]) — steepest descent with an exact
//!   majorized step, no linear solves. The cheap member.
//!
//! Both run every operator sweep over the same conflict-free wave
//! schedule as the Dykstra drivers and are bitwise independent of the
//! thread count ([`operator::WaveOperator`]); neither supports disk
//! stores or checkpoint resume (the iterate is a dense resident pair
//! vector by construction — [`crate::solver::nearness::solve_traced`]
//! rejects those combinations typed).

pub mod cg;
pub mod mm;
pub mod operator;
pub mod sd;

use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::solver::error::SolveError;
use crate::solver::nearness::{NearnessOpts, NearnessSolution};
use crate::solver::Algorithm;
use crate::telemetry::{NullRecorder, Recorder};
use operator::{MetricOperator, WaveOperator};

/// Iteration-schedule knobs of the proximal family. The defaults are
/// the values tuned in the f64 prototype (see EXPERIMENTS.md,
/// "Cross-family oracle"): they reach ≤1e-7 violation and ~1e-4 relative
/// objective agreement with Dykstra on seeded random instances up to
/// n ≈ 24 in a few hundred outer iterations. [`NearnessOpts`] supplies
/// what the proximal loops share with Dykstra (`tol_violation`,
/// `threads`, `tile`); everything schedule-specific lives here, because
/// `max_passes = 50`-style Dykstra budgets would cripple a penalty
/// method that needs hundreds of cheap outer steps.
#[derive(Clone, Copy, Debug)]
pub struct ProxTuning {
    /// Initial penalty ρ.
    pub rho_init: f64,
    /// MM: per-outer-iteration geometric anneal factor of ρ.
    pub mm_rho_mult: f64,
    /// MM: outer-iteration budget.
    pub mm_max_outer: usize,
    /// MM: run the exact violation scan every this many outer
    /// iterations (clamped to ≥ 1).
    pub mm_check_every: usize,
    /// MM: CG stop when the residual shrinks by this factor relative to
    /// the warm-start residual.
    pub cg_rtol: f64,
    /// MM: CG iteration cap per outer solve.
    pub cg_max: usize,
    /// SD: per-level geometric anneal factor of ρ.
    pub sd_rho_mult: f64,
    /// SD: number of ρ levels.
    pub sd_levels: usize,
    /// SD: descent-iteration budget per level.
    pub sd_inner: usize,
    /// SD: declare a level stationary when `‖∇h‖ ≤ rtol · max(1, ‖x‖)`.
    pub sd_grad_rtol: f64,
}

impl Default for ProxTuning {
    fn default() -> Self {
        ProxTuning {
            rho_init: 1.0,
            mm_rho_mult: 1.05,
            mm_max_outer: 600,
            mm_check_every: 10,
            cg_rtol: 1e-6,
            cg_max: 100,
            sd_rho_mult: 1.5,
            sd_levels: 80,
            sd_inner: 60,
            sd_grad_rtol: 1e-9,
        }
    }
}

/// Solve metric nearness with the proximal family selected by
/// `opts.algorithm`, untraced. Convenience over
/// [`solve_nearness_traced`].
pub fn solve_nearness(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
) -> Result<NearnessSolution, SolveError> {
    solve_nearness_traced(inst, opts, &NullRecorder)
}

/// The entry the nearness dispatcher calls: build the production
/// [`WaveOperator`] from the shared opts and run the member selected by
/// `opts.algorithm` with default [`ProxTuning`].
pub fn solve_nearness_traced(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    let threads = opts.threads.max(1);
    let op = WaveOperator::new(inst.n, opts.tile, threads);
    solve_nearness_with(
        inst,
        opts.algorithm,
        opts.tol_violation,
        threads,
        &ProxTuning::default(),
        &op,
        rec,
    )
}

/// Full-control entry point with an injectable [`MetricOperator`] —
/// this is how the differential oracle's negative tests drive the
/// solver over [`operator::BrokenOperator`] to prove the tolerance
/// band catches a wrong kernel.
pub fn solve_nearness_with(
    inst: &MetricNearnessInstance,
    algorithm: Algorithm,
    tol_violation: f64,
    threads: usize,
    tuning: &ProxTuning,
    op: &dyn MetricOperator,
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    match algorithm {
        Algorithm::ProxMm => mm::run(inst, op, tol_violation, threads, tuning, rec),
        Algorithm::ProxSd => sd::run(inst, op, tol_violation, threads, tuning, rec),
        Algorithm::Dykstra => Err(SolveError::Other(anyhow::anyhow!(
            "Algorithm::Dykstra is not a proximal member; call the nearness drivers"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::metric_nearness::max_triangle_violation;
    use crate::solver::nearness;

    fn opts(algorithm: Algorithm, threads: usize) -> NearnessOpts {
        NearnessOpts { algorithm, threads, tol_violation: 1e-7, tile: 8, ..Default::default() }
    }

    #[test]
    fn mm_converges_to_dykstra_projection() {
        let inst = MetricNearnessInstance::random(12, 2.0, 41);
        let dyk = nearness::solve(
            &inst,
            &NearnessOpts {
                max_passes: 3000,
                check_every: 10,
                tol_violation: 1e-10,
                threads: 2,
                ..Default::default()
            },
        );
        let mm = solve_nearness(&inst, &opts(Algorithm::ProxMm, 2)).unwrap();
        assert!(mm.max_violation <= 1e-6, "viol {}", mm.max_violation);
        let scale = dyk.objective.max(1.0);
        assert!(
            (mm.objective - dyk.objective).abs() <= 5e-3 * scale,
            "objectives: mm {} vs dykstra {}",
            mm.objective,
            dyk.objective
        );
    }

    #[test]
    fn sd_converges_to_dykstra_projection_loosely() {
        let inst = MetricNearnessInstance::random(10, 2.0, 42);
        let dyk = nearness::solve(
            &inst,
            &NearnessOpts {
                max_passes: 3000,
                check_every: 10,
                tol_violation: 1e-10,
                threads: 1,
                ..Default::default()
            },
        );
        let mut o = opts(Algorithm::ProxSd, 2);
        o.tol_violation = 1e-6;
        let sd = solve_nearness(&inst, &o).unwrap();
        assert!(sd.max_violation <= 1e-5, "viol {}", sd.max_violation);
        let scale = dyk.objective.max(1.0);
        assert!(
            (sd.objective - dyk.objective).abs() <= 2e-2 * scale,
            "objectives: sd {} vs dykstra {}",
            sd.objective,
            dyk.objective
        );
    }

    #[test]
    fn proximal_results_thread_count_independent_bitwise() {
        let inst = MetricNearnessInstance::random(11, 2.0, 43);
        for algorithm in [Algorithm::ProxMm, Algorithm::ProxSd] {
            let a = solve_nearness(&inst, &opts(algorithm, 1)).unwrap();
            let b = solve_nearness(&inst, &opts(algorithm, 4)).unwrap();
            assert_eq!(a.x, b.x, "{algorithm:?} differs across thread counts");
            assert_eq!(a.passes, b.passes);
        }
    }

    #[test]
    fn already_metric_is_near_fixed_point() {
        // d = all-ones is metric: the projection is d itself, and the
        // proximal path must stay within tolerance of it.
        let inst = MetricNearnessInstance::new(crate::matrix::PackedSym::filled(8, 1.0));
        for algorithm in [Algorithm::ProxMm, Algorithm::ProxSd] {
            let sol = solve_nearness(&inst, &opts(algorithm, 1)).unwrap();
            assert!(sol.objective <= 1e-8, "{algorithm:?} objective {}", sol.objective);
            assert!(max_triangle_violation(&sol.x) <= 1e-6);
        }
    }

    #[test]
    fn nearness_dispatch_routes_proximal_and_rejects_disk_and_resume() {
        let inst = MetricNearnessInstance::random(9, 2.0, 44);
        // routed through the standard nearness entry
        let sol = nearness::solve_stored(
            &inst,
            &opts(Algorithm::ProxMm, 1),
            &crate::matrix::store::StoreCfg::mem(),
            None,
            &mut |_| {},
        )
        .unwrap();
        assert!(sol.max_violation <= 1e-6);
        // disk store is a typed refusal
        let dir = std::env::temp_dir().join(format!("mp-prox-reject-{}", std::process::id()));
        let err = nearness::solve_stored(
            &inst,
            &opts(Algorithm::ProxSd, 1),
            &crate::matrix::store::StoreCfg::disk(&dir, 1 << 20),
            None,
            &mut |_| {},
        )
        .unwrap_err();
        assert!(err.to_string().contains("resident-only"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dykstra_is_rejected_by_proximal_entry() {
        let inst = MetricNearnessInstance::random(6, 2.0, 45);
        let op = WaveOperator::new(inst.n, 4, 1);
        let err = solve_nearness_with(
            &inst,
            Algorithm::Dykstra,
            1e-6,
            1,
            &ProxTuning::default(),
            &op,
            &NullRecorder,
        )
        .unwrap_err();
        assert!(err.to_string().contains("not a proximal member"), "{err}");
    }
}
