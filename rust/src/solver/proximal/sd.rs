//! The proximal-distance **steepest-descent** driver: minimize the
//! penalized objective `h(x) = ½ Σ w_e (x_e - d_e)² + ρ/2 · dist²(Dx, ℝ₊)`
//! by exact-line-search gradient descent at a ladder of ρ levels.
//!
//! The gradient collapses to one fused scatter sweep:
//!
//! ```text
//!   ∇h = W∘(x - d) + ρ (T'·min(Tx, 0) + min(x, 0))
//! ```
//!
//! and because `h` restricted to the descent ray is a piecewise
//! quadratic whose curvature is bounded by the *unclamped* quadratic
//! `g'Wg + ρ (‖Tg‖² + ‖g‖²)`, the majorized exact step is
//!
//! ```text
//!   γ = ‖g‖² / (g'Wg + ρ (‖Tg‖² + ‖g‖²))
//! ```
//!
//! (the identity block of `D = [T; I]` contributes the `ρ‖g‖²` term
//! exactly once — folding it into `‖Tg‖²` would double-count it and
//! halve the step for no reason). Each iteration costs two operator
//! sweeps (gradient scatter + `‖Tg‖²`); there is no linear solve, which
//! is what makes this the cheap member of the family — paid for with a
//! looser tolerance band in the oracle ([`crate::eval::cross_check`]).
//!
//! Like the MM driver, stopping is on the true triangle-violation scan,
//! never on operator-derived quantities.

use super::operator::MetricOperator;
use super::ProxTuning;
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::matrix::PackedSym;
use crate::solver::error::SolveError;
use crate::solver::nearness::{self, NearnessSolution};
use crate::telemetry::{Counters, Event, PassKind, PhaseName, PhaseProbe, Recorder};

pub(crate) fn run(
    inst: &MetricNearnessInstance,
    op: &dyn MetricOperator,
    tol_violation: f64,
    threads: usize,
    tuning: &ProxTuning,
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    let n = inst.n;
    let p = threads.max(1);
    let d = inst.d.as_slice();
    let w = inst.w.as_slice();
    let m = d.len();
    let col_starts = inst.d.col_starts().to_vec();
    let tps = op.sweep_triplets();

    let mut x = d.to_vec();
    let mut g = vec![0.0; m];
    let mut tmp = vec![0.0; m];
    let mut rho = tuning.rho_init;

    let mut triplet_visits: u64 = 0;
    let mut levels_done = 0usize;
    let mut max_violation = f64::INFINITY;
    let mut probe = PhaseProbe::new(rec, p);

    'levels: for level in 0..tuning.sd_levels {
        let t_pass = probe.start();
        let pass_no = (level + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });
        let pt = probe.start();
        let mut level_visits = 0u64;
        for _ in 0..tuning.sd_inner {
            tmp.fill(0.0);
            op.scatter_clamped(&x, false, &mut tmp);
            let mut gn2 = 0.0;
            let mut xn2 = 0.0;
            let mut gwg = 0.0;
            for e in 0..m {
                let ge = w[e] * (x[e] - d[e]) + rho * (tmp[e] + x[e].min(0.0));
                g[e] = ge;
                gn2 += ge * ge;
                gwg += w[e] * ge * ge;
                xn2 += x[e] * x[e];
            }
            level_visits += tps;
            if gn2 <= tuning.sd_grad_rtol * tuning.sd_grad_rtol * xn2.max(1.0) {
                break; // stationary at this rho level
            }
            let tg2 = op.t_norm_sq(&g);
            level_visits += tps;
            let denom = gwg + rho * (tg2 + gn2);
            if denom <= 0.0 || !denom.is_finite() {
                triplet_visits += level_visits;
                return Err(SolveError::Other(anyhow::anyhow!(
                    "prox-sd step-size breakdown (denominator {denom:.3e}) at \
                     level {level}, rho = {rho:.3e}"
                )));
            }
            let gamma = gn2 / denom;
            for e in 0..m {
                x[e] -= gamma * g[e];
            }
        }
        triplet_visits += level_visits;
        probe.finish(pass_no, PhaseName::Metric, pt, level_visits, None);
        levels_done = level + 1;

        let pt = probe.start();
        max_violation = nearness::violation(&x, &col_starts, n, p);
        probe.finish(pass_no, PhaseName::ResidualScan, pt, tps, None);
        probe.emit(Event::Residuals {
            pass: pass_no,
            max_violation,
            rel_gap: 0.0,
            lp_objective: 0.0,
            exact: true,
        });
        if !max_violation.is_finite() {
            return Err(SolveError::Other(anyhow::anyhow!(
                "prox-sd diverged (non-finite iterate) at level {levels_done}, rho = {rho:.3e}"
            )));
        }
        if probe.on() {
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t_pass.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0),
                triplet_visits,
                active_triplets: tps,
            });
        }
        if max_violation <= tol_violation {
            break 'levels;
        }
        rho *= tuning.sd_rho_mult;
    }
    let mut xm = PackedSym::zeros(n);
    xm.as_mut_slice().copy_from_slice(&x);
    let sol = NearnessSolution {
        objective: inst.objective(&xm),
        x: xm,
        max_violation,
        passes: levels_done,
        metric_visits: triplet_visits * 3,
        active_triplets: tps as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: None,
    };
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                ..sol.counters()
            },
        });
    }
    Ok(sol)
}
