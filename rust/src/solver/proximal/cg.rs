//! Matrix-free preconditioned conjugate gradients for the MM normal
//! equations `(W + ρ (T'T + I)) x = rhs`.
//!
//! The matvec is one fused [`MetricOperator::normal_matvec`] sweep plus
//! an `O(C(n,2))` diagonal combine; the preconditioner is Jacobi with
//! the exact diagonal `w_e + ρ (3(n-2) + 1)` (each pair sits in `n-2`
//! triplets contributing `3` to its own coefficient, plus the identity
//! block). All vector arithmetic is serial — it is `O(n²)` against the
//! sweep's `O(n³)` — which keeps the whole solve bitwise independent of
//! the thread count (the parallel sweep already is; see
//! [`super::operator`]).

use super::operator::MetricOperator;

/// Outcome of one CG solve.
#[derive(Clone, Copy, Debug)]
pub struct CgOutcome {
    /// Iterations executed (= operator sweeps billed).
    pub iters: usize,
    /// Final residual norm relative to the initial one.
    pub rel_residual: f64,
}

/// Reusable CG work vectors (the MM loop calls [`solve`] hundreds of
/// times; allocating four `C(n,2)` vectors per call would dominate small
/// instances).
#[derive(Default)]
pub struct CgScratch {
    r: Vec<f64>,
    z: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
}

impl CgScratch {
    fn resize(&mut self, m: usize) {
        self.r.resize(m, 0.0);
        self.z.resize(m, 0.0);
        self.p.resize(m, 0.0);
        self.ap.resize(m, 0.0);
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Apply `A v = w∘v + ρ (T'T v + v)` into `out`.
fn apply(op: &dyn MetricOperator, w: &[f64], rho: f64, v: &[f64], out: &mut [f64]) {
    op.normal_matvec(v, out);
    for ((o, &vv), &we) in out.iter_mut().zip(v).zip(w) {
        *o = we * vv + rho * (*o + vv);
    }
}

/// Solve `(W + ρ (T'T + I)) x = rhs` in place from the warm start in
/// `x`, stopping when the residual has shrunk by `rtol` relative to the
/// *initial* residual (an absolute-in-context criterion: the MM loop
/// warm-starts from the previous iterate, so the initial residual is
/// exactly the gap this outer step must close) or after `max_iters`
/// matvecs. Breakdown (non-positive or non-finite curvature) stops
/// early with the best iterate so far.
pub fn solve(
    op: &dyn MetricOperator,
    w: &[f64],
    rho: f64,
    rhs: &[f64],
    x: &mut [f64],
    rtol: f64,
    max_iters: usize,
    scratch: &mut CgScratch,
) -> CgOutcome {
    let m = rhs.len();
    scratch.resize(m);
    let n = op.n() as f64;
    // Exact Jacobi diagonal of A.
    let diag_tail = rho * (3.0 * (n - 2.0).max(0.0) + 1.0);
    apply(op, w, rho, x, &mut scratch.ap);
    for e in 0..m {
        scratch.r[e] = rhs[e] - scratch.ap[e];
        scratch.z[e] = scratch.r[e] / (w[e] + diag_tail);
        scratch.p[e] = scratch.z[e];
    }
    let r0 = dot(&scratch.r, &scratch.r).sqrt();
    if r0 == 0.0 || !r0.is_finite() {
        return CgOutcome { iters: 0, rel_residual: if r0 == 0.0 { 0.0 } else { f64::NAN } };
    }
    let mut rz = dot(&scratch.r, &scratch.z);
    let mut rnorm = r0;
    let mut iters = 0;
    while iters < max_iters && rnorm > rtol * r0 {
        apply(op, w, rho, &scratch.p, &mut scratch.ap);
        let pap = dot(&scratch.p, &scratch.ap);
        if pap <= 0.0 || !pap.is_finite() {
            break; // breakdown: A not SPD along p (broken operator) or overflow
        }
        let alpha = rz / pap;
        for e in 0..m {
            x[e] += alpha * scratch.p[e];
            scratch.r[e] -= alpha * scratch.ap[e];
        }
        for e in 0..m {
            scratch.z[e] = scratch.r[e] / (w[e] + diag_tail);
        }
        let rz_new = dot(&scratch.r, &scratch.z);
        let beta = rz_new / rz;
        if !beta.is_finite() {
            break;
        }
        for e in 0..m {
            scratch.p[e] = scratch.z[e] + beta * scratch.p[e];
        }
        rz = rz_new;
        rnorm = dot(&scratch.r, &scratch.r).sqrt();
        iters += 1;
    }
    CgOutcome { iters, rel_residual: rnorm / r0 }
}

#[cfg(test)]
mod tests {
    use super::super::operator::WaveOperator;
    use super::*;
    use crate::matrix::packed::n_pairs;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn solves_normal_equations_to_tolerance() {
        check("cg_residual", 0xc6, 16, |rng, case| {
            let n = 5 + case % 8;
            let m = n_pairs(n);
            let op = WaveOperator::new(n, 1 + case % 4, 1 + case % 3);
            let w: Vec<f64> = (0..m).map(|_| rng.f64_in(0.5, 3.0)).collect();
            let rhs: Vec<f64> = (0..m).map(|_| rng.f64_in(-1.0, 1.0)).collect();
            let rho = [0.1, 1.0, 50.0][case % 3];
            let mut x = vec![0.0; m];
            let mut scratch = CgScratch::default();
            let out = solve(&op, &w, rho, &rhs, &mut x, 1e-10, 400, &mut scratch);
            // verify against an independent residual computation
            let mut ax = vec![0.0; m];
            apply(&op, &w, rho, &x, &mut ax);
            let res: f64 =
                ax.iter().zip(&rhs).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
            let rhs_norm = dot(&rhs, &rhs).sqrt();
            prop_assert!(
                res <= 1e-8 * rhs_norm.max(1.0),
                "n={n} rho={rho} residual {res} after {} iters (rel {})",
                out.iters,
                out.rel_residual
            );
            Ok(())
        });
    }

    #[test]
    fn warm_start_at_solution_is_free() {
        let n = 8;
        let m = n_pairs(n);
        let op = WaveOperator::new(n, 3, 2);
        let w = vec![1.0; m];
        let rhs: Vec<f64> = (0..m).map(|e| (e as f64 * 0.37).sin()).collect();
        let mut x = vec![0.0; m];
        let mut scratch = CgScratch::default();
        solve(&op, &w, 2.0, &rhs, &mut x, 1e-12, 500, &mut scratch);
        let x_sol = x.clone();
        let out = solve(&op, &w, 2.0, &rhs, &mut x, 1e-6, 500, &mut scratch);
        assert!(out.iters <= 1, "warm start at the solution took {} iters", out.iters);
        for (a, b) in x.iter().zip(&x_sol) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn zero_rhs_zero_start_returns_immediately() {
        let n = 6;
        let m = n_pairs(n);
        let op = WaveOperator::new(n, 2, 1);
        let w = vec![1.0; m];
        let rhs = vec![0.0; m];
        let mut x = vec![0.0; m];
        let out = solve(&op, &w, 1.0, &rhs, &mut x, 1e-10, 100, &mut CgScratch::default());
        assert_eq!(out.iters, 0);
        assert_eq!(out.rel_residual, 0.0);
        assert!(x.iter().all(|&v| v == 0.0));
    }
}
