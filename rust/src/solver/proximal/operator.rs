//! The triangle operator `T` as fused matrix-free sweeps over the wave
//! schedule.
//!
//! `T` has one row triple per ordered triplet `i < j < k`: with
//! `a = v_ij`, `b = v_ik`, `c = v_jk`, the three metric rows are
//! `t1 = -a + b + c`, `t2 = a - b + c`, `t3 = a + b - c` (each `>= 0`
//! at a metric point). The proximal solvers never materialize `T v`
//! (length `3·C(n,3)`); every quantity they need collapses to one
//! closed-form visit per triplet, accumulated straight into
//! pair-indexed vectors:
//!
//! * `T'T v`   — `out_ij += 3a - b - c`, `out_ik += 3b - a - c`,
//!   `out_jk += 3c - a - b`;
//! * `T'·clamp(T v)` — clamp `t1..t3` at zero (above or below), then
//!   scatter `out_ij += -u1 + u2 + u3`, `out_ik += u1 - u2 + u3`,
//!   `out_jk += u1 + u2 - u3`;
//! * `‖T v‖²`  — `t1² + t2² + t3²` summed per tile.
//!
//! All sweeps run over the existing conflict-free wave schedule
//! ([`crate::solver::schedule`]): tiles within a wave touch disjoint
//! pair footprints, so the scatter is lock-free, and waves are separated
//! by barriers, so each entry's accumulation order is the fixed wave
//! order — results are **bitwise independent of the thread count**, the
//! same discipline as the Dykstra drivers. The reduction in
//! [`MetricOperator::t_norm_sq`] keeps that property by summing
//! per-tile partials serially in schedule order.
//!
//! The trait exists (rather than free functions) so the
//! differential-testing oracle can prove its own sensitivity:
//! [`BrokenOperator`] is a deliberately sign-flipped implementation that
//! the cross-family tests inject to confirm a wrong kernel cannot slip
//! through the tolerance band ([`crate::eval::cross_check`]).

use crate::matrix::PackedSym;
use crate::solver::schedule::Schedule;
use crate::solver::tiling;
use crate::util::parallel::scoped_workers;
use crate::util::shared::SharedMut;

/// Matrix-free access to the triangle operator `T`, on packed
/// pair-indexed vectors of length `C(n,2)`.
pub trait MetricOperator: Sync {
    /// Number of points `n`.
    fn n(&self) -> usize;

    /// `out = T'T v` (overwrites `out`).
    fn normal_matvec(&self, v: &[f64], out: &mut [f64]);

    /// `out += T'·max(T v, 0)` when `positive`, else `out += T'·min(T v, 0)`.
    fn scatter_clamped(&self, v: &[f64], positive: bool, out: &mut [f64]);

    /// `‖T v‖²`.
    fn t_norm_sq(&self, v: &[f64]) -> f64;

    /// Triplets visited by one full sweep (telemetry billing: every
    /// method above costs exactly one sweep).
    fn sweep_triplets(&self) -> u64;
}

/// The production implementation: fused sweeps over the wave schedule.
pub struct WaveOperator {
    n: usize,
    threads: usize,
    schedule: Schedule,
    col_starts: Vec<usize>,
    /// Global slot index of each wave's first tile (for the
    /// deterministic per-tile reduction in [`Self::t_norm_sq`]).
    tile_offsets: Vec<usize>,
    total_tiles: usize,
}

impl WaveOperator {
    /// Build the operator for `n` points with the given wave-schedule
    /// tile size and worker count.
    pub fn new(n: usize, tile: usize, threads: usize) -> WaveOperator {
        let schedule = Schedule::new(n, tile.max(1));
        let mut tile_offsets = Vec::with_capacity(schedule.waves().len());
        let mut total = 0usize;
        for wave in schedule.waves() {
            tile_offsets.push(total);
            total += wave.len();
        }
        WaveOperator {
            n,
            threads: threads.max(1),
            schedule,
            col_starts: PackedSym::zeros(n).col_starts().to_vec(),
            tile_offsets,
            total_tiles: total,
        }
    }

    /// Packed pair indices of a triplet `i < j < k`.
    #[inline(always)]
    fn pidx(&self, i: usize, j: usize, k: usize) -> (usize, usize, usize) {
        let ci = self.col_starts[i];
        (ci + (j - i - 1), ci + (k - i - 1), self.col_starts[j] + (k - j - 1))
    }

    /// Run `visit` over every triplet, wave-parallel: tiles of a wave are
    /// dealt round-robin to workers, and a barrier separates waves so the
    /// visits' disjoint-footprint writes stay conflict-free.
    fn sweep<F: Fn(usize, usize, usize) + Sync>(&self, visit: &F) {
        let p = self.threads;
        let b = self.schedule.tile_size();
        scoped_workers(p, |tid, barrier| {
            for wave in self.schedule.waves() {
                let mut r = tid;
                while r < wave.len() {
                    tiling::for_each_triplet(&wave[r], b, |i, j, k| visit(i, j, k));
                    r += p;
                }
                barrier.wait();
            }
        });
    }
}

impl MetricOperator for WaveOperator {
    fn n(&self) -> usize {
        self.n
    }

    fn normal_matvec(&self, v: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        let o = SharedMut::new(out);
        self.sweep(&|i, j, k| {
            let (ij, ik, jk) = self.pidx(i, j, k);
            let (a, b, c) = (v[ij], v[ik], v[jk]);
            // SAFETY: tiles within a wave have disjoint pair footprints
            // (schedule invariant, tested exhaustively) and waves are
            // barrier-separated, so no other thread touches these slots.
            unsafe {
                o.add(ij, 3.0 * a - b - c);
                o.add(ik, 3.0 * b - a - c);
                o.add(jk, 3.0 * c - a - b);
            }
        });
    }

    fn scatter_clamped(&self, v: &[f64], positive: bool, out: &mut [f64]) {
        let o = SharedMut::new(out);
        self.sweep(&|i, j, k| {
            let (ij, ik, jk) = self.pidx(i, j, k);
            let (a, b, c) = (v[ij], v[ik], v[jk]);
            let (t1, t2, t3) = (-a + b + c, a - b + c, a + b - c);
            let (u1, u2, u3) = if positive {
                (t1.max(0.0), t2.max(0.0), t3.max(0.0))
            } else {
                (t1.min(0.0), t2.min(0.0), t3.min(0.0))
            };
            // SAFETY: as in `normal_matvec`.
            unsafe {
                o.add(ij, -u1 + u2 + u3);
                o.add(ik, u1 - u2 + u3);
                o.add(jk, u1 + u2 - u3);
            }
        });
    }

    fn t_norm_sq(&self, v: &[f64]) -> f64 {
        // Per-tile partials, then a serial sum in schedule order: the
        // value is bitwise identical for every thread count.
        let mut slots = vec![0.0f64; self.total_tiles];
        let s = SharedMut::new(&mut slots);
        let p = self.threads;
        let b = self.schedule.tile_size();
        scoped_workers(p, |tid, _| {
            for (w_idx, wave) in self.schedule.waves().iter().enumerate() {
                let mut r = tid;
                while r < wave.len() {
                    let mut acc = 0.0;
                    tiling::for_each_triplet(&wave[r], b, |i, j, k| {
                        let (ij, ik, jk) = self.pidx(i, j, k);
                        let (a, bb, c) = (v[ij], v[ik], v[jk]);
                        let (t1, t2, t3) = (-a + bb + c, a - bb + c, a + bb - c);
                        acc += t1 * t1 + t2 * t2 + t3 * t3;
                    });
                    // SAFETY: slot (wave, r) is owned by this worker.
                    unsafe { s.set(self.tile_offsets[w_idx] + r, acc) };
                    r += p;
                }
            }
        });
        slots.iter().sum()
    }

    fn sweep_triplets(&self) -> u64 {
        self.schedule.total_triplets()
    }
}

/// A deliberately wrong operator for the oracle's negative tests: the
/// `c`-coupling of the `ij` row in `T'T` carries a flipped sign, the
/// kind of one-character kernel bug the cross-family oracle exists to
/// catch. Everything else is forwarded to the wrapped real operator.
/// Exposed (not test-gated) so `tests/cross_family.rs` and the
/// `cross-check --self-test` CLI path can prove oracle sensitivity.
pub struct BrokenOperator(pub WaveOperator);

impl MetricOperator for BrokenOperator {
    fn n(&self) -> usize {
        self.0.n()
    }

    fn normal_matvec(&self, v: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        let o = SharedMut::new(out);
        self.0.sweep(&|i, j, k| {
            let (ij, ik, jk) = self.0.pidx(i, j, k);
            let (a, b, c) = (v[ij], v[ik], v[jk]);
            // The bug: `+ c` where the true operator has `- c`.
            unsafe {
                o.add(ij, 3.0 * a - b + c);
                o.add(ik, 3.0 * b - a - c);
                o.add(jk, 3.0 * c - a - b);
            }
        });
    }

    fn scatter_clamped(&self, v: &[f64], positive: bool, out: &mut [f64]) {
        self.0.scatter_clamped(v, positive, out)
    }

    fn t_norm_sq(&self, v: &[f64]) -> f64 {
        self.0.t_norm_sq(v)
    }

    fn sweep_triplets(&self) -> u64 {
        self.0.sweep_triplets()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::packed::n_pairs;
    use crate::prop_assert;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    /// Reference `T v` via explicit lexicographic row enumeration.
    fn t_apply_ref(n: usize, v: &[f64]) -> Vec<f64> {
        let cs = PackedSym::zeros(n).col_starts().to_vec();
        let mut out = Vec::new();
        tiling::for_each_triplet_lex(n, |i, j, k| {
            let (ij, ik, jk) =
                (cs[i] + (j - i - 1), cs[i] + (k - i - 1), cs[j] + (k - j - 1));
            let (a, b, c) = (v[ij], v[ik], v[jk]);
            out.push(-a + b + c);
            out.push(a - b + c);
            out.push(a + b - c);
        });
        out
    }

    /// Reference `T' u` via the same enumeration.
    fn tt_apply_ref(n: usize, u: &[f64]) -> Vec<f64> {
        let cs = PackedSym::zeros(n).col_starts().to_vec();
        let mut out = vec![0.0; n_pairs(n)];
        let mut row = 0;
        tiling::for_each_triplet_lex(n, |i, j, k| {
            let (ij, ik, jk) =
                (cs[i] + (j - i - 1), cs[i] + (k - i - 1), cs[j] + (k - j - 1));
            let (u1, u2, u3) = (u[row], u[row + 1], u[row + 2]);
            out[ij] += -u1 + u2 + u3;
            out[ik] += u1 - u2 + u3;
            out[jk] += u1 + u2 - u3;
            row += 3;
        });
        out
    }

    fn rand_vec(rng: &mut Rng, m: usize) -> Vec<f64> {
        (0..m).map(|_| rng.f64_in(-2.0, 2.0)).collect()
    }

    #[test]
    fn normal_matvec_matches_explicit_composition() {
        check("ttt_vs_ref", 0x7a11, 24, |rng, case| {
            let n = 4 + case % 9;
            let tile = 1 + case % 5;
            let threads = 1 + case % 3;
            let m = n_pairs(n);
            let v = rand_vec(rng, m);
            let op = WaveOperator::new(n, tile, threads);
            let mut got = vec![f64::NAN; m];
            op.normal_matvec(&v, &mut got);
            let want = tt_apply_ref(n, &t_apply_ref(n, &v));
            for e in 0..m {
                prop_assert!(
                    (got[e] - want[e]).abs() <= 1e-9,
                    "n={n} tile={tile} p={threads} entry {e}: {} vs {}",
                    got[e],
                    want[e]
                );
            }
            Ok(())
        });
    }

    #[test]
    fn scatter_clamped_matches_explicit_composition() {
        check("scatter_vs_ref", 0x7a12, 24, |rng, case| {
            let n = 4 + case % 9;
            let m = n_pairs(n);
            let v = rand_vec(rng, m);
            let op = WaveOperator::new(n, 1 + case % 4, 1 + case % 3);
            for positive in [true, false] {
                let mut got = vec![0.25; m];
                op.scatter_clamped(&v, positive, &mut got);
                let tv = t_apply_ref(n, &v);
                let clamped: Vec<f64> = tv
                    .iter()
                    .map(|&t| if positive { t.max(0.0) } else { t.min(0.0) })
                    .collect();
                let want = tt_apply_ref(n, &clamped);
                for e in 0..m {
                    prop_assert!(
                        (got[e] - (0.25 + want[e])).abs() <= 1e-9,
                        "positive={positive} entry {e}"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn t_norm_sq_matches_explicit_rows() {
        check("tnorm_vs_ref", 0x7a13, 24, |rng, case| {
            let n = 4 + case % 9;
            let v = rand_vec(rng, n_pairs(n));
            let op = WaveOperator::new(n, 1 + case % 4, 1 + case % 3);
            let want: f64 = t_apply_ref(n, &v).iter().map(|t| t * t).sum();
            let got = op.t_norm_sq(&v);
            prop_assert!(
                (got - want).abs() <= 1e-9 * want.max(1.0),
                "{got} vs {want}"
            );
            Ok(())
        });
    }

    #[test]
    fn sweeps_bitwise_thread_count_independent() {
        let n = 13;
        let m = n_pairs(n);
        let mut rng = Rng::new(0x7a14);
        let v = rand_vec(&mut rng, m);
        let op1 = WaveOperator::new(n, 3, 1);
        let (mut a1, mut s1) = (vec![0.0; m], vec![0.0; m]);
        op1.normal_matvec(&v, &mut a1);
        op1.scatter_clamped(&v, true, &mut s1);
        let norm1 = op1.t_norm_sq(&v);
        for p in [2, 4, 7] {
            let op = WaveOperator::new(n, 3, p);
            let (mut a, mut s) = (vec![0.0; m], vec![0.0; m]);
            op.normal_matvec(&v, &mut a);
            op.scatter_clamped(&v, true, &mut s);
            assert_eq!(a, a1, "normal_matvec differs at p={p}");
            assert_eq!(s, s1, "scatter differs at p={p}");
            assert_eq!(op.t_norm_sq(&v), norm1, "t_norm_sq differs at p={p}");
        }
    }

    #[test]
    fn metric_point_is_normal_matvec_consistent() {
        // At the all-ones (metric) point every row is t = 1, so
        // T'T·1 has the closed form (n-2)·1 per entry: 3·1 - 1 - 1 = 1
        // per incident triplet, and each pair sits in n-2 triplets.
        let n = 9;
        let m = n_pairs(n);
        let op = WaveOperator::new(n, 4, 2);
        let v = vec![1.0; m];
        let mut out = vec![0.0; m];
        op.normal_matvec(&v, &mut out);
        for &o in &out {
            assert!((o - (n as f64 - 2.0)).abs() < 1e-12, "{o}");
        }
        assert_eq!(op.sweep_triplets(), crate::solver::schedule::n_triplets(n));
    }

    #[test]
    fn broken_operator_disagrees_with_real_one() {
        let n = 8;
        let m = n_pairs(n);
        let mut rng = Rng::new(0x7a15);
        let v = rand_vec(&mut rng, m);
        let real = WaveOperator::new(n, 3, 1);
        let broken = BrokenOperator(WaveOperator::new(n, 3, 1));
        let (mut a, mut b) = (vec![0.0; m], vec![0.0; m]);
        real.normal_matvec(&v, &mut a);
        broken.normal_matvec(&v, &mut b);
        assert!(a.iter().zip(&b).any(|(x, y)| (x - y).abs() > 1e-6));
    }
}
