//! The versioned, endian-stable binary encoding of [`SolverState`].
//!
//! Layout (all integers little-endian, all floats IEEE-754 bit patterns
//! written little-endian; offsets in bytes):
//!
//! ```text
//! 0   magic     b"MPROJCKP"
//! 8   version   u32   (currently 2; version-1 bytes are still read)
//! 12  problem   u8    (0 = CC-LP, 1 = metric nearness)
//! 13  flags     u8    (bit 0 = skip_initial_sweep; bit 1 = x_external;
//!                      other bits reserved 0)
//! 14  reserved  u16   (0)
//! 16  n         u64   number of objects
//! 24  gamma     f64   CC regularization (0 for nearness)
//! 32  pass      u64   passes completed when saved
//! 40  visits    u64   cumulative metric-triplet visits
//! 48  next_check u64  active-driver convergence cadence state
//! 56  d_hash    u64   FNV-1a over the instance targets' f64 bit patterns
//! 64  x_fnv     u64   tile-store fingerprint (version >= 2; 0
//!                     unless x_external — see below)
//! 72  sections  ...   (see below)
//! end checksum  u64   FNV-1a over every preceding byte
//! ```
//!
//! Sections follow in a fixed order, each a `u64` element count followed
//! by its payload: `x`, `f`, `y_upper`, `y_lower`, `y_box`, `w` (plain
//! `f64` arrays; `f`/`y_*` are empty for nearness states, `y_box` is
//! empty when the solve ran without box constraints), `metric_duals`
//! (`u64` key + `f64` value per entry, key-sorted), `active` (`u64`
//! triplet key + `u32` zero-pass streak per entry, key-sorted), and
//! `history` (`u64` pass + `f64` max violation + `f64` relative gap per
//! record).
//!
//! **External x** (version 2): when flags bit 1 is set the `x` section
//! is empty and the packed distances live in a
//! [`crate::matrix::store::DiskStore`] tile file instead; `x_fnv` holds
//! the store fingerprint stamped by
//! [`crate::matrix::store::DiskStore::flush_and_stamp`] at the moment
//! this state was captured, and the store header carries the matching
//! `pass`. A resume re-derives the fingerprint from the store file and
//! refuses to continue from a store that drifted past (or behind) the
//! checkpoint. Originally defined for nearness states only; PR 5 allows
//! it for CC-LP states too (only `x` goes external — slacks and
//! pair/box duals stay inline, so their length checks are unchanged).
//! Version-1 bytes decode with `x_external = false` and `x_fnv = 0`.
//!
//! [`decode`] validates everything it can: magic, version, checksum,
//! section lengths against the header's `n`, key ordering and range,
//! finiteness and sign of every float, and the external-x coupling
//! rules. Truncated, corrupted, or wrong-version bytes produce a
//! [`CheckpointError`], never a panic.
//!
//! [`SolverState`]: super::SolverState

use super::{ActiveMember, CheckRecord, Problem, SolverState};
use crate::solver::active::set::decode_key;
use std::fmt;

/// File magic: identifies a metric-proj checkpoint.
pub const MAGIC: [u8; 8] = *b"MPROJCKP";

/// Current format version (2 added the `x_fnv` header field and the
/// external-x flag; version-1 bytes are still decoded).
pub const VERSION: u32 = 2;

/// Why a checkpoint could not be written, read, or applied.
#[derive(Debug)]
pub enum CheckpointError {
    /// Underlying I/O failure.
    Io(std::io::Error),
    /// The bytes do not start with the checkpoint magic.
    BadMagic,
    /// The bytes carry a version this build cannot read.
    UnsupportedVersion(u32),
    /// Truncated or internally inconsistent bytes (checksum, lengths,
    /// key order, value ranges).
    Corrupt(String),
    /// The state is well-formed but does not apply to the given
    /// instance/options (wrong problem, size, weights, ...).
    Mismatch(String),
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Io(e) => write!(f, "checkpoint I/O error: {e}"),
            CheckpointError::BadMagic => write!(f, "not a metric-proj checkpoint (bad magic)"),
            CheckpointError::UnsupportedVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (this build reads {VERSION})")
            }
            CheckpointError::Corrupt(msg) => write!(f, "corrupt checkpoint: {msg}"),
            CheckpointError::Mismatch(msg) => write!(f, "checkpoint mismatch: {msg}"),
        }
    }
}

impl std::error::Error for CheckpointError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CheckpointError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CheckpointError {
    fn from(e: std::io::Error) -> CheckpointError {
        CheckpointError::Io(e)
    }
}

/// The hash core behind both the checkpoint checksum and the instance
/// fingerprint ([`super::hash_f64s`]) — shared with the tile-store file
/// format ([`crate::matrix::store`]).
pub(super) use crate::util::hash::Fnv1a;

/// FNV-1a over a byte slice — the checkpoint checksum (not cryptographic;
/// guards against truncation and accidental corruption).
pub use crate::util::hash::fnv1a64;

fn corrupt(msg: impl Into<String>) -> CheckpointError {
    CheckpointError::Corrupt(msg.into())
}

// --- encoding ---------------------------------------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn f64_vec(&mut self, xs: &[f64]) {
        self.u64(xs.len() as u64);
        for &v in xs {
            self.f64(v);
        }
    }
}

/// Serialize a state to its canonical byte representation (checksummed).
pub(super) fn encode(s: &SolverState) -> Vec<u8> {
    let mut e = Enc(Vec::new());
    e.0.extend_from_slice(&MAGIC);
    e.u32(VERSION);
    e.u8(match s.problem {
        Problem::CcLp => 0,
        Problem::Nearness => 1,
    });
    e.u8(u8::from(s.skip_initial_sweep) | (u8::from(s.x_external) << 1));
    e.u16(0);
    e.u64(s.n as u64);
    e.f64(s.gamma);
    e.u64(s.pass);
    e.u64(s.triplet_visits);
    e.u64(s.next_check);
    e.u64(s.d_hash);
    e.u64(s.x_fnv);
    e.f64_vec(&s.x);
    e.f64_vec(&s.f);
    e.f64_vec(&s.y_upper);
    e.f64_vec(&s.y_lower);
    e.f64_vec(&s.y_box);
    e.f64_vec(&s.w);
    e.u64(s.metric_duals.len() as u64);
    for &(key, v) in &s.metric_duals {
        e.u64(key);
        e.f64(v);
    }
    e.u64(s.active.len() as u64);
    for m in &s.active {
        e.u64(m.key);
        e.u32(m.zero_passes);
    }
    e.u64(s.history.len() as u64);
    for r in &s.history {
        e.u64(r.pass);
        e.f64(r.max_violation);
        e.f64(r.rel_gap);
    }
    let sum = fnv1a64(&e.0);
    e.u64(sum);
    e.0
}

// --- decoding ---------------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, len: usize) -> Result<&'a [u8], CheckpointError> {
        if self.buf.len() - self.pos < len {
            return Err(corrupt("truncated"));
        }
        let out = &self.buf[self.pos..self.pos + len];
        self.pos += len;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, CheckpointError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, CheckpointError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, CheckpointError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, CheckpointError> {
        Ok(f64::from_bits(self.u64()?))
    }
    /// Element count for items of `size` bytes, bounded by the remaining
    /// buffer so a corrupted count cannot trigger a huge allocation.
    fn count(&mut self, size: usize) -> Result<usize, CheckpointError> {
        let count = self.u64()?;
        let remaining = (self.buf.len() - self.pos) as u64;
        if count > remaining / size as u64 {
            return Err(corrupt("section count exceeds remaining bytes"));
        }
        Ok(count as usize)
    }
    fn f64_vec(&mut self) -> Result<Vec<f64>, CheckpointError> {
        let count = self.count(8)?;
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            out.push(self.f64()?);
        }
        Ok(out)
    }
}

fn check_finite(name: &str, xs: &[f64]) -> Result<(), CheckpointError> {
    if xs.iter().any(|v| !v.is_finite()) {
        return Err(corrupt(format!("non-finite value in {name}")));
    }
    Ok(())
}

fn check_triplet(key: u64, n: usize) -> Result<(), CheckpointError> {
    let (i, j, k) = decode_key(key);
    if i < j && j < k && k < n {
        Ok(())
    } else {
        Err(corrupt(format!("key {key:#x} is not a valid triplet for n = {n}")))
    }
}

/// Parse and validate a checkpoint byte buffer.
pub(super) fn decode(buf: &[u8]) -> Result<SolverState, CheckpointError> {
    if buf.len() < MAGIC.len() + 4 {
        return Err(corrupt("truncated header"));
    }
    if buf[..MAGIC.len()] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = u32::from_le_bytes(buf[8..12].try_into().unwrap());
    if version != 1 && version != VERSION {
        return Err(CheckpointError::UnsupportedVersion(version));
    }
    if buf.len() < 12 + 8 {
        return Err(corrupt("truncated (no checksum)"));
    }
    let body_end = buf.len() - 8;
    let stored = u64::from_le_bytes(buf[body_end..].try_into().unwrap());
    if fnv1a64(&buf[..body_end]) != stored {
        return Err(corrupt("checksum mismatch"));
    }

    let mut d = Dec { buf: &buf[..body_end], pos: 12 };
    let problem = match d.u8()? {
        0 => Problem::CcLp,
        1 => Problem::Nearness,
        other => return Err(corrupt(format!("unknown problem tag {other}"))),
    };
    let flags = d.u8()?;
    let known_flags: u8 = if version >= 2 { 3 } else { 1 };
    if flags & !known_flags != 0 {
        return Err(corrupt(format!("unknown flags {flags:#x}")));
    }
    let skip_initial_sweep = flags & 1 != 0;
    let x_external = flags & 2 != 0;
    if d.u16()? != 0 {
        return Err(corrupt("nonzero reserved field"));
    }
    let n = d.u64()?;
    if n > 1 << 20 {
        return Err(corrupt(format!("n = {n} exceeds the key encoding limit")));
    }
    let n = n as usize;
    let gamma = d.f64()?;
    let pass = d.u64()?;
    let triplet_visits = d.u64()?;
    let next_check = d.u64()?;
    let d_hash = d.u64()?;
    let x_fnv = if version >= 2 { d.u64()? } else { 0 };
    let x = d.f64_vec()?;
    let f = d.f64_vec()?;
    let y_upper = d.f64_vec()?;
    let y_lower = d.f64_vec()?;
    let y_box = d.f64_vec()?;
    let w = d.f64_vec()?;
    let n_duals = d.count(16)?;
    let mut metric_duals = Vec::with_capacity(n_duals);
    for _ in 0..n_duals {
        let key = d.u64()?;
        let v = d.f64()?;
        metric_duals.push((key, v));
    }
    let n_active = d.count(12)?;
    let mut active = Vec::with_capacity(n_active);
    for _ in 0..n_active {
        let key = d.u64()?;
        let zero_passes = d.u32()?;
        active.push(ActiveMember { key, zero_passes });
    }
    let n_hist = d.count(24)?;
    let mut history = Vec::with_capacity(n_hist);
    for _ in 0..n_hist {
        let pass = d.u64()?;
        let max_violation = d.f64()?;
        let rel_gap = d.f64()?;
        history.push(CheckRecord { pass, max_violation, rel_gap });
    }
    if d.pos != body_end {
        return Err(corrupt("trailing bytes after the last section"));
    }

    // --- semantic validation ------------------------------------------------
    let m = n * n.saturating_sub(1) / 2;
    if x_external {
        if !x.is_empty() {
            return Err(corrupt("external-x state carries an inline x section"));
        }
    } else {
        if x_fnv != 0 {
            return Err(corrupt("x fingerprint set without the external-x flag"));
        }
        if x.len() != m {
            return Err(corrupt(format!("x has {} entries, expected {m}", x.len())));
        }
    }
    if w.len() != m {
        return Err(corrupt(format!("w has {} entries, expected {m}", w.len())));
    }
    match problem {
        Problem::CcLp => {
            if f.len() != m || y_upper.len() != m || y_lower.len() != m {
                return Err(corrupt("CC-LP state is missing slack/pair-dual sections"));
            }
            if !(y_box.is_empty() || y_box.len() == m) {
                return Err(corrupt("y_box has a bad length"));
            }
            if !gamma.is_finite() || gamma <= 0.0 {
                return Err(corrupt(format!("bad gamma {gamma}")));
            }
        }
        Problem::Nearness => {
            if !(f.is_empty() && y_upper.is_empty() && y_lower.is_empty() && y_box.is_empty()) {
                return Err(corrupt("nearness state carries CC-only sections"));
            }
            if gamma != 0.0 {
                return Err(corrupt("nearness state carries a nonzero gamma"));
            }
        }
    }
    check_finite("x", &x)?;
    check_finite("f", &f)?;
    if history.iter().any(|r| !r.max_violation.is_finite() || !r.rel_gap.is_finite()) {
        return Err(corrupt("non-finite value in history"));
    }
    for (name, ys) in [("y_upper", &y_upper), ("y_lower", &y_lower), ("y_box", &y_box)] {
        if ys.iter().any(|v| !v.is_finite() || *v < 0.0) {
            return Err(corrupt(format!("negative or non-finite dual in {name}")));
        }
    }
    if w.iter().any(|v| !v.is_finite() || *v <= 0.0) {
        return Err(corrupt("non-positive weight in w"));
    }
    let mut prev_key = None;
    for &(key, v) in &metric_duals {
        if prev_key.is_some_and(|p| p >= key) {
            return Err(corrupt("metric duals are not strictly key-sorted"));
        }
        prev_key = Some(key);
        if key & 3 == 3 {
            return Err(corrupt(format!("key {key:#x} has constraint type 3")));
        }
        check_triplet(key, n)?;
        if !v.is_finite() || v <= 0.0 {
            return Err(corrupt(format!("metric dual {v} at key {key:#x} is not positive")));
        }
    }
    let mut prev_key = None;
    for a in &active {
        if prev_key.is_some_and(|p| p >= a.key) {
            return Err(corrupt("active members are not strictly key-sorted"));
        }
        prev_key = Some(a.key);
        if a.key & 3 != 0 {
            return Err(corrupt(format!("active key {:#x} has type bits set", a.key)));
        }
        check_triplet(a.key, n)?;
    }

    Ok(SolverState {
        problem,
        n,
        gamma,
        pass,
        triplet_visits,
        next_check,
        skip_initial_sweep,
        x_external,
        x_fnv,
        x,
        f,
        y_upper,
        y_lower,
        y_box,
        w,
        d_hash,
        metric_duals,
        active,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_state() -> SolverState {
        SolverState {
            problem: Problem::Nearness,
            n: 4,
            gamma: 0.0,
            pass: 3,
            triplet_visits: 12,
            next_check: 5,
            skip_initial_sweep: true,
            x_external: false,
            x_fnv: 0,
            x: vec![0.5; 6],
            f: vec![],
            y_upper: vec![],
            y_lower: vec![],
            y_box: vec![],
            w: vec![1.0; 6],
            d_hash: 0xDEAD,
            metric_duals: vec![(crate::solver::duals::metric_key(0, 1, 2, 1), 0.25)],
            active: vec![ActiveMember {
                key: crate::solver::active::set::triplet_key(0, 1, 2),
                zero_passes: 2,
            }],
            history: vec![CheckRecord { pass: 2, max_violation: 0.1, rel_gap: 0.0 }],
        }
    }

    #[test]
    fn roundtrip_is_identity() {
        let s = tiny_state();
        let bytes = encode(&s);
        let back = decode(&bytes).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn truncation_rejected_at_every_length() {
        let bytes = encode(&tiny_state());
        for len in 0..bytes.len() {
            assert!(decode(&bytes[..len]).is_err(), "accepted a {len}-byte prefix");
        }
    }

    #[test]
    fn bitflip_rejected_everywhere() {
        let bytes = encode(&tiny_state());
        for pos in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            assert!(decode(&bad).is_err(), "accepted a flip at byte {pos}");
        }
    }

    #[test]
    fn external_x_state_roundtrips() {
        let mut s = tiny_state();
        s.x_external = true;
        s.x_fnv = 0x1234_5678_9ABC_DEF0;
        s.x = Vec::new();
        let back = decode(&encode(&s)).unwrap();
        assert_eq!(s, back);
        assert!(back.x_external);
        assert_eq!(back.x_fnv, 0x1234_5678_9ABC_DEF0);
    }

    #[test]
    fn cc_external_x_state_roundtrips() {
        // Since PR 5, CC-LP states may also reference an external store:
        // x empty, slacks and pair/box duals still inline.
        let m = 6; // n = 4
        let s = SolverState {
            problem: Problem::CcLp,
            n: 4,
            gamma: 5.0,
            pass: 3,
            triplet_visits: 12,
            next_check: 5,
            skip_initial_sweep: false,
            x_external: true,
            x_fnv: 0xFEED,
            x: vec![],
            f: vec![-5.0; m],
            y_upper: vec![0.0; m],
            y_lower: vec![0.0; m],
            y_box: vec![0.0; m],
            w: vec![1.0; m],
            d_hash: 0xBEEF,
            metric_duals: vec![],
            active: vec![],
            history: vec![],
        };
        let back = decode(&encode(&s)).unwrap();
        assert_eq!(s, back);
        assert!(back.x_external);
        // An inline x alongside the flag is still rejected for CC.
        let mut bad = s;
        bad.x = vec![0.0; m];
        assert!(decode(&encode(&bad)).is_err());
    }

    #[test]
    fn external_x_coupling_rules_enforced() {
        // Inline x together with the external flag must be rejected.
        let mut s = tiny_state();
        s.x_external = true;
        s.x_fnv = 1;
        assert!(decode(&encode(&s)).is_err(), "external flag with inline x accepted");
        // A fingerprint without the flag must be rejected.
        let mut s = tiny_state();
        s.x_fnv = 1;
        assert!(decode(&encode(&s)).is_err(), "fingerprint without external flag accepted");
    }

    #[test]
    fn version1_bytes_still_decode() {
        // Synthesize version-1 bytes from the v2 encoder: drop the x_fnv
        // header field, rewrite the version, restamp the checksum.
        let s = tiny_state();
        let v2 = encode(&s);
        let mut v1 = Vec::with_capacity(v2.len() - 8);
        v1.extend_from_slice(&v2[..64]);
        v1.extend_from_slice(&v2[72..v2.len() - 8]);
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let sum = fnv1a64(&v1);
        v1.extend_from_slice(&sum.to_le_bytes());
        let back = decode(&v1).unwrap();
        assert_eq!(back, s, "a version-1 checkpoint must restore identically");
        assert!(!back.x_external);
        assert_eq!(back.x_fnv, 0);
    }

    #[test]
    fn wrong_version_rejected_specifically() {
        let mut bytes = encode(&tiny_state());
        bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
        // Re-stamp the checksum so the version check (not the checksum)
        // is what rejects the bytes.
        let end = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..end]);
        bytes[end..].copy_from_slice(&sum.to_le_bytes());
        match decode(&bytes) {
            Err(CheckpointError::UnsupportedVersion(99)) => {}
            other => panic!("expected UnsupportedVersion, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = encode(&tiny_state());
        bytes[0] = b'X';
        assert!(matches!(decode(&bytes), Err(CheckpointError::BadMagic)));
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }
}
