//! Warm-start checkpoints: serializable solver state for interrupted and
//! perturbed re-solves.
//!
//! The early passes of Dykstra's method are where the work is: duals are
//! dense, the active set is still being discovered, and every pass
//! touches all `3·C(n,3)` metric rows. Project-and-forget shows the
//! *final* active set is tiny and stable, and the metric-nearness line of
//! work motivates re-solving the same graph under perturbed weights. This
//! module is the state layer that exploits both: a [`SolverState`]
//! snapshot of everything a solve needs to continue — packed `x` (plus
//! slacks and pair/box duals for CC-LP), the nonzero metric duals as
//! key-sorted `(u64, f64)` pairs, active-set membership with forget
//! streaks, pass/sweep counters, and the termination history — behind a
//! versioned, endian-stable binary format ([`format`], no external
//! dependencies) with `save`/`load` over [`std::io::Write`] /
//! [`std::io::Read`].
//!
//! Three ways to use a state:
//!
//! * **Periodic checkpointing** — set [`SolveOpts::checkpoint_every`]
//!   (or [`NearnessOpts::checkpoint_every`]) and call the drivers'
//!   `solve_checkpointed` entry points with a sink closure; the CLI's
//!   `--checkpoint <path>` does exactly this with an atomic
//!   write-then-rename per snapshot.
//! * **Exact resume** — `resume` entry points on the serial
//!   ([`dykstra_serial::resume`]), parallel
//!   ([`dykstra_parallel::resume`]), and active-set
//!   ([`active::resume_cc`] / [`active::resume_nearness`]) drivers
//!   continue a saved solve. Resuming with unchanged options reproduces
//!   the uninterrupted run **bitwise** (tested): duals are redistributed
//!   into each worker's deterministic visit order
//!   ([`SolverState::worker_duals`]), so even the thread count may change
//!   without changing the iterates.
//! * **Warm start** — [`warm_start_cc`] / [`warm_start_nearness`] take a
//!   state from instance `A` and a perturbed instance `A'` (same `n`,
//!   updated weights), rescale the carried duals by the per-constraint
//!   curvature ratio, drop the ones below a threshold, rebuild the primal
//!   from the Dykstra invariant `x = x0' − W'⁻¹Aᵀy'`, and seed the active
//!   set so the first discovery sweep is deferred
//!   ([`SolverState::skip_initial_sweep`]). Because Dykstra is dual
//!   block-coordinate ascent for these projection QPs, restarting from
//!   any nonnegative duals with a consistent primal converges to the same
//!   unique optimum — warm starting changes the path length, not the
//!   destination. [`crate::eval::warm_start_ablation`] measures the
//!   passes-to-tolerance saving.
//!
//! [`SolveOpts::checkpoint_every`]: crate::solver::SolveOpts::checkpoint_every
//! [`NearnessOpts::checkpoint_every`]: crate::solver::nearness::NearnessOpts::checkpoint_every
//! [`dykstra_serial::resume`]: crate::solver::dykstra_serial::resume
//! [`dykstra_parallel::resume`]: crate::solver::dykstra_parallel::resume
//! [`active::resume_cc`]: crate::solver::active::resume_cc
//! [`active::resume_nearness`]: crate::solver::active::resume_nearness

pub mod format;
pub mod warm;

pub use format::{CheckpointError, MAGIC, VERSION};
pub use warm::{warm_start_cc, warm_start_nearness, WarmStartOpts};

use super::active::set::{decode_key, ActiveSet, ActiveTriplet};
use super::duals::DualStore;
use super::schedule::{Assignment, Schedule, TileRouter};
use super::{CcState, SolveOpts};
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::instance::CcLpInstance;
use crate::util::shared::PerWorker;
use std::io::{Read, Write};
use std::path::Path;

/// Which optimization problem a state belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Problem {
    /// The CC-LP relaxation (distances + slacks + pair/box constraints).
    CcLp,
    /// Metric nearness (distances only).
    Nearness,
}

/// Active-set membership of one triplet: its key and how many
/// consecutive zero-dual active passes it has survived (the forget
/// streak of [`crate::solver::active::forget`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ActiveMember {
    pub key: u64,
    pub zero_passes: u32,
}

/// One convergence-check measurement, kept as the termination history.
/// For the active strategy the recorded value is the *exact* scan's when
/// one ran (the trusted-sweep screen is overwritten by its confirming
/// scan), so the history never reports a stale sweep violation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CheckRecord {
    /// Passes completed when the check ran.
    pub pass: u64,
    /// Max constraint violation measured at the check.
    pub max_violation: f64,
    /// Relative duality gap (0 for nearness, which has no dual gap).
    pub rel_gap: f64,
}

/// A complete, serializable snapshot of a solve.
///
/// Everything here is strategy-portable: a state saved by the full
/// solver can seed the active driver (membership is derived from the
/// nonzero duals) and vice versa (active entries flatten to key-sorted
/// dual pairs). See the [module docs](self) for the three use cases and
/// [`format`] for the byte layout.
#[derive(Clone, Debug, PartialEq)]
pub struct SolverState {
    pub problem: Problem,
    /// Number of objects (the packed arrays hold `n(n-1)/2` entries).
    pub n: usize,
    /// CC regularization gamma at save time (0 for nearness).
    pub gamma: f64,
    /// Passes completed.
    pub pass: u64,
    /// Cumulative metric-triplet visits (work counter).
    pub triplet_visits: u64,
    /// Active-driver convergence cadence state (0 = start from
    /// `check_every`).
    pub next_check: u64,
    /// Warm-start flag: the active set is already seeded, so the active
    /// driver treats its first pass as a cheap pass instead of a
    /// discovery sweep. Ignored by the full-strategy drivers.
    pub skip_initial_sweep: bool,
    /// True when the packed distances live in an external
    /// [`crate::matrix::store::DiskStore`] tile file instead of the
    /// inline `x` section (nearness and, since format revision 2 of
    /// PR 5, CC-LP states — CC slacks and pair/box duals stay inline).
    /// The store's header carries the matching `pass` and `x_fnv` stamp.
    pub x_external: bool,
    /// Tile-store fingerprint at capture time (0 unless
    /// `x_external`); a resume recomputes the store's fingerprint and
    /// refuses a store that no longer matches.
    pub x_fnv: u64,
    /// Packed distance variables (empty when `x_external`).
    pub x: Vec<f64>,
    /// Packed slacks (CC-LP only; empty for nearness).
    pub f: Vec<f64>,
    /// Scaled pair-upper duals (CC-LP only).
    pub y_upper: Vec<f64>,
    /// Scaled pair-lower duals (CC-LP only).
    pub y_lower: Vec<f64>,
    /// Scaled box duals (empty when the solve ran without box rows).
    pub y_box: Vec<f64>,
    /// Packed instance weights at save time — what warm starts rescale
    /// against, and what resume validates against.
    pub w: Vec<f64>,
    /// FNV-1a hash of the instance targets' bit patterns (resume guard).
    pub d_hash: u64,
    /// Nonzero scaled metric duals, strictly key-sorted
    /// (key = [`crate::solver::duals::metric_key`]).
    pub metric_duals: Vec<(u64, f64)>,
    /// Active-set membership, strictly key-sorted. Empty for states
    /// saved by a full-strategy driver.
    pub active: Vec<ActiveMember>,
    /// Convergence checks observed so far.
    pub history: Vec<CheckRecord>,
}

impl SolverState {
    /// Serialize to a writer (see [`format`] for the layout).
    pub fn save<W: Write>(&self, w: &mut W) -> Result<(), CheckpointError> {
        w.write_all(&format::encode(self))?;
        Ok(())
    }

    /// Deserialize from a reader, validating magic, version, checksum,
    /// and every invariant the format promises. Never panics on bad
    /// bytes.
    pub fn load<R: Read>(r: &mut R) -> Result<SolverState, CheckpointError> {
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        format::decode(&buf)
    }

    /// Save to a file, atomically: write a sibling temp file then
    /// rename. The temp name is the full file name plus `.tmp` (not a
    /// replaced extension), so checkpoints sharing a stem in one
    /// directory never collide on the same temp file.
    pub fn save_path(&self, path: &Path) -> Result<(), CheckpointError> {
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_name);
        {
            let mut fh = std::fs::File::create(&tmp)?;
            self.save(&mut fh)?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load from a file.
    pub fn load_path(path: &Path) -> Result<SolverState, CheckpointError> {
        let mut fh = std::fs::File::open(path)?;
        SolverState::load(&mut fh)
    }

    /// Number of nonzero metric duals carried.
    pub fn nnz_duals(&self) -> usize {
        self.metric_duals.len()
    }

    // --- captures (called by the drivers at checkpoint boundaries) ----------

    /// Snapshot a full-strategy CC-LP solve. `metric_duals` must be the
    /// key-sorted nonzero duals written by the pass just completed; `x`
    /// is the packed iterate (held by the driver's `XBacking`, no longer
    /// by `CcState` itself).
    pub(crate) fn capture_cc_full(
        state: &CcState,
        x: &[f64],
        metric_duals: Vec<(u64, f64)>,
        pass: usize,
        triplet_visits: u64,
        history: &[CheckRecord],
    ) -> SolverState {
        debug_assert!(metric_duals.windows(2).all(|p| p[0].0 < p[1].0));
        SolverState {
            problem: Problem::CcLp,
            n: state.n,
            gamma: state.gamma,
            pass: pass as u64,
            triplet_visits,
            next_check: 0,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: x.to_vec(),
            f: state.f.clone(),
            y_upper: state.y_upper.clone(),
            y_lower: state.y_lower.clone(),
            y_box: if state.include_box { state.y_box.clone() } else { Vec::new() },
            w: state.w.clone(),
            d_hash: hash_f64s(&state.d),
            metric_duals,
            active: Vec::new(),
            history: history.to_vec(),
        }
    }

    /// Snapshot an active-strategy CC-LP solve (`x` supplied by the
    /// driver's backing, as in [`SolverState::capture_cc_full`]).
    pub(crate) fn capture_cc_active(
        state: &CcState,
        x: &[f64],
        active: &mut ActiveSet,
        pass: usize,
        triplet_visits: u64,
        next_check: usize,
        history: &[CheckRecord],
    ) -> SolverState {
        let (metric_duals, members) = flatten_active(active);
        SolverState {
            problem: Problem::CcLp,
            n: state.n,
            gamma: state.gamma,
            pass: pass as u64,
            triplet_visits,
            next_check: next_check as u64,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: x.to_vec(),
            f: state.f.clone(),
            y_upper: state.y_upper.clone(),
            y_lower: state.y_lower.clone(),
            y_box: if state.include_box { state.y_box.clone() } else { Vec::new() },
            w: state.w.clone(),
            d_hash: hash_f64s(&state.d),
            metric_duals,
            active: members,
            history: history.to_vec(),
        }
    }

    /// Snapshot a full-strategy CC-LP solve whose `x` lives in an
    /// external tile store. `x_fnv` must be the fingerprint returned by
    /// [`crate::matrix::store::DiskStore::flush_and_stamp`] for this
    /// exact pass, so the checkpoint and the store file form a
    /// consistent pair. Slacks and pair/box duals stay inline.
    pub(crate) fn capture_cc_full_external(
        state: &CcState,
        x_fnv: u64,
        metric_duals: Vec<(u64, f64)>,
        pass: usize,
        triplet_visits: u64,
        history: &[CheckRecord],
    ) -> SolverState {
        let mut st = SolverState::capture_cc_full(
            state,
            &[],
            metric_duals,
            pass,
            triplet_visits,
            history,
        );
        st.x_external = true;
        st.x_fnv = x_fnv;
        st
    }

    /// Snapshot an active-strategy CC-LP solve whose `x` lives in an
    /// external tile store (see [`SolverState::capture_cc_full_external`]).
    pub(crate) fn capture_cc_active_external(
        state: &CcState,
        x_fnv: u64,
        active: &mut ActiveSet,
        pass: usize,
        triplet_visits: u64,
        next_check: usize,
        history: &[CheckRecord],
    ) -> SolverState {
        let mut st = SolverState::capture_cc_active(
            state,
            &[],
            active,
            pass,
            triplet_visits,
            next_check,
            history,
        );
        st.x_external = true;
        st.x_fnv = x_fnv;
        st
    }

    /// Snapshot a full-strategy nearness solve.
    pub(crate) fn capture_nearness_full(
        inst: &MetricNearnessInstance,
        x: &[f64],
        metric_duals: Vec<(u64, f64)>,
        pass: usize,
        triplet_visits: u64,
        history: &[CheckRecord],
    ) -> SolverState {
        SolverState {
            problem: Problem::Nearness,
            n: inst.n,
            gamma: 0.0,
            pass: pass as u64,
            triplet_visits,
            next_check: 0,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: x.to_vec(),
            f: Vec::new(),
            y_upper: Vec::new(),
            y_lower: Vec::new(),
            y_box: Vec::new(),
            w: inst.w.as_slice().to_vec(),
            d_hash: hash_f64s(inst.d.as_slice()),
            metric_duals,
            active: Vec::new(),
            history: history.to_vec(),
        }
    }

    /// Snapshot an active-strategy nearness solve.
    pub(crate) fn capture_nearness_active(
        inst: &MetricNearnessInstance,
        x: &[f64],
        active: &mut ActiveSet,
        pass: usize,
        triplet_visits: u64,
        next_check: usize,
        history: &[CheckRecord],
    ) -> SolverState {
        let (metric_duals, members) = flatten_active(active);
        SolverState {
            problem: Problem::Nearness,
            n: inst.n,
            gamma: 0.0,
            pass: pass as u64,
            triplet_visits,
            next_check: next_check as u64,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: x.to_vec(),
            f: Vec::new(),
            y_upper: Vec::new(),
            y_lower: Vec::new(),
            y_box: Vec::new(),
            w: inst.w.as_slice().to_vec(),
            d_hash: hash_f64s(inst.d.as_slice()),
            metric_duals,
            active: members,
            history: history.to_vec(),
        }
    }

    /// Snapshot a full-strategy nearness solve whose `x` lives in an
    /// external tile store. `x_fnv` must be the fingerprint returned by
    /// [`crate::matrix::store::DiskStore::flush_and_stamp`] for this
    /// exact pass, so the checkpoint and the store file form a
    /// consistent pair.
    pub(crate) fn capture_nearness_full_external(
        inst: &MetricNearnessInstance,
        x_fnv: u64,
        metric_duals: Vec<(u64, f64)>,
        pass: usize,
        triplet_visits: u64,
        history: &[CheckRecord],
    ) -> SolverState {
        let mut st = SolverState::capture_nearness_full(
            inst,
            &[],
            metric_duals,
            pass,
            triplet_visits,
            history,
        );
        st.x_external = true;
        st.x_fnv = x_fnv;
        st
    }

    /// Snapshot an active-strategy nearness solve whose `x` lives in an
    /// external tile store (see
    /// [`SolverState::capture_nearness_full_external`]).
    pub(crate) fn capture_nearness_active_external(
        inst: &MetricNearnessInstance,
        x_fnv: u64,
        active: &mut ActiveSet,
        pass: usize,
        triplet_visits: u64,
        next_check: usize,
        history: &[CheckRecord],
    ) -> SolverState {
        let mut st = SolverState::capture_nearness_active(
            inst,
            &[],
            active,
            pass,
            triplet_visits,
            next_check,
            history,
        );
        st.x_external = true;
        st.x_fnv = x_fnv;
        st
    }

    // --- resume validation and restoration ----------------------------------

    /// Check that this state can resume a CC-LP solve of `inst` under
    /// `opts`: same problem, size, targets, weights (bitwise — for a
    /// *changed* instance use [`warm_start_cc`]), gamma, and box setting.
    pub fn validate_cc(
        &self,
        inst: &CcLpInstance,
        opts: &SolveOpts,
    ) -> Result<(), CheckpointError> {
        let mismatch = |msg: String| Err(CheckpointError::Mismatch(msg));
        if self.problem != Problem::CcLp {
            return mismatch("state is not a CC-LP checkpoint".into());
        }
        if self.n != inst.n {
            return mismatch(format!("state has n = {}, instance has n = {}", self.n, inst.n));
        }
        if self.gamma != opts.gamma {
            return mismatch(format!(
                "state was saved with gamma = {}, opts use {}",
                self.gamma, opts.gamma
            ));
        }
        if opts.include_box != !self.y_box.is_empty() {
            return mismatch("box-constraint setting differs from the saved state".into());
        }
        if self.w != inst.w.as_slice() {
            return mismatch(
                "instance weights differ from the saved state (use warm_start_cc)".into(),
            );
        }
        if self.d_hash != hash_f64s(inst.d.as_slice()) {
            return mismatch("instance targets differ from the saved state".into());
        }
        self.check_keys_in_range()
    }

    /// Check that this state can resume a nearness solve of `inst`.
    pub fn validate_nearness(
        &self,
        inst: &MetricNearnessInstance,
    ) -> Result<(), CheckpointError> {
        let mismatch = |msg: String| Err(CheckpointError::Mismatch(msg));
        if self.problem != Problem::Nearness {
            return mismatch("state is not a metric-nearness checkpoint".into());
        }
        if self.n != inst.n {
            return mismatch(format!("state has n = {}, instance has n = {}", self.n, inst.n));
        }
        if self.w != inst.w.as_slice() {
            return mismatch(
                "instance weights differ from the saved state (use warm_start_nearness)".into(),
            );
        }
        if self.d_hash != hash_f64s(inst.d.as_slice()) {
            return mismatch("instance dissimilarities differ from the saved state".into());
        }
        self.check_keys_in_range()
    }

    /// Guard hand-built states: every carried key must decode to a valid
    /// triplet below `n` (states from `load` are already validated).
    fn check_keys_in_range(&self) -> Result<(), CheckpointError> {
        let valid = |key: u64| {
            let (i, j, k) = decode_key(key);
            i < j && j < k && k < self.n
        };
        if self.metric_duals.iter().any(|&(key, _)| !valid(key))
            || self.active.iter().any(|m| !valid(m.key))
        {
            return Err(CheckpointError::Corrupt(
                "state carries a key outside the instance's triplet range".into(),
            ));
        }
        Ok(())
    }

    /// Rebuild the mutable CC solve state this snapshot describes. For
    /// external-x states the packed distances live in the tile store the
    /// driver's backing opens, so the state's `x` is left at its
    /// placeholder (the backing takes it over either way).
    pub(crate) fn restore_cc_state(&self, inst: &CcLpInstance, opts: &SolveOpts) -> CcState {
        let mut st = CcState::new(inst, opts.gamma, opts.include_box);
        if !self.x_external {
            st.x.copy_from_slice(&self.x);
        }
        st.f.copy_from_slice(&self.f);
        st.y_upper.copy_from_slice(&self.y_upper);
        st.y_lower.copy_from_slice(&self.y_lower);
        if !self.y_box.is_empty() {
            st.y_box.copy_from_slice(&self.y_box);
        }
        st
    }

    /// The carried constraints as active-set entries: membership drives
    /// (preserving forget streaks), duals attach to their triplets, and
    /// for full-strategy states (no membership) every nonzero-dual
    /// triplet becomes a fresh member.
    pub(crate) fn active_entries(&self) -> Vec<ActiveTriplet> {
        // Group key-sorted dual lanes into per-triplet [f64; 3]s.
        let mut triplets: Vec<(u64, [f64; 3])> = Vec::new();
        for &(key, v) in &self.metric_duals {
            let base = key & !3;
            let t = (key & 3) as usize;
            match triplets.last_mut() {
                Some((b, y)) if *b == base => y[t] = v,
                _ => {
                    let mut y = [0.0; 3];
                    y[t] = v;
                    triplets.push((base, y));
                }
            }
        }
        if self.active.is_empty() {
            return triplets
                .into_iter()
                .map(|(key, y)| ActiveTriplet { key, y, zero_passes: 0 })
                .collect();
        }
        // Merge two key-sorted lists; stray dual triplets outside the
        // membership (possible only for hand-built states) join fresh.
        let mut out = Vec::with_capacity(self.active.len());
        let mut di = 0;
        for m in &self.active {
            while di < triplets.len() && triplets[di].0 < m.key {
                let (key, y) = triplets[di];
                out.push(ActiveTriplet { key, y, zero_passes: 0 });
                di += 1;
            }
            let mut y = [0.0; 3];
            if di < triplets.len() && triplets[di].0 == m.key {
                y = triplets[di].1;
                di += 1;
            }
            out.push(ActiveTriplet { key: m.key, y, zero_passes: m.zero_passes });
        }
        while di < triplets.len() {
            let (key, y) = triplets[di];
            out.push(ActiveTriplet { key, y, zero_passes: 0 });
            di += 1;
        }
        out
    }

    /// Distribute the carried metric duals into per-worker lists, each in
    /// that worker's deterministic visit order under `schedule` and
    /// `assignment` — exactly what each worker's [`DualStore`] would hold
    /// at this point of an uninterrupted run, for any worker count.
    pub(crate) fn worker_duals(
        &self,
        schedule: &Schedule,
        assignment: Assignment,
        p: usize,
    ) -> Vec<Vec<(u64, f64)>> {
        split_duals(schedule, assignment, p, &self.metric_duals)
    }
}

/// Flatten an active set into (key-sorted nonzero duals, key-sorted
/// membership).
fn flatten_active(active: &mut ActiveSet) -> (Vec<(u64, f64)>, Vec<ActiveMember>) {
    let mut duals = Vec::new();
    let mut members = Vec::new();
    for e in active.iter() {
        members.push(ActiveMember { key: e.key, zero_passes: e.zero_passes });
        for (t, &v) in e.y.iter().enumerate() {
            if v != 0.0 {
                duals.push((e.key | t as u64, v));
            }
        }
    }
    duals.sort_unstable_by_key(|&(k, _)| k);
    members.sort_unstable_by_key(|m| m.key);
    (duals, members)
}

/// Split a key-sorted dual list by owning worker, ordering each worker's
/// share by its visit order: waves in execution order, owned tiles by
/// ascending in-wave index, cube order (j-chunks, then `(i, j, k)`)
/// inside a tile, constraint type ascending — the order
/// [`crate::solver::hot_loop`] fetches duals in.
pub(crate) fn split_duals(
    schedule: &Schedule,
    assignment: Assignment,
    p: usize,
    duals: &[(u64, f64)],
) -> Vec<Vec<(u64, f64)>> {
    let router = TileRouter::new(schedule);
    let mut tagged: Vec<Vec<((usize, usize, usize, u64), (u64, f64))>> =
        (0..p).map(|_| Vec::new()).collect();
    for &(key, y) in duals {
        let (i, j, k) = decode_key(key);
        let (wi, r, chunk) = router.locate(i, j, k);
        let tid = assignment.worker_of(r, wi, p);
        // Within a chunk the cube order is (i, j, k, t) — the key's
        // numeric order.
        tagged[tid].push(((wi, r, chunk, key), (key, y)));
    }
    tagged
        .into_iter()
        .map(|mut v| {
            v.sort_unstable_by_key(|&(k, _)| k);
            v.into_iter().map(|(_, e)| e).collect()
        })
        .collect()
}

/// Merge every worker's just-written duals into one key-sorted list —
/// the canonical checkpoint form.
pub(crate) fn collect_duals(stores: &mut PerWorker<DualStore>) -> Vec<(u64, f64)> {
    let mut all = Vec::new();
    for s in stores.iter_mut() {
        all.extend(s.iter_next());
    }
    all.sort_unstable_by_key(|&(k, _)| k);
    all
}

/// FNV-1a over the bit patterns of a float slice (instance
/// fingerprint). Shares the hash core with the format's checksum.
pub fn hash_f64s(xs: &[f64]) -> u64 {
    let mut h = format::Fnv1a::new();
    for &v in xs {
        h.update(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::active::set::triplet_key;
    use crate::solver::duals::metric_key;
    use crate::solver::dykstra_serial;
    use crate::solver::hot_loop;
    use crate::util::shared::SharedMut;

    #[test]
    fn hash_distinguishes_and_is_stable() {
        let a = vec![1.0, 2.0, 3.0];
        let mut b = a.clone();
        assert_eq!(hash_f64s(&a), hash_f64s(&b));
        b[1] = 2.0 + 1e-15;
        assert_ne!(hash_f64s(&a), hash_f64s(&b));
        // -0.0 and 0.0 differ bitwise, so the fingerprint sees them.
        assert_ne!(hash_f64s(&[0.0]), hash_f64s(&[-0.0]));
    }

    #[test]
    fn active_entries_derived_from_full_state_duals() {
        let base = triplet_key(1, 2, 5);
        let st = SolverState {
            problem: Problem::Nearness,
            n: 8,
            gamma: 0.0,
            pass: 0,
            triplet_visits: 0,
            next_check: 0,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: vec![0.0; 28],
            f: vec![],
            y_upper: vec![],
            y_lower: vec![],
            y_box: vec![],
            w: vec![1.0; 28],
            d_hash: 0,
            metric_duals: vec![(base | 1, 0.5), (base | 2, 0.25), (triplet_key(2, 3, 4), 0.1)],
            active: vec![],
            history: vec![],
        };
        let entries = st.active_entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].key, base);
        assert_eq!(entries[0].y, [0.0, 0.5, 0.25]);
        assert_eq!(entries[1].key, triplet_key(2, 3, 4));
        assert_eq!(entries[1].y, [0.1, 0.0, 0.0]);
    }

    #[test]
    fn active_entries_membership_preserves_streaks_and_zero_duals() {
        let st = SolverState {
            problem: Problem::Nearness,
            n: 8,
            gamma: 0.0,
            pass: 0,
            triplet_visits: 0,
            next_check: 0,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: vec![0.0; 28],
            f: vec![],
            y_upper: vec![],
            y_lower: vec![],
            y_box: vec![],
            w: vec![1.0; 28],
            d_hash: 0,
            metric_duals: vec![(triplet_key(0, 1, 2), 0.7)],
            active: vec![
                ActiveMember { key: triplet_key(0, 1, 2), zero_passes: 0 },
                ActiveMember { key: triplet_key(0, 1, 3), zero_passes: 2 },
            ],
            history: vec![],
        };
        let entries = st.active_entries();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].y, [0.7, 0.0, 0.0]);
        assert_eq!(entries[1].y, [0.0; 3]);
        assert_eq!(entries[1].zero_passes, 2);
    }

    /// split_duals must reproduce each worker's DualStore contents: run
    /// one serial-equivalent metric pass per worker layout and compare
    /// against redistributing the merged list.
    #[test]
    fn split_duals_matches_worker_visit_order() {
        let inst = CcLpInstance::random(17, 0.5, 0.7, 1.9, 23);
        let schedule = Schedule::new(17, 3);
        for p in [1usize, 2, 5] {
            for assignment in [Assignment::RoundRobin, Assignment::Rotated] {
                // Run one real parallel-order pass to fill per-worker stores.
                let mut state = CcState::new(&inst, 5.0, true);
                for (v, d) in state.x.iter_mut().zip(inst.d.as_slice()) {
                    *v = 0.9 * d;
                }
                let mut stores: Vec<DualStore> = (0..p).map(|_| DualStore::new()).collect();
                for s in stores.iter_mut() {
                    s.begin_pass();
                }
                {
                    let x = SharedMut::new(state.x.as_mut_slice());
                    for (wi, wave) in schedule.waves().iter().enumerate() {
                        // Serial emulation of the wave: workers in any
                        // order is fine (tiles are conflict-free).
                        for tid in 0..p {
                            let mut r = assignment.first_tile(tid, wi, p);
                            while r < wave.len() {
                                unsafe {
                                    hot_loop::process_tile(
                                        &x,
                                        &state.winv,
                                        &state.col_starts,
                                        &wave[r],
                                        3,
                                        &mut stores[tid],
                                    )
                                };
                                r += p;
                            }
                        }
                    }
                }
                let per_worker: Vec<Vec<(u64, f64)>> =
                    stores.iter().map(|s| s.iter_next().collect()).collect();
                let mut merged: Vec<(u64, f64)> =
                    per_worker.iter().flatten().copied().collect();
                merged.sort_unstable_by_key(|&(k, _)| k);
                let split = split_duals(&schedule, assignment, p, &merged);
                assert_eq!(split, per_worker, "p={p} {assignment:?}");
            }
        }
    }

    #[test]
    fn save_load_roundtrips_a_real_solve_state() {
        let inst = CcLpInstance::random(12, 0.5, 0.8, 1.6, 7);
        let opts = SolveOpts { max_passes: 4, checkpoint_every: 2, ..Default::default() };
        let mut states = Vec::new();
        dykstra_serial::solve_checkpointed(&inst, &opts, None, &mut |s| states.push(s.clone()))
            .unwrap();
        assert!(!states.is_empty());
        for s in &states {
            let mut bytes = Vec::new();
            s.save(&mut bytes).unwrap();
            let back = SolverState::load(&mut bytes.as_slice()).unwrap();
            assert_eq!(*s, back);
            back.validate_cc(&inst, &opts).unwrap();
        }
    }

    #[test]
    fn validate_rejects_wrong_instance_and_opts() {
        let inst = CcLpInstance::random(10, 0.5, 0.8, 1.6, 7);
        let opts = SolveOpts { max_passes: 2, checkpoint_every: 1, ..Default::default() };
        let mut last = None;
        dykstra_serial::solve_checkpointed(&inst, &opts, None, &mut |s| last = Some(s.clone()))
            .unwrap();
        let st = last.unwrap();
        st.validate_cc(&inst, &opts).unwrap();
        let other = CcLpInstance::random(10, 0.5, 0.8, 1.6, 8);
        assert!(st.validate_cc(&other, &opts).is_err(), "different weights must be rejected");
        let bad_gamma = SolveOpts { gamma: 7.0, ..opts };
        assert!(st.validate_cc(&inst, &bad_gamma).is_err());
        let no_box = SolveOpts { include_box: false, ..opts };
        assert!(st.validate_cc(&inst, &no_box).is_err());
        let near = MetricNearnessInstance::random(10, 2.0, 3);
        assert!(st.validate_nearness(&near).is_err());
    }

    #[test]
    fn metric_key_and_triplet_key_share_layout() {
        // The checkpoint relies on duals::metric_key and set::triplet_key
        // agreeing: base | t IS the dual key.
        let base = triplet_key(3, 9, 14);
        for t in 0..3 {
            assert_eq!(base | t as u64, metric_key(3, 9, 14, t));
        }
    }
}
