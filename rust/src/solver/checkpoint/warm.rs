//! Warm starts for perturbed re-solves: carry a solved instance's duals
//! to an updated-weights instance.
//!
//! Dykstra's method for these projection QPs is block-coordinate ascent
//! on the dual, so any nonnegative dual vector is a valid starting point
//! *provided the primal is consistent with it*: the iterate invariant
//! `x = x0 − W⁻¹Aᵀŷ` must hold. A warm start therefore does three
//! things:
//!
//! 1. **Rescale** each carried dual by its constraint's curvature ratio
//!    `(aᵀW⁻¹a) / (aᵀW'⁻¹a)`, which preserves the constraint-space
//!    displacement `aᵀ(W'⁻¹a)·ŷ'` each dual contributes — the best
//!    single-scalar transplant of the old correction when the three
//!    touched weights move independently.
//! 2. **Filter** duals at or below `drop_tol` — near-converged duals of
//!    constraints the perturbation deactivated just slow the first
//!    passes down.
//! 3. **Rebuild the primal** from the invariant under the *new* weights,
//!    so the state handed to the solver is exactly a mid-ascent Dykstra
//!    state of the perturbed problem.
//!
//! The carried nonzero-dual triplets also become the seeded active set,
//! and [`SolverState::skip_initial_sweep`] defers the first discovery
//! sweep — the expensive early discovery phase the warm start exists to
//! skip.

use super::format::CheckpointError;
use super::{hash_f64s, ActiveMember, Problem, SolverState};
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::instance::CcLpInstance;
use crate::solver::active::set::decode_key;
use crate::solver::projection::METRIC_SIGNS;
use crate::solver::SolveOpts;

/// Warm-start tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct WarmStartOpts {
    /// Rescale carried duals by the curvature ratio (on by default;
    /// off carries them verbatim).
    pub rescale: bool,
    /// Drop carried duals at or below this value after rescaling
    /// (0 drops nothing but exact zeros).
    pub drop_tol: f64,
}

impl Default for WarmStartOpts {
    fn default() -> Self {
        WarmStartOpts { rescale: true, drop_tol: 0.0 }
    }
}

fn mismatch(msg: String) -> CheckpointError {
    CheckpointError::Mismatch(msg)
}

/// Build a warm-start state for a perturbed CC-LP instance from a state
/// saved on the original instance (same `n` and targets, updated
/// weights). `opts` supplies the gamma and box setting of the upcoming
/// solve. Feed the result to any `resume` entry point.
pub fn warm_start_cc(
    state: &SolverState,
    inst: &CcLpInstance,
    opts: &SolveOpts,
    wopts: &WarmStartOpts,
) -> Result<SolverState, CheckpointError> {
    if state.problem != Problem::CcLp {
        return Err(mismatch("warm_start_cc needs a CC-LP state".into()));
    }
    if state.n != inst.n {
        return Err(mismatch(format!(
            "state has n = {}, perturbed instance has n = {}",
            state.n, inst.n
        )));
    }
    // Sized off the (always-inline) weights, not `x`: the primal is
    // rebuilt from the Dykstra invariant below, so external-x states —
    // whose `x` section is empty — warm start like inline ones.
    let m = state.w.len();
    let w_new = inst.w.as_slice();
    let w_old = state.w.as_slice();
    debug_assert_eq!(w_new.len(), m);
    let winv_new: Vec<f64> = w_new.iter().map(|&v| 1.0 / v).collect();
    let col_starts = inst.d.col_starts();

    // Pair and box rows touch one pair each: the curvature ratio reduces
    // to w'_e / w_e.
    let carry_pair = |ys: &[f64]| -> Vec<f64> {
        ys.iter()
            .enumerate()
            .map(|(e, &y)| {
                let v = if wopts.rescale { y * w_new[e] / w_old[e] } else { y };
                if v > wopts.drop_tol {
                    v
                } else {
                    0.0
                }
            })
            .collect()
    };
    let y_upper = carry_pair(&state.y_upper);
    let y_lower = carry_pair(&state.y_lower);
    let y_box = if opts.include_box {
        if state.y_box.is_empty() {
            vec![0.0; m]
        } else {
            carry_pair(&state.y_box)
        }
    } else {
        Vec::new()
    };

    let metric_duals = carry_metric(
        &state.metric_duals,
        w_old,
        &winv_new,
        col_starts,
        wopts.rescale,
        wopts.drop_tol,
    );

    // Rebuild the primal from x0' = (x = 0, f = -gamma) under the new
    // weights: x = x0' − W'⁻¹ Aᵀ ŷ'.
    let mut x = vec![0.0; m];
    let mut f = vec![-opts.gamma; m];
    for e in 0..m {
        let yb = if y_box.is_empty() { 0.0 } else { y_box[e] };
        x[e] += winv_new[e] * (y_lower[e] - y_upper[e] - yb);
        f[e] += winv_new[e] * (y_upper[e] + y_lower[e]);
    }
    apply_metric_duals(&mut x, &metric_duals, &winv_new, col_starts);

    let active = members_of(&metric_duals);
    Ok(SolverState {
        problem: Problem::CcLp,
        n: inst.n,
        gamma: opts.gamma,
        pass: 0,
        triplet_visits: 0,
        next_check: 0,
        skip_initial_sweep: true,
        x_external: false,
        x_fnv: 0,
        x,
        f,
        y_upper,
        y_lower,
        y_box,
        w: w_new.to_vec(),
        d_hash: hash_f64s(inst.d.as_slice()),
        metric_duals,
        active,
        history: Vec::new(),
    })
}

/// Build a warm-start state for a perturbed metric-nearness instance
/// (same `n`; weights and/or dissimilarities updated).
pub fn warm_start_nearness(
    state: &SolverState,
    inst: &MetricNearnessInstance,
    wopts: &WarmStartOpts,
) -> Result<SolverState, CheckpointError> {
    if state.problem != Problem::Nearness {
        return Err(mismatch("warm_start_nearness needs a metric-nearness state".into()));
    }
    if state.n != inst.n {
        return Err(mismatch(format!(
            "state has n = {}, perturbed instance has n = {}",
            state.n, inst.n
        )));
    }
    let w_new = inst.w.as_slice();
    let w_old = state.w.as_slice();
    let winv_new: Vec<f64> = w_new.iter().map(|&v| 1.0 / v).collect();
    let col_starts = inst.d.col_starts();

    let metric_duals = carry_metric(
        &state.metric_duals,
        w_old,
        &winv_new,
        col_starts,
        wopts.rescale,
        wopts.drop_tol,
    );

    // x0' = D' under the new weights: x = D' − W'⁻¹ Aᵀ ŷ'.
    let mut x = inst.d.as_slice().to_vec();
    apply_metric_duals(&mut x, &metric_duals, &winv_new, col_starts);

    let active = members_of(&metric_duals);
    Ok(SolverState {
        problem: Problem::Nearness,
        n: inst.n,
        gamma: 0.0,
        pass: 0,
        triplet_visits: 0,
        next_check: 0,
        skip_initial_sweep: true,
        x_external: false,
        x_fnv: 0,
        x,
        f: Vec::new(),
        y_upper: Vec::new(),
        y_lower: Vec::new(),
        y_box: Vec::new(),
        w: w_new.to_vec(),
        d_hash: hash_f64s(inst.d.as_slice()),
        metric_duals,
        active,
        history: Vec::new(),
    })
}

/// Packed indices of a triplet's three pairs.
#[inline]
fn triplet_pairs(col_starts: &[usize], i: usize, j: usize, k: usize) -> (usize, usize, usize) {
    let ci = col_starts[i];
    (ci + (j - i - 1), ci + (k - i - 1), col_starts[j] + (k - j - 1))
}

/// Rescale-and-filter the metric duals (key order preserved).
fn carry_metric(
    duals: &[(u64, f64)],
    w_old: &[f64],
    winv_new: &[f64],
    col_starts: &[usize],
    rescale: bool,
    drop_tol: f64,
) -> Vec<(u64, f64)> {
    let mut out = Vec::with_capacity(duals.len());
    let mut last_base = u64::MAX;
    let mut ratio = 1.0;
    for &(key, y) in duals {
        let base = key & !3;
        if base != last_base {
            last_base = base;
            ratio = if rescale {
                let (i, j, k) = decode_key(base);
                let (pij, pik, pjk) = triplet_pairs(col_starts, i, j, k);
                let curv_old = 1.0 / w_old[pij] + 1.0 / w_old[pik] + 1.0 / w_old[pjk];
                let curv_new = winv_new[pij] + winv_new[pik] + winv_new[pjk];
                curv_old / curv_new
            } else {
                1.0
            };
        }
        let v = y * ratio;
        if v > drop_tol {
            out.push((key, v));
        }
    }
    out
}

/// Subtract each dual's correction from `x` (the `− W⁻¹Aᵀŷ` term of the
/// Dykstra invariant).
fn apply_metric_duals(
    x: &mut [f64],
    duals: &[(u64, f64)],
    winv: &[f64],
    col_starts: &[usize],
) {
    for &(key, y) in duals {
        let t = (key & 3) as usize;
        let (i, j, k) = decode_key(key);
        let (pij, pik, pjk) = triplet_pairs(col_starts, i, j, k);
        let [s0, s1, s2] = METRIC_SIGNS[t];
        x[pij] -= winv[pij] * s0 * y;
        x[pik] -= winv[pik] * s1 * y;
        x[pjk] -= winv[pjk] * s2 * y;
    }
}

/// Membership list of a key-sorted dual list: one member per distinct
/// triplet, fresh forget streaks.
fn members_of(duals: &[(u64, f64)]) -> Vec<ActiveMember> {
    let mut out: Vec<ActiveMember> = Vec::new();
    for &(key, _) in duals {
        let base = key & !3;
        if out.last().map(|m| m.key) != Some(base) {
            out.push(ActiveMember { key: base, zero_passes: 0 });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::dykstra_serial;
    use crate::solver::nearness::{self, NearnessOpts};

    /// Capture the final state of a serial CC solve.
    fn final_cc_state(inst: &CcLpInstance, opts: &SolveOpts) -> SolverState {
        let mut last = None;
        let opts = SolveOpts { checkpoint_every: usize::MAX, ..*opts };
        dykstra_serial::solve_checkpointed(inst, &opts, None, &mut |s| last = Some(s.clone()))
            .unwrap();
        last.expect("final checkpoint emitted")
    }

    #[test]
    fn unchanged_instance_carries_everything_and_stays_consistent() {
        let inst = CcLpInstance::random(12, 0.5, 0.8, 1.6, 5);
        let opts = SolveOpts { max_passes: 60, ..Default::default() };
        let st = final_cc_state(&inst, &opts);
        assert!(!st.metric_duals.is_empty(), "test needs live duals");
        let warm = warm_start_cc(&st, &inst, &opts, &WarmStartOpts::default()).unwrap();
        // Same weights: ratios are exactly 1, duals carried verbatim.
        assert_eq!(warm.metric_duals, st.metric_duals);
        assert_eq!(warm.y_upper, st.y_upper);
        // The rebuilt primal satisfies the Dykstra invariant, which the
        // iterated x also satisfies — they agree to rounding error.
        for (a, b) in warm.x.iter().zip(st.x.iter()) {
            assert!((a - b).abs() < 1e-9, "invariant rebuild drifted: {a} vs {b}");
        }
        for (a, b) in warm.f.iter().zip(st.f.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(warm.skip_initial_sweep);
        assert_eq!(warm.pass, 0);
        assert_eq!(warm.active.len(), {
            let mut bases: Vec<u64> = st.metric_duals.iter().map(|&(k, _)| k & !3).collect();
            bases.dedup();
            bases.len()
        });
        warm.validate_cc(&inst, &opts).unwrap();
    }

    #[test]
    fn perturbed_weights_rescale_by_curvature_ratio() {
        let inst = CcLpInstance::random(10, 0.5, 0.8, 1.6, 9);
        let opts = SolveOpts { max_passes: 40, ..Default::default() };
        let st = final_cc_state(&inst, &opts);
        assert!(!st.metric_duals.is_empty());
        let perturbed = inst.perturb_weights(0.5, 0.3, 11);
        let warm = warm_start_cc(&st, &perturbed, &opts, &WarmStartOpts::default()).unwrap();
        warm.validate_cc(&perturbed, &opts).unwrap();
        let col_starts = perturbed.d.col_starts().to_vec();
        let wn = perturbed.w.as_slice();
        let wo = inst.w.as_slice();
        for (&(key, v_new), &(key_old, v_old)) in
            warm.metric_duals.iter().zip(st.metric_duals.iter())
        {
            assert_eq!(key, key_old);
            let (i, j, k) = decode_key(key);
            let (pij, pik, pjk) = triplet_pairs(&col_starts, i, j, k);
            let curv_old = 1.0 / wo[pij] + 1.0 / wo[pik] + 1.0 / wo[pjk];
            let curv_new = 1.0 / wn[pij] + 1.0 / wn[pik] + 1.0 / wn[pjk];
            let want = v_old * curv_old / curv_new;
            assert!((v_new - want).abs() < 1e-12 * want.abs().max(1.0));
            assert!(v_new > 0.0);
        }
    }

    #[test]
    fn drop_tol_filters_small_duals_and_membership_follows() {
        let inst = CcLpInstance::random(10, 0.5, 0.8, 1.6, 13);
        let opts = SolveOpts { max_passes: 40, ..Default::default() };
        let st = final_cc_state(&inst, &opts);
        let vals: Vec<f64> = st.metric_duals.iter().map(|&(_, v)| v).collect();
        assert!(!vals.is_empty());
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = sorted[sorted.len() / 2];
        let wopts = WarmStartOpts { rescale: false, drop_tol: cut };
        let warm = warm_start_cc(&st, &inst, &opts, &wopts).unwrap();
        assert!(warm.metric_duals.len() < st.metric_duals.len());
        assert!(warm.metric_duals.iter().all(|&(_, v)| v > cut));
        // every member corresponds to at least one kept dual
        for m in &warm.active {
            assert!(warm.metric_duals.iter().any(|&(k, _)| k & !3 == m.key));
        }
    }

    #[test]
    fn nearness_warm_state_resumes_near_the_old_solution() {
        let inst = MetricNearnessInstance::random(14, 2.0, 3);
        let opts = NearnessOpts {
            max_passes: 400,
            check_every: 5,
            tol_violation: 1e-8,
            checkpoint_every: usize::MAX,
            ..Default::default()
        };
        let mut last = None;
        nearness::solve_checkpointed(&inst, &opts, None, &mut |s| last = Some(s.clone()))
            .unwrap();
        let st = last.unwrap();
        let warm = warm_start_nearness(&st, &inst, &WarmStartOpts::default()).unwrap();
        warm.validate_nearness(&inst).unwrap();
        for (a, b) in warm.x.iter().zip(st.x.iter()) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn wrong_problem_or_size_rejected() {
        let inst = CcLpInstance::random(10, 0.5, 0.8, 1.6, 13);
        let opts = SolveOpts { max_passes: 5, ..Default::default() };
        let st = final_cc_state(&inst, &opts);
        let near = MetricNearnessInstance::random(10, 2.0, 3);
        assert!(warm_start_nearness(&st, &near, &WarmStartOpts::default()).is_err());
        let small = CcLpInstance::random(9, 0.5, 0.8, 1.6, 13);
        assert!(warm_start_cc(&st, &small, &opts, &WarmStartOpts::default()).is_err());
    }
}
