//! Delta-class batch schedule — conflict-free *flat batches* for the
//! XLA/PJRT engine.
//!
//! The wave schedule (§III-B/C) is ideal for threads: tiles are
//! conflict-free across a wave, and each tile is processed sequentially by
//! one worker. A batched kernel, however, needs every lane of a batch to
//! be independent — and triplets *within* a tile share variables (every
//! triplet of `S_{i,k}` contains the pair `(i,k)`).
//!
//! This module provides the alternative decomposition: group triplets by
//! their index deltas. For fixed `(a, b)` with `a, b >= 1`, the class
//!
//! ```text
//! D_{a,b} = { (i, i+a, i+a+b) : 0 <= i < n-a-b }
//! ```
//!
//! has pair deltas `{a, b, a+b}` at offsets fixed relative to `i`, and
//! one shows (tested exhaustively below) that two triplets of the same
//! class share a pair only when `a == b` and their bases differ by exactly
//! `a` — so classes with `a != b` are fully conflict-free, and `a == b`
//! classes split into two conflict-free halves by the parity of
//! `floor(i/a)`. Moreover two classes whose delta sets `{a, b, a+b}` are
//! disjoint can never share a pair, so whole classes pack greedily into
//! large batches. Every triplet is covered exactly once, so Dykstra's
//! convergence guarantees are untouched (it is again just a re-ordering).

/// A batched, conflict-free enumeration of all C(n,3) triplets.
#[derive(Clone, Debug)]
pub struct BatchSchedule {
    n: usize,
    batches: Vec<Vec<(u32, u32, u32)>>,
}

impl BatchSchedule {
    /// Build with batches of at most `max_lanes` triplets.
    pub fn new(n: usize, max_lanes: usize) -> BatchSchedule {
        assert!(max_lanes >= 1);
        let mut groups: Vec<(Vec<usize>, Vec<(u32, u32, u32)>)> = Vec::new();
        if n >= 3 {
            // Enumerate classes largest-first (small a+b = more lanes).
            for s in 2..n {
                // s = a + b
                for a in 1..s {
                    let b = s - a;
                    if n < s + 1 {
                        continue;
                    }
                    let count = n - s;
                    if a != b {
                        let lanes: Vec<(u32, u32, u32)> = (0..count)
                            .map(|i| (i as u32, (i + a) as u32, (i + s) as u32))
                            .collect();
                        groups.push((vec![a, b, s], lanes));
                    } else {
                        // split by parity of floor(i/a) to break the chains
                        for parity in 0..2usize {
                            let lanes: Vec<(u32, u32, u32)> = (0..count)
                                .filter(|i| (i / a) % 2 == parity)
                                .map(|i| (i as u32, (i + a) as u32, (i + s) as u32))
                                .collect();
                            if !lanes.is_empty() {
                                groups.push((vec![a, s], lanes));
                            }
                        }
                    }
                }
            }
        }
        // First-fit packing over open bins: a class joins the first bin
        // whose used-delta set is disjoint from the class's `{a, b, a+b}`
        // and whose lane budget holds. Disjoint delta sets cannot produce
        // a shared pair, so every bin stays internally conflict-free.
        struct Bin {
            used: std::collections::HashSet<usize>,
            lanes: Vec<(u32, u32, u32)>,
        }
        let mut bins: Vec<Bin> = Vec::new();
        let mut batches: Vec<Vec<(u32, u32, u32)>> = Vec::new();
        // Largest classes first improves fill substantially.
        groups.sort_by_key(|(_, lanes)| std::cmp::Reverse(lanes.len()));
        for (deltas, lanes) in groups {
            // Oversized classes are chunked (any subset of a conflict-free
            // class is conflict-free).
            if lanes.len() > max_lanes {
                for chunk in lanes.chunks(max_lanes) {
                    batches.push(chunk.to_vec());
                }
                continue;
            }
            let slot = bins.iter_mut().find(|b| {
                b.lanes.len() + lanes.len() <= max_lanes
                    && deltas.iter().all(|d| !b.used.contains(d))
            });
            match slot {
                Some(bin) => {
                    bin.used.extend(deltas.iter().copied());
                    bin.lanes.extend(lanes);
                }
                None => bins.push(Bin {
                    used: deltas.into_iter().collect(),
                    lanes,
                }),
            }
        }
        batches.extend(bins.into_iter().map(|b| b.lanes));
        BatchSchedule { n, batches }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Conflict-free batches, in execution order.
    pub fn batches(&self) -> &[Vec<(u32, u32, u32)>] {
        &self.batches
    }

    /// Total triplets (== C(n,3)).
    pub fn total(&self) -> u64 {
        self.batches.iter().map(|b| b.len() as u64).sum()
    }

    /// Mean lanes per batch — dispatch efficiency diagnostic.
    pub fn mean_lanes(&self) -> f64 {
        if self.batches.is_empty() {
            0.0
        } else {
            self.total() as f64 / self.batches.len() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::solver::schedule::n_triplets;
    use crate::util::proptest::check;

    #[test]
    fn covers_all_triplets_exactly_once() {
        for n in [3usize, 4, 7, 12, 25, 40] {
            for max_lanes in [4usize, 64, 100_000] {
                let s = BatchSchedule::new(n, max_lanes);
                let mut seen = std::collections::HashSet::new();
                for batch in s.batches() {
                    for &(i, j, k) in batch {
                        assert!(i < j && j < k && (k as usize) < n);
                        assert!(seen.insert((i, j, k)), "dup ({i},{j},{k}) n={n}");
                    }
                }
                assert_eq!(seen.len() as u64, n_triplets(n), "n={n} lanes={max_lanes}");
            }
        }
    }

    #[test]
    fn batches_are_pairwise_conflict_free() {
        // No two lanes of one batch may share a PAIR (two indices) — the
        // safety property for the batched kernel's gather/scatter.
        for n in [6usize, 10, 16, 30] {
            let s = BatchSchedule::new(n, 100_000);
            for batch in s.batches() {
                let mut pairs = std::collections::HashSet::new();
                for &(i, j, k) in batch {
                    for (u, v) in [(i, j), (i, k), (j, k)] {
                        assert!(pairs.insert((u, v)), "pair ({u},{v}) reused in batch, n={n}");
                    }
                }
            }
        }
    }

    #[test]
    fn conflict_freeness_property() {
        check("delta batches conflict-free", 0xDE17A, 20, |rng, _| {
            let n = rng.usize_in(3, 70);
            let lanes = rng.usize_in(2, 512);
            let s = BatchSchedule::new(n, lanes);
            for batch in s.batches() {
                let mut pairs = std::collections::HashSet::new();
                for &(i, j, k) in batch {
                    for (u, v) in [(i, j), (i, k), (j, k)] {
                        prop_assert!(pairs.insert((u, v)), "pair reuse n={n} lanes={lanes}");
                    }
                }
            }
            prop_assert!(s.total() == n_triplets(n), "coverage n={n}");
            Ok(())
        });
    }

    #[test]
    fn respects_max_lanes() {
        let s = BatchSchedule::new(40, 50);
        for batch in s.batches() {
            assert!(batch.len() <= 50);
        }
    }

    #[test]
    fn packing_is_effective() {
        // With a generous lane budget, mean batch size should be much
        // larger than a single class (packing works).
        let s = BatchSchedule::new(60, 100_000);
        assert!(
            s.mean_lanes() > 60.0,
            "mean lanes {} suggests packing failed",
            s.mean_lanes()
        );
    }

    #[test]
    fn deterministic() {
        let a = BatchSchedule::new(20, 64);
        let b = BatchSchedule::new(20, 64);
        assert_eq!(a.batches(), b.batches());
    }
}
