//! Auto-resume harness: re-run a solve from its last periodic checkpoint
//! after a store failure.
//!
//! Out-of-core solves run for hours against real disks; a transient
//! fault burst that outlives the store's retry budget should cost one
//! resume, not the whole run. [`run_with_recovery`] wraps a solve
//! closure: on a [`SolveError::Store`] unwind it reloads the most recent
//! checkpoint, emits a [`Event::Recovery`] trace event, and re-invokes
//! the closure with the reloaded state (the CLI's dispatch maps
//! `Some(state)` onto the drivers' `resume` entry points, which also
//! re-open the tile store — promoting the store's `.ckpt` snapshot when
//! the live file no longer matches the checkpoint's stamp). Attempts are
//! bounded; exhaustion returns the final error with the last-good
//! checkpoint path attached so the operator can resume by hand once the
//! device recovers.
//!
//! Only store failures recover: an [`Interrupted`](SolveError::Interrupted)
//! unwind is deliberate, a [`Watchdog`](SolveError::Watchdog) trip would
//! reproduce itself from the same state, and
//! [`Other`](SolveError::Other) covers setup errors a retry cannot fix.

use super::checkpoint::SolverState;
use super::error::SolveError;
use crate::telemetry::{Event, Recorder};
use std::path::Path;

/// Run `run`, auto-resuming from `checkpoint` on store failure.
///
/// The closure receives `None` on the first invocation and
/// `Some(&state)` (the reloaded checkpoint) on each recovery attempt; it
/// decides how to restart from the state — the drivers' `resume` entry
/// points reproduce the uninterrupted run bitwise. `attempts` bounds the
/// number of *re*-invocations (`0` disables recovery). Any error other
/// than [`SolveError::Store`], a missing/unreadable checkpoint, or an
/// exhausted budget ends the harness; store errors leave with the
/// last-good checkpoint path attached when one is still loadable.
pub fn run_with_recovery<T>(
    attempts: usize,
    checkpoint: Option<&Path>,
    rec: &dyn Recorder,
    mut run: impl FnMut(Option<&SolverState>) -> Result<T, SolveError>,
) -> Result<T, SolveError> {
    let mut state: Option<SolverState> = None;
    let mut tried = 0usize;
    loop {
        let err = match run(state.as_ref()) {
            Ok(t) => return Ok(t),
            Err(e) => e,
        };
        if err.is_store() && tried < attempts {
            if let Some(st) = checkpoint.and_then(|p| SolverState::load_path(p).ok()) {
                tried += 1;
                if rec.enabled() {
                    rec.record(&Event::Recovery {
                        attempt: tried as u64,
                        pass: st.pass,
                        msg: err.to_string(),
                    });
                }
                state = Some(st);
                continue;
            }
        }
        // Out of attempts (or no usable checkpoint): report the failure,
        // naming the last-good checkpoint if one is still loadable.
        let last_good = checkpoint
            .filter(|p| SolverState::load_path(p).is_ok())
            .map(Path::to_path_buf);
        return Err(err.with_checkpoint(last_good));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::store::StoreError;
    use crate::solver::checkpoint::Problem;
    use crate::telemetry::NullRecorder;
    use std::path::PathBuf;
    use std::sync::Mutex;

    fn mini_state(pass: u64) -> SolverState {
        SolverState {
            problem: Problem::Nearness,
            n: 8,
            gamma: 0.0,
            pass,
            triplet_visits: 0,
            next_check: 0,
            skip_initial_sweep: false,
            x_external: false,
            x_fnv: 0,
            x: vec![0.0; 28],
            f: vec![],
            y_upper: vec![],
            y_lower: vec![],
            y_box: vec![],
            w: vec![1.0; 28],
            d_hash: 0,
            metric_duals: vec![],
            active: vec![],
            history: vec![],
        }
    }

    fn tmp_ckpt(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("metric_proj_recover_{tag}_{}.bin", std::process::id()))
    }

    struct VecRecorder(Mutex<Vec<Event>>);

    impl Recorder for VecRecorder {
        fn record(&self, ev: &Event) {
            self.0.lock().unwrap().push(ev.clone());
        }
    }

    #[test]
    fn recovers_from_store_failure_with_the_checkpoint_state() {
        let path = tmp_ckpt("heals");
        mini_state(7).save_path(&path).expect("save checkpoint");
        let sink = VecRecorder(Mutex::new(Vec::new()));
        let mut calls = Vec::new();
        let out = run_with_recovery(2, Some(&path), &sink, |st| {
            calls.push(st.map(|s| s.pass));
            if st.is_none() {
                Err(SolveError::from(StoreError::BadMagic))
            } else {
                Ok(42)
            }
        });
        assert_eq!(out.expect("second attempt succeeds"), 42);
        assert_eq!(calls, vec![None, Some(7)]);
        let events = sink.0.lock().unwrap();
        assert_eq!(events.len(), 1);
        match &events[0] {
            Event::Recovery { attempt: 1, pass: 7, msg } => {
                assert!(msg.contains("bad magic"), "got {msg}");
            }
            other => panic!("unexpected {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exhaustion_names_the_last_good_checkpoint() {
        let path = tmp_ckpt("exhausts");
        mini_state(3).save_path(&path).expect("save checkpoint");
        let mut calls = 0usize;
        let out: Result<(), _> = run_with_recovery(2, Some(&path), &NullRecorder, |_| {
            calls += 1;
            Err(SolveError::from(StoreError::BadMagic))
        });
        assert_eq!(calls, 3, "one first run + two recovery attempts");
        match out.unwrap_err() {
            SolveError::Store { last_good_checkpoint, .. } => {
                assert_eq!(last_good_checkpoint, Some(path.clone()));
            }
            other => panic!("wrong variant: {other}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn no_checkpoint_and_non_store_errors_end_immediately() {
        let mut calls = 0usize;
        let out: Result<(), _> = run_with_recovery(5, None, &NullRecorder, |_| {
            calls += 1;
            Err(SolveError::from(StoreError::BadMagic))
        });
        assert_eq!(calls, 1, "nothing to resume from");
        assert!(out.unwrap_err().is_store());

        let path = tmp_ckpt("nonstore");
        mini_state(1).save_path(&path).expect("save checkpoint");
        let mut calls = 0usize;
        let out: Result<(), _> = run_with_recovery(5, Some(&path), &NullRecorder, |_| {
            calls += 1;
            Err(SolveError::Interrupted { pass: 2, checkpointed: true })
        });
        assert_eq!(calls, 1, "interrupts are deliberate, never retried");
        assert!(matches!(out.unwrap_err(), SolveError::Interrupted { .. }));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_checkpoint_disables_recovery_and_is_not_named() {
        let path = tmp_ckpt("corrupt");
        std::fs::write(&path, b"not a checkpoint").expect("write junk");
        let mut calls = 0usize;
        let out: Result<(), _> = run_with_recovery(3, Some(&path), &NullRecorder, |_| {
            calls += 1;
            Err(SolveError::from(StoreError::BadMagic))
        });
        assert_eq!(calls, 1);
        match out.unwrap_err() {
            SolveError::Store { last_good_checkpoint, .. } => {
                assert_eq!(last_good_checkpoint, None, "junk is not a last-good checkpoint");
            }
            other => panic!("wrong variant: {other}"),
        }
        let _ = std::fs::remove_file(&path);
    }
}
