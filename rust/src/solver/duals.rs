//! Sparse dual-variable storage for Dykstra's method (§III-D).
//!
//! A dual variable `y_c` exists per constraint, but is nonzero only if the
//! last visit performed a non-trivial projection. Storing all `3·C(n,3)`
//! of them densely is impossible at scale, so — exactly as the paper
//! describes — each worker keeps an *ordered array* of `(key, y)` tuples
//! for the constraints it owns. Because every worker visits its
//! constraints in the same deterministic order each pass, the previous
//! pass's array can be merge-scanned with a single advancing pointer:
//! every lookup is O(1).

/// Ordered sparse dual store for one worker.
#[derive(Clone, Debug, Default)]
pub struct DualStore {
    /// Duals written last pass, in that pass's visit order.
    prev: Vec<(u64, f64)>,
    /// Duals being written this pass.
    next: Vec<(u64, f64)>,
    /// Read cursor into `prev`.
    ptr: usize,
}

impl DualStore {
    pub fn new() -> DualStore {
        DualStore::default()
    }

    /// Start a new pass: what was written becomes the read array.
    pub fn begin_pass(&mut self) {
        std::mem::swap(&mut self.prev, &mut self.next);
        self.next.clear();
        self.ptr = 0;
    }

    /// Fetch the dual stored for `key` last pass (0.0 if none). Must be
    /// called in exactly the same key order as last pass's `store` calls.
    #[inline(always)]
    pub fn fetch(&mut self, key: u64) -> f64 {
        if self.ptr < self.prev.len() {
            // SAFETY of logic: prev is ordered by last pass's visit order;
            // if the head entry is not ours it belongs to a later visit.
            let (k, v) = self.prev[self.ptr];
            if k == key {
                self.ptr += 1;
                return v;
            }
        }
        0.0
    }

    /// Record the new dual for `key` (only nonzero values are kept).
    #[inline(always)]
    pub fn store(&mut self, key: u64, y: f64) {
        if y != 0.0 {
            self.next.push((key, y));
        }
    }

    /// Combined fetch-then-store visit used by the solvers: returns the
    /// old dual; caller computes the new one and calls `store`.
    #[inline(always)]
    pub fn visit(&mut self, key: u64) -> f64 {
        self.fetch(key)
    }

    /// Fetch the three duals of a triplet whose constraint keys are
    /// `base | t` for t = 0, 1, 2 (see [`metric_key`]). Because the three
    /// entries were stored consecutively in visit order, an inactive
    /// triplet costs a single key comparison (§Perf).
    #[inline(always)]
    pub fn fetch3(&mut self, base: u64) -> [f64; 3] {
        debug_assert_eq!(base & 3, 0);
        let mut out = [0.0; 3];
        while self.ptr < self.prev.len() {
            // SAFETY of logic: same merge-scan argument as `fetch`.
            let (k, v) = unsafe { *self.prev.get_unchecked(self.ptr) };
            if k & !3 != base {
                break;
            }
            out[(k & 3) as usize] = v;
            self.ptr += 1;
        }
        out
    }

    /// Store the three duals of a triplet (zeros skipped).
    #[inline(always)]
    pub fn store3(&mut self, base: u64, y: [f64; 3]) {
        for (t, &v) in y.iter().enumerate() {
            if v != 0.0 {
                self.next.push((base | t as u64, v));
            }
        }
    }

    /// Number of nonzero duals written this pass so far.
    pub fn nnz(&self) -> usize {
        self.next.len()
    }

    /// Number of nonzero duals from the previous pass.
    pub fn prev_nnz(&self) -> usize {
        self.prev.len()
    }

    /// Iterate over duals written this pass (key, value).
    pub fn iter_next(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.next.iter().copied()
    }

    /// Drop everything (restart).
    pub fn reset(&mut self) {
        self.prev.clear();
        self.next.clear();
        self.ptr = 0;
    }

    /// Install `entries` as the duals written by the (checkpointed) pass
    /// just "completed", for resume: the next [`Self::begin_pass`] makes
    /// them the read array, exactly as if this store had executed that
    /// pass itself. Entries must be in this store's visit order.
    pub fn restore(&mut self, entries: Vec<(u64, f64)>) {
        self.next = entries;
        self.prev.clear();
        self.ptr = 0;
    }
}

/// Triplet-granular dual store: one `(key, [y0, y1, y2])` entry per triplet
/// with any nonzero dual.
///
/// **Recorded negative result** (EXPERIMENTS.md §Perf attempt 4): measured
/// ~13% slower than [`DualStore`] with [`DualStore::fetch3`] in the full
/// pass — the 32-byte entries and stored zero lanes cost more memory
/// traffic than the saved key compares. Kept for the record; the hot
/// loops use the scalar store's `fetch3`/`store3`.
#[derive(Clone, Debug, Default)]
pub struct TripletDualStore {
    prev: Vec<(u64, [f64; 3])>,
    next: Vec<(u64, [f64; 3])>,
    ptr: usize,
}

impl TripletDualStore {
    pub fn new() -> TripletDualStore {
        TripletDualStore::default()
    }

    /// Start a new pass: what was written becomes the read array.
    pub fn begin_pass(&mut self) {
        std::mem::swap(&mut self.prev, &mut self.next);
        self.next.clear();
        self.ptr = 0;
    }

    /// Fetch the triplet's duals from last pass ([0;3] if none).
    /// Must be called in last pass's visit order.
    #[inline(always)]
    pub fn fetch(&mut self, key: u64) -> [f64; 3] {
        if self.ptr < self.prev.len() {
            // SAFETY of logic: identical merge-scan argument as DualStore.
            let (k, v) = unsafe { *self.prev.get_unchecked(self.ptr) };
            if k == key {
                self.ptr += 1;
                return v;
            }
        }
        [0.0; 3]
    }

    /// Record the triplet's new duals (dropped if all zero).
    #[inline(always)]
    pub fn store(&mut self, key: u64, y: [f64; 3]) {
        if y[0] != 0.0 || y[1] != 0.0 || y[2] != 0.0 {
            self.next.push((key, y));
        }
    }

    /// Number of triplets with nonzero duals written this pass.
    pub fn nnz(&self) -> usize {
        self.next.len()
    }

    /// Iterate over (key, duals) written this pass.
    pub fn iter_next(&self) -> impl Iterator<Item = (u64, [f64; 3])> + '_ {
        self.next.iter().copied()
    }
}

/// Encode a metric-constraint identity as a store key:
/// triplet `(i, j, k)` plus constraint type `t ∈ {0, 1, 2}`.
/// Unique for n < 2^20 (n ≤ 1M pairsets), far beyond feasible scales.
#[inline(always)]
pub fn metric_key(i: usize, j: usize, k: usize, t: usize) -> u64 {
    debug_assert!(t < 3 && i < j && j < k);
    (((i as u64) << 42) | ((j as u64) << 22) | ((k as u64) << 2)) | t as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fetch_returns_zero_first_pass() {
        let mut d = DualStore::new();
        d.begin_pass();
        assert_eq!(d.fetch(metric_key(0, 1, 2, 0)), 0.0);
    }

    #[test]
    fn roundtrip_one_pass() {
        let mut d = DualStore::new();
        d.begin_pass();
        let keys: Vec<u64> = (0..10).map(|t| metric_key(1, 2, 3 + t, 0)).collect();
        for (idx, &k) in keys.iter().enumerate() {
            assert_eq!(d.fetch(k), 0.0);
            // store only even positions
            if idx % 2 == 0 {
                d.store(k, idx as f64 + 1.0);
            }
        }
        d.begin_pass();
        for (idx, &k) in keys.iter().enumerate() {
            let want = if idx % 2 == 0 { idx as f64 + 1.0 } else { 0.0 };
            assert_eq!(d.fetch(k), want, "idx={idx}");
        }
    }

    #[test]
    fn store_skips_zeros() {
        let mut d = DualStore::new();
        d.begin_pass();
        d.store(1, 0.0);
        d.store(2, 5.0);
        assert_eq!(d.nnz(), 1);
    }

    #[test]
    fn sparse_pattern_many_passes() {
        // Simulate 5 passes over 200 constraints with a pseudo-random but
        // pass-consistent activity pattern; verify fetch always returns
        // what the previous pass stored.
        let mut d = DualStore::new();
        let keys: Vec<u64> = (0..200u64).map(|q| q * 7 + 3).collect();
        let mut expected: Vec<f64> = vec![0.0; 200];
        let mut rng = Rng::new(42);
        for pass in 0..5 {
            d.begin_pass();
            for (idx, &k) in keys.iter().enumerate() {
                let got = d.fetch(k);
                assert_eq!(got, expected[idx], "pass={pass} idx={idx}");
                let newval = if rng.bool(0.4) { rng.f64_in(0.1, 2.0) } else { 0.0 };
                d.store(k, newval);
                expected[idx] = newval;
            }
        }
    }

    #[test]
    fn metric_key_unique_small_n() {
        let n = 12;
        let mut seen = std::collections::HashSet::new();
        for i in 0..n {
            for j in (i + 1)..n {
                for k in (j + 1)..n {
                    for t in 0..3 {
                        assert!(seen.insert(metric_key(i, j, k, t)));
                    }
                }
            }
        }
    }

    #[test]
    fn restore_feeds_the_next_pass() {
        let keys: Vec<u64> = (0..6).map(|t| metric_key(0, 1, 2 + t, 0)).collect();
        // Reference: a store that actually executed the "pass".
        let mut a = DualStore::new();
        a.begin_pass();
        for (idx, &k) in keys.iter().enumerate() {
            a.fetch(k);
            a.store(k, idx as f64 + 0.5);
        }
        // Restored: same written duals installed from a checkpoint.
        let mut b = DualStore::new();
        b.restore(keys.iter().enumerate().map(|(i, &k)| (k, i as f64 + 0.5)).collect());
        assert_eq!(a.nnz(), b.nnz());
        a.begin_pass();
        b.begin_pass();
        for &k in &keys {
            assert_eq!(a.fetch(k), b.fetch(k));
        }
    }

    #[test]
    fn reset_clears() {
        let mut d = DualStore::new();
        d.begin_pass();
        d.store(5, 1.0);
        d.reset();
        d.begin_pass();
        assert_eq!(d.fetch(5), 0.0);
        assert_eq!(d.nnz(), 0);
    }
}
