//! The localized Dykstra constraint visit (Algorithm 1, §II-B(c)).
//!
//! Every visit to a constraint `a'x <= b` performs, in the W-inner-product:
//!
//! ```text
//! correction:  x += yhat * W^{-1} a          (yhat = dual from last pass)
//! projection:  theta = max(a'x - b, 0) / (a' W^{-1} a)
//!              x -= theta * W^{-1} a
//! dual update: yhat := theta
//! ```
//!
//! We store *scaled* duals `yhat = y / eps`, which removes `eps` from every
//! visit (it only enters through the starting point `x0 = -(1/eps) W^{-1} c`;
//! see DESIGN.md §6). Because correction and projection move along the same
//! direction `W^{-1} a`, we fuse them into a single write with coefficient
//! `yhat_old - theta` — one read-modify-write per variable per visit.
//!
//! Metric rows have exactly 3 nonzeros (±1), pair rows 2, box rows 1, so
//! each function below is O(1).

use crate::util::shared::SharedMut;

/// Sign patterns of the three metric constraints of a triplet `(i,j,k)`,
/// ordered by constraint type `t`:
/// t=0: `x_ij - x_ik - x_jk <= 0`
/// t=1: `-x_ij + x_ik - x_jk <= 0`
/// t=2: `-x_ij - x_ik + x_jk <= 0`
pub const METRIC_SIGNS: [[f64; 3]; 3] =
    [[1.0, -1.0, -1.0], [-1.0, 1.0, -1.0], [-1.0, -1.0, 1.0]];

/// Visit one metric constraint. `x` = packed distance variables;
/// `winv` = packed 1/w; `(pij, pik, pjk)` = packed indices of the triplet's
/// pairs; `t` = constraint type; `y` = scaled dual from last pass.
/// Returns the new scaled dual `theta`.
///
/// # Safety
/// Indices must be in bounds and no other thread may concurrently access
/// the three entries (guaranteed by the wave schedule).
#[inline(always)]
pub unsafe fn visit_metric(
    x: &SharedMut<f64>,
    winv: &[f64],
    pij: usize,
    pik: usize,
    pjk: usize,
    t: usize,
    y: f64,
) -> f64 {
    let [s0, s1, s2] = METRIC_SIGNS[t];
    let (w0, w1, w2) = (
        *winv.get_unchecked(pij),
        *winv.get_unchecked(pik),
        *winv.get_unchecked(pjk),
    );
    let (mut x0, mut x1, mut x2) = (x.get(pij), x.get(pik), x.get(pjk));
    // Corrected point (in registers only).
    x0 += y * s0 * w0;
    x1 += y * s1 * w1;
    x2 += y * s2 * w2;
    let delta = s0 * x0 + s1 * x1 + s2 * x2; // b = 0 for metric rows
    let theta = if delta > 0.0 { delta / (w0 + w1 + w2) } else { 0.0 };
    // Fused write-back: net coefficient (y - theta) along W^{-1} a.
    let c = y - theta;
    if c != 0.0 {
        // x currently holds the *uncorrected* values; apply net change.
        x.set(pij, x.get(pij) + c * s0 * w0);
        x.set(pik, x.get(pik) + c * s1 * w1);
        x.set(pjk, x.get(pjk) + c * s2 * w2);
    }
    theta
}

/// Fused visit of ALL THREE metric constraints of one triplet.
///
/// Numerically identical sequence to three [`visit_metric`] calls (t = 0,
/// 1, 2) except that (a) the three variables stay in registers across the
/// three constraint visits — one load and one store per variable per
/// *triplet* instead of per *constraint* — and (b) `theta` uses a
/// precomputed reciprocal (one division per triplet, not three). This is
/// the solver hot path (~10 cycles/constraint); see EXPERIMENTS.md §Perf.
///
/// **No-op contract** (load-bearing): with `y = [0; 3]` and all three
/// residuals `<= 0`, this function returns `[0; 3]` and does not touch
/// `x` — and the residuals it tests are exactly
/// `(x0 - x1 - x2, x1 - x0 - x2, x2 - x0 - x1)` on the raw values. The
/// screen-then-project sweep ([`crate::solver::active::sweep`]) skips
/// precisely the triplets this contract covers; weakening it (e.g.
/// reordering the residual arithmetic, or writing back on the fast
/// path) would silently break the screened sweep's bitwise equivalence
/// with the scalar sweep, which `tests/sweep_backends.rs` pins.
///
/// Returns the three new scaled duals.
///
/// # Safety
/// Same contract as [`visit_metric`].
#[inline(always)]
pub unsafe fn visit_triplet(
    x: &SharedMut<f64>,
    winv: &[f64],
    pij: usize,
    pik: usize,
    pjk: usize,
    y: [f64; 3],
) -> [f64; 3] {
    let (mut x0, mut x1, mut x2) = (x.get(pij), x.get(pik), x.get(pjk));
    // Fast path: zero duals and all three constraints slack — by far the
    // most common case in steady state — needs only the three deltas and
    // no weight loads, no division, no stores, no dual writes.
    if y[0] == 0.0 && y[1] == 0.0 && y[2] == 0.0 {
        let d0 = x0 - x1 - x2;
        let d1 = x1 - x0 - x2;
        let d2 = x2 - x0 - x1;
        if d0 <= 0.0 && d1 <= 0.0 && d2 <= 0.0 {
            return [0.0; 3];
        }
    }
    let w0 = *winv.get_unchecked(pij);
    let w1 = *winv.get_unchecked(pik);
    let w2 = *winv.get_unchecked(pjk);
    let sinv = 1.0 / (w0 + w1 + w2);
    // t = 0: x_ij - x_ik - x_jk <= 0   signs (+, -, -)
    x0 += y[0] * w0;
    x1 -= y[0] * w1;
    x2 -= y[0] * w2;
    let d0 = x0 - x1 - x2;
    let t0 = if d0 > 0.0 { d0 * sinv } else { 0.0 };
    x0 -= t0 * w0;
    x1 += t0 * w1;
    x2 += t0 * w2;
    // t = 1: -x_ij + x_ik - x_jk <= 0  signs (-, +, -)
    x0 -= y[1] * w0;
    x1 += y[1] * w1;
    x2 -= y[1] * w2;
    let d1 = x1 - x0 - x2;
    let t1 = if d1 > 0.0 { d1 * sinv } else { 0.0 };
    x0 += t1 * w0;
    x1 -= t1 * w1;
    x2 += t1 * w2;
    // t = 2: -x_ij - x_ik + x_jk <= 0  signs (-, -, +)
    x0 -= y[2] * w0;
    x1 -= y[2] * w1;
    x2 += y[2] * w2;
    let d2 = x2 - x0 - x1;
    let t2 = if d2 > 0.0 { d2 * sinv } else { 0.0 };
    x0 += t2 * w0;
    x1 += t2 * w1;
    x2 -= t2 * w2;
    // Write back only if anything moved: in steady state most triplets are
    // strictly feasible with zero duals, and skipping the 3 stores keeps
    // their cache lines clean (measured ~2.4x on the full pass, §Perf).
    if y[0] != 0.0 || y[1] != 0.0 || y[2] != 0.0 || t0 != 0.0 || t1 != 0.0 || t2 != 0.0 {
        x.set(pij, x0);
        x.set(pik, x1);
        x.set(pjk, x2);
    }
    [t0, t1, t2]
}

/// As [`visit_triplet`], but with the `x_ij` variable and its inverse
/// weight carried in registers by the caller (inside the innermost `k`
/// loop of the hot path, `p_ij` is fixed).
///
/// **Recorded negative result** (EXPERIMENTS.md §Perf attempt 5): this
/// measured ~75% *slower* than [`visit_triplet`] in the full pass — the
/// carried value extends a live range across the loop and defeats the
/// compiler's scheduling of the inactive fast path. Kept for the record
/// and for callers that genuinely hold `x_ij` elsewhere; the hot loops
/// use [`visit_triplet`].
///
/// # Safety
/// Same contract as [`visit_triplet`]; additionally `*x0` must be the
/// current value of the variable at `p_ij` and nothing else may touch it.
#[inline(always)]
pub unsafe fn visit_triplet_carried(
    x: &SharedMut<f64>,
    winv: &[f64],
    x0: &mut f64,
    w0: f64,
    pik: usize,
    pjk: usize,
    y: [f64; 3],
) -> [f64; 3] {
    let (mut x1, mut x2) = (x.get(pik), x.get(pjk));
    let mut v0 = *x0;
    if y[0] == 0.0 && y[1] == 0.0 && y[2] == 0.0 {
        let d0 = v0 - x1 - x2;
        let d1 = x1 - v0 - x2;
        let d2 = x2 - v0 - x1;
        if d0 <= 0.0 && d1 <= 0.0 && d2 <= 0.0 {
            return [0.0; 3];
        }
    }
    let w1 = *winv.get_unchecked(pik);
    let w2 = *winv.get_unchecked(pjk);
    let sinv = 1.0 / (w0 + w1 + w2);
    // t = 0
    v0 += y[0] * w0;
    x1 -= y[0] * w1;
    x2 -= y[0] * w2;
    let d0 = v0 - x1 - x2;
    let t0 = if d0 > 0.0 { d0 * sinv } else { 0.0 };
    v0 -= t0 * w0;
    x1 += t0 * w1;
    x2 += t0 * w2;
    // t = 1
    v0 -= y[1] * w0;
    x1 += y[1] * w1;
    x2 -= y[1] * w2;
    let d1 = x1 - v0 - x2;
    let t1 = if d1 > 0.0 { d1 * sinv } else { 0.0 };
    v0 += t1 * w0;
    x1 -= t1 * w1;
    x2 += t1 * w2;
    // t = 2
    v0 -= y[2] * w0;
    x1 -= y[2] * w1;
    x2 += y[2] * w2;
    let d2 = x2 - v0 - x1;
    let t2 = if d2 > 0.0 { d2 * sinv } else { 0.0 };
    v0 += t2 * w0;
    x1 += t2 * w1;
    x2 -= t2 * w2;
    if y[0] != 0.0 || y[1] != 0.0 || y[2] != 0.0 || t0 != 0.0 || t1 != 0.0 || t2 != 0.0 {
        *x0 = v0;
        x.set(pik, x1);
        x.set(pjk, x2);
    }
    [t0, t1, t2]
}

/// Visit the pair constraint `x_e - f_e <= d_e` (slack upper side).
/// Returns the new scaled dual.
///
/// # Safety
/// `e` in bounds; exclusive access to `x[e]`, `f[e]`.
#[inline(always)]
pub unsafe fn visit_pair_upper(
    x: &SharedMut<f64>,
    f: &SharedMut<f64>,
    winv: &[f64],
    d: &[f64],
    e: usize,
    y: f64,
) -> f64 {
    let w = *winv.get_unchecked(e);
    let (xv, fv) = (x.get(e), f.get(e));
    // delta at the corrected point: (x + yw) - (f - yw) - d
    let delta = xv - fv - *d.get_unchecked(e) + 2.0 * y * w;
    let theta = if delta > 0.0 { delta / (2.0 * w) } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        x.set(e, xv + c * w);
        f.set(e, fv - c * w);
    }
    theta
}

/// Visit the pair constraint `-x_e - f_e <= -d_e` (slack lower side).
///
/// # Safety
/// Same contract as [`visit_pair_upper`].
#[inline(always)]
pub unsafe fn visit_pair_lower(
    x: &SharedMut<f64>,
    f: &SharedMut<f64>,
    winv: &[f64],
    d: &[f64],
    e: usize,
    y: f64,
) -> f64 {
    let w = *winv.get_unchecked(e);
    let (xv, fv) = (x.get(e), f.get(e));
    let delta = *d.get_unchecked(e) - xv - fv + 2.0 * y * w;
    let theta = if delta > 0.0 { delta / (2.0 * w) } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        x.set(e, xv - c * w);
        f.set(e, fv - c * w);
    }
    theta
}

/// Visit the box constraint `x_e <= 1`.
///
/// # Safety
/// Same contract as [`visit_pair_upper`].
#[inline(always)]
pub unsafe fn visit_box_upper(x: &SharedMut<f64>, winv: &[f64], e: usize, y: f64) -> f64 {
    let w = *winv.get_unchecked(e);
    let xv = x.get(e);
    let delta = xv + y * w - 1.0;
    let theta = if delta > 0.0 { delta / w } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        x.set(e, xv + c * w);
    }
    theta
}

/// Value-based [`visit_pair_upper`]: identical arithmetic with the
/// distance entry supplied directly — the streamed pair phase holds `x`
/// in a leased segment ([`TileStore::with_pair_range`]) rather than a
/// global view. Bitwise equal to the indexed variant by construction
/// (same reads, same operation order, same writes).
///
/// [`TileStore::with_pair_range`]: crate::matrix::store::TileStore::with_pair_range
#[inline(always)]
pub fn visit_pair_upper_val(xv: &mut f64, fv: &mut f64, w: f64, d: f64, y: f64) -> f64 {
    let delta = *xv - *fv - d + 2.0 * y * w;
    let theta = if delta > 0.0 { delta / (2.0 * w) } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        *xv += c * w;
        *fv -= c * w;
    }
    theta
}

/// Value-based [`visit_pair_lower`] (see [`visit_pair_upper_val`]).
#[inline(always)]
pub fn visit_pair_lower_val(xv: &mut f64, fv: &mut f64, w: f64, d: f64, y: f64) -> f64 {
    let delta = d - *xv - *fv + 2.0 * y * w;
    let theta = if delta > 0.0 { delta / (2.0 * w) } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        *xv -= c * w;
        *fv -= c * w;
    }
    theta
}

/// Value-based [`visit_box_upper`] (see [`visit_pair_upper_val`]).
#[inline(always)]
pub fn visit_box_upper_val(xv: &mut f64, w: f64, y: f64) -> f64 {
    let delta = *xv + y * w - 1.0;
    let theta = if delta > 0.0 { delta / w } else { 0.0 };
    let c = y - theta;
    if c != 0.0 {
        *xv += c * w;
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shared(v: &mut Vec<f64>) -> SharedMut<'_, f64> {
        SharedMut::new(v.as_mut_slice())
    }

    #[test]
    fn satisfied_constraint_no_dual_is_noop() {
        let mut xv = vec![1.0, 2.0, 2.0];
        let winv = vec![1.0, 1.0, 1.0];
        let x = shared(&mut xv);
        let theta = unsafe { visit_metric(&x, &winv, 0, 1, 2, 0, 0.0) };
        assert_eq!(theta, 0.0);
        assert_eq!(xv, vec![1.0, 2.0, 2.0]);
    }

    #[test]
    fn violated_constraint_projects_onto_hyperplane() {
        // x_ij=3, x_ik=1, x_jk=1: delta=1; unit weights -> theta=1/3
        let mut xv = vec![3.0, 1.0, 1.0];
        let winv = vec![1.0, 1.0, 1.0];
        let x = shared(&mut xv);
        let theta = unsafe { visit_metric(&x, &winv, 0, 1, 2, 0, 0.0) };
        assert!((theta - 1.0 / 3.0).abs() < 1e-12);
        // paper's example update: x_ij -= delta/3, others += delta/3
        assert!((xv[0] - (3.0 - 1.0 / 3.0)).abs() < 1e-12);
        assert!((xv[1] - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
        assert!((xv[2] - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
        // now exactly on the hyperplane
        assert!((xv[0] - xv[1] - xv[2]).abs() < 1e-12);
    }

    #[test]
    fn correction_undone_when_constraint_becomes_satisfied() {
        // After a projection with dual y, if the constraint is now slack
        // the correction step must add y back (Dykstra's memory).
        let mut xv = vec![0.0, 5.0, 5.0]; // hugely satisfied
        let winv = vec![1.0, 1.0, 1.0];
        let x = shared(&mut xv);
        let y = 0.3;
        let theta = unsafe { visit_metric(&x, &winv, 0, 1, 2, 0, y) };
        // corrected point: (0.3, 4.7, 4.7): delta = -9.1 < 0 -> theta = 0
        assert_eq!(theta, 0.0);
        assert!((xv[0] - 0.3).abs() < 1e-12);
        assert!((xv[1] - 4.7).abs() < 1e-12);
        assert!((xv[2] - 4.7).abs() < 1e-12);
    }

    #[test]
    fn weighted_projection_minimizes_w_norm() {
        // With weights, the projection must be the W-norm-least correction:
        // update along W^{-1} a. Verify the constraint lands exactly on the
        // plane and the step direction is proportional to winv.
        let mut xv = vec![2.0, 0.0, 0.0];
        let winv = vec![0.5, 0.25, 1.0]; // w = 2, 4, 1
        let x = shared(&mut xv);
        let theta = unsafe { visit_metric(&x, &winv, 0, 1, 2, 0, 0.0) };
        let s = 0.5 + 0.25 + 1.0;
        assert!((theta - 2.0 / s).abs() < 1e-12);
        assert!((xv[0] - (2.0 - theta * 0.5)).abs() < 1e-12);
        assert!((xv[1] - theta * 0.25).abs() < 1e-12);
        assert!((xv[2] - theta).abs() < 1e-12);
        assert!((xv[0] - xv[1] - xv[2]).abs() < 1e-12);
    }

    #[test]
    fn all_three_types_cover_each_orientation() {
        for t in 0..3 {
            let mut xv = vec![0.0, 0.0, 0.0];
            xv[t] = 3.0; // make variable t the violating "long side"
            let winv = vec![1.0, 1.0, 1.0];
            let x = shared(&mut xv);
            let theta = unsafe { visit_metric(&x, &winv, 0, 1, 2, t, 0.0) };
            assert!(theta > 0.0, "type {t} should project");
            let [s0, s1, s2] = METRIC_SIGNS[t];
            let delta = s0 * xv[0] + s1 * xv[1] + s2 * xv[2];
            assert!(delta.abs() < 1e-12, "type {t} lands on plane");
        }
    }

    #[test]
    fn fused_triplet_matches_three_visits() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(123);
        for _ in 0..2000 {
            let xs: Vec<f64> = (0..3).map(|_| rng.f64_in(-1.5, 2.5)).collect();
            let ws: Vec<f64> = (0..3).map(|_| rng.f64_in(0.3, 3.0)).collect();
            let ys = [
                if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 },
                if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 },
                if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 },
            ];
            let mut a = xs.clone();
            let mut b = xs.clone();
            let (ta, tb);
            {
                let sa = SharedMut::new(a.as_mut_slice());
                let mut t = [0.0; 3];
                for (tt, slot) in t.iter_mut().enumerate() {
                    *slot = unsafe { visit_metric(&sa, &ws, 0, 1, 2, tt, ys[tt]) };
                }
                ta = t;
            }
            {
                let sb = SharedMut::new(b.as_mut_slice());
                tb = unsafe { visit_triplet(&sb, &ws, 0, 1, 2, ys) };
            }
            for k in 0..3 {
                assert!(
                    (a[k] - b[k]).abs() < 1e-9,
                    "x[{k}]: {:.17} vs {:.17}",
                    a[k],
                    b[k]
                );
                assert!((ta[k] - tb[k]).abs() < 1e-9, "theta[{k}]");
            }
        }
    }

    #[test]
    fn fused_triplet_noop_when_feasible() {
        let mut xv = vec![0.5, 1.0, 1.0];
        let winv = vec![1.0, 1.0, 1.0];
        let x = SharedMut::new(xv.as_mut_slice());
        let t = unsafe { visit_triplet(&x, &winv, 0, 1, 2, [0.0; 3]) };
        assert_eq!(t, [0.0; 3]);
        assert_eq!(xv, vec![0.5, 1.0, 1.0]);
    }

    #[test]
    fn pair_upper_projection() {
        // x - f <= d with x=2, f=0, d=1: delta=1, unit w -> theta=1/2
        let mut xv = vec![2.0];
        let mut fv = vec![0.0];
        let winv = vec![1.0];
        let d = vec![1.0];
        let x = shared(&mut xv);
        let f = shared(&mut fv);
        let theta = unsafe { visit_pair_upper(&x, &f, &winv, &d, 0, 0.0) };
        assert!((theta - 0.5).abs() < 1e-12);
        assert!((xv[0] - 1.5).abs() < 1e-12);
        assert!((fv[0] - 0.5).abs() < 1e-12);
        assert!((xv[0] - fv[0] - 1.0).abs() < 1e-12); // on the plane
    }

    #[test]
    fn pair_lower_projection() {
        // -x - f <= -d with x=0, f=0, d=1: delta = 1 -> theta = 1/2
        let mut xv = vec![0.0];
        let mut fv = vec![0.0];
        let winv = vec![1.0];
        let d = vec![1.0];
        let x = shared(&mut xv);
        let f = shared(&mut fv);
        let theta = unsafe { visit_pair_lower(&x, &f, &winv, &d, 0, 0.0) };
        assert!((theta - 0.5).abs() < 1e-12);
        assert!((xv[0] - 0.5).abs() < 1e-12);
        assert!((fv[0] - 0.5).abs() < 1e-12);
        assert!((1.0 - xv[0] - fv[0]).abs() < 1e-12);
    }

    #[test]
    fn box_projection_clamps_via_dual() {
        let mut xv = vec![1.5];
        let winv = vec![2.0]; // w = 0.5
        let x = shared(&mut xv);
        let theta = unsafe { visit_box_upper(&x, &winv, 0, 0.0) };
        assert!((theta - 0.25).abs() < 1e-12); // delta 0.5 / w 2.0
        assert!((xv[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn value_based_pair_visits_match_indexed_bitwise() {
        // The streamed pair phase relies on the _val variants being
        // bitwise interchangeable with the indexed visits — pin it.
        use crate::util::rng::Rng;
        let mut rng = Rng::new(77);
        for _ in 0..2000 {
            let x0 = rng.f64_in(-1.5, 2.5);
            let f0 = rng.f64_in(-1.0, 1.0);
            let w = rng.f64_in(0.3, 3.0);
            let d = rng.f64_in(0.0, 1.0);
            let yu = if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 };
            let yl = if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 };
            let ybx = if rng.bool(0.5) { rng.f64_in(0.0, 0.8) } else { 0.0 };
            let mut xa = vec![x0];
            let mut fa = vec![f0];
            let winv = vec![w];
            let dd = vec![d];
            let (tu_a, tl_a, tb_a);
            {
                let xs = SharedMut::new(xa.as_mut_slice());
                let fs = SharedMut::new(fa.as_mut_slice());
                unsafe {
                    tu_a = visit_pair_upper(&xs, &fs, &winv, &dd, 0, yu);
                    tl_a = visit_pair_lower(&xs, &fs, &winv, &dd, 0, yl);
                    tb_a = visit_box_upper(&xs, &winv, 0, ybx);
                }
            }
            let (mut xb, mut fb) = (x0, f0);
            let tu_b = visit_pair_upper_val(&mut xb, &mut fb, w, d, yu);
            let tl_b = visit_pair_lower_val(&mut xb, &mut fb, w, d, yl);
            let tb_b = visit_box_upper_val(&mut xb, w, ybx);
            assert_eq!(xa[0].to_bits(), xb.to_bits());
            assert_eq!(fa[0].to_bits(), fb.to_bits());
            assert_eq!(tu_a.to_bits(), tu_b.to_bits());
            assert_eq!(tl_a.to_bits(), tl_b.to_bits());
            assert_eq!(tb_a.to_bits(), tb_b.to_bits());
        }
    }

    /// Project the 3-vector `xs` onto metric halfspace `t` (pure
    /// projection: zero incoming dual), returning the new point and
    /// `theta`.
    fn project(xs: &[f64; 3], winv: &[f64; 3], t: usize) -> ([f64; 3], f64) {
        let mut v = xs.to_vec();
        let theta = {
            let x = shared(&mut v);
            unsafe { visit_metric(&x, winv, 0, 1, 2, t, 0.0) }
        };
        ([v[0], v[1], v[2]], theta)
    }

    fn residual(xs: &[f64; 3], t: usize) -> f64 {
        let [s0, s1, s2] = METRIC_SIGNS[t];
        s0 * xs[0] + s1 * xs[1] + s2 * xs[2]
    }

    /// Squared W-norm of a difference (`w = 1/winv`; the inner product
    /// the projection is taken in).
    fn w_dist_sq(a: &[f64; 3], b: &[f64; 3], winv: &[f64; 3]) -> f64 {
        (0..3).map(|k| (a[k] - b[k]).powi(2) / winv[k]).sum()
    }

    #[test]
    fn projection_is_feasible_and_idempotent() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        check("proj_feas_idem", 0x9e01, 128, |rng, case| {
            let t = case % 3;
            let xs = [
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
            ];
            let winv =
                [rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0)];
            let (p, theta) = project(&xs, &winv, t);
            // Feasibility: one visit lands on or inside the halfspace.
            prop_assert!(
                residual(&p, t) <= 1e-9,
                "t={t} residual {} after projection",
                residual(&p, t)
            );
            prop_assert!(theta >= 0.0, "negative dual {theta}");
            // Idempotence: projecting the projected point is a no-op up
            // to roundoff of the (now ~0) residual.
            let (pp, theta2) = project(&p, &winv, t);
            prop_assert!(theta2 <= 1e-12, "second projection moved: theta {theta2}");
            for k in 0..3 {
                prop_assert!((pp[k] - p[k]).abs() <= 1e-11, "idempotence at {k}");
            }
            Ok(())
        });
    }

    #[test]
    fn projection_is_nonexpansive_in_w_norm() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        // ||P(a) - P(b)||_W <= ||a - b||_W — the defining property of a
        // projection in the W-inner product, and the reason Dykstra
        // converges at all. Checked across random pairs, weights, and
        // all three constraint orientations.
        check("proj_nonexpansive", 0x9e02, 128, |rng, case| {
            let t = case % 3;
            let a = [
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
            ];
            let b = [
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
                rng.f64_in(-2.5, 2.5),
            ];
            let winv =
                [rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0)];
            let (pa, _) = project(&a, &winv, t);
            let (pb, _) = project(&b, &winv, t);
            let before = w_dist_sq(&a, &b, &winv);
            let after = w_dist_sq(&pa, &pb, &winv);
            prop_assert!(
                after <= before * (1.0 + 1e-12) + 1e-12,
                "t={t} expanded: {after} > {before}"
            );
            Ok(())
        });
    }

    #[test]
    fn zero_inverse_weight_freezes_the_coordinate() {
        // winv = 0 is the w -> infinity limit: an immovable entry. The
        // projection must leave it bitwise untouched and still land on
        // the constraint plane by moving only the free coordinates.
        let winv = [0.0, 1.0, 1.0];
        let xs = [3.0, 0.5, 0.5]; // residual 2 for t = 0
        let (p, theta) = project(&xs, &winv, 0);
        assert_eq!(p[0].to_bits(), xs[0].to_bits(), "frozen coordinate moved");
        assert!((theta - 1.0).abs() < 1e-12, "theta = delta / (0+1+1), got {theta}");
        assert!(residual(&p, 0).abs() < 1e-12, "not on the plane: {}", residual(&p, 0));
    }

    #[test]
    fn exactly_tight_constraint_is_a_bitwise_noop() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        // A point exactly on the plane (residual == 0.0) must produce
        // theta == 0 and no store at all — the same contract the
        // screened sweep's skip path relies on for feasible triplets.
        check("proj_tight_noop", 0x9e03, 64, |rng, case| {
            let t = case % 3;
            // Dyadic draws (multiples of 1/8, small magnitude) keep every
            // sum below exact, so the constructed point sits on the plane
            // with residual exactly 0.0, not merely near it.
            let dyadic = |rng: &mut crate::util::rng::Rng| {
                (rng.usize_in(0, 33) as f64 - 16.0) / 8.0
            };
            let free = [dyadic(rng), dyadic(rng)];
            // Solve the plane equation for the t-th coordinate.
            let mut xs = [0.0; 3];
            let (a, b) = ((t + 1) % 3, (t + 2) % 3);
            xs[a] = free[0];
            xs[b] = free[1];
            xs[t] = free[0] + free[1]; // s_t*x_t = x_a + x_b -> residual 0
            prop_assert!(residual(&xs, t) == 0.0, "dyadic construction not exact");
            let winv =
                [rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0)];
            let (p, theta) = project(&xs, &winv, t);
            prop_assert!(theta == 0.0, "tight constraint produced dual {theta}");
            for k in 0..3 {
                prop_assert!(
                    p[k].to_bits() == xs[k].to_bits(),
                    "tight visit wrote to x[{k}]"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn fused_triplet_output_is_metric_feasible() {
        use crate::prop_assert;
        use crate::util::proptest::check;
        // Repeated fused visits (with Dykstra memory) must drive any
        // random triple to a point satisfying all three inequalities —
        // convergence of cyclic Dykstra on one triplet's constraint set.
        check("triplet_converges", 0x9e04, 48, |rng, _case| {
            let mut v = vec![
                rng.f64_in(-2.0, 4.0),
                rng.f64_in(-2.0, 4.0),
                rng.f64_in(-2.0, 4.0),
            ];
            let winv =
                vec![rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0), rng.f64_in(0.2, 5.0)];
            let mut y = [0.0; 3];
            for _ in 0..400 {
                let x = shared(&mut v);
                y = unsafe { visit_triplet(&x, &winv, 0, 1, 2, y) };
            }
            for t in 0..3 {
                let r = residual(&[v[0], v[1], v[2]], t);
                prop_assert!(r <= 1e-7, "constraint {t} violated by {r} after 400 visits");
            }
            Ok(())
        });
    }

    #[test]
    fn dykstra_two_halfspace_convergence() {
        // Classic sanity check: alternating Dykstra visits to two
        // constraints converge to the projection onto the intersection.
        // Constraints (on a 3-vector, unit weights):
        //   A: x0 - x1 - x2 <= 0   (metric type 0)
        //   B: x0 <= 1             (box)
        // Start x = (3, 0.5, 0.5). True projection onto {A ∩ B}:
        // project onto A: (3-δ/3, .5+δ/3, .5+δ/3), δ=2 → (2.333,1.166,1.166)
        // that violates B. The intersection projection solves a small QP;
        // verify instead: final point feasible AND fixed point of both
        // projections AND closer to start than naive sequential projection.
        let winv = vec![1.0, 1.0, 1.0];
        let mut xv = vec![3.0, 0.5, 0.5];
        let (mut ya, mut yb) = (0.0, 0.0);
        for _ in 0..500 {
            let x = SharedMut::new(xv.as_mut_slice());
            ya = unsafe { visit_metric(&x, &winv, 0, 1, 2, 0, ya) };
            yb = unsafe { visit_box_upper(&x, &winv, 0, yb) };
        }
        assert!(xv[0] <= 1.0 + 1e-9);
        assert!(xv[0] - xv[1] - xv[2] <= 1e-9);
        // Optimality via KKT: x - x_start = -ya*a_A - yb*a_B with ya,yb >= 0.
        assert!(ya >= 0.0 && yb >= 0.0);
        let dx = [xv[0] - 3.0, xv[1] - 0.5, xv[2] - 0.5];
        assert!((dx[0] - (-ya - yb)).abs() < 1e-6, "dx0={} ya={} yb={}", dx[0], ya, yb);
        assert!((dx[1] - ya).abs() < 1e-6);
        assert!((dx[2] - ya).abs() < 1e-6);
    }
}
