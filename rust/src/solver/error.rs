//! Typed solve failures.
//!
//! Every traced driver entry point returns `Result<_, SolveError>` so
//! embedders (and the CLI's recovery harness, [`super::recover`]) can
//! tell *why* a solve unwound and react mechanically: a [`Store`] error
//! carries the last-good checkpoint to resume from, an [`Interrupted`]
//! unwind is a clean exit (the work is checkpointed, not lost), a
//! [`Watchdog`] trip carries a structured diagnostic dump. The plain
//! `solve`/`resume` wrappers keep their `anyhow::Result` signatures —
//! `SolveError` implements `std::error::Error`, so `?` converts.
//!
//! [`Store`]: SolveError::Store
//! [`Interrupted`]: SolveError::Interrupted
//! [`Watchdog`]: SolveError::Watchdog

use crate::matrix::store::StoreError;
use std::fmt;
use std::path::PathBuf;

/// Why a solve unwound before producing a [`super::Solution`].
#[derive(Debug)]
pub enum SolveError {
    /// The tile store failed permanently (retry budget exhausted, or a
    /// non-retryable fault like `ENOSPC`).
    Store {
        /// The store failure that ended the solve.
        error: StoreError,
        /// The most recent checkpoint known to be consistent, if any —
        /// what a `--resume` (or the auto-recovery harness) starts from.
        last_good_checkpoint: Option<PathBuf>,
    },
    /// The interrupt flag was raised and `--on-interrupt checkpoint`
    /// finished the pass, checkpointed, and unwound cleanly.
    Interrupted {
        /// Passes completed before the interrupt was honored.
        pass: usize,
        /// Whether a checkpoint was emitted through the run's sink (it
        /// is whenever periodic checkpointing is configured).
        checkpointed: bool,
    },
    /// The watchdog detected a stall or NaN/∞ divergence.
    Watchdog {
        /// Pass at which the watchdog tripped.
        pass: usize,
        /// Structured diagnostic dump (JSON lines; the CLI writes it to
        /// `--watchdog-dump`).
        report: String,
    },
    /// Any other failure (setup, instance mismatch, checkpoint I/O...),
    /// carried through from the pre-existing `anyhow` paths.
    Other(anyhow::Error),
}

impl fmt::Display for SolveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SolveError::Store { error, last_good_checkpoint } => match last_good_checkpoint {
                Some(p) => write!(
                    f,
                    "store failure: {error} (last good checkpoint: {})",
                    p.display()
                ),
                None => write!(f, "store failure: {error} (no checkpoint to resume from)"),
            },
            SolveError::Interrupted { pass, checkpointed } => write!(
                f,
                "interrupted after pass {pass} ({})",
                if *checkpointed { "state checkpointed" } else { "no checkpoint configured" }
            ),
            SolveError::Watchdog { pass, .. } => {
                write!(f, "watchdog tripped at pass {pass} (stall or divergence)")
            }
            SolveError::Other(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SolveError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SolveError::Store { error, .. } => Some(error),
            _ => None,
        }
    }
}

impl From<anyhow::Error> for SolveError {
    fn from(e: anyhow::Error) -> SolveError {
        SolveError::Other(e)
    }
}

impl From<StoreError> for SolveError {
    fn from(error: StoreError) -> SolveError {
        SolveError::Store { error, last_good_checkpoint: None }
    }
}

impl From<super::checkpoint::CheckpointError> for SolveError {
    fn from(e: super::checkpoint::CheckpointError) -> SolveError {
        SolveError::Other(anyhow::Error::from(e))
    }
}

impl SolveError {
    /// Attach the last-good checkpoint path to a store failure (no-op
    /// for every other variant). Drivers return store failures bare;
    /// the layer that knows where checkpoints were written (the CLI /
    /// recovery harness) fills this in.
    pub fn with_checkpoint(self, path: Option<PathBuf>) -> SolveError {
        match self {
            SolveError::Store { error, last_good_checkpoint: None } => {
                SolveError::Store { error, last_good_checkpoint: path }
            }
            other => other,
        }
    }

    /// True for store failures — the recoverable class the auto-resume
    /// harness retries.
    pub fn is_store(&self) -> bool {
        matches!(self, SolveError::Store { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_checkpoint() {
        let e = SolveError::from(StoreError::BadMagic)
            .with_checkpoint(Some(PathBuf::from("/tmp/ck.bin")));
        let s = e.to_string();
        assert!(s.contains("bad magic"), "got {s}");
        assert!(s.contains("/tmp/ck.bin"), "got {s}");
        assert!(e.is_store());
    }

    #[test]
    fn with_checkpoint_never_overwrites_or_leaks() {
        let e = SolveError::from(StoreError::BadMagic)
            .with_checkpoint(Some(PathBuf::from("a")))
            .with_checkpoint(Some(PathBuf::from("b")));
        match e {
            SolveError::Store { last_good_checkpoint, .. } => {
                assert_eq!(last_good_checkpoint, Some(PathBuf::from("a")));
            }
            other => panic!("wrong variant: {other}"),
        }
        let i = SolveError::Interrupted { pass: 3, checkpointed: true }
            .with_checkpoint(Some(PathBuf::from("a")));
        assert!(matches!(i, SolveError::Interrupted { .. }));
        assert!(!i.is_store());
    }

    #[test]
    fn converts_both_ways_with_anyhow() {
        let from_anyhow: SolveError = anyhow::anyhow!("setup failed").into();
        assert_eq!(from_anyhow.to_string(), "setup failed");
        // std::error::Error impl -> anyhow's blanket From applies.
        let back: anyhow::Error = SolveError::Interrupted { pass: 1, checkpointed: false }.into();
        assert!(back.to_string().contains("interrupted after pass 1"));
    }
}
