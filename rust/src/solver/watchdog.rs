//! Stall and divergence watchdog for long out-of-core solves.
//!
//! A multi-hour disk-backed solve can fail in two quiet ways that a
//! store-error latch never sees: the iterate drifts to NaN/∞ (a logic or
//! data bug — every further pass is wasted heat), or the residual stops
//! improving for a long stretch (a stall: bad tolerance, cycling active
//! set, or corrupted-but-checksum-valid input). The [`Watchdog`] sits in
//! every traced driver's per-pass residual check and trips a
//! [`SolveError::Watchdog`] carrying a structured diagnostic dump —
//! JSON lines the CLI writes to `--watchdog-dump` — instead of letting
//! the run spin forever or print `NaN` at the end.
//!
//! Divergence detection is always on (a non-finite residual is never
//! legitimate). Stall detection is opt-in via
//! [`SolveOpts::watchdog_stall`](super::SolveOpts::watchdog_stall): `0`
//! disables it, `K` trips after `K` consecutive residual observations
//! with no improvement of the best-seen max violation. Observations
//! happen at the driver's existing residual cadence (`check_every`), so
//! `K` is measured in *checks*, not passes.

use super::checkpoint::CheckRecord;
use super::error::SolveError;
use std::fmt::Write as _;

/// How many trailing convergence-history records the dump keeps.
const DUMP_HISTORY: usize = 16;

/// Per-solve stall/divergence monitor. Create one per traced solve and
/// feed it every residual observation; it returns `Err` when the run
/// should be aborted with a diagnostic dump.
#[derive(Debug)]
pub struct Watchdog {
    /// Consecutive non-improving checks tolerated before a stall trips;
    /// `0` disables stall detection.
    stall_checks: usize,
    /// Best (smallest) max violation seen so far.
    best: f64,
    /// Residual checks since `best` last improved.
    since_best: usize,
}

impl Watchdog {
    /// A watchdog that trips a stall after `stall_checks` non-improving
    /// residual checks (`0` = divergence detection only).
    pub fn new(stall_checks: usize) -> Watchdog {
        Watchdog { stall_checks, best: f64::INFINITY, since_best: 0 }
    }

    /// Record one residual observation. `history` is the driver's
    /// convergence history (used only to enrich the dump).
    pub fn observe(
        &mut self,
        pass: usize,
        max_violation: f64,
        rel_gap: f64,
        history: &[CheckRecord],
    ) -> Result<(), SolveError> {
        if !max_violation.is_finite() || !rel_gap.is_finite() {
            return Err(self.trip("divergence", pass, max_violation, rel_gap, history));
        }
        if max_violation < self.best {
            self.best = max_violation;
            self.since_best = 0;
        } else {
            self.since_best += 1;
            if self.stall_checks > 0 && self.since_best >= self.stall_checks {
                return Err(self.trip("stall", pass, max_violation, rel_gap, history));
            }
        }
        Ok(())
    }

    fn trip(
        &self,
        kind: &str,
        pass: usize,
        max_violation: f64,
        rel_gap: f64,
        history: &[CheckRecord],
    ) -> SolveError {
        let mut report = String::new();
        let _ = writeln!(
            report,
            "{{\"event\":\"watchdog\",\"kind\":\"{kind}\",\"pass\":{pass},\
             \"max_violation\":{},\"rel_gap\":{},\"best_seen\":{},\
             \"checks_since_best\":{},\"stall_budget\":{}}}",
            json_f64(max_violation),
            json_f64(rel_gap),
            json_f64(self.best),
            self.since_best,
            self.stall_checks,
        );
        let tail = history.len().saturating_sub(DUMP_HISTORY);
        for rec in &history[tail..] {
            let _ = writeln!(
                report,
                "{{\"event\":\"watchdog_history\",\"pass\":{},\
                 \"max_violation\":{},\"rel_gap\":{}}}",
                rec.pass,
                json_f64(rec.max_violation),
                json_f64(rec.rel_gap),
            );
        }
        SolveError::Watchdog { pass, report }
    }
}

/// Render an `f64` as a JSON value. NaN/∞ are not representable as JSON
/// numbers, so they are quoted — which is exactly the divergence case
/// the dump exists to describe.
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        format!("\"{x}\"")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pass: u64, v: f64) -> CheckRecord {
        CheckRecord { pass, max_violation: v, rel_gap: v / 2.0 }
    }

    #[test]
    fn divergence_always_trips_even_with_stall_disabled() {
        let mut dog = Watchdog::new(0);
        dog.observe(1, 0.5, 0.1, &[]).expect("finite is fine");
        let err = dog.observe(2, f64::NAN, 0.1, &[rec(1, 0.5)]).unwrap_err();
        match err {
            SolveError::Watchdog { pass, report } => {
                assert_eq!(pass, 2);
                assert!(report.contains("\"kind\":\"divergence\""), "got {report}");
                assert!(report.contains("\"NaN\""), "NaN must be quoted: {report}");
                assert!(report.contains("watchdog_history"), "got {report}");
            }
            other => panic!("wrong variant: {other}"),
        }
        let mut dog = Watchdog::new(0);
        assert!(dog.observe(1, 0.5, f64::INFINITY, &[]).is_err());
    }

    #[test]
    fn stall_trips_after_budget_and_improvement_resets_it() {
        let mut dog = Watchdog::new(3);
        dog.observe(1, 1.0, 0.0, &[]).expect("first check sets best");
        dog.observe(2, 1.0, 0.0, &[]).expect("1 flat check");
        dog.observe(3, 2.0, 0.0, &[]).expect("2 flat checks");
        dog.observe(4, 0.5, 0.0, &[]).expect("improvement resets the count");
        dog.observe(5, 0.5, 0.0, &[]).expect("1 flat");
        dog.observe(6, 0.5, 0.0, &[]).expect("2 flat");
        let err = dog.observe(7, 0.5, 0.0, &[]).unwrap_err();
        match err {
            SolveError::Watchdog { pass: 7, report } => {
                assert!(report.contains("\"kind\":\"stall\""), "got {report}");
                assert!(report.contains("\"best_seen\":0.5"), "got {report}");
            }
            other => panic!("wrong variant: {other}"),
        }
    }

    #[test]
    fn stall_disabled_never_trips_on_flat_residuals() {
        let mut dog = Watchdog::new(0);
        for pass in 0..1000 {
            dog.observe(pass, 1.0, 0.5, &[]).expect("flat but finite");
        }
    }

    #[test]
    fn dump_keeps_only_the_trailing_history() {
        let mut dog = Watchdog::new(1);
        let history: Vec<CheckRecord> = (0..40).map(|p| rec(p, 1.0)).collect();
        dog.observe(0, 1.0, 0.0, &history).expect("sets best");
        let err = dog.observe(1, 1.0, 0.0, &history).unwrap_err();
        let report = match err {
            SolveError::Watchdog { report, .. } => report,
            other => panic!("wrong variant: {other}"),
        };
        let lines = report.lines().count();
        assert_eq!(lines, 1 + DUMP_HISTORY, "header + {DUMP_HISTORY} history lines");
        assert!(report.contains("\"pass\":39"), "keeps the newest records: {report}");
        assert!(!report.contains("\"pass\":10,"), "drops the oldest: {report}");
    }
}
