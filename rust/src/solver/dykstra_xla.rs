//! Hybrid solver: the coordinator (L3) driving the AOT-compiled JAX/Pallas
//! projection kernel (L1/L2) through PJRT.
//!
//! The thread-oriented wave schedule cannot feed a batched kernel directly:
//! triplets *within* one tile share variables (every triplet of `S_{i,k}`
//! contains the pair `(i, k)`), and only the sequential per-worker visit
//! makes that safe. Batched lanes must be pairwise independent, so this
//! solver uses the [`schedule_delta::BatchSchedule`] decomposition instead:
//! delta classes `(i, i+a, i+a+b)` are conflict-free and pack into large
//! flat batches. Dykstra converges under any fixed constraint order, so
//! this is again "simply a re-ordering" (§III-A).
//!
//! Dual variables for this path are stored densely per triplet
//! (`3·C(n,3)` f32), which caps practical n at a few hundred — fine for
//! its purpose: an end-to-end proof that L3/L2/L1 compose, and the engine
//! ablation bench. Production runs use the scalar CPU engine with sparse
//! per-worker dual stores.

use super::error::SolveError;
use super::schedule_delta::BatchSchedule;
use super::termination::compute_residuals;
use super::watchdog::Watchdog;
use super::{CcState, OnInterrupt, Residuals, Solution, SolveOpts};
use crate::instance::CcLpInstance;
use crate::runtime::engine::XlaEngine;
use crate::telemetry::{Counters, Event, NullRecorder, PassKind, PhaseName, PhaseProbe, Recorder};
use anyhow::Result;

/// Lexicographic rank of triplet (i, j, k) among all i<j<k over n nodes.
/// O(1) via prefix tables; used to index the dense dual array.
pub struct TripletRank {
    /// a_prefix[i] = #triplets with first index < i.
    a_prefix: Vec<u64>,
    /// p_prefix[b] = sum_{b' < b} (n - 1 - b').
    p_prefix: Vec<u64>,
}

impl TripletRank {
    pub fn new(n: usize) -> TripletRank {
        let mut a_prefix = vec![0u64; n + 1];
        for i in 0..n {
            let rem = (n - 1 - i) as u64; // choices of (j,k) above i: C(rem,2)
            a_prefix[i + 1] = a_prefix[i] + rem * rem.saturating_sub(1) / 2;
        }
        let mut p_prefix = vec![0u64; n + 1];
        for b in 0..n {
            p_prefix[b + 1] = p_prefix[b] + (n - 1 - b) as u64;
        }
        TripletRank { a_prefix, p_prefix }
    }

    /// Rank of (i, j, k), i < j < k.
    #[inline]
    pub fn rank(&self, i: usize, j: usize, k: usize) -> u64 {
        self.a_prefix[i] + (self.p_prefix[j] - self.p_prefix[i + 1]) + (k - j - 1) as u64
    }
}

/// Solve the CC-LP instance through the PJRT engine. Full strategy only —
/// `Strategy::Active` callers must use [`super::dykstra_parallel::solve`].
pub fn solve(inst: &CcLpInstance, opts: &SolveOpts, engine: &XlaEngine) -> Result<Solution> {
    Ok(solve_traced(inst, opts, engine, &NullRecorder)?)
}

/// [`solve`] with a telemetry [`Recorder`] attached. All instrumentation
/// is gated on [`Recorder::enabled`]; the engine path is single-threaded
/// on the host side, so phase events carry no per-worker busy timings.
///
/// This is the typed-error boundary: interrupts and watchdog trips come
/// back as the matching [`SolveError`] variant. This driver has no
/// checkpoint sink, so an interrupt unwind never reports saved state.
pub fn solve_traced(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    engine: &XlaEngine,
    rec: &dyn Recorder,
) -> std::result::Result<Solution, SolveError> {
    if opts.strategy.is_active() {
        return Err(anyhow::anyhow!(
            "the XLA engine runs the full strategy only; use dykstra_parallel::solve for active"
        )
        .into());
    }
    let n = inst.n;
    let schedule = BatchSchedule::new(n, crate::runtime::engine::PROJECT_BATCHES[2]);
    let rank = TripletRank::new(n);
    let n_triplets = super::schedule::n_triplets(n) as usize;
    if n_triplets * 3 > 200_000_000 {
        return Err(anyhow::anyhow!(
            "XLA engine path caps at ~n=800 (dense duals); use the CPU engine"
        )
        .into());
    }
    let mut state = CcState::new(inst, opts.gamma, opts.include_box);
    // Dense metric duals, 3 per triplet, f32 (artifact dtype).
    let mut metric_duals = vec![0.0f32; n_triplets * 3];
    // f32 mirrors of the pair-phase state.
    let m = state.x.len();
    let winv32: Vec<f32> = state.winv.iter().map(|&v| v as f32).collect();
    let d32: Vec<f32> = state.d.iter().map(|&v| v as f32).collect();

    let mut pass_times = Vec::new();
    let mut residuals = Residuals::default();
    let mut passes_done = 0;
    // passes_done at which `residuals` was measured (MAX = never).
    let mut measured_at = usize::MAX;

    // Reused gather buffers.
    let mut lanes: Vec<(usize, usize, usize, u64)> = Vec::new();
    let mut x3: Vec<f32> = Vec::new();
    let mut w3: Vec<f32> = Vec::new();
    let mut y3: Vec<f32> = Vec::new();

    let mut probe = PhaseProbe::new(rec, 1);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);
    for pass in 0..opts.max_passes {
        let t0 = std::time::Instant::now();
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });
        let pt = probe.start();
        for batch in schedule.batches() {
            // Gather the batch (lanes are pairwise variable-disjoint).
            lanes.clear();
            x3.clear();
            w3.clear();
            y3.clear();
            for &(i, j, k) in batch {
                let (i, j, k) = (i as usize, j as usize, k as usize);
                let pij = state.pidx(i, j);
                let pik = state.pidx(i, k);
                let pjk = state.pidx(j, k);
                let r = rank.rank(i, j, k);
                lanes.push((pij, pik, pjk, r));
                x3.extend_from_slice(&[
                    state.x[pij] as f32,
                    state.x[pik] as f32,
                    state.x[pjk] as f32,
                ]);
                w3.extend_from_slice(&[
                    state.winv[pij] as f32,
                    state.winv[pik] as f32,
                    state.winv[pjk] as f32,
                ]);
                let db = r as usize * 3;
                y3.extend_from_slice(&metric_duals[db..db + 3]);
            }
            if lanes.is_empty() {
                continue;
            }
            engine.project_batch(&mut x3, &w3, &mut y3)?;
            // Scatter back.
            for (lane, &(pij, pik, pjk, r)) in lanes.iter().enumerate() {
                let b = lane * 3;
                state.x[pij] = x3[b] as f64;
                state.x[pik] = x3[b + 1] as f64;
                state.x[pjk] = x3[b + 2] as f64;
                let db = r as usize * 3;
                metric_duals[db..db + 3].copy_from_slice(&y3[b..b + 3]);
            }
        }
        probe.finish(pass_no, PhaseName::Metric, pt, n_triplets as u64, None);
        // Pair phase through the pair artifact.
        {
            let pt = probe.start();
            let mut x32: Vec<f32> = state.x.iter().map(|&v| v as f32).collect();
            let mut f32v: Vec<f32> = state.f.iter().map(|&v| v as f32).collect();
            let mut yu: Vec<f32> = state.y_upper.iter().map(|&v| v as f32).collect();
            let mut yl: Vec<f32> = state.y_lower.iter().map(|&v| v as f32).collect();
            let mut yb: Vec<f32> = state.y_box.iter().map(|&v| v as f32).collect();
            engine.pair_sweep(&mut x32, &mut f32v, &winv32, &d32, &mut yu, &mut yl, &mut yb)?;
            for e in 0..m {
                state.x[e] = x32[e] as f64;
                state.f[e] = f32v[e] as f64;
                state.y_upper[e] = yu[e] as f64;
                state.y_lower[e] = yl[e] as f64;
                state.y_box[e] = yb[e] as f64;
            }
            probe.finish(pass_no, PhaseName::Pair, pt, m as u64, None);
        }
        passes_done = pass + 1;
        if opts.track_pass_times {
            pass_times.push(t0.elapsed().as_secs_f64());
        }
        let mut stop = false;
        if opts.check_every > 0 && passes_done % opts.check_every == 0 {
            let pt = probe.start();
            residuals = compute_residuals(&state, opts.threads.max(1));
            residuals.stamp_work(passes_done as u64 * n_triplets as u64, n_triplets);
            probe.finish(pass_no, PhaseName::ResidualScan, pt, n_triplets as u64, None);
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                lp_objective: residuals.lp_objective,
                exact: true,
            });
            measured_at = passes_done;
            watchdog.observe(passes_done, residuals.max_violation, residuals.rel_gap, &[])?;
            if residuals.max_violation <= opts.tol_violation
                && residuals.rel_gap.abs() <= opts.tol_gap
            {
                stop = true;
            }
        }
        if probe.on() {
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t0.elapsed().as_secs_f64(),
                triplet_visits: passes_done as u64 * n_triplets as u64,
                active_triplets: n_triplets as u64,
            });
        }
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted() {
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed: false });
        }
        if stop {
            break;
        }
    }
    // Re-measure unless the last checkpoint already measured the final
    // iterate — reported residuals always describe the returned x.
    if measured_at != passes_done {
        let pt = probe.start();
        residuals = compute_residuals(&state, opts.threads.max(1));
        residuals.stamp_work(passes_done as u64 * n_triplets as u64, n_triplets);
        probe.finish(passes_done as u64, PhaseName::ResidualScan, pt, n_triplets as u64, None);
        probe.emit(Event::Residuals {
            pass: passes_done as u64,
            max_violation: residuals.max_violation,
            rel_gap: residuals.rel_gap,
            lp_objective: residuals.lp_objective,
            exact: true,
        });
    }
    let nnz = metric_duals.iter().filter(|&&y| y != 0.0).count();
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: passes_done as u64 * n_triplets as u64 * 3,
                active_triplets: n_triplets as u64,
                sweep_screened: 0,
                sweep_projected: 0,
                nnz_duals: nnz as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: None,
            },
        });
    }
    Ok(Solution {
        x: state.x_matrix(),
        f: Some(state.f_matrix()),
        passes: passes_done,
        residuals,
        pass_times,
        nnz_duals: nnz,
        metric_visits: passes_done as u64 * n_triplets as u64 * 3,
        active_triplets: n_triplets,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::dykstra_parallel;

    #[test]
    fn triplet_rank_is_lex_order() {
        for n in [3usize, 5, 9, 20] {
            let r = TripletRank::new(n);
            let mut expect = 0u64;
            for i in 0..n {
                for j in (i + 1)..n {
                    for k in (j + 1)..n {
                        assert_eq!(r.rank(i, j, k), expect, "({i},{j},{k}) n={n}");
                        expect += 1;
                    }
                }
            }
            assert_eq!(expect, super::super::schedule::n_triplets(n));
        }
    }

    fn engine() -> Option<XlaEngine> {
        if !std::path::Path::new("artifacts/project_b1024.hlo.txt").exists() {
            crate::telemetry::warn("skipping: run `make artifacts` first");
            return None;
        }
        Some(XlaEngine::load("artifacts").unwrap())
    }

    #[test]
    fn xla_solver_tracks_cpu_solver() {
        // Different constraint orders (delta batches vs tiled waves) take
        // different trajectories but converge to the SAME unique QP
        // optimum; compare at convergence with f32-appropriate tolerance.
        let Some(eng) = engine() else { return };
        let inst = CcLpInstance::random(12, 0.5, 0.8, 1.6, 13);
        let opts = SolveOpts { max_passes: 300, threads: 2, tile: 3, ..Default::default() };
        let cpu = dykstra_parallel::solve(&inst, &opts);
        let xla = solve(&inst, &opts, &eng).unwrap();
        let mut worst: f64 = 0.0;
        for (i, j, v) in xla.x.iter_pairs() {
            worst = worst.max((v - cpu.x.get(i, j)).abs());
        }
        assert!(worst < 2e-2, "xla vs cpu engines diverged: {worst}");
    }

    #[test]
    fn xla_solver_converges() {
        let Some(eng) = engine() else { return };
        let inst = CcLpInstance::random(10, 0.5, 0.8, 1.6, 29);
        let opts = SolveOpts { max_passes: 200, tile: 4, ..Default::default() };
        let sol = solve(&inst, &opts, &eng).unwrap();
        // f32 duals floor the achievable violation around 1e-3.
        assert!(
            sol.residuals.max_violation < 1e-2,
            "violation {}",
            sol.residuals.max_violation
        );
    }
}
