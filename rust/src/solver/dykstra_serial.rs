//! Serial Dykstra baseline — the method of [37] that the paper's 1-core
//! rows in Table I measure. Constraints are visited in the standard
//! lexicographic triplet order with a single sparse dual array, then the
//! pair (and optional box) constraints per pair.

use super::checkpoint::{CheckRecord, SolverState};
use super::duals::DualStore;
use super::dykstra_parallel::run_pair_phase;
use super::error::SolveError;
use super::termination::compute_residuals;
use super::watchdog::Watchdog;
use super::{CcState, OnInterrupt, Residuals, Solution, SolveOpts};
use crate::instance::CcLpInstance;
use crate::telemetry::{Counters, Event, NullRecorder, PassKind, PhaseName, PhaseProbe, Recorder};
use crate::util::shared::SharedMut;

/// Solve the CC-LP instance with serial Dykstra. Full strategy only —
/// the active set requires the wave schedule, so `Strategy::Active`
/// callers must use [`super::dykstra_parallel::solve`].
pub fn solve(inst: &CcLpInstance, opts: &SolveOpts) -> Solution {
    solve_checkpointed(inst, opts, None, &mut |_| {})
        .expect("cold serial solve cannot fail")
}

/// Continue a previously saved serial solve from its checkpoint. With
/// unchanged options this reproduces the uninterrupted run bitwise.
pub fn resume(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    state: &SolverState,
) -> anyhow::Result<Solution> {
    solve_checkpointed(inst, opts, Some(state), &mut |_| {})
}

/// Full-control entry point: optionally resume from a saved state and
/// receive a [`SolverState`] through `on_checkpoint` every
/// [`SolveOpts::checkpoint_every`] passes (plus one for the final
/// state).
pub fn solve_checkpointed(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<Solution> {
    Ok(solve_traced(inst, opts, resume_from, on_checkpoint, &NullRecorder)?)
}

/// [`solve_checkpointed`] with a telemetry [`Recorder`] attached. All
/// instrumentation is gated on [`Recorder::enabled`], so passing
/// [`NullRecorder`] reproduces the untraced solve bitwise (pinned by
/// `tests/telemetry.rs`). Serial phases report no per-worker busy
/// timings (the `workers` array of each phase event is empty).
///
/// This is the typed-error boundary: interrupts and watchdog trips come
/// back as the matching [`SolveError`] variant (this driver is
/// memory-resident, so store failures cannot occur).
pub fn solve_traced(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<Solution, SolveError> {
    assert!(
        !opts.strategy.is_active(),
        "dykstra_serial runs the full strategy only; use dykstra_parallel::solve for Strategy::Active"
    );
    if resume_from.is_some_and(|st| st.x_external) {
        return Err(anyhow::anyhow!(
            "checkpoint references an external x store; resume through the parallel \
             driver's disk backend (dykstra_parallel::solve_stored / --store disk)"
        )
        .into());
    }
    let mut state = match resume_from {
        Some(st) => {
            st.validate_cc(inst, opts)?;
            st.restore_cc_state(inst, opts)
        }
        None => CcState::new(inst, opts.gamma, opts.include_box),
    };
    let mut store = DualStore::new();
    if let Some(st) = resume_from {
        // The serial visit order is lexicographic, which IS key order.
        store.restore(st.metric_duals.clone());
    }
    let start_pass = resume_from.map_or(0, |st| st.pass as usize);
    let mut history: Vec<CheckRecord> =
        resume_from.map(|st| st.history.clone()).unwrap_or_default();
    let triplets_per_pass = super::schedule::n_triplets(inst.n);
    // Cumulative work, carried across resumes (an active-strategy
    // checkpoint's cheap passes keep their true cost).
    let mut triplet_visits: u64 = resume_from.map_or(0, |st| st.triplet_visits);
    let mut pass_times = Vec::new();
    let mut residuals = Residuals::default();
    let mut passes_done = start_pass;
    // passes_done at which `residuals` was measured (MAX = never).
    let mut measured_at = usize::MAX;
    let mut last_saved = usize::MAX;
    let pairs_per_pass = (inst.n * (inst.n - 1) / 2) as u64;
    let mut probe = PhaseProbe::new(rec, 1);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);

    for pass in start_pass..opts.max_passes {
        let t0 = std::time::Instant::now();
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });
        let pt = probe.start();
        run_metric_lex(&mut state, &mut store);
        probe.finish(pass_no, PhaseName::Metric, pt, triplets_per_pass, None);
        let pt = probe.start();
        run_pair_phase(&mut state, 1);
        probe.finish(pass_no, PhaseName::Pair, pt, pairs_per_pass, None);
        passes_done = pass + 1;
        triplet_visits += triplets_per_pass;
        if opts.track_pass_times {
            pass_times.push(t0.elapsed().as_secs_f64());
        }
        let mut stop = false;
        if opts.check_every > 0 && passes_done % opts.check_every == 0 {
            let pt = probe.start();
            residuals = compute_residuals(&state, 1);
            residuals.stamp_work(triplet_visits, triplets_per_pass as usize);
            probe.finish(pass_no, PhaseName::ResidualScan, pt, triplets_per_pass, None);
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                lp_objective: residuals.lp_objective,
                exact: true,
            });
            measured_at = passes_done;
            history.push(CheckRecord {
                pass: passes_done as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
            });
            watchdog.observe(passes_done, residuals.max_violation, residuals.rel_gap, &history)?;
            if residuals.max_violation <= opts.tol_violation
                && residuals.rel_gap.abs() <= opts.tol_gap
            {
                stop = true;
            }
        }
        if opts.checkpoint_every > 0 && (passes_done % opts.checkpoint_every == 0 || stop) {
            let pt = probe.start();
            let duals = store.iter_next().collect();
            on_checkpoint(&SolverState::capture_cc_full(
                &state,
                &state.x,
                duals,
                passes_done,
                triplet_visits,
                &history,
            ));
            probe.finish(pass_no, PhaseName::Checkpoint, pt, 0, None);
            last_saved = passes_done;
        }
        if probe.on() {
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t0.elapsed().as_secs_f64(),
                triplet_visits,
                active_triplets: triplets_per_pass,
            });
        }
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted() {
            let checkpointed = opts.checkpoint_every > 0;
            if checkpointed && last_saved != passes_done {
                let duals = store.iter_next().collect();
                on_checkpoint(&SolverState::capture_cc_full(
                    &state,
                    &state.x,
                    duals,
                    passes_done,
                    triplet_visits,
                    &history,
                ));
            }
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed });
        }
        if stop {
            break;
        }
    }
    if opts.checkpoint_every > 0 && last_saved != passes_done {
        let pt = probe.start();
        let duals = store.iter_next().collect();
        on_checkpoint(&SolverState::capture_cc_full(
            &state,
            &state.x,
            duals,
            passes_done,
            triplet_visits,
            &history,
        ));
        probe.finish(passes_done as u64, PhaseName::Checkpoint, pt, 0, None);
    }
    // Re-measure unless the last checkpoint already measured the final
    // iterate — reported residuals always describe the returned x.
    if measured_at != passes_done {
        let pt = probe.start();
        residuals = compute_residuals(&state, 1);
        residuals.stamp_work(triplet_visits, triplets_per_pass as usize);
        probe.finish(passes_done as u64, PhaseName::ResidualScan, pt, triplets_per_pass, None);
        probe.emit(Event::Residuals {
            pass: passes_done as u64,
            max_violation: residuals.max_violation,
            rel_gap: residuals.rel_gap,
            lp_objective: residuals.lp_objective,
            exact: true,
        });
    }
    let nnz = store.nnz();
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: triplet_visits * 3,
                active_triplets: triplets_per_pass,
                sweep_screened: 0,
                sweep_projected: 0,
                nnz_duals: nnz as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: None,
            },
        });
    }
    Ok(Solution {
        x: state.x_matrix(),
        f: Some(state.f_matrix()),
        passes: passes_done,
        residuals,
        pass_times,
        nnz_duals: nnz,
        metric_visits: triplet_visits * 3,
        active_triplets: triplets_per_pass as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: None,
    })
}

/// One full pass: all metric constraints (lexicographic), then all pair
/// constraints.
pub fn run_pass(state: &mut CcState, store: &mut DualStore) {
    run_metric_lex(state, store);
    // Pair constraints: identical code path as the parallel solver, p = 1.
    run_pair_phase(state, 1);
}

/// The metric half of [`run_pass`]: one lexicographic sweep over every
/// triplet (split out so the traced driver can time the metric and pair
/// phases separately).
pub fn run_metric_lex(state: &mut CcState, store: &mut DualStore) {
    store.begin_pass();
    let n = state.n;
    let col_starts = std::mem::take(&mut state.col_starts);
    {
        let x = SharedMut::new(state.x.as_mut_slice());
        // SAFETY: single thread, indices in bounds by construction.
        unsafe { super::hot_loop::process_lex(&x, &state.winv, &col_starts, n, store) };
    }
    state.col_starts = col_starts;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::metric_nearness::max_triangle_violation;

    fn tiny() -> CcLpInstance {
        CcLpInstance::random(8, 0.5, 0.8, 1.6, 7)
    }

    #[test]
    fn violation_decreases_over_passes() {
        let inst = tiny();
        let few = solve(&inst, &SolveOpts { max_passes: 2, ..Default::default() });
        let many = solve(&inst, &SolveOpts { max_passes: 300, ..Default::default() });
        assert!(
            many.residuals.max_violation <= few.residuals.max_violation + 1e-12,
            "few={} many={}",
            few.residuals.max_violation,
            many.residuals.max_violation
        );
        assert!(many.residuals.max_violation < 1e-2);
    }

    #[test]
    fn x_becomes_metric_and_bounded() {
        let inst = tiny();
        let sol = solve(&inst, &SolveOpts { max_passes: 400, ..Default::default() });
        assert!(max_triangle_violation(&sol.x) < 1e-3);
        for (_, _, v) in sol.x.iter_pairs() {
            assert!(v <= 1.0 + 1e-3, "x={v} exceeds box");
            assert!(v >= -1e-3, "x={v} negative");
        }
    }

    #[test]
    fn slacks_dominate_deviation() {
        let inst = tiny();
        let sol = solve(&inst, &SolveOpts { max_passes: 400, ..Default::default() });
        let f = sol.f.unwrap();
        for i in 0..inst.n {
            for j in (i + 1)..inst.n {
                let dev = (sol.x.get(i, j) - inst.d.get(i, j)).abs();
                assert!(f.get(i, j) >= dev - 1e-3, "f < |x-d| at ({i},{j})");
            }
        }
    }

    #[test]
    fn duality_gap_shrinks() {
        let inst = tiny();
        let sol5 = solve(&inst, &SolveOpts { max_passes: 5, ..Default::default() });
        let sol80 = solve(&inst, &SolveOpts { max_passes: 120, ..Default::default() });
        assert!(
            sol80.residuals.rel_gap.abs() < sol5.residuals.rel_gap.abs() + 1e-9,
            "gap5={} gap80={}",
            sol5.residuals.rel_gap,
            sol80.residuals.rel_gap
        );
        assert!(sol80.residuals.rel_gap.abs() < 0.05, "gap={}", sol80.residuals.rel_gap);
    }

    #[test]
    fn early_stop_on_tolerance() {
        let inst = tiny();
        let opts = SolveOpts {
            max_passes: 500,
            check_every: 5,
            tol_violation: 1e-3,
            tol_gap: 5e-2,
            ..Default::default()
        };
        let sol = solve(&inst, &opts);
        assert!(sol.passes < 500, "should stop early, ran {}", sol.passes);
        assert!(sol.residuals.max_violation <= 1e-3);
    }

    #[test]
    fn deterministic() {
        let inst = tiny();
        let opts = SolveOpts { max_passes: 10, ..Default::default() };
        let a = solve(&inst, &opts);
        let b = solve(&inst, &opts);
        assert_eq!(a.x, b.x);
        assert_eq!(a.nnz_duals, b.nnz_duals);
    }

    #[test]
    fn trivially_consistent_instance_stays_at_targets() {
        // d == 0 everywhere: x = 0, f = 0 is optimal (LP value 0); solver
        // must converge to lp_objective ~ 0.
        let inst = CcLpInstance::unweighted(6, &[]);
        let sol = solve(&inst, &SolveOpts { max_passes: 80, ..Default::default() });
        assert!(inst.lp_objective(&sol.x) < 1e-3);
    }
}
