//! Weighted l2 **metric nearness** solver (paper (1), Sra–Tropp–Dhillon
//! [36]): project the dissimilarity matrix `D` onto the cone of metric
//! matrices in the W-norm. This is Dykstra with `x0 = D` and *only* the
//! metric constraints — no slacks, no pair phase — run on the same
//! parallel wave schedule as the CC-LP solver.
//!
//! Nonnegativity needs no extra constraints: summing the two constraint
//! orientations `x_ik - x_ij - x_jk <= 0` and `x_jk - x_ij - x_ik <= 0`
//! gives `x_ij >= 0` at any feasible point.

use super::backing::XBacking;
use super::checkpoint::{self, CheckRecord, SolverState};
use super::duals::DualStore;
use super::dykstra_parallel::{emit_retries, run_metric_phase_timed};
use super::error::SolveError;
use super::schedule::{Assignment, Schedule};
use super::watchdog::Watchdog;
use super::{Algorithm, OnInterrupt, Strategy, SweepBackend, SweepPolicy};
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::matrix::store::StoreCfg;
use crate::matrix::PackedSym;
use crate::telemetry::{Counters, Event, NullRecorder, PassKind, PhaseName, PhaseProbe, Recorder};
use crate::util::parallel::par_reduce_max;
use crate::util::shared::{PerWorker, SharedMut};

/// Options for a nearness solve (subset of the CC-LP options).
#[derive(Clone, Copy, Debug)]
pub struct NearnessOpts {
    /// Maximum passes through the metric constraints.
    pub max_passes: usize,
    /// Stop early once the max triangle violation falls below this
    /// (checked every `check_every` passes).
    pub tol_violation: f64,
    /// Check convergence every this many passes (0 = never; run the
    /// fixed `max_passes`).
    pub check_every: usize,
    /// Worker threads (1 = serial execution of the parallel schedule;
    /// results are bitwise independent of this).
    pub threads: usize,
    /// Tile size `b` of the wave schedule.
    pub tile: usize,
    /// Tile-to-worker assignment policy within a wave.
    pub assignment: Assignment,
    /// Metric-constraint visiting strategy (see [`Strategy`]); the active
    /// variant runs in [`super::active::solve_nearness`].
    pub strategy: Strategy,
    /// How discovery sweeps walk the triplets (active strategy only).
    pub sweep_backend: SweepBackend,
    /// When discovery sweeps fire (active strategy only). `None` derives
    /// [`SweepPolicy::Fixed`] from the strategy's `sweep_every`.
    pub sweep_policy: Option<SweepPolicy>,
    /// Emit a [`SolverState`] every this many passes through
    /// [`solve_checkpointed`] (0 = never; a final state is always emitted
    /// when nonzero). Ignored by the plain [`solve`] call.
    pub checkpoint_every: usize,
    /// What to do when the process-wide interrupt flag is raised (see
    /// [`crate::util::interrupt`]); mirrors `SolveOpts::on_interrupt`.
    pub on_interrupt: OnInterrupt,
    /// Watchdog stall budget in residual *checks* without improvement
    /// (0 = stall detection off; divergence detection is always on).
    pub watchdog_stall: usize,
    /// Algorithm family ([`Algorithm`]). The proximal members route the
    /// whole solve to [`super::proximal`] (resident store only, no
    /// resume); every other option above that the proximal family does
    /// not consume (`strategy`, sweep knobs, checkpoint cadence) is
    /// ignored there.
    pub algorithm: Algorithm,
}

impl Default for NearnessOpts {
    fn default() -> Self {
        NearnessOpts {
            max_passes: 50,
            tol_violation: 1e-6,
            check_every: 10,
            threads: 1,
            tile: 40,
            assignment: Assignment::RoundRobin,
            strategy: Strategy::Full,
            sweep_backend: SweepBackend::default(),
            sweep_policy: None,
            checkpoint_every: 0,
            on_interrupt: OnInterrupt::Ignore,
            watchdog_stall: 0,
            algorithm: Algorithm::Dykstra,
        }
    }
}

/// Result of a nearness solve.
#[derive(Clone, Debug)]
pub struct NearnessSolution {
    /// The nearest metric matrix found.
    pub x: PackedSym,
    /// Weighted squared distance to D.
    pub objective: f64,
    /// Max triangle violation at the end.
    pub max_violation: f64,
    pub passes: usize,
    /// Total metric-constraint visits (3 per triplet visit).
    pub metric_visits: u64,
    /// Active triplets at the end (= C(n,3) for the full strategy).
    pub active_triplets: usize,
    /// Triplets examined by discovery sweeps (0 for the full strategy).
    pub sweep_screened: u64,
    /// Sweep triplets that actually needed a projection — see
    /// [`super::Residuals::sweep_projected`].
    pub sweep_projected: u64,
    /// Tile-store cache counters when the solve ran on a disk store
    /// (`None` for the resident path) — loads, evictions, write-backs,
    /// and the peak resident cache bytes.
    pub store_stats: Option<crate::matrix::store::StoreStats>,
}

impl NearnessSolution {
    /// The unified [`Counters`] snapshot of this solve — the same shape
    /// as a trace footer ([`Event::Footer`]). Nearness solves have no
    /// duality gap and do not track nonzero duals, so `rel_gap` and
    /// `nnz_duals` are 0; the phase timing vectors are empty (they exist
    /// only inside a traced run's footer).
    pub fn counters(&self) -> Counters {
        Counters {
            passes: self.passes as u64,
            metric_visits: self.metric_visits,
            active_triplets: self.active_triplets as u64,
            sweep_screened: self.sweep_screened,
            sweep_projected: self.sweep_projected,
            nnz_duals: 0,
            max_violation: self.max_violation,
            rel_gap: 0.0,
            phase_secs: Vec::new(),
            worker_busy_secs: Vec::new(),
            store: self.store_stats,
        }
    }
}

/// Solve with the parallel wave schedule (threads = 1 for serial order use
/// [`solve_serial_order`]). Dispatches on [`NearnessOpts::strategy`].
pub fn solve(inst: &MetricNearnessInstance, opts: &NearnessOpts) -> NearnessSolution {
    solve_checkpointed(inst, opts, None, &mut |_| {})
        .expect("cold nearness solve cannot fail")
}

/// Continue a previously saved nearness solve from its checkpoint,
/// dispatching on [`NearnessOpts::strategy`] like [`solve`]. With
/// unchanged options this reproduces the uninterrupted run bitwise (for
/// any worker count).
pub fn resume(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    state: &SolverState,
) -> anyhow::Result<NearnessSolution> {
    solve_checkpointed(inst, opts, Some(state), &mut |_| {})
}

/// Full-control entry point: optionally resume from a saved state and
/// receive a [`SolverState`] through `on_checkpoint` every
/// [`NearnessOpts::checkpoint_every`] passes (plus one for the final
/// state). Dispatches on [`NearnessOpts::strategy`]. Runs on the
/// in-memory store; use [`solve_stored`] to pick the backend.
pub fn solve_checkpointed(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<NearnessSolution> {
    solve_stored(inst, opts, &StoreCfg::mem(), resume_from, on_checkpoint)
}

/// [`solve_checkpointed`] with an explicit `X` storage backend
/// ([`StoreCfg`]): the memory configuration is the classic resident
/// solve; the disk configuration streams `X` through a bounded
/// [`crate::matrix::store::DiskStore`] working set so the solve runs at
/// `n` beyond RAM,
/// bitwise identically (pinned by `tests/store_equivalence.rs`). With a
/// disk store, checkpoints reference the flushed-and-stamped store file
/// instead of re-serializing `x`. Dispatches on
/// [`NearnessOpts::strategy`].
pub fn solve_stored(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<NearnessSolution> {
    Ok(solve_traced(inst, opts, store_cfg, resume_from, on_checkpoint, &NullRecorder)?)
}

/// [`solve_stored`] with a telemetry [`Recorder`] attached. All
/// instrumentation is gated on [`Recorder::enabled`], so passing
/// [`NullRecorder`] reproduces the untraced solve bitwise (pinned by
/// `tests/telemetry.rs`).
///
/// This is the typed-error boundary: store failures, interrupts, and
/// watchdog trips come back as the matching [`SolveError`] variant.
pub fn solve_traced(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    if opts.algorithm.is_proximal() {
        if store_cfg.kind != crate::matrix::store::StoreKind::Mem {
            return Err(SolveError::Other(anyhow::anyhow!(
                "--algorithm {} runs resident-only (the penalty subproblems sweep \
                 dense vectors, not leased tiles); drop --store disk/shard or use dykstra",
                opts.algorithm.name()
            )));
        }
        if resume_from.is_some() {
            return Err(SolveError::Other(anyhow::anyhow!(
                "--algorithm {} does not support checkpoint resume; re-run from \
                 the instance or resume with the dykstra family",
                opts.algorithm.name()
            )));
        }
        return super::proximal::solve_nearness_traced(inst, opts, rec);
    }
    if opts.strategy.is_active() {
        return super::active::solve_nearness_traced(
            inst,
            opts,
            store_cfg,
            resume_from,
            on_checkpoint,
            rec,
        );
    }
    let n = inst.n;
    let p = opts.threads.max(1);
    let schedule = Schedule::new(n, opts.tile);
    let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
    let col_starts = inst.d.col_starts().to_vec();
    let mut stores = PerWorker::new((0..p).map(|_| DualStore::new()).collect());
    if let Some(st) = resume_from {
        st.validate_nearness(inst)?;
        let per_worker = st.worker_duals(&schedule, opts.assignment, p);
        for (store, entries) in stores.iter_mut().zip(per_worker) {
            store.restore(entries);
        }
    }
    let mut backing = XBacking::init_nearness(inst, opts.tile, store_cfg, resume_from)?;
    let start_pass = resume_from.map_or(0, |st| st.pass as usize);
    let mut history: Vec<CheckRecord> =
        resume_from.map(|st| st.history.clone()).unwrap_or_default();
    let triplets_per_pass = schedule.total_triplets();
    // Cumulative work, carried across resumes (an active-strategy
    // checkpoint's cheap passes keep their true cost).
    let mut triplet_visits: u64 = resume_from.map_or(0, |st| st.triplet_visits);

    let mut passes_done = start_pass;
    let mut max_violation = f64::INFINITY;
    // passes_done at which `max_violation` was measured (MAX = never).
    let mut measured_at = usize::MAX;
    let mut last_saved = usize::MAX;
    let mut probe = PhaseProbe::new(rec, p);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);
    for pass in start_pass..opts.max_passes {
        let t_pass = probe.start();
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart { pass: pass_no, kind: PassKind::Full });
        {
            let pt = probe.start();
            let ws = probe.workers();
            backing.with_store(&col_starts, &winv, |store| {
                run_metric_phase_timed(store, &schedule, &stores, p, opts.assignment, ws.as_ref())
            });
            probe.finish(pass_no, PhaseName::Metric, pt, triplets_per_pass, ws);
        }
        // A failed lease parks inside the wave (barriers cannot unwind
        // mid-pass); the latched error surfaces here, once per pass.
        backing.health()?;
        emit_retries(&probe, pass_no, backing.drain_retries());
        passes_done = pass + 1;
        triplet_visits += triplets_per_pass;
        let mut stop = false;
        if opts.check_every > 0 && passes_done % opts.check_every == 0 {
            let pt = probe.start();
            max_violation = backing.violation(&col_starts, n, p, &schedule);
            probe.finish(pass_no, PhaseName::ResidualScan, pt, triplets_per_pass, None);
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation,
                rel_gap: 0.0,
                lp_objective: 0.0,
                exact: true,
            });
            measured_at = passes_done;
            history.push(CheckRecord {
                pass: passes_done as u64,
                max_violation,
                rel_gap: 0.0,
            });
            watchdog.observe(passes_done, max_violation, 0.0, &history)?;
            if max_violation <= opts.tol_violation {
                stop = true;
            }
        }
        if opts.checkpoint_every > 0 && (passes_done % opts.checkpoint_every == 0 || stop) {
            let pt = probe.start();
            on_checkpoint(&capture_nearness_full_backed(
                inst,
                &mut backing,
                &mut stores,
                passes_done,
                triplet_visits,
                &history,
            )?);
            probe.finish(pass_no, PhaseName::Checkpoint, pt, 0, None);
            last_saved = passes_done;
        }
        if probe.on() {
            if let Some(stats) = backing.store_stats() {
                probe.emit(Event::StoreIo { pass: pass_no, stats });
            }
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t_pass.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0),
                triplet_visits,
                active_triplets: triplets_per_pass,
            });
        }
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted() {
            let checkpointed = opts.checkpoint_every > 0;
            if checkpointed && last_saved != passes_done {
                on_checkpoint(&capture_nearness_full_backed(
                    inst,
                    &mut backing,
                    &mut stores,
                    passes_done,
                    triplet_visits,
                    &history,
                )?);
            }
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed });
        }
        if stop {
            break;
        }
    }
    if opts.checkpoint_every > 0 && last_saved != passes_done {
        let pt = probe.start();
        on_checkpoint(&capture_nearness_full_backed(
            inst,
            &mut backing,
            &mut stores,
            passes_done,
            triplet_visits,
            &history,
        )?);
        probe.finish(passes_done as u64, PhaseName::Checkpoint, pt, 0, None);
    }
    // Re-measure unless the last checkpoint already measured the final
    // iterate — the reported violation always describes the returned x.
    if measured_at != passes_done {
        let pt = probe.start();
        max_violation = backing.violation(&col_starts, n, p, &schedule);
        probe.finish(passes_done as u64, PhaseName::ResidualScan, pt, triplets_per_pass, None);
        probe.emit(Event::Residuals {
            pass: passes_done as u64,
            max_violation,
            rel_gap: 0.0,
            lp_objective: 0.0,
            exact: true,
        });
    }
    if probe.on() {
        let nnz: usize = stores.iter_mut().map(|s| s.nnz()).sum();
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: triplet_visits * 3,
                active_triplets: triplets_per_pass,
                sweep_screened: 0,
                sweep_projected: 0,
                nnz_duals: nnz as u64,
                max_violation,
                rel_gap: 0.0,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: backing.store_stats(),
            },
        });
    }
    let x_final = backing.extract()?;
    let mut xm = PackedSym::zeros(n);
    xm.as_mut_slice().copy_from_slice(&x_final);
    Ok(NearnessSolution {
        objective: inst.objective(&xm),
        x: xm,
        max_violation,
        passes: passes_done,
        metric_visits: triplet_visits * 3,
        active_triplets: triplets_per_pass as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: backing.store_stats(),
    })
}

/// Capture a full-strategy nearness checkpoint against either backing:
/// inline `x` for the memory store, a flush-and-stamp reference for the
/// disk store.
fn capture_nearness_full_backed(
    inst: &MetricNearnessInstance,
    backing: &mut XBacking,
    stores: &mut PerWorker<DualStore>,
    passes_done: usize,
    triplet_visits: u64,
    history: &[CheckRecord],
) -> Result<SolverState, SolveError> {
    let duals = checkpoint::collect_duals(stores);
    Ok(match backing {
        XBacking::Mem { x } => SolverState::capture_nearness_full(
            inst,
            x,
            duals,
            passes_done,
            triplet_visits,
            history,
        ),
        backing @ (XBacking::Disk { .. } | XBacking::Shard { .. }) => {
            let x_fnv = backing
                .stamp_external(passes_done as u64)?
                .expect("external backings always stamp");
            SolverState::capture_nearness_full_external(
                inst,
                x_fnv,
                duals,
                passes_done,
                triplet_visits,
                history,
            )
        }
    })
}

/// Serial baseline with the standard lexicographic order ([36]/[37]).
/// Full strategy only — `Strategy::Active` callers must use [`solve`].
pub fn solve_serial_order(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
) -> NearnessSolution {
    assert!(
        !opts.strategy.is_active(),
        "solve_serial_order runs the full strategy only; use nearness::solve for Strategy::Active"
    );
    let n = inst.n;
    let mut x: Vec<f64> = inst.d.as_slice().to_vec();
    let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
    let col_starts = inst.d.col_starts().to_vec();
    let mut store = DualStore::new();
    let mut passes_done = 0;
    let mut max_violation = f64::INFINITY;
    // passes_done at which `max_violation` was measured (MAX = never).
    let mut measured_at = usize::MAX;
    for pass in 0..opts.max_passes {
        store.begin_pass();
        {
            let xs = SharedMut::new(x.as_mut_slice());
            // SAFETY: single thread.
            unsafe { super::hot_loop::process_lex(&xs, &winv, &col_starts, n, &mut store) };
        }
        passes_done = pass + 1;
        if opts.check_every > 0 && passes_done % opts.check_every == 0 {
            max_violation = violation(&x, &col_starts, n, 1);
            measured_at = passes_done;
            if max_violation <= opts.tol_violation {
                break;
            }
        }
    }
    // Re-measure unless the last checkpoint already measured the final
    // iterate — the reported violation always describes the returned x.
    if measured_at != passes_done {
        max_violation = violation(&x, &col_starts, n, 1);
    }
    let mut xm = PackedSym::zeros(n);
    xm.as_mut_slice().copy_from_slice(&x);
    let triplets_per_pass = super::schedule::n_triplets(n);
    NearnessSolution {
        objective: inst.objective(&xm),
        x: xm,
        max_violation,
        passes: passes_done,
        metric_visits: passes_done as u64 * triplets_per_pass * 3,
        active_triplets: triplets_per_pass as usize,
        sweep_screened: 0,
        sweep_projected: 0,
        store_stats: None,
    }
}

/// Exact max triangle violation over packed `x` (shared with the active
/// driver's final report).
pub(crate) fn violation(x: &[f64], col_starts: &[usize], n: usize, p: usize) -> f64 {
    par_reduce_max(p, n, |i| {
        let mut worst = f64::NEG_INFINITY;
        for j in (i + 1)..n {
            let xij = x[col_starts[i] + (j - i - 1)];
            for k in (j + 1)..n {
                let xik = x[col_starts[i] + (k - i - 1)];
                let xjk = x[col_starts[j] + (k - j - 1)];
                let v = (xij - xik - xjk).max(xik - xij - xjk).max(xjk - xij - xik);
                worst = worst.max(v);
            }
        }
        worst
    })
    .max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::metric_nearness::max_triangle_violation;

    #[test]
    fn already_metric_is_fixed_point() {
        let inst = MetricNearnessInstance::new(PackedSym::filled(8, 1.0));
        let sol = solve(&inst, &NearnessOpts { max_passes: 5, threads: 2, ..Default::default() });
        assert!(sol.objective < 1e-20);
        assert_eq!(sol.x, inst.d);
    }

    #[test]
    fn output_is_metric() {
        let inst = MetricNearnessInstance::random(12, 3.0, 7);
        let sol = solve(
            &inst,
            &NearnessOpts { max_passes: 200, threads: 3, tile: 3, ..Default::default() },
        );
        assert!(max_triangle_violation(&sol.x) < 1e-5, "viol {}", sol.max_violation);
        assert!(sol.objective > 0.0); // random D isn't metric
    }

    #[test]
    fn thread_count_does_not_change_result() {
        let inst = MetricNearnessInstance::random(10, 2.0, 9);
        let a = solve(&inst, &NearnessOpts { max_passes: 10, threads: 1, tile: 2, ..Default::default() });
        let b = solve(&inst, &NearnessOpts { max_passes: 10, threads: 4, tile: 2, ..Default::default() });
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn parallel_and_serial_order_agree_at_convergence() {
        let inst = MetricNearnessInstance::random(9, 2.0, 3);
        let par = solve(
            &inst,
            &NearnessOpts { max_passes: 300, threads: 2, tile: 2, ..Default::default() },
        );
        let ser = solve_serial_order(&inst, &NearnessOpts { max_passes: 300, ..Default::default() });
        let mut worst: f64 = 0.0;
        for (i, j, v) in par.x.iter_pairs() {
            worst = worst.max((v - ser.x.get(i, j)).abs());
        }
        assert!(worst < 1e-4, "optima differ by {worst}");
        assert!((par.objective - ser.objective).abs() < 1e-4 * ser.objective.max(1.0));
    }

    #[test]
    fn projection_shrinks_objective_monotone_feasibility() {
        // objective must be near the infimum: check that doubling passes
        // doesn't change it much (converged), and violation decreased.
        let inst = MetricNearnessInstance::random(10, 2.0, 11);
        let s1 = solve(&inst, &NearnessOpts { max_passes: 50, threads: 2, ..Default::default() });
        let s2 = solve(&inst, &NearnessOpts { max_passes: 400, threads: 2, ..Default::default() });
        assert!(s2.max_violation <= s1.max_violation + 1e-12);
        assert!((s1.objective - s2.objective).abs() < 0.05 * s2.objective.max(1e-9));
    }

    #[test]
    fn early_stop_works() {
        let inst = MetricNearnessInstance::random(8, 2.0, 5);
        let sol = solve(
            &inst,
            &NearnessOpts {
                max_passes: 10_000,
                check_every: 5,
                tol_violation: 1e-4,
                threads: 2,
                ..Default::default()
            },
        );
        assert!(sol.passes < 10_000);
        assert!(sol.max_violation <= 1e-4);
    }
}
