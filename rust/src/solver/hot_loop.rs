//! The optimized metric-phase inner loops (EXPERIMENTS.md §Perf).
//!
//! Same visit order as [`tiling::for_each_triplet`] (cube order) and the
//! lexicographic baseline, but with the per-triplet work minimized:
//!
//! * fused [`visit_triplet`] — one load + one store per variable per
//!   triplet (not per constraint), one division per triplet;
//! * incremental packed indices — inside the innermost `k` loop, `p_ik`
//!   and `p_jk` advance by 1 (both walk contiguous column segments) and
//!   the dual key advances by 4, so no per-visit index arithmetic.
//!
//! Discovery sweeps use the same incremental-index idea but hoist the
//! whole innermost `k` loop into a vectorized violation screen — see
//! [`crate::solver::active::sweep`] (screen-then-project) and
//! [`crate::solver::tiling::for_each_run`].

use super::duals::{metric_key, DualStore};
use super::projection::visit_triplet;
use super::schedule::Tile;
use crate::util::shared::SharedMut;

/// Process every triplet of `tile` (cube order, chunk size `b`).
///
/// # Safety
/// Caller guarantees exclusive access to all variables reachable from the
/// tile (the wave schedule invariant) and in-bounds packed indices.
#[inline]
pub(crate) unsafe fn process_tile(
    x: &SharedMut<f64>,
    winv: &[f64],
    col_starts: &[usize],
    tile: &Tile,
    b: usize,
    store: &mut DualStore,
) {
    let j_min = tile.i_lo + 1;
    let j_end = tile.k_hi.saturating_sub(1);
    let mut chunk_lo = j_min;
    while chunk_lo < j_end {
        let chunk_hi = (chunk_lo + b).min(j_end);
        for i in tile.i_lo..tile.i_hi {
            let ci = *col_starts.get_unchecked(i);
            let j_lo = chunk_lo.max(i + 1);
            for j in j_lo..chunk_hi {
                let k0 = tile.k_lo.max(j + 1);
                if k0 >= tile.k_hi {
                    continue;
                }
                let pij = ci + (j - i - 1);
                let mut pik = ci + (k0 - i - 1);
                let mut pjk = *col_starts.get_unchecked(j) + (k0 - j - 1);
                let mut key = metric_key(i, j, k0, 0);
                for _ in k0..tile.k_hi {
                    let y = store.fetch3(key);
                    let th = visit_triplet(x, winv, pij, pik, pjk, y);
                    store.store3(key, th);
                    pik += 1;
                    pjk += 1;
                    key += 4;
                }
            }
        }
        chunk_lo = chunk_hi;
    }
}

/// Process all `C(n,3)` triplets in the lexicographic order of the serial
/// baseline [37], fused + incremental.
///
/// # Safety
/// Single-threaded access to `x`.
#[inline]
pub(crate) unsafe fn process_lex(
    x: &SharedMut<f64>,
    winv: &[f64],
    col_starts: &[usize],
    n: usize,
    store: &mut DualStore,
) {
    for i in 0..n {
        let ci = *col_starts.get_unchecked(i);
        for j in (i + 1)..n {
            let k0 = j + 1;
            if k0 >= n {
                continue;
            }
            let pij = ci + (j - i - 1);
            let mut pik = ci + (k0 - i - 1);
            let mut pjk = *col_starts.get_unchecked(j);
            let mut key = metric_key(i, j, k0, 0);
            for _ in k0..n {
                let y = store.fetch3(key);
                let th = visit_triplet(x, winv, pij, pik, pjk, y);
                store.store3(key, th);
                pik += 1;
                pjk += 1;
                key += 4;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::CcLpInstance;
    use crate::solver::schedule::Schedule;
    use crate::solver::tiling::{for_each_triplet, for_each_triplet_lex};
    use crate::solver::CcState;

    /// Reference implementation: cube-order iteration + fused visit, via
    /// the (slower) callback iterator. Must match process_tile bitwise.
    unsafe fn reference_tile(
        x: &SharedMut<f64>,
        winv: &[f64],
        col_starts: &[usize],
        tile: &Tile,
        b: usize,
        store: &mut DualStore,
    ) {
        for_each_triplet(tile, b, |i, j, k| {
            let pij = col_starts[i] + (j - i - 1);
            let pik = col_starts[i] + (k - i - 1);
            let pjk = col_starts[j] + (k - j - 1);
            let key = metric_key(i, j, k, 0);
            let y = [store.fetch(key), store.fetch(key | 1), store.fetch(key | 2)];
            let th = visit_triplet(x, winv, pij, pik, pjk, y);
            store.store(key, th[0]);
            store.store(key | 1, th[1]);
            store.store(key | 2, th[2]);
        });
    }

    #[test]
    fn process_tile_bitwise_matches_reference() {
        let inst = CcLpInstance::random(24, 0.5, 0.7, 1.8, 5);
        let schedule = Schedule::new(24, 4);
        for passes in [1usize, 3] {
            let mut sa = CcState::new(&inst, 5.0, true);
            let mut sb = CcState::new(&inst, 5.0, true);
            let mut da = DualStore::new();
            let mut db = DualStore::new();
            for _ in 0..passes {
                da.begin_pass();
                db.begin_pass();
                let xa = SharedMut::new(sa.x.as_mut_slice());
                let xb = SharedMut::new(sb.x.as_mut_slice());
                for wave in schedule.waves() {
                    for tile in wave {
                        unsafe {
                            process_tile(&xa, &sa.winv, &sa.col_starts, tile, 4, &mut da);
                            reference_tile(&xb, &sb.winv, &sb.col_starts, tile, 4, &mut db);
                        }
                    }
                }
            }
            assert_eq!(sa.x, sb.x, "passes={passes}");
            assert_eq!(da.nnz(), db.nnz());
        }
    }

    #[test]
    fn process_lex_bitwise_matches_reference() {
        let inst = CcLpInstance::random(20, 0.5, 0.7, 1.8, 9);
        let mut sa = CcState::new(&inst, 5.0, true);
        let mut sb = CcState::new(&inst, 5.0, true);
        let mut da = DualStore::new();
        let mut db = DualStore::new();
        for _ in 0..3 {
            da.begin_pass();
            db.begin_pass();
            let xa = SharedMut::new(sa.x.as_mut_slice());
            let xb = SharedMut::new(sb.x.as_mut_slice());
            unsafe { process_lex(&xa, &sa.winv, &sa.col_starts, 20, &mut da) };
            for_each_triplet_lex(20, |i, j, k| {
                let pij = sb.col_starts[i] + (j - i - 1);
                let pik = sb.col_starts[i] + (k - i - 1);
                let pjk = sb.col_starts[j] + (k - j - 1);
                let key = metric_key(i, j, k, 0);
                let y = [db.fetch(key), db.fetch(key | 1), db.fetch(key | 2)];
                let th = unsafe { visit_triplet(&xb, &sb.winv, pij, pik, pjk, y) };
                db.store(key, th[0]);
                db.store(key | 1, th[1]);
                db.store(key | 2, th[2]);
            });
        }
        assert_eq!(sa.x, sb.x);
    }
}
