//! When to run the next discovery sweep ([`SweepPolicy`]).
//!
//! The fixed cadence reproduces the classic `pass % sweep_every == 0`
//! schedule exactly (pass indices are absolute, so checkpoint resumes
//! keep the phase — the bitwise-resume tests rely on it). The adaptive
//! cadence instead watches the solve:
//!
//! * **Shrinkage stall** — after each cheap pass the active set should
//!   keep losing forgotten entries; when it fails to shrink by
//!   [`MIN_SHRINK`] for [`STALL_PATIENCE`] consecutive cheap passes, the
//!   watched constraints have settled and the next sweep is due (either
//!   the solve converged, or progress now needs constraints outside the
//!   set).
//! * **Trusted-violation plateau** — when consecutive sweeps measure
//!   violations that barely improve (ratio above [`PLATEAU_RATIO`]), the
//!   active set is likely missing the rows that matter, so the interval
//!   cap tightens from [`MAX_INTERVAL`] to [`PLATEAU_INTERVAL`].
//! * **Interval cap** — a sweep always fires after at most
//!   `MAX_INTERVAL` cheap passes, which bounds how long a violation that
//!   arose unwatched can go unnoticed (the project-and-forget
//!   convergence argument needs sweeps to stay quasi-cyclic).
//!
//! The controller's observations are runtime-only and not checkpointed:
//! resuming an adaptive run re-learns its signals, so sweep placement
//! may differ from the uninterrupted run (fixed cadences resume
//! bitwise).

use crate::solver::SweepPolicy;

/// Cheap passes without sufficient shrinkage before a sweep is due.
pub const STALL_PATIENCE: usize = 3;
/// Relative active-set shrinkage per cheap pass that counts as progress.
pub const MIN_SHRINK: f64 = 0.005;
/// Hard cap on cheap passes between sweeps.
pub const MAX_INTERVAL: usize = 32;
/// Tightened cap while the trusted violation plateaus.
pub const PLATEAU_INTERVAL: usize = 8;
/// Violation ratio between consecutive sweeps that counts as a plateau.
pub const PLATEAU_RATIO: f64 = 0.95;

/// Decides, pass by pass, whether the active driver sweeps or runs a
/// cheap pass. Feed it every completed pass via [`note_sweep`] /
/// [`note_cheap`]; ask [`wants_sweep`] before each pass.
///
/// [`note_sweep`]: SweepCadence::note_sweep
/// [`note_cheap`]: SweepCadence::note_cheap
/// [`wants_sweep`]: SweepCadence::wants_sweep
#[derive(Clone, Copy, Debug)]
pub struct SweepCadence {
    policy: SweepPolicy,
    /// Cheap passes since the last sweep.
    since_sweep: usize,
    /// Active-set size after the previous cheap pass.
    prev_active: Option<usize>,
    /// Consecutive cheap passes without sufficient shrinkage.
    stall: usize,
    /// Max violation measured by the previous sweep.
    last_violation: Option<f64>,
    /// The last two sweeps plateaued.
    plateau: bool,
    /// A stall already marked the next sweep due.
    due: bool,
}

impl SweepCadence {
    /// Fresh controller for a (possibly resumed) solve.
    pub fn new(policy: SweepPolicy) -> SweepCadence {
        SweepCadence {
            policy,
            since_sweep: 0,
            prev_active: None,
            stall: 0,
            last_violation: None,
            plateau: false,
            due: false,
        }
    }

    /// Should pass `pass` (absolute index) be a discovery sweep?
    pub fn wants_sweep(&self, pass: usize) -> bool {
        match self.policy {
            SweepPolicy::Fixed(k) => pass % k.max(1) == 0,
            SweepPolicy::Adaptive => {
                pass == 0 || self.due || self.since_sweep >= self.interval_cap()
            }
        }
    }

    fn interval_cap(&self) -> usize {
        if self.plateau {
            PLATEAU_INTERVAL
        } else {
            MAX_INTERVAL
        }
    }

    /// Record a completed sweep and the max violation it measured.
    pub fn note_sweep(&mut self, max_violation: f64) {
        self.plateau = match self.last_violation {
            Some(prev) => prev.is_finite() && max_violation > prev * PLATEAU_RATIO,
            None => false,
        };
        self.last_violation = Some(max_violation);
        self.since_sweep = 0;
        self.prev_active = None;
        self.stall = 0;
        self.due = false;
    }

    /// Record a completed cheap pass and the active-set size after its
    /// forget step.
    pub fn note_cheap(&mut self, active_len: usize) {
        self.since_sweep += 1;
        if let Some(prev) = self.prev_active {
            // Strict `<`: an unchanged size is a stall — in particular a
            // set frozen at 0 (everything forgotten, solve likely done)
            // must trip the trigger rather than wait out the full cap.
            let shrunk = (active_len as f64) < (prev as f64) * (1.0 - MIN_SHRINK);
            if shrunk {
                self.stall = 0;
            } else {
                self.stall += 1;
            }
            if self.stall >= STALL_PATIENCE {
                self.due = true;
            }
        }
        self.prev_active = Some(active_len);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_cadence_reproduces_modular_schedule() {
        let c = SweepCadence::new(SweepPolicy::Fixed(4));
        for pass in 0..20 {
            assert_eq!(c.wants_sweep(pass), pass % 4 == 0, "pass {pass}");
        }
        // Period 0 is clamped like ActiveParams clamps sweep_every.
        let c0 = SweepCadence::new(SweepPolicy::Fixed(0));
        assert!((0..5).all(|p| c0.wants_sweep(p)));
    }

    /// The ISSUE's synthetic stall trace: a steadily shrinking active set
    /// never triggers an early sweep, a plateaued one does after
    /// STALL_PATIENCE cheap passes.
    #[test]
    fn adaptive_triggers_on_shrinkage_stall() {
        let mut c = SweepCadence::new(SweepPolicy::Adaptive);
        assert!(c.wants_sweep(0), "pass 0 must discover");
        c.note_sweep(1.0);
        // Healthy shrinkage: 1000 -> 990 -> 980 ... never due early.
        let mut size = 1000usize;
        for pass in 1..=10 {
            assert!(!c.wants_sweep(pass), "healthy shrinkage must not sweep (pass {pass})");
            size -= 10;
            c.note_cheap(size);
        }
        // Stall: the size freezes; after STALL_PATIENCE frozen cheap
        // passes the next sweep is due.
        let mut fired_at = None;
        for extra in 1..=STALL_PATIENCE + 2 {
            if c.wants_sweep(10 + extra) {
                fired_at = Some(extra);
                break;
            }
            c.note_cheap(size);
        }
        // note_cheap compares against the previous cheap pass, so the
        // first frozen observation lands one pass after the freeze.
        assert_eq!(fired_at, Some(STALL_PATIENCE + 1), "stall must trigger a sweep");
        // A sweep resets the signals.
        c.note_sweep(0.5);
        assert!(!c.wants_sweep(99));
    }

    /// Regression: a set frozen at size 0 (everything forgotten) must
    /// count as stalled, not as "shrunk to target" — `0 <= 0·(1-ε)`
    /// would hold forever and defer the sweep to the interval cap.
    #[test]
    fn adaptive_triggers_on_an_empty_frozen_set() {
        let mut c = SweepCadence::new(SweepPolicy::Adaptive);
        c.note_sweep(1.0);
        let mut fired_at = None;
        for pass in 1..=STALL_PATIENCE + 3 {
            if c.wants_sweep(pass) {
                fired_at = Some(pass);
                break;
            }
            c.note_cheap(0);
        }
        assert_eq!(fired_at, Some(STALL_PATIENCE + 2), "empty set must stall-trigger");
    }

    #[test]
    fn adaptive_interval_cap_bounds_staleness() {
        let mut c = SweepCadence::new(SweepPolicy::Adaptive);
        c.note_sweep(1.0);
        let mut size = 1_000_000usize;
        let mut swept_at = None;
        for pass in 1..=MAX_INTERVAL + 1 {
            if c.wants_sweep(pass) {
                swept_at = Some(pass);
                break;
            }
            // keep shrinking briskly so no stall fires
            size = (size as f64 * 0.9) as usize;
            c.note_cheap(size);
        }
        assert_eq!(swept_at, Some(MAX_INTERVAL + 1), "cap must force a sweep");
    }

    #[test]
    fn violation_plateau_tightens_the_cap() {
        let mut c = SweepCadence::new(SweepPolicy::Adaptive);
        c.note_sweep(1.0);
        c.note_sweep(0.999); // barely improved: plateau
        let mut size = 1_000_000usize;
        let mut swept_at = None;
        for pass in 1..=MAX_INTERVAL {
            if c.wants_sweep(pass) {
                swept_at = Some(pass);
                break;
            }
            size = (size as f64 * 0.9) as usize;
            c.note_cheap(size);
        }
        assert_eq!(swept_at, Some(PLATEAU_INTERVAL + 1));
        // A clear improvement clears the plateau.
        c.note_sweep(0.1);
        assert!(!c.wants_sweep(1));
        let mut later = None;
        let mut sz = 1_000_000usize;
        for pass in 1..=MAX_INTERVAL + 1 {
            if c.wants_sweep(pass) {
                later = Some(pass);
                break;
            }
            sz = (sz as f64 * 0.9) as usize;
            c.note_cheap(sz);
        }
        assert_eq!(later, Some(MAX_INTERVAL + 1));
    }
}
