//! The active-constraint list: compact `u64` triplet keys plus their
//! Dykstra duals, bucketed by schedule tile.
//!
//! Storing the duals *inside* the active entries (instead of the
//! per-worker merge-scan arrays of [`crate::solver::duals`]) is what lets
//! active passes visit an arbitrary sparse subset: there is no cross-pass
//! visit-order contract to honor, only the per-tile cube order that keeps
//! discovery sweeps mergeable. Bucketing by tile preserves the wave
//! schedule's ownership structure, so active passes and sweeps inherit
//! its conflict-freeness unchanged: the worker that owns a tile owns its
//! bucket for the duration of the wave.

use crate::solver::schedule::Schedule;
use std::cell::UnsafeCell;

/// Bits per index in a triplet key — the layout of
/// [`crate::solver::duals::metric_key`] with the 2 type bits left zero,
/// so keys are directly comparable across the two stores.
const INDEX_MASK: u64 = (1 << 20) - 1;

/// Largest representable instance size: each index must fit the 20-bit
/// key fields, so `n` must stay below `2^20`. Instance constructors
/// reject anything larger — past the check, key packing cannot collide.
pub const MAX_N: usize = 1 << 20;

/// Encode triplet `(i, j, k)`, `i < j < k`, as a compact key.
#[inline(always)]
pub fn triplet_key(i: usize, j: usize, k: usize) -> u64 {
    debug_assert!(i < j && j < k);
    // `i < j < k`, so checking the largest index covers all three.
    // Instances with `n >= MAX_N` are rejected at construction; this
    // backstops that check where a collision would corrupt duals.
    debug_assert!(k < MAX_N, "index {k} overflows the 20-bit key field");
    ((i as u64) << 42) | ((j as u64) << 22) | ((k as u64) << 2)
}

/// Decode a key back to `(i, j, k)`.
#[inline(always)]
pub fn decode_key(key: u64) -> (usize, usize, usize) {
    (
        ((key >> 42) & INDEX_MASK) as usize,
        ((key >> 22) & INDEX_MASK) as usize,
        ((key >> 2) & INDEX_MASK) as usize,
    )
}

/// High bits shared by every key of the `k`-run with fixed `(i, j)` —
/// what the screened sweep's merge-scan segments bucket entries by.
#[inline(always)]
pub fn run_prefix(i: usize, j: usize) -> u64 {
    debug_assert!(i < MAX_N && j < MAX_N, "index overflows the 20-bit key field");
    ((i as u64) << 20) | (j as u64)
}

/// The [`run_prefix`] of an existing key (drops `k` and the type bits).
/// Keeping this next to [`triplet_key`] means the bit layout lives in
/// exactly one module.
#[inline(always)]
pub fn key_run_prefix(key: u64) -> u64 {
    key >> 22
}

/// One active triplet: its key, the three scaled Dykstra duals from its
/// last visit, and how many consecutive active passes those duals have
/// been all-zero (the forget counter).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActiveTriplet {
    pub key: u64,
    pub y: [f64; 3],
    pub zero_passes: u32,
}

/// Active triplets bucketed per schedule tile, in cube order within each
/// bucket (the order [`crate::solver::tiling::for_each_triplet`] visits a
/// tile), flat-indexed wave by wave.
///
/// Parallel phases hand each worker exclusive access to the buckets of
/// the tiles it owns in the current wave via [`ActiveSet::bucket_mut`];
/// all bookkeeping between phases goes through `&mut self` methods.
pub struct ActiveSet {
    buckets: Vec<UnsafeCell<Vec<ActiveTriplet>>>,
    /// `wave_offsets[w]` = flat index of wave `w`'s first tile
    /// (length = number of waves + 1).
    wave_offsets: Vec<usize>,
}

// SAFETY: buckets are only mutated through `bucket_mut`, whose contract
// (one owner per tile per wave, barriers between waves) is exactly the
// wave schedule's conflict-freeness argument — same as `SharedMut`.
unsafe impl Sync for ActiveSet {}

impl ActiveSet {
    /// An empty active set shaped after `schedule`'s waves and tiles.
    pub fn new(schedule: &Schedule) -> ActiveSet {
        let mut wave_offsets = Vec::with_capacity(schedule.waves().len() + 1);
        let mut flat = 0usize;
        wave_offsets.push(0);
        for wave in schedule.waves() {
            flat += wave.len();
            wave_offsets.push(flat);
        }
        ActiveSet {
            buckets: (0..flat).map(|_| UnsafeCell::new(Vec::new())).collect(),
            wave_offsets,
        }
    }

    /// Flat bucket index of tile `r` of wave `wave`.
    #[inline(always)]
    pub fn flat_index(&self, wave: usize, r: usize) -> usize {
        debug_assert!(self.wave_offsets[wave] + r < self.wave_offsets[wave + 1]);
        self.wave_offsets[wave] + r
    }

    /// Total number of tile buckets.
    pub fn n_tiles(&self) -> usize {
        self.buckets.len()
    }

    /// Mutable access to one tile's bucket during a parallel phase.
    ///
    /// # Safety
    /// Only the worker owning tile `flat` in the current wave may call
    /// this, and the reference must not outlive that ownership (wave
    /// barriers delimit it) — the same discipline as
    /// [`crate::util::shared::PerWorker::get_mut`].
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn bucket_mut(&self, flat: usize) -> &mut Vec<ActiveTriplet> {
        &mut *self.buckets[flat].get()
    }

    /// Exclusive iteration over all buckets (between phases).
    pub fn buckets_mut(&mut self) -> impl Iterator<Item = &mut Vec<ActiveTriplet>> {
        self.buckets.iter_mut().map(|c| c.get_mut())
    }

    /// Iterate over all active triplets (between phases).
    pub fn iter(&mut self) -> impl Iterator<Item = &ActiveTriplet> {
        self.buckets.iter_mut().flat_map(|c| c.get_mut().iter())
    }

    /// Number of active triplets.
    pub fn len(&mut self) -> usize {
        self.buckets.iter_mut().map(|c| c.get_mut().len()).sum()
    }

    /// True iff no triplet is active.
    pub fn is_empty(&mut self) -> bool {
        self.len() == 0
    }

    /// Count of nonzero dual *lanes* across the set — directly comparable
    /// to the sum of [`crate::solver::duals::DualStore::nnz`] over workers.
    pub fn nnz_duals(&mut self) -> usize {
        self.iter().map(|e| e.y.iter().filter(|&&v| v != 0.0).count()).sum()
    }

    /// Drop every entry (restart).
    pub fn clear(&mut self) {
        for bucket in self.buckets_mut() {
            bucket.clear();
        }
    }

    /// Rebuild the set from `entries` (any order), e.g. from a
    /// checkpoint: each triplet is routed to the tile bucket owning it
    /// and buckets are ordered by the cube order
    /// [`crate::solver::tiling::for_each_triplet`] visits — the order the
    /// sweep merge-scan requires. `schedule` must be the schedule this
    /// set was shaped after.
    pub fn seed(&mut self, schedule: &Schedule, entries: Vec<ActiveTriplet>) {
        assert_eq!(
            self.n_tiles(),
            schedule.n_tiles(),
            "seeding an active set shaped after a different schedule"
        );
        self.clear();
        let router = crate::solver::schedule::TileRouter::new(schedule);
        let mut routed: Vec<Vec<((usize, u64), ActiveTriplet)>> =
            (0..self.buckets.len()).map(|_| Vec::new()).collect();
        for e in entries {
            let (i, j, k) = decode_key(e.key);
            let (wi, r, chunk) = router.locate(i, j, k);
            let flat = self.flat_index(wi, r);
            // Cube order inside a tile: j-chunks first, then (i, j, k) —
            // which for a fixed chunk is the key's numeric order.
            routed[flat].push(((chunk, e.key), e));
        }
        for (flat, mut v) in routed.into_iter().enumerate() {
            if v.is_empty() {
                continue;
            }
            v.sort_unstable_by_key(|&(rank, _)| rank);
            self.buckets[flat].get_mut().extend(v.into_iter().map(|(_, e)| e));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::duals::metric_key;

    #[test]
    fn key_roundtrip_and_matches_dual_key_base() {
        for &(i, j, k) in &[(0usize, 1usize, 2usize), (3, 7, 19), (100, 5000, 900_000)] {
            let key = triplet_key(i, j, k);
            assert_eq!(decode_key(key), (i, j, k));
            assert_eq!(key_run_prefix(key), run_prefix(i, j), "run prefix mismatch");
            if k < (1 << 20) {
                assert_eq!(key, metric_key(i, j, k, 0));
                assert_eq!(key & 3, 0, "type bits must be clear");
            }
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overflows the 20-bit key field")]
    fn triplet_key_rejects_indices_past_the_field_width() {
        let _ = triplet_key(0, 1, MAX_N);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "overflows the 20-bit key field")]
    fn run_prefix_rejects_indices_past_the_field_width() {
        let _ = run_prefix(0, MAX_N);
    }

    #[test]
    fn buckets_shaped_after_schedule() {
        let schedule = Schedule::new(20, 3);
        let mut set = ActiveSet::new(&schedule);
        assert_eq!(set.n_tiles(), schedule.n_tiles());
        assert!(set.is_empty());
        // flat_index enumerates tiles wave-major without gaps or overlaps
        let mut seen = vec![false; set.n_tiles()];
        for (w, wave) in schedule.waves().iter().enumerate() {
            for r in 0..wave.len() {
                let f = set.flat_index(w, r);
                assert!(!seen[f], "flat index {f} reused");
                seen[f] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn seed_reproduces_cube_order_in_every_bucket() {
        use crate::solver::tiling::for_each_triplet;
        use crate::util::rng::Rng;
        let schedule = Schedule::new(19, 3);
        let mut set = ActiveSet::new(&schedule);
        // Random subset of all triplets, handed to seed() in shuffled order.
        let mut rng = Rng::new(0x5EED);
        let mut entries = Vec::new();
        for i in 0..19usize {
            for j in (i + 1)..19 {
                for k in (j + 1)..19 {
                    if rng.bool(0.3) {
                        entries.push(ActiveTriplet {
                            key: triplet_key(i, j, k),
                            y: [rng.f64_in(0.1, 1.0), 0.0, 0.0],
                            zero_passes: rng.usize_in(0, 4) as u32,
                        });
                    }
                }
            }
        }
        let expected_len = entries.len();
        rng.shuffle(&mut entries);
        let by_key: std::collections::HashMap<u64, ActiveTriplet> =
            entries.iter().map(|e| (e.key, *e)).collect();
        set.seed(&schedule, entries);
        assert_eq!(set.len(), expected_len);
        // Every bucket must hold exactly its tile's seeded triplets, in
        // the order for_each_triplet visits that tile.
        let b = schedule.tile_size();
        for (w, wave) in schedule.waves().iter().enumerate() {
            for (r, tile) in wave.iter().enumerate() {
                let mut want = Vec::new();
                for_each_triplet(tile, b, |i, j, k| {
                    let key = triplet_key(i, j, k);
                    if let Some(e) = by_key.get(&key) {
                        want.push(*e);
                    }
                });
                let flat = set.flat_index(w, r);
                let got = unsafe { set.bucket_mut(flat) }.clone();
                assert_eq!(got, want, "wave {w} tile {r}");
            }
        }
    }

    #[test]
    fn len_and_nnz_track_contents() {
        let schedule = Schedule::new(10, 2);
        let mut set = ActiveSet::new(&schedule);
        {
            // Exclusive context: stuff two buckets by hand.
            let b0 = unsafe { set.bucket_mut(0) };
            b0.push(ActiveTriplet { key: triplet_key(0, 1, 9), y: [0.5, 0.0, 0.0], zero_passes: 0 });
            b0.push(ActiveTriplet { key: triplet_key(0, 2, 9), y: [0.0, 0.0, 0.0], zero_passes: 2 });
            let b1 = unsafe { set.bucket_mut(1) };
            b1.push(ActiveTriplet { key: triplet_key(1, 2, 8), y: [0.1, 0.2, 0.0], zero_passes: 0 });
        }
        assert_eq!(set.len(), 3);
        assert_eq!(set.nnz_duals(), 3); // 1 + 0 + 2 nonzero lanes
        set.clear();
        assert!(set.is_empty());
        assert_eq!(set.nnz_duals(), 0);
    }
}
