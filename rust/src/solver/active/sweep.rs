//! Full-sweep violation discovery.
//!
//! A discovery sweep is a normal wave-parallel Dykstra pass over **all**
//! `C(n,3)` triplets that additionally (a) measures the largest metric
//! violation it encounters (each triplet inspected just before its visit
//! — the sweep's Gauss–Seidel residual, which [`crate::solver::termination`]
//! trusts for early stopping) and (b) rebuilds the active set to exactly
//! the triplets that finish the sweep holding a nonzero dual. A violated
//! constraint gets projected during the sweep, so it ends with a nonzero
//! dual and is discovered; a satisfied zero-dual constraint is a no-op
//! visit and is dropped. Because only zero-dual triplets are ever outside
//! the set, fetching "no entry" as `y = [0; 3]` is exact — discovery is
//! just the full pass with a different dual store.
//!
//! The sweep reuses the wave [`Schedule`] directly, so discovery itself
//! is conflict-free and parallel: same tile-to-worker assignment, same
//! cube order inside each tile, barriers between waves.

use super::set::{triplet_key, ActiveSet, ActiveTriplet};
use crate::solver::projection::visit_triplet;
use crate::solver::schedule::{Assignment, Schedule};
use crate::solver::tiling::for_each_triplet;
use crate::util::parallel::scoped_workers;
use crate::util::shared::{PerWorker, SharedMut};

/// What one discovery sweep observed.
#[derive(Clone, Copy, Debug)]
pub struct SweepReport {
    /// Max violation over all metric rows, each measured at the moment
    /// just before its triplet's visit.
    pub max_violation: f64,
    /// Triplets visited (= C(n,3)).
    pub triplet_visits: u64,
}

/// Run one discovery sweep over every triplet; rebuilds `set` in place.
///
/// `x` must view the packed distance variables; the caller guarantees no
/// other access to them for the duration (same contract as the full
/// metric phase).
pub(crate) fn discovery_sweep(
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    col_starts: &[usize],
    schedule: &Schedule,
    set: &ActiveSet,
    p: usize,
    assignment: Assignment,
) -> SweepReport {
    let b = schedule.tile_size();
    let maxima = PerWorker::new(vec![f64::NEG_INFINITY; p]);
    scoped_workers(p, |tid, barrier| {
        let mut local_max = f64::NEG_INFINITY;
        for (wave_idx, wave) in schedule.waves().iter().enumerate() {
            let mut r = assignment.first_tile(tid, wave_idx, p);
            while r < wave.len() {
                let flat = set.flat_index(wave_idx, r);
                // SAFETY: this worker owns tile `r` of the current wave,
                // hence bucket `flat`, until the wave barrier.
                let bucket = unsafe { set.bucket_mut(flat) };
                let old = std::mem::take(bucket);
                let mut cursor = 0usize;
                for_each_triplet(&wave[r], b, |i, j, k| {
                    let key = triplet_key(i, j, k);
                    // Merge-scan: `old` is in cube order from the last
                    // rebuild (forgetting preserves order), the exact
                    // enumeration order here — O(1) per triplet.
                    let y = if cursor < old.len() && old[cursor].key == key {
                        cursor += 1;
                        old[cursor - 1].y
                    } else {
                        [0.0; 3]
                    };
                    let ci = col_starts[i];
                    let pij = ci + (j - i - 1);
                    let pik = ci + (k - i - 1);
                    let pjk = col_starts[j] + (k - j - 1);
                    // SAFETY: wave conflict-freeness gives exclusive
                    // access to the triplet's three variables.
                    unsafe {
                        let (x0, x1, x2) = (x.get(pij), x.get(pik), x.get(pjk));
                        let v = (x0 - x1 - x2).max(x1 - x0 - x2).max(x2 - x0 - x1);
                        if v > local_max {
                            local_max = v;
                        }
                        let th = visit_triplet(x, winv, pij, pik, pjk, y);
                        if th[0] != 0.0 || th[1] != 0.0 || th[2] != 0.0 {
                            bucket.push(ActiveTriplet { key, y: th, zero_passes: 0 });
                        }
                    }
                });
                debug_assert_eq!(cursor, old.len(), "stale active entries not consumed");
                r += p;
            }
            barrier.wait();
        }
        // SAFETY: slot `tid` belongs to this worker.
        unsafe { *maxima.get_mut(tid) = local_max };
    });
    let max_violation =
        maxima.into_inner().into_iter().fold(f64::NEG_INFINITY, f64::max).max(0.0);
    SweepReport { max_violation, triplet_visits: schedule.total_triplets() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::CcLpInstance;
    use crate::solver::duals::DualStore;
    use crate::solver::dykstra_parallel::run_metric_phase;
    use crate::solver::CcState;

    /// A sweep is bitwise a full metric pass: same x afterwards, and the
    /// rebuilt active set holds exactly the constraints a DualStore-based
    /// pass leaves with nonzero duals.
    #[test]
    fn sweep_is_bitwise_a_full_metric_pass() {
        let inst = CcLpInstance::random(18, 0.5, 0.7, 1.8, 11);
        let schedule = Schedule::new(18, 4);
        for p in [1usize, 3] {
            let mut sa = CcState::new(&inst, 5.0, true);
            let mut sb = CcState::new(&inst, 5.0, true);
            // Give the metric phase something to project: pull x toward d.
            for (xa, (xb, d)) in
                sa.x.iter_mut().zip(sb.x.iter_mut().zip(inst.d.as_slice()))
            {
                *xa = 0.9 * d;
                *xb = 0.9 * d;
            }
            let mut set = ActiveSet::new(&schedule);
            let stores = PerWorker::new((0..p).map(|_| DualStore::new()).collect());
            for _pass in 0..3 {
                {
                    let xs = SharedMut::new(sa.x.as_mut_slice());
                    discovery_sweep(
                        &xs,
                        &sa.winv,
                        &sa.col_starts,
                        &schedule,
                        &set,
                        p,
                        Assignment::RoundRobin,
                    );
                }
                run_metric_phase(&mut sb, &schedule, &stores, p, Assignment::RoundRobin);
                assert_eq!(sa.x, sb.x, "p={p}");
            }
            let mut stores = stores.into_inner();
            let store_nnz: usize = stores.iter_mut().map(|s| s.nnz()).sum();
            assert_eq!(set.nnz_duals(), store_nnz, "p={p}");
        }
    }

    #[test]
    fn sweep_reports_initial_violation_and_discovers() {
        // x = d (0/1 targets): a negative pair inside a positive triangle
        // violates the metric constraints, so the sweep must observe a
        // violation of exactly 1 and activate some triplets.
        let inst = CcLpInstance::unweighted(6, &[(0, 1)]);
        let mut st = CcState::new(&inst, 5.0, true);
        st.x.copy_from_slice(inst.d.as_slice());
        let schedule = Schedule::new(6, 2);
        let mut set = ActiveSet::new(&schedule);
        let report = {
            let xs = SharedMut::new(st.x.as_mut_slice());
            discovery_sweep(
                &xs,
                &st.winv,
                &st.col_starts,
                &schedule,
                &set,
                1,
                Assignment::RoundRobin,
            )
        };
        assert_eq!(report.triplet_visits, crate::solver::schedule::n_triplets(6));
        assert!((report.max_violation - 1.0).abs() < 1e-12, "{}", report.max_violation);
        assert!(!set.is_empty(), "violated constraints must be discovered");
        // every activated entry carries a nonzero dual
        for e in set.iter() {
            assert!(e.y.iter().any(|&v| v != 0.0));
            assert_eq!(e.zero_passes, 0);
        }
    }

    #[test]
    fn sweep_on_feasible_point_keeps_set_empty() {
        // x = 0 satisfies every metric row with zero duals -> no entries.
        let inst = CcLpInstance::random(9, 0.5, 0.8, 1.6, 5);
        let mut st = CcState::new(&inst, 5.0, true);
        let schedule = Schedule::new(9, 3);
        let mut set = ActiveSet::new(&schedule);
        let report = {
            let xs = SharedMut::new(st.x.as_mut_slice());
            discovery_sweep(
                &xs,
                &st.winv,
                &st.col_starts,
                &schedule,
                &set,
                2,
                Assignment::RoundRobin,
            )
        };
        assert_eq!(report.max_violation, 0.0);
        assert!(set.is_empty());
        assert!(st.x.iter().all(|&v| v == 0.0), "feasible point must not move");
    }
}
