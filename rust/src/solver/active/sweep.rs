//! Full-sweep violation discovery — the screen-then-project engine.
//!
//! A discovery sweep is a normal wave-parallel Dykstra pass over **all**
//! `C(n,3)` triplets that additionally (a) measures the largest metric
//! violation it encounters (each triplet inspected just before its visit
//! — the sweep's Gauss–Seidel residual, which [`crate::solver::termination`]
//! trusts for early stopping) and (b) rebuilds the active set to exactly
//! the triplets that finish the sweep holding a nonzero dual. A violated
//! constraint gets projected during the sweep, so it ends with a nonzero
//! dual and is discovered; a satisfied zero-dual constraint is a no-op
//! visit and is dropped. Because only zero-dual triplets are ever outside
//! the set, fetching "no entry" as `y = [0; 3]` is exact — discovery is
//! just the full pass with a different dual store.
//!
//! The sweep reuses the wave [`Schedule`] directly, so discovery itself
//! is conflict-free and parallel: same tile-to-worker assignment, same
//! cube order inside each tile, barriers between waves.
//!
//! # Screen-then-project
//!
//! After the first few rounds only a vanishing fraction of triplets are
//! violated or carry a nonzero dual, so almost every sweep visit is a
//! provable no-op. The [`SweepBackend::Screened`] path exploits this in
//! two phases, working one contiguous `k`-run at a time
//! ([`for_each_run`]):
//!
//! 1. **Screen** — broadcast `x_ij`, stream the contiguous `x[p_ik..]` /
//!    `x[p_jk..]` column segments, and compute each triplet's worst
//!    metric residual into a stripe buffer: a branch-free,
//!    auto-vectorizable loop with no key construction and no per-triplet
//!    index arithmetic. Merged with the bucket's ordered entries (the
//!    same merge-scan the scalar sweep uses), this yields a compact
//!    worklist of triplets that actually need work: violated now, or
//!    holding a nonzero dual.
//! 2. **Project** — visit only the worklist with the fused scalar kernel
//!    ([`visit_triplet`]), in cube order. A visit that moves `x` rewrites
//!    `x_ij`, which the rest of the run reads, so the tail of the stripe
//!    is re-screened after every projecting visit; between re-screens the
//!    stripe holds exactly the value the scalar sweep would measure just
//!    before each visit.
//!
//! Skipped triplets are satisfied with zero duals at the moment their
//! visit would have happened, so skipping them is an exact no-op — the
//! same invariant that lets the sweep drop them from the set. The
//! screened sweep is therefore **bitwise identical** to the scalar sweep:
//! same `x`, same rebuilt active set, same `max_violation` (tested, and
//! pinned by `tests/sweep_backends.rs`).
//!
//! [`SweepBackend::Engine`] runs the phase-1 screen through the
//! PJRT-compiled batch kernels instead (one [`XlaEngine::project_batch`]
//! probe per tile, f32), keeping phase 2 on the exact scalar kernel; it
//! falls back to `Screened` whenever no engine is supplied, which is
//! always the case under the offline `xla` stub. See
//! [`sweep_tile_engine`] for the f32 screen's accuracy caveats (it is a
//! throughput backend, not a tight-tolerance one).

use super::set::{decode_key, key_run_prefix, run_prefix, triplet_key, ActiveSet, ActiveTriplet};
use crate::matrix::store::{TileScratch, TileStore};
use crate::runtime::engine::XlaEngine;
use crate::solver::projection::visit_triplet;
use crate::solver::schedule::{next_owned_tile, Assignment, Schedule, Tile};
use crate::solver::tiling::{for_each_run, for_each_triplet};
use crate::solver::SweepBackend;
use crate::util::parallel::scoped_workers;
use crate::util::shared::{PerWorker, SharedMut};

/// What one discovery sweep observed.
#[derive(Clone, Copy, Debug)]
pub struct SweepReport {
    /// Max violation over all metric rows, each measured at the moment
    /// just before its triplet's visit.
    pub max_violation: f64,
    /// Triplets screened (= C(n,3)): every triplet is examined by every
    /// backend, so this is the stable work axis across backends and
    /// checkpoint resumes.
    pub triplet_visits: u64,
    /// Triplets that actually reached the projection kernel — violated
    /// at their visit, or holding a nonzero dual. The scalar backend
    /// projects everything, so there `triplets_projected ==
    /// triplet_visits`; `triplets_projected / triplet_visits` is the
    /// screen hit rate.
    pub triplets_projected: u64,
}

impl SweepReport {
    /// Fraction of screened triplets that needed a projection.
    pub fn hit_rate(&self) -> f64 {
        self.triplets_projected as f64 / (self.triplet_visits.max(1)) as f64
    }
}

/// Run one discovery sweep over every triplet; rebuilds `set` in place.
///
/// `store` holds the packed distance variables ([`TileStore`]); each
/// tile's working set is leased for exactly the duration of its visits,
/// so the sweep runs unchanged over the resident array and the
/// disk-backed store alike (and prefetches the worker's next tile in
/// sweep order). The caller guarantees no other access to the variables
/// for the duration (same contract as the full metric phase). `engine`
/// is consulted only by [`SweepBackend::Engine`]; passing `None` there
/// falls back to the (bitwise-equal) screened path.
#[allow(clippy::too_many_arguments)]
pub fn discovery_sweep(
    store: &dyn TileStore,
    schedule: &Schedule,
    set: &ActiveSet,
    p: usize,
    assignment: Assignment,
    backend: SweepBackend,
    engine: Option<&XlaEngine>,
) -> SweepReport {
    discovery_sweep_timed(store, schedule, set, p, assignment, backend, engine, None)
}

/// [`discovery_sweep`] with optional per-worker busy-seconds
/// accumulation (`worker_secs[tid]` gains each worker's in-wave wall
/// time; barrier waits are excluded). `None` adds no timing work.
// The lease callbacks carry their own `unsafe` blocks so they stay sound
// whether or not the enclosing block's context reaches into the closure.
#[allow(unused_unsafe)]
#[allow(clippy::too_many_arguments)]
pub(crate) fn discovery_sweep_timed(
    store: &dyn TileStore,
    schedule: &Schedule,
    set: &ActiveSet,
    p: usize,
    assignment: Assignment,
    backend: SweepBackend,
    engine: Option<&XlaEngine>,
    worker_secs: Option<&PerWorker<f64>>,
) -> SweepReport {
    let b = schedule.tile_size();
    let maxima = PerWorker::new(vec![f64::NEG_INFINITY; p]);
    let projected = PerWorker::new(vec![0u64; p]);
    scoped_workers(p, |tid, barrier| {
        let mut local_max = f64::NEG_INFINITY;
        let mut local_projected = 0u64;
        // Stripe buffer for the screen; runs never exceed the tile's
        // k-span, which the schedule caps at b.
        let mut stripe = vec![0.0f64; b];
        let mut lanes = EngineLanes::default();
        let mut scratch = TileScratch::default();
        for (wave_idx, wave) in schedule.waves().iter().enumerate() {
            let tb = crate::telemetry::busy_start(worker_secs);
            let mut r = assignment.first_tile(tid, wave_idx, p);
            while r < wave.len() {
                let tile = &wave[r];
                if let Some(next) = next_owned_tile(schedule, assignment, tid, p, wave_idx, r)
                {
                    store.prefetch(next);
                }
                let span = tile.k_hi - tile.k_lo;
                if stripe.len() < span {
                    stripe.resize(span, 0.0);
                }
                let flat = set.flat_index(wave_idx, r);
                // SAFETY: this worker owns tile `r` of the current wave,
                // hence bucket `flat`, until the wave barrier. Wave
                // conflict-freeness gives exclusive access to every
                // variable reachable from the tile (all tile fns below),
                // which is exactly the lease contract of `with_tile`.
                let bucket = unsafe { set.bucket_mut(flat) };
                let old = std::mem::take(bucket);
                let mut tile_projected = 0u64;
                unsafe {
                    store.with_tile(tile, &mut scratch, &mut |x, col_starts, winv| {
                        // SAFETY: forwarded from the lease contract.
                        tile_projected = unsafe {
                            match backend {
                                SweepBackend::Scalar => sweep_tile_scalar(
                                    x, winv, col_starts, tile, b, &old, bucket,
                                    &mut local_max,
                                ),
                                SweepBackend::Screened => sweep_tile_screened(
                                    x,
                                    winv,
                                    col_starts,
                                    tile,
                                    b,
                                    &old,
                                    bucket,
                                    &mut stripe,
                                    &mut local_max,
                                ),
                                SweepBackend::Engine => {
                                    // The probe mutates only scratch
                                    // lanes, so a failure (or no engine)
                                    // cleanly falls back to the screened
                                    // path before any visit.
                                    let probed = match engine {
                                        Some(eng) => engine_screen_flags(
                                            eng, x, winv, col_starts, tile, b, &mut lanes,
                                        )
                                        .is_ok(),
                                        None => false,
                                    };
                                    if probed {
                                        sweep_tile_engine(
                                            x,
                                            winv,
                                            col_starts,
                                            tile,
                                            b,
                                            &lanes.flags,
                                            &old,
                                            bucket,
                                            &mut local_max,
                                        )
                                    } else {
                                        sweep_tile_screened(
                                            x,
                                            winv,
                                            col_starts,
                                            tile,
                                            b,
                                            &old,
                                            bucket,
                                            &mut stripe,
                                            &mut local_max,
                                        )
                                    }
                                }
                            }
                        };
                    });
                }
                local_projected += tile_projected;
                r += p;
            }
            // SAFETY: slot `tid` belongs to this worker.
            unsafe { crate::telemetry::add_busy(worker_secs, tid, tb) };
            barrier.wait();
        }
        // SAFETY: slot `tid` belongs to this worker.
        unsafe {
            *maxima.get_mut(tid) = local_max;
            *projected.get_mut(tid) = local_projected;
        }
    });
    let max_violation =
        maxima.into_inner().into_iter().fold(f64::NEG_INFINITY, f64::max).max(0.0);
    SweepReport {
        max_violation,
        triplet_visits: schedule.total_triplets(),
        triplets_projected: projected.into_inner().into_iter().sum(),
    }
}

/// Exact max metric violation over all `C(n,3)` triplets, measured
/// through tile leases — the confirming/final residual scan of the
/// disk-backed drivers. The in-memory drivers keep their direct
/// lexicographic scan (`nearness::violation`); both compute a plain
/// max of the same residuals, so the values agree exactly.
#[allow(unused_unsafe)]
pub fn exact_violation(store: &dyn TileStore, schedule: &Schedule, p: usize) -> f64 {
    let b = schedule.tile_size();
    let maxima = PerWorker::new(vec![f64::NEG_INFINITY; p]);
    scoped_workers(p, |tid, barrier| {
        let mut local_max = f64::NEG_INFINITY;
        let mut scratch = TileScratch::default();
        for (wave_idx, wave) in schedule.waves().iter().enumerate() {
            let mut r = Assignment::RoundRobin.first_tile(tid, wave_idx, p);
            while r < wave.len() {
                let tile = &wave[r];
                // SAFETY: tile ownership per wave. The read-only lease
                // keeps a disk store clean — a residual scan must not
                // dirty every block it visits.
                unsafe {
                    store.with_tile_read(tile, &mut scratch, &mut |x, col_starts, _winv| {
                        for_each_run(tile, b, |i, j, k0, k1| {
                            let ci = col_starts[i];
                            let pij = ci + (j - i - 1);
                            let pik0 = ci + (k0 - i - 1);
                            let pjk0 = col_starts[j] + (k0 - j - 1);
                            // SAFETY: lease addressing is in bounds, and
                            // the read-only lease means nothing writes the
                            // run while the slices live. Slice iteration
                            // keeps the loop auto-vectorizable; the
                            // residual expression and max-fold order are
                            // unchanged, so the scan stays bitwise equal
                            // to the direct one.
                            let x0 = unsafe { x.get(pij) };
                            let xs1 = unsafe { x.slice(pik0, pik0 + (k1 - k0)) };
                            let xs2 = unsafe { x.slice(pjk0, pjk0 + (k1 - k0)) };
                            for (&x1, &x2) in xs1.iter().zip(xs2) {
                                let v =
                                    (x0 - x1 - x2).max(x1 - x0 - x2).max(x2 - x0 - x1);
                                if v > local_max {
                                    local_max = v;
                                }
                            }
                        });
                    });
                }
                r += p;
            }
            barrier.wait();
        }
        // SAFETY: slot `tid` belongs to this worker.
        unsafe { *maxima.get_mut(tid) = local_max };
    });
    maxima.into_inner().into_iter().fold(f64::NEG_INFINITY, f64::max).max(0.0)
}

/// The original callback sweep over one tile: visit every triplet.
///
/// # Safety
/// Exclusive access to the tile's variables and bucket (wave invariant).
#[allow(clippy::too_many_arguments)]
unsafe fn sweep_tile_scalar(
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    col_starts: &[usize],
    tile: &Tile,
    b: usize,
    old: &[ActiveTriplet],
    bucket: &mut Vec<ActiveTriplet>,
    local_max: &mut f64,
) -> u64 {
    let mut cursor = 0usize;
    let mut projected = 0u64;
    for_each_triplet(tile, b, |i, j, k| {
        let key = triplet_key(i, j, k);
        // Merge-scan: `old` is in cube order from the last rebuild
        // (forgetting preserves order), the exact enumeration order here
        // — O(1) per triplet.
        let y = if cursor < old.len() && old[cursor].key == key {
            cursor += 1;
            old[cursor - 1].y
        } else {
            [0.0; 3]
        };
        let ci = col_starts[i];
        let pij = ci + (j - i - 1);
        let pik = ci + (k - i - 1);
        let pjk = col_starts[j] + (k - j - 1);
        // SAFETY: wave conflict-freeness gives exclusive access to the
        // triplet's three variables.
        unsafe {
            let (x0, x1, x2) = (x.get(pij), x.get(pik), x.get(pjk));
            let v = (x0 - x1 - x2).max(x1 - x0 - x2).max(x2 - x0 - x1);
            if v > *local_max {
                *local_max = v;
            }
            let th = visit_triplet(x, winv, pij, pik, pjk, y);
            projected += 1;
            if th[0] != 0.0 || th[1] != 0.0 || th[2] != 0.0 {
                bucket.push(ActiveTriplet { key, y: th, zero_passes: 0 });
            }
        }
    });
    debug_assert_eq!(cursor, old.len(), "stale active entries not consumed");
    projected
}

/// Screen-then-project over one tile, run by run (bitwise equal to
/// [`sweep_tile_scalar`]).
///
/// # Safety
/// Exclusive access to the tile's variables and bucket (wave invariant).
#[allow(clippy::too_many_arguments)]
unsafe fn sweep_tile_screened(
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    col_starts: &[usize],
    tile: &Tile,
    b: usize,
    old: &[ActiveTriplet],
    bucket: &mut Vec<ActiveTriplet>,
    stripe: &mut [f64],
    local_max: &mut f64,
) -> u64 {
    let mut cursor = 0usize;
    let mut projected = 0u64;
    for_each_run(tile, b, |i, j, k0, k1| {
        // The bucket's entries for this run sit contiguously at the
        // cursor: cube order enumerates runs in this exact order, and a
        // key's run prefix identifies the run.
        let run_hi = run_prefix(i, j);
        let e_start = cursor;
        while cursor < old.len() && key_run_prefix(old[cursor].key) == run_hi {
            cursor += 1;
        }
        let ci = col_starts[i];
        let pij = ci + (j - i - 1);
        let pik0 = ci + (k0 - i - 1);
        let pjk0 = col_starts[j] + (k0 - j - 1);
        // SAFETY: forwarded wave invariant.
        projected += unsafe {
            project_run(
                x,
                winv,
                i,
                j,
                pij,
                pik0,
                pjk0,
                k0,
                k1 - k0,
                &old[e_start..cursor],
                bucket,
                stripe,
                local_max,
            )
        };
    });
    debug_assert_eq!(cursor, old.len(), "stale active entries not consumed");
    projected
}

/// Branch-free violation screen of (part of) one run: `stripe[t]` gets
/// the worst metric residual of triplet `(i, j, k0 + t)` for
/// `t ∈ [lo, hi)`, computed with the exact expression (and operation
/// order) of the scalar sweep. `x_ij` is broadcast; `x[p_ik..]` and
/// `x[p_jk..]` stream down contiguous column segments.
///
/// # Safety
/// Indices in bounds; exclusive access to the run's variables.
#[inline]
unsafe fn screen_run(
    x: &SharedMut<'_, f64>,
    pij: usize,
    pik0: usize,
    pjk0: usize,
    lo: usize,
    hi: usize,
    stripe: &mut [f64],
) {
    let x0 = x.get(pij);
    // Plain-slice iteration over the two contiguous column segments: no
    // per-element bounds checks or raw-pointer `add`s in the loop body,
    // so the compiler can unroll and vectorize the stripe. Exact same
    // per-element expression and evaluation order as before — results
    // stay bitwise identical to the scalar sweep.
    let xs1 = x.slice(pik0 + lo, pik0 + hi);
    let xs2 = x.slice(pjk0 + lo, pjk0 + hi);
    for ((s, &x1), &x2) in stripe[lo..hi].iter_mut().zip(xs1).zip(xs2) {
        *s = (x0 - x1 - x2).max(x1 - x0 - x2).max(x2 - x0 - x1);
    }
}

/// Phase 2 for one run: walk the screened stripe in cube order, visiting
/// only triplets that are violated or hold a dual. A projecting visit
/// rewrites `x_ij`, which the rest of the stripe reads, so positions
/// past the last write are stale; the walk consumes each position
/// exactly once in order, so a stale position is recomputed lazily at
/// the moment it is consumed (O(1) each, O(len) per run total — not the
/// O(len · writes) an eager tail re-screen would cost on the dense
/// early sweeps). Either way the consumed value is exactly the
/// pre-visit residual the scalar sweep would measure.
///
/// # Safety
/// Exclusive access to the run's variables and the bucket.
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn project_run(
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    i: usize,
    j: usize,
    pij: usize,
    pik0: usize,
    pjk0: usize,
    k0: usize,
    len: usize,
    entries: &[ActiveTriplet],
    bucket: &mut Vec<ActiveTriplet>,
    stripe: &mut [f64],
    local_max: &mut f64,
) -> u64 {
    screen_run(x, pij, pik0, pjk0, 0, len, stripe);
    // Positions >= stale_from were screened before the latest write to
    // `x_ij` and must be recomputed when consumed.
    let mut stale_from = len;
    let mut projected = 0u64;
    let mut pos = 0usize;
    let mut ei = 0usize;
    loop {
        // Next triplet holding a dual (entries are in ascending k).
        let next_ek = if ei < entries.len() {
            decode_key(entries[ei].key).2 - k0
        } else {
            usize::MAX
        };
        // Scan to the next triplet needing work; everything passed over
        // is satisfied with zero duals — an exact no-op to skip, after
        // folding its residual into the running max.
        let mut f = pos;
        loop {
            if f >= len {
                break;
            }
            if f >= stale_from {
                screen_run(x, pij, pik0, pjk0, f, f + 1, stripe);
            }
            if f == next_ek || stripe[f] > 0.0 {
                break;
            }
            if stripe[f] > *local_max {
                *local_max = stripe[f];
            }
            f += 1;
        }
        if f >= len {
            break;
        }
        if stripe[f] > *local_max {
            *local_max = stripe[f];
        }
        let y = if f == next_ek {
            ei += 1;
            entries[ei - 1].y
        } else {
            [0.0; 3]
        };
        let th = visit_triplet(x, winv, pij, pik0 + f, pjk0 + f, y);
        projected += 1;
        if th != [0.0; 3] {
            bucket.push(ActiveTriplet {
                key: triplet_key(i, j, k0 + f),
                y: th,
                zero_passes: 0,
            });
        }
        pos = f + 1;
        if pos >= len {
            break;
        }
        // `visit_triplet` wrote back iff it had a dual to correct or
        // projected something; everything after this position is stale.
        if y != [0.0; 3] || th != [0.0; 3] {
            stale_from = pos;
        }
    }
    debug_assert_eq!(ei, entries.len(), "stale run entries not consumed");
    projected
}

/// Scratch for the engine probe: one f32 lane per triplet of a tile.
#[derive(Default)]
struct EngineLanes {
    x3: Vec<f32>,
    w3: Vec<f32>,
    y3: Vec<f32>,
    /// `flags[lane]` = the probe kernel emitted a dual for the lane,
    /// i.e. the triplet screened as violated (in f32).
    flags: Vec<bool>,
}

/// Phase-1 screen of one tile through the PJRT engine: pack every
/// triplet into an f32 lane, run one [`XlaEngine::project_batch`] probe
/// on scratch copies (zero duals in), and flag the lanes the kernel
/// projected. Mutates only `lanes`, so a failure leaves the sweep free
/// to fall back to the screened path.
///
/// # Safety
/// Exclusive read access to the tile's variables.
unsafe fn engine_screen_flags(
    eng: &XlaEngine,
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    col_starts: &[usize],
    tile: &Tile,
    b: usize,
    lanes: &mut EngineLanes,
) -> anyhow::Result<()> {
    lanes.x3.clear();
    lanes.w3.clear();
    lanes.y3.clear();
    for_each_run(tile, b, |i, j, k0, k1| {
        let ci = col_starts[i];
        let pij = ci + (j - i - 1);
        let pik0 = ci + (k0 - i - 1);
        let pjk0 = col_starts[j] + (k0 - j - 1);
        for t in 0..k1 - k0 {
            // SAFETY: forwarded from the caller's wave invariant.
            unsafe {
                lanes.x3.extend([
                    x.get(pij) as f32,
                    x.get(pik0 + t) as f32,
                    x.get(pjk0 + t) as f32,
                ]);
                lanes.w3.extend([
                    winv[pij] as f32,
                    winv[pik0 + t] as f32,
                    winv[pjk0 + t] as f32,
                ]);
            }
            lanes.y3.extend([0.0f32; 3]);
        }
    });
    eng.project_batch(&mut lanes.x3, &lanes.w3, &mut lanes.y3)?;
    lanes.flags.clear();
    lanes
        .flags
        .extend(lanes.y3.chunks_exact(3).map(|y| y[0] != 0.0 || y[1] != 0.0 || y[2] != 0.0));
    Ok(())
}

/// Phase 2 of the engine sweep: visit flagged-or-dual triplets with the
/// exact scalar kernel, in cube order. Two approximations, both of
/// which the exact confirming scan guards against ever producing a
/// falsely-converged result: (a) flags are not refreshed after writes,
/// so a violation created mid-tile surfaces one sweep late; (b) a
/// violation below f32 resolution screens as satisfied, and — because
/// every engine sweep repeats the same f32 probe — keeps screening as
/// satisfied, so the engine backend cannot drive such a row feasible at
/// all and a solve with `tol_violation` near f32 resolution may never
/// pass its confirming scan (it runs to `max_passes` instead of
/// terminating early). Use `Screened` for tight tolerances; the engine
/// backend targets throughput at f32-scale accuracy. The measured
/// violation covers visited rows only.
///
/// # Safety
/// Exclusive access to the tile's variables and bucket (wave invariant).
#[allow(clippy::too_many_arguments)]
unsafe fn sweep_tile_engine(
    x: &SharedMut<'_, f64>,
    winv: &[f64],
    col_starts: &[usize],
    tile: &Tile,
    b: usize,
    flags: &[bool],
    old: &[ActiveTriplet],
    bucket: &mut Vec<ActiveTriplet>,
    local_max: &mut f64,
) -> u64 {
    let mut cursor = 0usize;
    let mut lane = 0usize;
    let mut projected = 0u64;
    for_each_run(tile, b, |i, j, k0, k1| {
        let run_hi = run_prefix(i, j);
        let e_start = cursor;
        while cursor < old.len() && key_run_prefix(old[cursor].key) == run_hi {
            cursor += 1;
        }
        let entries = &old[e_start..cursor];
        let mut ei = 0usize;
        let ci = col_starts[i];
        let pij = ci + (j - i - 1);
        let pik0 = ci + (k0 - i - 1);
        let pjk0 = col_starts[j] + (k0 - j - 1);
        for t in 0..k1 - k0 {
            let has_dual = ei < entries.len() && decode_key(entries[ei].key).2 == k0 + t;
            if !(flags[lane] || has_dual) {
                lane += 1;
                continue;
            }
            let y = if has_dual {
                ei += 1;
                entries[ei - 1].y
            } else {
                [0.0; 3]
            };
            // SAFETY: forwarded wave invariant.
            unsafe {
                let (pik, pjk) = (pik0 + t, pjk0 + t);
                let (x0, x1, x2) = (x.get(pij), x.get(pik), x.get(pjk));
                let v = (x0 - x1 - x2).max(x1 - x0 - x2).max(x2 - x0 - x1);
                if v > *local_max {
                    *local_max = v;
                }
                let th = visit_triplet(x, winv, pij, pik, pjk, y);
                projected += 1;
                if th != [0.0; 3] {
                    bucket.push(ActiveTriplet {
                        key: triplet_key(i, j, k0 + t),
                        y: th,
                        zero_passes: 0,
                    });
                }
            }
            lane += 1;
        }
        debug_assert_eq!(ei, entries.len(), "stale run entries not consumed");
    });
    debug_assert_eq!(cursor, old.len(), "stale active entries not consumed");
    debug_assert_eq!(lane, flags.len(), "engine lanes out of step with the tile");
    projected
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::CcLpInstance;
    use crate::matrix::store::MemStore;
    use crate::solver::duals::DualStore;
    use crate::solver::dykstra_parallel::run_metric_phase;
    use crate::solver::CcState;

    const ALL_BACKENDS: [SweepBackend; 3] =
        [SweepBackend::Scalar, SweepBackend::Screened, SweepBackend::Engine];

    fn sweep(
        st: &mut CcState,
        schedule: &Schedule,
        set: &ActiveSet,
        p: usize,
        backend: SweepBackend,
    ) -> SweepReport {
        let store = MemStore::new(st.x.as_mut_slice(), &st.col_starts, &st.winv);
        discovery_sweep(&store, schedule, set, p, Assignment::RoundRobin, backend, None)
    }

    /// A sweep is bitwise a full metric pass: same x afterwards, and the
    /// rebuilt active set holds exactly the constraints a DualStore-based
    /// pass leaves with nonzero duals. Holds for every backend.
    #[test]
    fn sweep_is_bitwise_a_full_metric_pass() {
        let inst = CcLpInstance::random(18, 0.5, 0.7, 1.8, 11);
        let schedule = Schedule::new(18, 4);
        for backend in ALL_BACKENDS {
            for p in [1usize, 3] {
                let mut sa = CcState::new(&inst, 5.0, true);
                let mut sb = CcState::new(&inst, 5.0, true);
                // Give the metric phase something to project: pull x toward d.
                for (xa, (xb, d)) in
                    sa.x.iter_mut().zip(sb.x.iter_mut().zip(inst.d.as_slice()))
                {
                    *xa = 0.9 * d;
                    *xb = 0.9 * d;
                }
                let mut set = ActiveSet::new(&schedule);
                let stores = PerWorker::new((0..p).map(|_| DualStore::new()).collect());
                for _pass in 0..3 {
                    sweep(&mut sa, &schedule, &set, p, backend);
                    run_metric_phase(&mut sb, &schedule, &stores, p, Assignment::RoundRobin);
                    assert_eq!(sa.x, sb.x, "{backend:?} p={p}");
                }
                let mut stores = stores.into_inner();
                let store_nnz: usize = stores.iter_mut().map(|s| s.nnz()).sum();
                assert_eq!(set.nnz_duals(), store_nnz, "{backend:?} p={p}");
            }
        }
    }

    /// The acceptance pin of the screened engine: every backend (Engine
    /// without artifacts falls back to Screened) reproduces the scalar
    /// sweep bitwise — same x trajectory, same rebuilt set, same
    /// max_violation — across tile sizes and worker counts, over several
    /// consecutive sweeps of a live solve state.
    #[test]
    fn screened_and_engine_sweeps_bitwise_match_scalar() {
        for (n, tile) in [(17usize, 2usize), (18, 4), (19, 7)] {
            let inst = CcLpInstance::random(n, 0.5, 0.7, 1.8, n as u64);
            let schedule = Schedule::new(n, tile);
            for p in [1usize, 3] {
                let mut st_ref = CcState::new(&inst, 5.0, true);
                for (v, d) in st_ref.x.iter_mut().zip(inst.d.as_slice()) {
                    *v = 0.9 * d;
                }
                let mut st_scr = CcState::new(&inst, 5.0, true);
                st_scr.x.copy_from_slice(&st_ref.x);
                let mut st_eng = CcState::new(&inst, 5.0, true);
                st_eng.x.copy_from_slice(&st_ref.x);
                let mut set_ref = ActiveSet::new(&schedule);
                let mut set_scr = ActiveSet::new(&schedule);
                let mut set_eng = ActiveSet::new(&schedule);
                for pass in 0..4 {
                    let ra = sweep(&mut st_ref, &schedule, &set_ref, p, SweepBackend::Scalar);
                    let rb =
                        sweep(&mut st_scr, &schedule, &set_scr, p, SweepBackend::Screened);
                    let rc = sweep(&mut st_eng, &schedule, &set_eng, p, SweepBackend::Engine);
                    let ctx = format!("n={n} tile={tile} p={p} pass={pass}");
                    assert_eq!(st_ref.x, st_scr.x, "screened x diverged ({ctx})");
                    assert_eq!(st_ref.x, st_eng.x, "engine-fallback x diverged ({ctx})");
                    assert_eq!(ra.max_violation, rb.max_violation, "{ctx}");
                    assert_eq!(ra.max_violation, rc.max_violation, "{ctx}");
                    assert_eq!(ra.triplet_visits, rb.triplet_visits, "{ctx}");
                    assert_eq!(rb.triplets_projected, rc.triplets_projected, "{ctx}");
                    // The scalar backend projects everything; the screen
                    // must do no more than that.
                    assert_eq!(ra.triplets_projected, ra.triplet_visits, "{ctx}");
                    assert!(rb.triplets_projected <= rb.triplet_visits, "{ctx}");
                    let entries = |s: &mut ActiveSet| -> Vec<ActiveTriplet> {
                        s.iter().copied().collect()
                    };
                    assert_eq!(entries(&mut set_ref), entries(&mut set_scr), "{ctx}");
                    assert_eq!(entries(&mut set_ref), entries(&mut set_eng), "{ctx}");
                }
            }
        }
    }

    /// Once the dual support has sparsified, the screen projects only a
    /// small fraction of the triplets it examines.
    #[test]
    fn screen_hit_rate_drops_as_the_solve_converges() {
        let inst = CcLpInstance::random(20, 0.5, 0.7, 1.8, 31);
        let schedule = Schedule::new(20, 4);
        let mut st = CcState::new(&inst, 5.0, true);
        for (v, d) in st.x.iter_mut().zip(inst.d.as_slice()) {
            *v = 0.9 * d;
        }
        let set = ActiveSet::new(&schedule);
        let first = sweep(&mut st, &schedule, &set, 2, SweepBackend::Screened);
        let mut last = first;
        for _ in 0..30 {
            last = sweep(&mut st, &schedule, &set, 2, SweepBackend::Screened);
        }
        assert!(
            last.triplets_projected < first.triplets_projected,
            "late sweeps must project less: first {} vs last {}",
            first.triplets_projected,
            last.triplets_projected
        );
        assert!(last.hit_rate() < 0.5, "late hit rate {}", last.hit_rate());
    }

    #[test]
    fn sweep_reports_initial_violation_and_discovers() {
        // x = d (0/1 targets): a negative pair inside a positive triangle
        // violates the metric constraints, so the sweep must observe a
        // violation of exactly 1 and activate some triplets.
        for backend in ALL_BACKENDS {
            let inst = CcLpInstance::unweighted(6, &[(0, 1)]);
            let mut st = CcState::new(&inst, 5.0, true);
            st.x.copy_from_slice(inst.d.as_slice());
            let schedule = Schedule::new(6, 2);
            let mut set = ActiveSet::new(&schedule);
            let report = sweep(&mut st, &schedule, &set, 1, backend);
            assert_eq!(report.triplet_visits, crate::solver::schedule::n_triplets(6));
            assert!(
                (report.max_violation - 1.0).abs() < 1e-12,
                "{backend:?}: {}",
                report.max_violation
            );
            assert!(!set.is_empty(), "violated constraints must be discovered");
            // every activated entry carries a nonzero dual
            for e in set.iter() {
                assert!(e.y.iter().any(|&v| v != 0.0));
                assert_eq!(e.zero_passes, 0);
            }
        }
    }

    #[test]
    fn exact_violation_matches_the_direct_scan() {
        // The store-addressed residual scan must agree exactly with the
        // lexicographic scan the in-memory drivers use (plain max of the
        // same residuals, order-independent).
        let inst = CcLpInstance::random(15, 0.5, 0.7, 1.8, 23);
        let mut st = CcState::new(&inst, 5.0, true);
        for (v, d) in st.x.iter_mut().zip(inst.d.as_slice()) {
            *v = 0.9 * d;
        }
        let schedule = Schedule::new(15, 4);
        for p in [1usize, 3] {
            let direct = crate::solver::nearness::violation(&st.x, &st.col_starts, 15, p);
            let store = MemStore::new(st.x.as_mut_slice(), &st.col_starts, &st.winv);
            assert_eq!(exact_violation(&store, &schedule, p), direct, "p={p}");
        }
    }

    #[test]
    fn sweep_on_feasible_point_keeps_set_empty() {
        // x = 0 satisfies every metric row with zero duals -> no entries,
        // and the screen projects nothing at all.
        for backend in ALL_BACKENDS {
            let inst = CcLpInstance::random(9, 0.5, 0.8, 1.6, 5);
            let mut st = CcState::new(&inst, 5.0, true);
            let schedule = Schedule::new(9, 3);
            let mut set = ActiveSet::new(&schedule);
            let report = sweep(&mut st, &schedule, &set, 2, backend);
            assert_eq!(report.max_violation, 0.0, "{backend:?}");
            assert!(set.is_empty(), "{backend:?}");
            assert!(st.x.iter().all(|&v| v == 0.0), "feasible point must not move");
            if backend != SweepBackend::Scalar {
                assert_eq!(report.triplets_projected, 0, "{backend:?}");
            }
        }
    }
}
