//! The retention policy: forget constraints whose duals stayed zero.
//!
//! A triplet whose three duals are exactly zero contributes nothing to
//! the next visit's correction step, so skipping it changes the iterate
//! only if it has become violated in the meantime — and the periodic
//! discovery sweep bounds how long such a violation can go unnoticed.
//! Dropping zero-dual entries after `forget_after` consecutive zero-dual
//! active passes therefore preserves Dykstra's convergence (the
//! project-and-forget argument): constraints with nonzero duals are
//! *never* forgotten, so no correction memory is ever lost.

use super::set::ActiveSet;

/// Drop every active triplet whose duals are all zero **and** have been
/// zero for at least `forget_after` consecutive active passes. Returns
/// the number of triplets forgotten. `forget_after = 0` forgets a
/// triplet the moment its duals hit zero.
pub fn forget_inactive(set: &mut ActiveSet, forget_after: usize) -> usize {
    let threshold = forget_after.min(u32::MAX as usize) as u32;
    let mut dropped = 0usize;
    for bucket in set.buckets_mut() {
        let before = bucket.len();
        bucket.retain(|e| e.y != [0.0; 3] || e.zero_passes < threshold);
        dropped += before - bucket.len();
    }
    dropped
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::active::set::{triplet_key, ActiveTriplet};
    use crate::solver::schedule::Schedule;

    fn entry(k: usize, y0: f64, zero_passes: u32) -> ActiveTriplet {
        ActiveTriplet { key: triplet_key(0, 1, k), y: [y0, 0.0, 0.0], zero_passes }
    }

    #[test]
    fn drops_only_persistently_zero_entries() {
        let schedule = Schedule::new(12, 3);
        let mut set = ActiveSet::new(&schedule);
        {
            let b = unsafe { set.bucket_mut(0) };
            b.push(entry(2, 0.7, 0)); // live dual: kept regardless
            b.push(entry(3, 0.0, 1)); // zero for 1 pass: kept at K = 2
            b.push(entry(4, 0.0, 2)); // zero for 2 passes: dropped at K = 2
            b.push(entry(5, 0.0, 9)); // long-dead: dropped
        }
        let dropped = forget_inactive(&mut set, 2);
        assert_eq!(dropped, 2);
        let keys: Vec<u64> = set.iter().map(|e| e.key).collect();
        assert_eq!(keys, vec![triplet_key(0, 1, 2), triplet_key(0, 1, 3)]);
    }

    #[test]
    fn forget_after_zero_is_immediate() {
        let schedule = Schedule::new(12, 3);
        let mut set = ActiveSet::new(&schedule);
        {
            let b = unsafe { set.bucket_mut(0) };
            b.push(entry(2, 0.0, 0));
            b.push(entry(3, 0.3, 0));
        }
        assert_eq!(forget_inactive(&mut set, 0), 1);
        assert_eq!(set.len(), 1);
        // a nonzero dual is never forgotten, whatever its streak says
        assert_eq!(forget_inactive(&mut set, 0), 0);
    }

    #[test]
    fn order_within_bucket_is_preserved() {
        // The sweep's merge-scan requires retain() to keep cube order.
        let schedule = Schedule::new(12, 3);
        let mut set = ActiveSet::new(&schedule);
        {
            let b = unsafe { set.bucket_mut(0) };
            for k in 2..8 {
                b.push(entry(k, if k % 2 == 0 { 0.4 } else { 0.0 }, 5));
            }
        }
        forget_inactive(&mut set, 1);
        let keys: Vec<u64> = set.iter().map(|e| e.key).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
        assert_eq!(keys.len(), 3);
    }
}
