//! `ActiveDykstra` — the project-and-forget active-set driver.
//!
//! After the first few passes of Dykstra's method only a small fraction
//! of the `3·C(n,3)` metric constraints are violated or carry nonzero
//! duals (the sparsity §III-D exploits for storage). This subsystem
//! exploits it for *work*: cheap passes visit only the active set
//! ([`set::ActiveSet`]), a full discovery sweep ([`sweep`]) runs every
//! `sweep_every` passes to catch constraints that became violated while
//! unwatched, and the retention policy ([`forget`]) drops constraints
//! whose duals stayed zero. Constraints holding a nonzero dual are never
//! dropped, so no Dykstra correction memory is lost; sweeps make the
//! visit order quasi-cyclic, which preserves convergence to the same
//! unique projection as the full solver (Sonthalia & Gilbert 2020).
//!
//! Both phases reuse the wave [`Schedule`] and its tile-to-worker
//! [`Assignment`], so every visit — sparse or dense — stays lock-free and
//! conflict-free, and results are bitwise independent of the worker
//! count, exactly like the full parallel solver. With
//! `sweep_every = 1` and convergence checks off every pass is a sweep
//! and the driver reproduces the full solver bitwise (tested).
//!
//! Discovery sweeps run on a pluggable [`SweepBackend`] (the
//! screen-then-project engine of [`sweep`]; the screened and scalar
//! backends are bitwise interchangeable) and fire on a [`SweepPolicy`]
//! cadence ([`cadence`]): the classic fixed `sweep_every`, or an
//! adaptive trigger driven by active-set shrinkage stalls and
//! trusted-violation plateaus.
//!
//! Termination trusts the last sweep: cheap passes cannot see constraints
//! outside the active set, so convergence is only ever screened at sweep
//! passes, using the sweep's measured max violation together with exact
//! pair/box residuals
//! ([`termination::compute_residuals_trusting_sweep`]). A stop is
//! declared only after one exact scan confirms the screen, and final
//! residuals are always recomputed exactly — the tolerance contract of
//! the returned solution matches the full solver's.

pub mod cadence;
pub mod forget;
pub mod set;
pub mod sweep;

use self::cadence::SweepCadence;
use self::set::{decode_key, ActiveSet};
use self::sweep::{discovery_sweep_timed, SweepReport};
use super::backing::XBacking;
use super::checkpoint::{CheckRecord, SolverState};
use super::dykstra_parallel::{emit_retries, run_pair_phase_timed};
use super::error::SolveError;
use super::nearness::{NearnessOpts, NearnessSolution};
use super::projection::visit_triplet;
use super::schedule::{Assignment, Schedule};
use super::termination::{compute_residuals_stored, compute_residuals_trusting_sweep_stored};
use super::watchdog::Watchdog;
use super::{
    CcState, OnInterrupt, Residuals, Solution, SolveOpts, Strategy, SweepBackend, SweepPolicy,
};
use crate::instance::metric_nearness::MetricNearnessInstance;
use crate::instance::CcLpInstance;
use crate::matrix::store::{StoreCfg, TileScratch, TileStore};
use crate::matrix::PackedSym;
use crate::runtime::engine::XlaEngine;
use crate::telemetry::{
    self, Counters, Event, NullRecorder, PassKind, PhaseName, PhaseProbe, Recorder,
};
use crate::util::parallel::scoped_workers;
use crate::util::shared::PerWorker;

/// Unpacked parameters of [`Strategy::Active`].
#[derive(Clone, Copy, Debug)]
pub struct ActiveParams {
    /// Full discovery sweep every this many passes (clamped to >= 1).
    pub sweep_every: usize,
    /// Forget after this many consecutive zero-dual active passes.
    pub forget_after: usize,
}

impl ActiveParams {
    /// Extract from a [`Strategy`]; `None` for [`Strategy::Full`].
    pub fn from_strategy(s: Strategy) -> Option<ActiveParams> {
        match s {
            Strategy::Active { sweep_every, forget_after } => {
                Some(ActiveParams { sweep_every: sweep_every.max(1), forget_after })
            }
            Strategy::Full => None,
        }
    }

    /// The cadence policy: an explicit option wins, otherwise the
    /// strategy's fixed `sweep_every`.
    pub fn policy(&self, opt: Option<SweepPolicy>) -> SweepPolicy {
        opt.unwrap_or(SweepPolicy::Fixed(self.sweep_every))
    }
}

/// Resolve the engine the sweep backend needs: `Engine` tries to load
/// the PJRT artifacts once per solve and falls back to the
/// (bitwise-equal) screened path when they are unavailable — which is
/// always the case under the offline `xla` stub. The fallback is
/// reported as a [`Event::Warn`] through the solve's recorder (or the
/// global [`telemetry::warn`] channel), never printed directly.
fn load_sweep_engine(backend: SweepBackend, rec: &dyn Recorder) -> Option<XlaEngine> {
    match backend {
        SweepBackend::Engine => {
            match XlaEngine::load(crate::runtime::DEFAULT_ARTIFACTS_DIR) {
                Ok(engine) => Some(engine),
                Err(e) => {
                    let msg = format!(
                        "sweep backend `engine`: PJRT artifacts unavailable ({e}); \
                         falling back to the bitwise-equal screened backend"
                    );
                    if rec.enabled() {
                        rec.record(&Event::Warn { msg });
                    } else {
                        telemetry::warn(&msg);
                    }
                    None
                }
            }
        }
        _ => None,
    }
}

/// One cheap pass over only the active set. Tile ownership is identical
/// to the full metric phase, so concurrent visits stay conflict-free;
/// within a tile, entries sit (and are visited) in cube order. Tiles
/// whose bucket is empty are skipped without leasing their working set,
/// and non-empty tiles go through the entry-granular
/// [`TileStore::with_entries`] lease, so on a disk-backed [`TileStore`]
/// a cheap pass only touches the blocks holding the pairs its duals
/// actually name — I/O scales with the active set, not tile geometry.
/// Returns the number of triplets visited.
pub fn active_pass(
    store: &dyn TileStore,
    schedule: &Schedule,
    set: &ActiveSet,
    p: usize,
    assignment: Assignment,
) -> u64 {
    active_pass_timed(store, schedule, set, p, assignment, None)
}

/// [`active_pass`] with optional per-worker busy-seconds accumulation
/// (`worker_secs[tid]` gains each worker's in-wave wall time; barrier
/// waits are excluded). `None` adds no timing work at all.
#[allow(unused_unsafe)]
pub(crate) fn active_pass_timed(
    store: &dyn TileStore,
    schedule: &Schedule,
    set: &ActiveSet,
    p: usize,
    assignment: Assignment,
    worker_secs: Option<&PerWorker<f64>>,
) -> u64 {
    let counts = PerWorker::new(vec![0u64; p]);
    scoped_workers(p, |tid, barrier| {
        let mut visited = 0u64;
        let mut scratch = TileScratch::default();
        // Reusable copy of the bucket's keys: the enumerator borrows it
        // immutably while the kernel callback holds the bucket `&mut`.
        let mut keys: Vec<u64> = Vec::new();
        for (wave_idx, wave) in schedule.waves().iter().enumerate() {
            let tb = telemetry::busy_start(worker_secs);
            let mut r = assignment.first_tile(tid, wave_idx, p);
            while r < wave.len() {
                let tile = &wave[r];
                let flat = set.flat_index(wave_idx, r);
                // SAFETY: this worker owns tile `r` of the current wave,
                // hence bucket `flat`, until the wave barrier.
                let bucket = unsafe { set.bucket_mut(flat) };
                if !bucket.is_empty() {
                    keys.clear();
                    keys.extend(bucket.iter().map(|e| e.key));
                    // SAFETY: wave conflict-freeness gives exclusive
                    // access to every pair reachable from the tile — the
                    // lease contract of `with_entries`; the enumerator
                    // names every pair the kernel below touches (the
                    // three sides of each active triplet).
                    unsafe {
                        store.with_entries(
                            tile,
                            &mut |emit| {
                                for &key in keys.iter() {
                                    let (i, j, k) = decode_key(key);
                                    emit(i, j);
                                    emit(i, k);
                                    emit(j, k);
                                }
                            },
                            &mut scratch,
                            &mut |x, col_starts, winv| {
                                for e in bucket.iter_mut() {
                                    let (i, j, k) = decode_key(e.key);
                                    let ci = col_starts[i];
                                    let pij = ci + (j - i - 1);
                                    let pik = ci + (k - i - 1);
                                    let pjk = col_starts[j] + (k - j - 1);
                                    // SAFETY: same contract as the full hot
                                    // loop, forwarded through the lease.
                                    let th = unsafe {
                                        visit_triplet(x, winv, pij, pik, pjk, e.y)
                                    };
                                    e.y = th;
                                    if th == [0.0; 3] {
                                        e.zero_passes += 1;
                                    } else {
                                        e.zero_passes = 0;
                                    }
                                }
                            },
                        );
                    }
                }
                visited += bucket.len() as u64;
                r += p;
            }
            // SAFETY: slot `tid` belongs to this worker.
            unsafe { telemetry::add_busy(worker_secs, tid, tb) };
            barrier.wait();
        }
        // SAFETY: slot `tid` belongs to this worker.
        unsafe { *counts.get_mut(tid) += visited };
    });
    counts.into_inner().into_iter().sum()
}

/// Solve the CC-LP instance with the active-set strategy.
///
/// Called by [`super::dykstra_parallel::solve`] when
/// `opts.strategy` is [`Strategy::Active`]; panics on [`Strategy::Full`].
pub fn solve_cc(inst: &CcLpInstance, opts: &SolveOpts) -> Solution {
    solve_cc_checkpointed(inst, opts, None, &mut |_| {})
        .expect("cold active solve cannot fail")
}

/// Continue a saved CC-LP solve with the active-set strategy. The saved
/// membership (with forget streaks) is rebuilt into the tile buckets;
/// states saved by a full-strategy driver seed the set from their
/// nonzero duals instead. With unchanged options, resuming a state saved
/// by this driver reproduces the uninterrupted run bitwise.
pub fn resume_cc(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    state: &SolverState,
) -> anyhow::Result<Solution> {
    solve_cc_checkpointed(inst, opts, Some(state), &mut |_| {})
}

/// Full-control active-set entry point (resume + checkpoint sink); see
/// [`super::dykstra_parallel::solve_checkpointed`], which dispatches
/// here for [`Strategy::Active`]. Runs on the in-memory store; use
/// [`solve_cc_stored`] to pick the backend.
pub fn solve_cc_checkpointed(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<Solution> {
    solve_cc_stored(inst, opts, &StoreCfg::mem(), resume_from, on_checkpoint)
}

/// The active-set CC-LP driver, generic over the `X` storage backend
/// ([`StoreCfg`]): the in-memory configuration reproduces the classic
/// driver exactly; the disk configuration streams `X` (and the inverse
/// weights) from a [`crate::matrix::store::DiskStore`] through every
/// phase — sweeps, cheap active passes, the pair phase, and the
/// residual scans — so the solve runs at `n` beyond RAM **bitwise
/// identically** (pinned by `tests/store_equivalence.rs`). With a disk
/// store, checkpoints reference the store file (flushed and stamped at
/// each capture) instead of re-serializing `x`.
pub fn solve_cc_stored(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<Solution> {
    Ok(solve_cc_traced(inst, opts, store_cfg, resume_from, on_checkpoint, &NullRecorder)?)
}

/// [`solve_cc_stored`] with a telemetry [`Recorder`] attached. All
/// instrumentation is gated on [`Recorder::enabled`], so passing
/// [`NullRecorder`] reproduces the untraced solve bitwise (pinned by
/// `tests/telemetry.rs`).
///
/// This is the typed-error boundary: store failures, interrupts, and
/// watchdog trips come back as the matching [`SolveError`] variant.
pub fn solve_cc_traced(
    inst: &CcLpInstance,
    opts: &SolveOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<Solution, SolveError> {
    let params = ActiveParams::from_strategy(opts.strategy)
        .expect("active::solve_cc requires SolveOpts::strategy = Strategy::Active");
    let mut cadence = SweepCadence::new(params.policy(opts.sweep_policy));
    let engine = load_sweep_engine(opts.sweep_backend, rec);
    let schedule = Schedule::new(inst.n, opts.tile);
    let p = opts.threads.max(1);
    let mut state = match resume_from {
        Some(st) => {
            st.validate_cc(inst, opts)?;
            st.restore_cc_state(inst, opts)
        }
        None => CcState::new(inst, opts.gamma, opts.include_box),
    };
    // The backing takes ownership of the packed iterate (state.x is left
    // empty); every phase below leases it back through a TileStore.
    let mut backing = XBacking::init_cc(&mut state, opts.tile, store_cfg, resume_from)?;
    let mut active = ActiveSet::new(&schedule);
    let mut triplet_visits = 0u64;
    let mut start_pass = 0usize;
    // Next passes_done at which a convergence check becomes due, honoring
    // the configured cadence even though checks can only fire at sweeps.
    let mut next_check = opts.check_every;
    // Warm starts arrive with a seeded set: their first pass is a cheap
    // pass, deferring discovery to the next scheduled sweep.
    let mut skip_sweep_at_start = false;
    let mut history: Vec<CheckRecord> = Vec::new();
    if let Some(st) = resume_from {
        active.seed(&schedule, st.active_entries());
        triplet_visits = st.triplet_visits;
        start_pass = st.pass as usize;
        if st.next_check > 0 {
            next_check = st.next_check as usize;
        }
        skip_sweep_at_start = st.skip_initial_sweep;
        history = st.history.clone();
    }
    let mut last_sweep: Option<SweepReport> = None;
    let mut pass_times = Vec::new();
    let mut passes_done = start_pass;
    let mut last_saved = usize::MAX;
    // Screen hit-rate accounting for this run segment (sweeps only).
    let mut sweep_screened = 0u64;
    let mut sweep_projected = 0u64;
    // Exact residuals of the confirming scan on early stop (state does
    // not change between that scan and the end of the loop).
    let mut exact_at_break: Option<Residuals> = None;
    let pairs_per_pass = (inst.n * (inst.n - 1) / 2) as u64;
    let mut probe = PhaseProbe::new(rec, p);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);

    for pass in start_pass..opts.max_passes {
        let t0 = std::time::Instant::now();
        // Pass 0 discovers — unless a warm start already seeded the set.
        let is_sweep =
            cadence.wants_sweep(pass) && !(skip_sweep_at_start && pass == start_pass);
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart {
            pass: pass_no,
            kind: if is_sweep { PassKind::Sweep } else { PassKind::Cheap },
        });
        if is_sweep {
            let pt = probe.start();
            let ws = probe.workers();
            let report = backing.with_store(&state.col_starts, &state.winv, |store| {
                discovery_sweep_timed(
                    store,
                    &schedule,
                    &active,
                    p,
                    opts.assignment,
                    opts.sweep_backend,
                    engine.as_ref(),
                    ws.as_ref(),
                )
            });
            triplet_visits += report.triplet_visits;
            sweep_screened += report.triplet_visits;
            sweep_projected += report.triplets_projected;
            probe.finish(pass_no, PhaseName::Sweep, pt, report.triplet_visits, ws);
            probe.emit(Event::Sweep {
                pass: pass_no,
                screened: report.triplet_visits,
                projected: report.triplets_projected,
                max_violation: report.max_violation,
            });
            last_sweep = Some(report);
        } else {
            let pt = probe.start();
            let ws = probe.workers();
            let visited = backing.with_store(&state.col_starts, &state.winv, |store| {
                active_pass_timed(store, &schedule, &active, p, opts.assignment, ws.as_ref())
            });
            triplet_visits += visited;
            probe.finish(pass_no, PhaseName::Metric, pt, visited, ws);
        }
        if is_sweep {
            cadence.note_sweep(last_sweep.expect("sweep pass recorded a report").max_violation);
            if probe.on() {
                probe.emit(Event::ActiveSet {
                    pass: pass_no,
                    size: active.len() as u64,
                    forgotten: 0,
                });
            }
        } else {
            let dropped = forget::forget_inactive(&mut active, params.forget_after);
            let size = active.len();
            cadence.note_cheap(size);
            if probe.on() {
                probe.emit(Event::ActiveSet {
                    pass: pass_no,
                    size: size as u64,
                    forgotten: dropped as u64,
                });
            }
        }
        {
            let pt = probe.start();
            let ws = probe.workers();
            let CcState { col_starts, winv, f, y_upper, y_lower, y_box, d, include_box, .. } =
                &mut state;
            let ib = *include_box;
            backing.with_store(col_starts.as_slice(), winv.as_slice(), |store| {
                run_pair_phase_timed(store, f, y_upper, y_lower, y_box, d, ib, p, ws.as_ref())
            });
            probe.finish(pass_no, PhaseName::Pair, pt, pairs_per_pass, ws);
        }
        // A failed lease parks inside the wave (barriers cannot unwind
        // mid-pass); the latched error surfaces here, once per pass.
        backing.health()?;
        emit_retries(&probe, pass_no, backing.drain_retries());
        passes_done = pass + 1;
        if opts.track_pass_times {
            pass_times.push(t0.elapsed().as_secs_f64());
        }
        // Convergence is only decided at sweep passes, where the last
        // trusted measurement of every metric row is at most one pair
        // phase old. The trusted residuals are a cheap *screen*: when
        // they pass, one exact scan confirms before stopping (the pair
        // phase that ran after the sweep can re-break metric rows the
        // sweep measured feasible), so the returned tolerance guarantee
        // is exact. Pass 0 is excluded: its sweep measured the *initial*
        // point x = 0, which is metric-feasible by construction.
        let mut stop = false;
        if opts.check_every > 0 && is_sweep && pass > 0 && passes_done >= next_check {
            while next_check <= passes_done {
                next_check += opts.check_every;
            }
            let report = last_sweep.expect("sweep pass recorded a report");
            let pt = probe.start();
            let r = backing.with_store(&state.col_starts, &state.winv, |store| {
                compute_residuals_trusting_sweep_stored(&state, store, p, report.max_violation)
            });
            probe.finish(pass_no, PhaseName::ResidualScan, pt, 0, None);
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation: r.max_violation,
                rel_gap: r.rel_gap,
                lp_objective: r.lp_objective,
                exact: false,
            });
            history.push(CheckRecord {
                pass: passes_done as u64,
                max_violation: r.max_violation,
                rel_gap: r.rel_gap,
            });
            watchdog.observe(passes_done, r.max_violation, r.rel_gap, &history)?;
            if r.max_violation <= opts.tol_violation && r.rel_gap.abs() <= opts.tol_gap {
                let pt = probe.start();
                let exact = backing.with_store(&state.col_starts, &state.winv, |store| {
                    compute_residuals_stored(&state, store, &schedule, p)
                });
                probe.finish(
                    pass_no,
                    PhaseName::ResidualScan,
                    pt,
                    schedule.total_triplets(),
                    None,
                );
                probe.emit(Event::Residuals {
                    pass: pass_no,
                    max_violation: exact.max_violation,
                    rel_gap: exact.rel_gap,
                    lp_objective: exact.lp_objective,
                    exact: true,
                });
                // The exact confirming scan is authoritative: its values
                // are what the history records and (on a stop) what
                // `Solution::residuals` reports — never the sweep's
                // screen, which is one pair phase stale.
                if let Some(last) = history.last_mut() {
                    last.max_violation = exact.max_violation;
                    last.rel_gap = exact.rel_gap;
                }
                if exact.max_violation <= opts.tol_violation
                    && exact.rel_gap.abs() <= opts.tol_gap
                {
                    exact_at_break = Some(exact);
                    stop = true;
                }
            }
        }
        if opts.checkpoint_every > 0 && (passes_done % opts.checkpoint_every == 0 || stop) {
            let pt = probe.start();
            on_checkpoint(&capture_cc_active_backed(
                &state,
                &mut backing,
                &mut active,
                passes_done,
                triplet_visits,
                next_check,
                &history,
            )?);
            probe.finish(pass_no, PhaseName::Checkpoint, pt, 0, None);
            last_saved = passes_done;
        }
        if probe.on() {
            if let Some(stats) = backing.store_stats() {
                probe.emit(Event::StoreIo { pass: pass_no, stats });
            }
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t0.elapsed().as_secs_f64(),
                triplet_visits,
                active_triplets: active.len() as u64,
            });
        }
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted() {
            let checkpointed = opts.checkpoint_every > 0;
            if checkpointed && last_saved != passes_done {
                on_checkpoint(&capture_cc_active_backed(
                    &state,
                    &mut backing,
                    &mut active,
                    passes_done,
                    triplet_visits,
                    next_check,
                    &history,
                )?);
            }
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed });
        }
        if stop {
            break;
        }
    }
    if opts.checkpoint_every > 0 && last_saved != passes_done {
        let pt = probe.start();
        on_checkpoint(&capture_cc_active_backed(
            &state,
            &mut backing,
            &mut active,
            passes_done,
            triplet_visits,
            next_check,
            &history,
        )?);
        probe.finish(passes_done as u64, PhaseName::Checkpoint, pt, 0, None);
    }

    // Final residuals are always exact (the O(n^3) scan), so active and
    // full solutions are directly comparable.
    let mut residuals = match exact_at_break {
        Some(r) => r,
        None => {
            let pt = probe.start();
            let r = backing.with_store(&state.col_starts, &state.winv, |store| {
                compute_residuals_stored(&state, store, &schedule, p)
            });
            probe.finish(
                passes_done as u64,
                PhaseName::ResidualScan,
                pt,
                schedule.total_triplets(),
                None,
            );
            probe.emit(Event::Residuals {
                pass: passes_done as u64,
                max_violation: r.max_violation,
                rel_gap: r.rel_gap,
                lp_objective: r.lp_objective,
                exact: true,
            });
            r
        }
    };
    let active_now = active.len();
    let nnz_duals = active.nnz_duals();
    residuals.metric_visits = triplet_visits * 3;
    residuals.active_triplets = active_now;
    residuals.sweep_screened = sweep_screened;
    residuals.sweep_projected = sweep_projected;
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: triplet_visits * 3,
                active_triplets: active_now as u64,
                sweep_screened,
                sweep_projected,
                nnz_duals: nnz_duals as u64,
                max_violation: residuals.max_violation,
                rel_gap: residuals.rel_gap,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: backing.store_stats(),
            },
        });
    }
    let x_final = backing.extract()?;
    let mut xm = PackedSym::zeros(inst.n);
    xm.as_mut_slice().copy_from_slice(&x_final);
    Ok(Solution {
        x: xm,
        f: Some(state.f_matrix()),
        passes: passes_done,
        residuals,
        pass_times,
        nnz_duals,
        metric_visits: triplet_visits * 3,
        active_triplets: active_now,
        sweep_screened,
        sweep_projected,
        store_stats: backing.store_stats(),
    })
}

/// Capture an active-strategy CC-LP checkpoint against either backing:
/// inline `x` for the memory store, a flush-and-stamp reference for the
/// disk store.
fn capture_cc_active_backed(
    state: &CcState,
    backing: &mut XBacking,
    active: &mut ActiveSet,
    passes_done: usize,
    triplet_visits: u64,
    next_check: usize,
    history: &[CheckRecord],
) -> Result<SolverState, SolveError> {
    Ok(match backing {
        XBacking::Mem { x } => SolverState::capture_cc_active(
            state,
            x,
            active,
            passes_done,
            triplet_visits,
            next_check,
            history,
        ),
        backing @ (XBacking::Disk { .. } | XBacking::Shard { .. }) => {
            let x_fnv = backing
                .stamp_external(passes_done as u64)?
                .expect("external backings always stamp");
            SolverState::capture_cc_active_external(
                state,
                x_fnv,
                active,
                passes_done,
                triplet_visits,
                next_check,
                history,
            )
        }
    })
}

/// Solve metric nearness with the active-set strategy.
///
/// Called by [`super::nearness::solve`] when `opts.strategy` is
/// [`Strategy::Active`]; panics on [`Strategy::Full`].
pub fn solve_nearness(inst: &MetricNearnessInstance, opts: &NearnessOpts) -> NearnessSolution {
    solve_nearness_checkpointed(inst, opts, None, &mut |_| {})
        .expect("cold active nearness solve cannot fail")
}

/// Continue a saved nearness solve with the active-set strategy (see
/// [`resume_cc`] for the seeding semantics).
pub fn resume_nearness(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    state: &SolverState,
) -> anyhow::Result<NearnessSolution> {
    solve_nearness_checkpointed(inst, opts, Some(state), &mut |_| {})
}

/// Full-control active-set nearness entry point (resume + checkpoint
/// sink); [`super::nearness::solve_checkpointed`] dispatches here for
/// [`Strategy::Active`]. Runs on the in-memory store; use
/// [`solve_nearness_stored`] to pick the backend.
pub fn solve_nearness_checkpointed(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<NearnessSolution> {
    solve_nearness_stored(inst, opts, &StoreCfg::mem(), resume_from, on_checkpoint)
}

/// The active-set nearness driver, generic over the `X` storage backend
/// ([`StoreCfg`]): the in-memory configuration reproduces the classic
/// driver exactly, the disk configuration streams `X` from a
/// [`crate::matrix::store::DiskStore`] so the solve runs at `n` beyond
/// RAM — bitwise identically (pinned by `tests/store_equivalence.rs`).
/// With a disk store, checkpoints reference the store file (flushed and
/// stamped at each capture) instead of re-serializing `x`.
pub fn solve_nearness_stored(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
) -> anyhow::Result<NearnessSolution> {
    Ok(solve_nearness_traced(inst, opts, store_cfg, resume_from, on_checkpoint, &NullRecorder)?)
}

/// [`solve_nearness_stored`] with a telemetry [`Recorder`] attached.
/// All instrumentation is gated on [`Recorder::enabled`], so passing
/// [`NullRecorder`] reproduces the untraced solve bitwise (pinned by
/// `tests/telemetry.rs`).
///
/// This is the typed-error boundary: store failures, interrupts, and
/// watchdog trips come back as the matching [`SolveError`] variant.
pub fn solve_nearness_traced(
    inst: &MetricNearnessInstance,
    opts: &NearnessOpts,
    store_cfg: &StoreCfg,
    resume_from: Option<&SolverState>,
    on_checkpoint: &mut dyn FnMut(&SolverState),
    rec: &dyn Recorder,
) -> Result<NearnessSolution, SolveError> {
    let params = ActiveParams::from_strategy(opts.strategy)
        .expect("active::solve_nearness requires NearnessOpts::strategy = Strategy::Active");
    let mut cadence = SweepCadence::new(params.policy(opts.sweep_policy));
    let engine = load_sweep_engine(opts.sweep_backend, rec);
    let n = inst.n;
    let p = opts.threads.max(1);
    let schedule = Schedule::new(n, opts.tile);
    let winv: Vec<f64> = inst.w.as_slice().iter().map(|&v| 1.0 / v).collect();
    let col_starts = inst.d.col_starts().to_vec();
    if let Some(st) = resume_from {
        st.validate_nearness(inst)?;
    }
    let mut backing = XBacking::init_nearness(inst, opts.tile, store_cfg, resume_from)?;
    let mut active = ActiveSet::new(&schedule);
    let mut triplet_visits = 0u64;
    let mut start_pass = 0usize;
    let mut next_check = opts.check_every;
    let mut skip_sweep_at_start = false;
    let mut history: Vec<CheckRecord> = Vec::new();
    if let Some(st) = resume_from {
        active.seed(&schedule, st.active_entries());
        triplet_visits = st.triplet_visits;
        start_pass = st.pass as usize;
        if st.next_check > 0 {
            next_check = st.next_check as usize;
        }
        skip_sweep_at_start = st.skip_initial_sweep;
        history = st.history.clone();
    }
    let mut last_sweep: Option<SweepReport> = None;
    let mut passes_done = start_pass;
    let mut last_saved = usize::MAX;
    // Screen hit-rate accounting for this run segment (sweeps only).
    let mut sweep_screened = 0u64;
    let mut sweep_projected = 0u64;
    // Exact violation of the confirming scan on early stop (x does not
    // change between that scan and the end of the loop).
    let mut exact_at_break: Option<f64> = None;
    let mut probe = PhaseProbe::new(rec, p);
    let mut watchdog = Watchdog::new(opts.watchdog_stall);

    for pass in start_pass..opts.max_passes {
        let t_pass = probe.start();
        let is_sweep =
            cadence.wants_sweep(pass) && !(skip_sweep_at_start && pass == start_pass);
        let pass_no = (pass + 1) as u64;
        probe.emit(Event::PassStart {
            pass: pass_no,
            kind: if is_sweep { PassKind::Sweep } else { PassKind::Cheap },
        });
        if is_sweep {
            let pt = probe.start();
            let ws = probe.workers();
            let report = backing.with_store(&col_starts, &winv, |store| {
                discovery_sweep_timed(
                    store,
                    &schedule,
                    &active,
                    p,
                    opts.assignment,
                    opts.sweep_backend,
                    engine.as_ref(),
                    ws.as_ref(),
                )
            });
            triplet_visits += report.triplet_visits;
            sweep_screened += report.triplet_visits;
            sweep_projected += report.triplets_projected;
            probe.finish(pass_no, PhaseName::Sweep, pt, report.triplet_visits, ws);
            probe.emit(Event::Sweep {
                pass: pass_no,
                screened: report.triplet_visits,
                projected: report.triplets_projected,
                max_violation: report.max_violation,
            });
            last_sweep = Some(report);
        } else {
            let pt = probe.start();
            let ws = probe.workers();
            let visited = backing.with_store(&col_starts, &winv, |store| {
                active_pass_timed(store, &schedule, &active, p, opts.assignment, ws.as_ref())
            });
            triplet_visits += visited;
            probe.finish(pass_no, PhaseName::Metric, pt, visited, ws);
        }
        if is_sweep {
            cadence.note_sweep(last_sweep.expect("sweep pass recorded a report").max_violation);
            if probe.on() {
                probe.emit(Event::ActiveSet {
                    pass: pass_no,
                    size: active.len() as u64,
                    forgotten: 0,
                });
            }
        } else {
            let dropped = forget::forget_inactive(&mut active, params.forget_after);
            let size = active.len();
            cadence.note_cheap(size);
            if probe.on() {
                probe.emit(Event::ActiveSet {
                    pass: pass_no,
                    size: size as u64,
                    forgotten: dropped as u64,
                });
            }
        }
        // A failed lease parks inside the wave (barriers cannot unwind
        // mid-pass); the latched error surfaces here, once per pass.
        backing.health()?;
        emit_retries(&probe, pass_no, backing.drain_retries());
        passes_done = pass + 1;
        // The sweep's mid-pass measurement is a cheap screen (later
        // projections in the same sweep can re-break rows measured
        // feasible earlier); when it passes, one exact scan confirms
        // before stopping, making the tolerance guarantee exact. The
        // history records the exact scan's value whenever one ran.
        let mut stop = false;
        if opts.check_every > 0 && is_sweep && passes_done >= next_check {
            while next_check <= passes_done {
                next_check += opts.check_every;
            }
            let screened = last_sweep.expect("sweep pass recorded a report").max_violation;
            probe.emit(Event::Residuals {
                pass: pass_no,
                max_violation: screened,
                rel_gap: 0.0,
                lp_objective: 0.0,
                exact: false,
            });
            history.push(CheckRecord {
                pass: passes_done as u64,
                max_violation: screened,
                rel_gap: 0.0,
            });
            watchdog.observe(passes_done, screened, 0.0, &history)?;
            if screened <= opts.tol_violation {
                let pt = probe.start();
                let v = backing.violation(&col_starts, n, p, &schedule);
                probe.finish(
                    pass_no,
                    PhaseName::ResidualScan,
                    pt,
                    schedule.total_triplets(),
                    None,
                );
                probe.emit(Event::Residuals {
                    pass: pass_no,
                    max_violation: v,
                    rel_gap: 0.0,
                    lp_objective: 0.0,
                    exact: true,
                });
                if let Some(last) = history.last_mut() {
                    last.max_violation = v;
                }
                if v <= opts.tol_violation {
                    exact_at_break = Some(v);
                    stop = true;
                }
            }
        }
        if opts.checkpoint_every > 0 && (passes_done % opts.checkpoint_every == 0 || stop) {
            let pt = probe.start();
            on_checkpoint(&capture_nearness_active_backed(
                inst,
                &mut backing,
                &mut active,
                passes_done,
                triplet_visits,
                next_check,
                &history,
            )?);
            probe.finish(pass_no, PhaseName::Checkpoint, pt, 0, None);
            last_saved = passes_done;
        }
        if probe.on() {
            if let Some(stats) = backing.store_stats() {
                probe.emit(Event::StoreIo { pass: pass_no, stats });
            }
            probe.emit(Event::PassEnd {
                pass: pass_no,
                secs: t_pass.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0),
                triplet_visits,
                active_triplets: active.len() as u64,
            });
        }
        if opts.on_interrupt == OnInterrupt::Checkpoint && crate::util::interrupt::interrupted() {
            let checkpointed = opts.checkpoint_every > 0;
            if checkpointed && last_saved != passes_done {
                on_checkpoint(&capture_nearness_active_backed(
                    inst,
                    &mut backing,
                    &mut active,
                    passes_done,
                    triplet_visits,
                    next_check,
                    &history,
                )?);
            }
            return Err(SolveError::Interrupted { pass: passes_done, checkpointed });
        }
        if stop {
            break;
        }
    }
    if opts.checkpoint_every > 0 && last_saved != passes_done {
        let pt = probe.start();
        on_checkpoint(&capture_nearness_active_backed(
            inst,
            &mut backing,
            &mut active,
            passes_done,
            triplet_visits,
            next_check,
            &history,
        )?);
        probe.finish(passes_done as u64, PhaseName::Checkpoint, pt, 0, None);
    }

    let max_violation = match exact_at_break {
        Some(v) => v,
        None => {
            let pt = probe.start();
            let v = backing.violation(&col_starts, n, p, &schedule);
            probe.finish(
                passes_done as u64,
                PhaseName::ResidualScan,
                pt,
                schedule.total_triplets(),
                None,
            );
            probe.emit(Event::Residuals {
                pass: passes_done as u64,
                max_violation: v,
                rel_gap: 0.0,
                lp_objective: 0.0,
                exact: true,
            });
            v
        }
    };
    let active_now = active.len();
    if probe.on() {
        probe.emit(Event::Footer {
            counters: Counters {
                passes: passes_done as u64,
                metric_visits: triplet_visits * 3,
                active_triplets: active_now as u64,
                sweep_screened,
                sweep_projected,
                nnz_duals: active.nnz_duals() as u64,
                max_violation,
                rel_gap: 0.0,
                phase_secs: probe.wall_totals(),
                worker_busy_secs: probe.busy_totals(),
                store: backing.store_stats(),
            },
        });
    }
    let x_final = backing.extract()?;
    let mut xm = PackedSym::zeros(n);
    xm.as_mut_slice().copy_from_slice(&x_final);
    Ok(NearnessSolution {
        objective: inst.objective(&xm),
        x: xm,
        max_violation,
        passes: passes_done,
        metric_visits: triplet_visits * 3,
        active_triplets: active_now,
        sweep_screened,
        sweep_projected,
        store_stats: backing.store_stats(),
    })
}

/// Capture an active-strategy nearness checkpoint against either
/// backing: inline `x` for the memory store, a flush-and-stamp reference
/// for the disk store.
fn capture_nearness_active_backed(
    inst: &MetricNearnessInstance,
    backing: &mut XBacking,
    active: &mut ActiveSet,
    passes_done: usize,
    triplet_visits: u64,
    next_check: usize,
    history: &[CheckRecord],
) -> Result<SolverState, SolveError> {
    Ok(match backing {
        XBacking::Mem { x } => SolverState::capture_nearness_active(
            inst,
            x,
            active,
            passes_done,
            triplet_visits,
            next_check,
            history,
        ),
        backing @ (XBacking::Disk { .. } | XBacking::Shard { .. }) => {
            let x_fnv = backing
                .stamp_external(passes_done as u64)?
                .expect("external backings always stamp");
            SolverState::capture_nearness_active_external(
                inst,
                x_fnv,
                active,
                passes_done,
                triplet_visits,
                next_check,
                history,
            )
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::PackedSym;
    use crate::prop_assert;
    use crate::solver::{dykstra_parallel, nearness};
    use crate::util::proptest::check;

    fn active(sweep_every: usize, forget_after: usize) -> Strategy {
        Strategy::Active { sweep_every, forget_after }
    }

    fn max_diff(a: &PackedSym, b: &PackedSym) -> f64 {
        let mut worst = 0.0f64;
        for (i, j, v) in a.iter_pairs() {
            worst = worst.max((v - b.get(i, j)).abs());
        }
        worst
    }

    /// Run full and active at growing pass budgets until the iterates
    /// agree coordinate-wise within `tol`; both converge geometrically to
    /// the same unique projection, so this terminates. Also checks the
    /// active run did measurably less metric work.
    fn cc_agrees(
        inst: &CcLpInstance,
        strategy: Strategy,
        threads: usize,
        tol: f64,
    ) -> Result<(), String> {
        let mut passes = 200usize;
        let mut last = f64::INFINITY;
        while passes <= 6400 {
            let base = SolveOpts {
                max_passes: passes,
                threads,
                tile: 5,
                check_every: 0,
                ..Default::default()
            };
            let full = dykstra_parallel::solve(inst, &base);
            let act = dykstra_parallel::solve(inst, &SolveOpts { strategy, ..base });
            if act.metric_visits >= full.metric_visits {
                return Err(format!(
                    "active visited {} >= full {}",
                    act.metric_visits, full.metric_visits
                ));
            }
            last = max_diff(&full.x, &act.x);
            if last <= tol {
                return Ok(());
            }
            passes *= 2;
        }
        Err(format!("full vs active still differ by {last} after 6400 passes"))
    }

    fn nearness_agrees(
        inst: &MetricNearnessInstance,
        strategy: Strategy,
        threads: usize,
        tol: f64,
    ) -> Result<(), String> {
        let mut passes = 200usize;
        let mut last = f64::INFINITY;
        while passes <= 6400 {
            let base = NearnessOpts {
                max_passes: passes,
                threads,
                tile: 6,
                check_every: 0,
                ..Default::default()
            };
            let full = nearness::solve(inst, &base);
            let act = nearness::solve(inst, &NearnessOpts { strategy, ..base });
            if act.metric_visits >= full.metric_visits {
                return Err(format!(
                    "active visited {} >= full {}",
                    act.metric_visits, full.metric_visits
                ));
            }
            last = max_diff(&full.x, &act.x);
            if last <= tol {
                return Ok(());
            }
            passes *= 2;
        }
        Err(format!("full vs active still differ by {last} after 6400 passes"))
    }

    #[test]
    fn sweep_every_one_is_bitwise_the_full_solver() {
        let inst = CcLpInstance::random(15, 0.5, 0.8, 1.6, 3);
        for p in [1usize, 4] {
            let base =
                SolveOpts { max_passes: 7, threads: p, tile: 3, ..Default::default() };
            let full = dykstra_parallel::solve(&inst, &base);
            let act = dykstra_parallel::solve(
                &inst,
                &SolveOpts { strategy: active(1, 2), ..base },
            );
            assert_eq!(full.x, act.x, "p={p}");
            assert_eq!(full.f, act.f, "p={p}");
            assert_eq!(full.nnz_duals, act.nnz_duals, "p={p}");
            assert_eq!(full.metric_visits, act.metric_visits, "p={p}");
        }
    }

    #[test]
    fn nearness_sweep_every_one_is_bitwise_full() {
        let inst = MetricNearnessInstance::random(14, 2.0, 21);
        let base = NearnessOpts { max_passes: 6, threads: 2, tile: 3, ..Default::default() };
        let full = nearness::solve(&inst, &base);
        let act = nearness::solve(&inst, &NearnessOpts { strategy: active(1, 1), ..base });
        assert_eq!(full.x, act.x);
        assert_eq!(full.metric_visits, act.metric_visits);
    }

    #[test]
    fn active_is_thread_count_invariant_bitwise() {
        let inst = CcLpInstance::random(14, 0.5, 0.8, 1.6, 9);
        let mk = |p| SolveOpts {
            max_passes: 12,
            threads: p,
            tile: 3,
            strategy: active(4, 1),
            ..Default::default()
        };
        let a = dykstra_parallel::solve(&inst, &mk(1));
        let b = dykstra_parallel::solve(&inst, &mk(4));
        assert_eq!(a.x, b.x);
        assert_eq!(a.f, b.f);
        assert_eq!(a.metric_visits, b.metric_visits);
        assert_eq!(a.active_triplets, b.active_triplets);
        assert_eq!(a.nnz_duals, b.nnz_duals);
    }

    #[test]
    fn active_matches_full_cc_within_tolerance_property() {
        // ISSUE acceptance: ActiveDykstra matches the full parallel
        // solution within 1e-6 on random CC-LP instances, threads {1, 4}.
        check("active vs full CC-LP", 0xACC1, 3, |rng, _| {
            let n = rng.usize_in(6, 21);
            let inst = CcLpInstance::random(n, 0.5, 0.8, 1.6, rng.next_u64());
            let strategy = active(rng.usize_in(2, 9), rng.usize_in(0, 4));
            for threads in [1usize, 4] {
                if let Err(msg) = cc_agrees(&inst, strategy, threads, 1e-6) {
                    prop_assert!(false, "n={n} {strategy:?} p={threads}: {msg}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn active_matches_full_nearness_within_tolerance_property() {
        // Same property on metric nearness, instance sizes up to n = 48.
        check("active vs full nearness", 0xACC2, 3, |rng, _| {
            let n = rng.usize_in(8, 49);
            let inst = MetricNearnessInstance::random(n, 2.0, rng.next_u64());
            let strategy = active(rng.usize_in(2, 9), rng.usize_in(0, 4));
            for threads in [1usize, 4] {
                if let Err(msg) = nearness_agrees(&inst, strategy, threads, 1e-6) {
                    prop_assert!(false, "n={n} {strategy:?} p={threads}: {msg}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn active_set_shrinks_as_the_solve_converges() {
        let inst = CcLpInstance::random(20, 0.5, 0.8, 1.6, 41);
        let opts = SolveOpts {
            max_passes: 800,
            threads: 2,
            tile: 4,
            strategy: active(6, 2),
            ..Default::default()
        };
        let sol = dykstra_parallel::solve(&inst, &opts);
        let total = crate::solver::schedule::n_triplets(20) as usize;
        assert!(
            sol.active_triplets < total,
            "active set ({}) should be a strict subset of {total}",
            sol.active_triplets
        );
        assert!(sol.metric_visits < 800 * total as u64 * 3, "must beat the full-visit count");
        assert!(sol.residuals.max_violation < 1e-2, "still must converge");
    }

    #[test]
    fn early_stop_via_trusted_sweep() {
        let inst = MetricNearnessInstance::random(16, 2.0, 77);
        let opts = NearnessOpts {
            max_passes: 5_000,
            check_every: 1,
            tol_violation: 1e-6,
            threads: 2,
            tile: 4,
            strategy: active(5, 2),
            ..Default::default()
        };
        let sol = nearness::solve(&inst, &opts);
        assert!(sol.passes < 5_000, "expected early stop, ran {}", sol.passes);
        // A stop requires an exact confirmation scan, so the reported
        // final violation honors the tolerance exactly.
        assert!(sol.max_violation <= 1e-6, "violation {}", sol.max_violation);
    }
}
