//! Convergence metrics: constraint satisfaction and duality gap (§II-B,
//! following [37]'s stopping criteria).
//!
//! Dykstra's iterate always satisfies `x = x0 - W^{-1} A' yhat`, so the
//! dual objective of QP (5) evaluates to
//! `g(y) = -(eps/2) x' W x - eps * b' yhat` with no extra matvec
//! (DESIGN.md §6). The primal is `P = c'x + (eps/2) x'Wx`. Both are exact
//! at pass boundaries; `P - g -> 0` as Dykstra converges.
//!
//! The metric-violation term is an `O(n^3)` scan. The active strategy
//! ([`crate::solver::active`]) does not visit every metric row each pass,
//! so its checkpoints use [`compute_residuals_trusting_sweep`]: identical
//! objectives and pair/box violations, with the metric violation taken
//! from the latest discovery sweep — which, by construction, is the last
//! time every metric row was actually measured.

use super::schedule::Schedule;
use super::{CcState, Residuals};
use crate::matrix::store::{TileScratch, TileStore};
use crate::util::parallel::{chunk_range, par_reduce_max, par_reduce_sum, scoped_workers};
use crate::util::shared::PerWorker;

/// Compute all residuals with `p` worker threads (exact everywhere).
pub fn compute_residuals(state: &CcState, p: usize) -> Residuals {
    finish_residuals(state, p, metric_violation(state, p))
}

/// Residuals for the active strategy: every term exact except the metric
/// violation, which is trusted from the latest discovery sweep instead of
/// re-running the `O(n^3)` scan. Callers must only pass a violation
/// measured this pass (the active driver checks at sweep passes only).
pub fn compute_residuals_trusting_sweep(
    state: &CcState,
    p: usize,
    sweep_metric_violation: f64,
) -> Residuals {
    finish_residuals(state, p, sweep_metric_violation)
}

/// [`compute_residuals`] against a [`TileStore`] instead of the resident
/// `state.x`: the metric term is the lease-addressed exact scan
/// ([`crate::solver::active::sweep::exact_violation`], a plain max of
/// the same residuals as [`metric_violation`]), and every elementwise
/// term streams `x` through pair-range leases while reproducing the
/// exact chunking and accumulation order of the resident reductions —
/// so a disk-backed solve reports residuals **bitwise identical** to the
/// resident solve's (pinned by a test below and by
/// `tests/store_equivalence.rs`).
pub(crate) fn compute_residuals_stored(
    state: &CcState,
    store: &dyn TileStore,
    schedule: &Schedule,
    p: usize,
) -> Residuals {
    let viol = super::active::sweep::exact_violation(store, schedule, p);
    finish_residuals_stored(state, store, p, viol)
}

/// [`compute_residuals_trusting_sweep`] against a [`TileStore`] (see
/// [`compute_residuals_stored`] for the bitwise contract).
pub(crate) fn compute_residuals_trusting_sweep_stored(
    state: &CcState,
    store: &dyn TileStore,
    p: usize,
    sweep_metric_violation: f64,
) -> Residuals {
    finish_residuals_stored(state, store, p, sweep_metric_violation)
}

/// Exact max violation over all `3·C(n,3)` metric rows — the `O(n^3)`
/// scan: for each smallest index `i`, all `(j, k)`.
pub fn metric_violation(state: &CcState, p: usize) -> f64 {
    let n = state.n;
    par_reduce_max(p, n, |i| {
        let mut worst = f64::NEG_INFINITY;
        let x = state.x.as_slice();
        for j in (i + 1)..n {
            let pij = state.pidx(i, j);
            let xij = x[pij];
            for k in (j + 1)..n {
                let xik = x[state.pidx(i, k)];
                let xjk = x[state.pidx(j, k)];
                let v = (xij - xik - xjk).max(xik - xij - xjk).max(xjk - xij - xik);
                if v > worst {
                    worst = v;
                }
            }
        }
        worst
    })
}

/// Everything but the metric scan: pair/box violations and objectives.
fn finish_residuals(state: &CcState, p: usize, metric_viol: f64) -> Residuals {
    let m = state.x.len();
    let gamma = state.gamma;

    // Pair constraints |x - d| <= f, box x <= 1.
    let pair_viol = par_reduce_max(p, m, |e| {
        let dev = (state.x[e] - state.d[e]).abs() - state.f[e];
        if state.include_box {
            dev.max(state.x[e] - 1.0)
        } else {
            dev
        }
    });
    let max_violation = metric_viol.max(pair_viol).max(0.0);

    // --- objectives -------------------------------------------------------
    let cx = par_reduce_sum(p, m, |e| state.w[e] * state.f[e]);
    let xwx = par_reduce_sum(p, m, |e| {
        state.w[e] * (state.x[e] * state.x[e] + state.f[e] * state.f[e])
    });
    // b' yhat: metric rows have b = 0; pair rows b = +d / -d; box rows b = 1.
    let b_yhat = par_reduce_sum(p, m, |e| {
        let mut acc = state.d[e] * (state.y_upper[e] - state.y_lower[e]);
        if state.include_box {
            acc += state.y_box[e];
        }
        acc
    });
    let eps = 1.0 / gamma;
    let qp_primal = cx + 0.5 * eps * xwx;
    let qp_dual = -0.5 * eps * xwx - eps * b_yhat;
    let rel_gap = (qp_primal - qp_dual) / qp_primal.abs().max(1.0);
    let lp_objective = par_reduce_sum(p, m, |e| state.w[e] * (state.x[e] - state.d[e]).abs());

    Residuals {
        max_violation,
        qp_primal,
        qp_dual,
        rel_gap,
        lp_objective,
        ..Residuals::default()
    }
}

/// Everything but the metric scan, streaming `x` from a store.
///
/// The terms that never read `x` (`c'x` — which is `w·f` here — and
/// `b'yhat`) run through the classic [`par_reduce_sum`]. The terms that
/// do (pair/box violation, `x'Wx`, the LP objective) stream `x` in
/// ascending order over the **same** chunk partition the resident
/// reductions use — including their small-`m` serial fallback — with
/// per-chunk accumulation in ascending entry order and cross-chunk
/// combination in chunk order. Floating-point addition is not
/// associative, so reproducing the grouping exactly is what makes the
/// disk-backed residuals bitwise equal to the resident ones.
fn finish_residuals_stored(
    state: &CcState,
    store: &dyn TileStore,
    p: usize,
    metric_viol: f64,
) -> Residuals {
    let m = store.n_pairs();
    let gamma = state.gamma;
    let include_box = state.include_box;

    let cx = par_reduce_sum(p, m, |e| state.w[e] * state.f[e]);
    // b' yhat: metric rows have b = 0; pair rows b = +d / -d; box rows b = 1.
    let b_yhat = par_reduce_sum(p, m, |e| {
        let mut acc = state.d[e] * (state.y_upper[e] - state.y_lower[e]);
        if include_box {
            acc += state.y_box[e];
        }
        acc
    });

    // The x-dependent terms: same chunks (and serial fallback) as
    // par_reduce_sum / par_reduce_max over m entries.
    let ranges: Vec<(usize, usize)> = if p <= 1 || m < 1024 {
        vec![(0, m)]
    } else {
        (0..p).map(|tid| chunk_range(m, p, tid)).collect()
    };
    let k = ranges.len();
    let parts = PerWorker::new(vec![(f64::NEG_INFINITY, 0.0f64, 0.0f64); k]);
    scoped_workers(k, |tid, _| {
        let (lo, hi) = ranges[tid];
        let mut viol = f64::NEG_INFINITY;
        let mut xwx = 0.0f64;
        let mut lp = 0.0f64;
        let mut scratch = TileScratch::default();
        // SAFETY: chunks are disjoint across workers; the callback only
        // reads (write = false keeps a disk store clean).
        unsafe {
            store.with_pair_range(lo, hi, false, &mut scratch, &mut |g, xs, _wv| {
                for (t, &xv) in xs.iter().enumerate() {
                    let e = g + t;
                    let dev = (xv - state.d[e]).abs() - state.f[e];
                    let v = if include_box { dev.max(xv - 1.0) } else { dev };
                    if v > viol {
                        viol = v;
                    }
                    xwx += state.w[e] * (xv * xv + state.f[e] * state.f[e]);
                    lp += state.w[e] * (xv - state.d[e]).abs();
                }
            });
        }
        // SAFETY: slot `tid` belongs to this worker.
        unsafe { *parts.get_mut(tid) = (viol, xwx, lp) };
    });
    let parts = parts.into_inner();
    let pair_viol = parts.iter().map(|&(v, _, _)| v).fold(f64::NEG_INFINITY, f64::max);
    let xwx: f64 = parts.iter().map(|&(_, s, _)| s).sum();
    let lp_objective: f64 = parts.iter().map(|&(_, _, s)| s).sum();

    let max_violation = metric_viol.max(pair_viol).max(0.0);
    let eps = 1.0 / gamma;
    let qp_primal = cx + 0.5 * eps * xwx;
    let qp_dual = -0.5 * eps * xwx - eps * b_yhat;
    let rel_gap = (qp_primal - qp_dual) / qp_primal.abs().max(1.0);

    Residuals {
        max_violation,
        qp_primal,
        qp_dual,
        rel_gap,
        lp_objective,
        ..Residuals::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::CcLpInstance;
    use crate::solver::CcState;

    #[test]
    fn residuals_at_start_point() {
        let inst = CcLpInstance::random(7, 0.5, 1.0, 1.0, 3);
        let st = CcState::new(&inst, 5.0, true);
        let r = compute_residuals(&st, 1);
        // x = 0: metric constraints tight (0 <= 0), |0 - d| - f = d + gamma.
        // With some d = 1 the worst pair violation is 1 + gamma... but f is
        // -gamma so violation = d - (-gamma) = d + gamma >= gamma.
        assert!(r.max_violation >= 5.0);
        // primal at x0: c'x0 + (eps/2)x0'Wx0 = -gamma*sum(w) + (1/(2gamma))
        // * gamma^2 * sum(w) = -gamma/2 * sum(w)
        let sw: f64 = inst.w.as_slice().iter().sum();
        assert!((r.qp_primal - (-2.5 * sw)).abs() < 1e-9);
        // dual at yhat=0: -(eps/2) x0'Wx0 = -2.5 sw -> gap 0 at start
        assert!((r.qp_dual - (-2.5 * sw)).abs() < 1e-9);
    }

    #[test]
    fn parallel_residuals_match_serial() {
        let inst = CcLpInstance::random(20, 0.4, 0.5, 2.0, 9);
        let mut st = CcState::new(&inst, 5.0, true);
        // perturb the state so all terms are nonzero
        let mut rng = crate::util::rng::Rng::new(5);
        for v in st.x.iter_mut() {
            *v = rng.f64_in(-0.2, 1.2);
        }
        for v in st.f.iter_mut() {
            *v = rng.f64_in(-0.5, 0.5);
        }
        for v in st.y_upper.iter_mut() {
            *v = rng.f64_in(0.0, 0.3);
        }
        for v in st.y_box.iter_mut() {
            *v = rng.f64_in(0.0, 0.2);
        }
        let a = compute_residuals(&st, 1);
        let b = compute_residuals(&st, 4);
        assert!((a.max_violation - b.max_violation).abs() < 1e-12);
        assert!((a.qp_primal - b.qp_primal).abs() < 1e-9);
        assert!((a.qp_dual - b.qp_dual).abs() < 1e-9);
        assert!((a.lp_objective - b.lp_objective).abs() < 1e-9);
    }

    #[test]
    fn trusting_sweep_matches_exact_when_given_exact_violation() {
        let inst = CcLpInstance::random(12, 0.4, 0.5, 2.0, 13);
        let mut st = CcState::new(&inst, 5.0, true);
        let mut rng = crate::util::rng::Rng::new(7);
        for v in st.x.iter_mut() {
            *v = rng.f64_in(-0.2, 1.2);
        }
        for v in st.f.iter_mut() {
            *v = rng.f64_in(-0.5, 0.5);
        }
        let exact = compute_residuals(&st, 2);
        let trusted =
            compute_residuals_trusting_sweep(&st, 2, metric_violation(&st, 2));
        assert_eq!(exact.max_violation, trusted.max_violation);
        assert_eq!(exact.qp_primal, trusted.qp_primal);
        assert_eq!(exact.qp_dual, trusted.qp_dual);
        assert_eq!(exact.lp_objective, trusted.lp_objective);
        // A stale (lower) sweep violation must not mask pair violations.
        let pair_only = compute_residuals_trusting_sweep(&st, 2, 0.0);
        assert!(pair_only.max_violation <= exact.max_violation);
        assert!(pair_only.max_violation >= 0.0);
    }

    #[test]
    fn stored_residuals_match_the_classic_scan() {
        // The store-addressed residual computation must agree with the
        // resident scan on every field (the disk==mem bitwise contract).
        // n = 18 (m = 153) drives the serial-fallback path; n = 50
        // (m = 1225 >= 1024) drives the chunked parallel branch whose
        // summation-order reproduction is the delicate part.
        for (n, tile) in [(18usize, 4usize), (50, 8)] {
            let inst = CcLpInstance::random(n, 0.4, 0.5, 2.0, 17);
            let mut st = CcState::new(&inst, 5.0, true);
            let mut rng = crate::util::rng::Rng::new(9 + n as u64);
            for v in st.x.iter_mut() {
                *v = rng.f64_in(-0.2, 1.2);
            }
            for v in st.f.iter_mut() {
                *v = rng.f64_in(-0.5, 0.5);
            }
            for v in st.y_upper.iter_mut() {
                *v = rng.f64_in(0.0, 0.3);
            }
            for v in st.y_lower.iter_mut() {
                *v = rng.f64_in(0.0, 0.2);
            }
            for v in st.y_box.iter_mut() {
                *v = rng.f64_in(0.0, 0.2);
            }
            let schedule = Schedule::new(n, tile);
            for p in [1usize, 3] {
                let classic = compute_residuals(&st, p);
                let trusted_classic =
                    compute_residuals_trusting_sweep(&st, p, metric_violation(&st, p));
                let mut x = st.x.clone();
                let store = crate::matrix::store::MemStore::new(
                    x.as_mut_slice(),
                    &st.col_starts,
                    &st.winv,
                );
                let stored = compute_residuals_stored(&st, &store, &schedule, p);
                let trusted_stored = compute_residuals_trusting_sweep_stored(
                    &st,
                    &store,
                    p,
                    metric_violation(&st, p),
                );
                for (a, b) in [(&classic, &stored), (&trusted_classic, &trusted_stored)] {
                    assert_eq!(a.max_violation, b.max_violation, "n={n} p={p}");
                    assert_eq!(a.qp_primal, b.qp_primal, "n={n} p={p}");
                    assert_eq!(a.qp_dual, b.qp_dual, "n={n} p={p}");
                    assert_eq!(a.rel_gap, b.rel_gap, "n={n} p={p}");
                    assert_eq!(a.lp_objective, b.lp_objective, "n={n} p={p}");
                }
            }
        }
    }

    #[test]
    fn violation_detects_metric_break() {
        let inst = CcLpInstance::random(5, 0.0, 1.0, 1.0, 1);
        let mut st = CcState::new(&inst, 5.0, false);
        // make f consistent so pair violations vanish
        for v in st.f.iter_mut() {
            *v = 10.0;
        }
        let e01 = st.pidx(0, 1);
        st.x[e01] = 9.0; // 9 > 0 + 0 for triple (0,1,k)
        let r = compute_residuals(&st, 1);
        assert!((r.max_violation - 9.0).abs() < 1e-12);
    }
}
