//! Convergence metrics: constraint satisfaction and duality gap (§II-B,
//! following [37]'s stopping criteria).
//!
//! Dykstra's iterate always satisfies `x = x0 - W^{-1} A' yhat`, so the
//! dual objective of QP (5) evaluates to
//! `g(y) = -(eps/2) x' W x - eps * b' yhat` with no extra matvec
//! (DESIGN.md §6). The primal is `P = c'x + (eps/2) x'Wx`. Both are exact
//! at pass boundaries; `P - g -> 0` as Dykstra converges.
//!
//! The metric-violation term is an `O(n^3)` scan. The active strategy
//! ([`crate::solver::active`]) does not visit every metric row each pass,
//! so its checkpoints use [`compute_residuals_trusting_sweep`]: identical
//! objectives and pair/box violations, with the metric violation taken
//! from the latest discovery sweep — which, by construction, is the last
//! time every metric row was actually measured.

use super::{CcState, Residuals};
use crate::util::parallel::{par_reduce_max, par_reduce_sum};

/// Compute all residuals with `p` worker threads (exact everywhere).
pub fn compute_residuals(state: &CcState, p: usize) -> Residuals {
    finish_residuals(state, p, metric_violation(state, p))
}

/// Residuals for the active strategy: every term exact except the metric
/// violation, which is trusted from the latest discovery sweep instead of
/// re-running the `O(n^3)` scan. Callers must only pass a violation
/// measured this pass (the active driver checks at sweep passes only).
pub fn compute_residuals_trusting_sweep(
    state: &CcState,
    p: usize,
    sweep_metric_violation: f64,
) -> Residuals {
    finish_residuals(state, p, sweep_metric_violation)
}

/// Exact max violation over all `3·C(n,3)` metric rows — the `O(n^3)`
/// scan: for each smallest index `i`, all `(j, k)`.
pub fn metric_violation(state: &CcState, p: usize) -> f64 {
    let n = state.n;
    par_reduce_max(p, n, |i| {
        let mut worst = f64::NEG_INFINITY;
        let x = state.x.as_slice();
        for j in (i + 1)..n {
            let pij = state.pidx(i, j);
            let xij = x[pij];
            for k in (j + 1)..n {
                let xik = x[state.pidx(i, k)];
                let xjk = x[state.pidx(j, k)];
                let v = (xij - xik - xjk).max(xik - xij - xjk).max(xjk - xij - xik);
                if v > worst {
                    worst = v;
                }
            }
        }
        worst
    })
}

/// Everything but the metric scan: pair/box violations and objectives.
fn finish_residuals(state: &CcState, p: usize, metric_viol: f64) -> Residuals {
    let m = state.x.len();
    let gamma = state.gamma;

    // Pair constraints |x - d| <= f, box x <= 1.
    let pair_viol = par_reduce_max(p, m, |e| {
        let dev = (state.x[e] - state.d[e]).abs() - state.f[e];
        if state.include_box {
            dev.max(state.x[e] - 1.0)
        } else {
            dev
        }
    });
    let max_violation = metric_viol.max(pair_viol).max(0.0);

    // --- objectives -------------------------------------------------------
    let cx = par_reduce_sum(p, m, |e| state.w[e] * state.f[e]);
    let xwx = par_reduce_sum(p, m, |e| {
        state.w[e] * (state.x[e] * state.x[e] + state.f[e] * state.f[e])
    });
    // b' yhat: metric rows have b = 0; pair rows b = +d / -d; box rows b = 1.
    let b_yhat = par_reduce_sum(p, m, |e| {
        let mut acc = state.d[e] * (state.y_upper[e] - state.y_lower[e]);
        if state.include_box {
            acc += state.y_box[e];
        }
        acc
    });
    let eps = 1.0 / gamma;
    let qp_primal = cx + 0.5 * eps * xwx;
    let qp_dual = -0.5 * eps * xwx - eps * b_yhat;
    let rel_gap = (qp_primal - qp_dual) / qp_primal.abs().max(1.0);
    let lp_objective = par_reduce_sum(p, m, |e| state.w[e] * (state.x[e] - state.d[e]).abs());

    Residuals {
        max_violation,
        qp_primal,
        qp_dual,
        rel_gap,
        lp_objective,
        ..Residuals::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::instance::CcLpInstance;
    use crate::solver::CcState;

    #[test]
    fn residuals_at_start_point() {
        let inst = CcLpInstance::random(7, 0.5, 1.0, 1.0, 3);
        let st = CcState::new(&inst, 5.0, true);
        let r = compute_residuals(&st, 1);
        // x = 0: metric constraints tight (0 <= 0), |0 - d| - f = d + gamma.
        // With some d = 1 the worst pair violation is 1 + gamma... but f is
        // -gamma so violation = d - (-gamma) = d + gamma >= gamma.
        assert!(r.max_violation >= 5.0);
        // primal at x0: c'x0 + (eps/2)x0'Wx0 = -gamma*sum(w) + (1/(2gamma))
        // * gamma^2 * sum(w) = -gamma/2 * sum(w)
        let sw: f64 = inst.w.as_slice().iter().sum();
        assert!((r.qp_primal - (-2.5 * sw)).abs() < 1e-9);
        // dual at yhat=0: -(eps/2) x0'Wx0 = -2.5 sw -> gap 0 at start
        assert!((r.qp_dual - (-2.5 * sw)).abs() < 1e-9);
    }

    #[test]
    fn parallel_residuals_match_serial() {
        let inst = CcLpInstance::random(20, 0.4, 0.5, 2.0, 9);
        let mut st = CcState::new(&inst, 5.0, true);
        // perturb the state so all terms are nonzero
        let mut rng = crate::util::rng::Rng::new(5);
        for v in st.x.iter_mut() {
            *v = rng.f64_in(-0.2, 1.2);
        }
        for v in st.f.iter_mut() {
            *v = rng.f64_in(-0.5, 0.5);
        }
        for v in st.y_upper.iter_mut() {
            *v = rng.f64_in(0.0, 0.3);
        }
        for v in st.y_box.iter_mut() {
            *v = rng.f64_in(0.0, 0.2);
        }
        let a = compute_residuals(&st, 1);
        let b = compute_residuals(&st, 4);
        assert!((a.max_violation - b.max_violation).abs() < 1e-12);
        assert!((a.qp_primal - b.qp_primal).abs() < 1e-9);
        assert!((a.qp_dual - b.qp_dual).abs() < 1e-9);
        assert!((a.lp_objective - b.lp_objective).abs() < 1e-9);
    }

    #[test]
    fn trusting_sweep_matches_exact_when_given_exact_violation() {
        let inst = CcLpInstance::random(12, 0.4, 0.5, 2.0, 13);
        let mut st = CcState::new(&inst, 5.0, true);
        let mut rng = crate::util::rng::Rng::new(7);
        for v in st.x.iter_mut() {
            *v = rng.f64_in(-0.2, 1.2);
        }
        for v in st.f.iter_mut() {
            *v = rng.f64_in(-0.5, 0.5);
        }
        let exact = compute_residuals(&st, 2);
        let trusted =
            compute_residuals_trusting_sweep(&st, 2, metric_violation(&st, 2));
        assert_eq!(exact.max_violation, trusted.max_violation);
        assert_eq!(exact.qp_primal, trusted.qp_primal);
        assert_eq!(exact.qp_dual, trusted.qp_dual);
        assert_eq!(exact.lp_objective, trusted.lp_objective);
        // A stale (lower) sweep violation must not mask pair violations.
        let pair_only = compute_residuals_trusting_sweep(&st, 2, 0.0);
        assert!(pair_only.max_violation <= exact.max_violation);
        assert!(pair_only.max_violation >= 0.0);
    }

    #[test]
    fn violation_detects_metric_break() {
        let inst = CcLpInstance::random(5, 0.0, 1.0, 1.0, 1);
        let mut st = CcState::new(&inst, 5.0, false);
        // make f consistent so pair violations vanish
        for v in st.f.iter_mut() {
            *v = 10.0;
        }
        let e01 = st.pidx(0, 1);
        st.x[e01] = 9.0; // 9 > 0 + 0 for triple (0,1,k)
        let r = compute_residuals(&st, 1);
        assert!((r.max_violation - 9.0).abs() < 1e-12);
    }
}
