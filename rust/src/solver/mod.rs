//! Projection solvers for metric-constrained optimization.
//!
//! * [`dykstra_serial`] — the serial baseline of [37] (standard
//!   lexicographic constraint order, single dual array).
//! * [`dykstra_parallel`] — the paper's contribution: wave-parallel
//!   execution over the conflict-free [`schedule`], tiled per
//!   [`tiling`], with per-worker [`duals`] arrays.
//! * [`active`] — the project-and-forget layer on top of the parallel
//!   solver: cheap passes visit only an *active set* of metric
//!   constraints, with periodic full discovery sweeps (Sonthalia &
//!   Gilbert 2020 style), selected via [`SolveOpts::strategy`].
//!
//! All solvers run the *identical* per-constraint visit
//! ([`projection`]); they differ only in constraint ordering, visit
//! sparsity, and parallelism, exactly as in the paper (§III-A: "this
//! amounts simply to a re-ordering of constraints").

pub mod active;
pub mod checkpoint;
pub mod duals;
pub mod dykstra_parallel;
pub mod dykstra_serial;
pub mod dykstra_xla;
pub(crate) mod hot_loop;
pub mod nearness;
pub mod projection;
pub mod schedule;
pub mod schedule_delta;
pub mod termination;
pub mod tiling;

use crate::instance::CcLpInstance;
use crate::matrix::PackedSym;

/// Which metric constraints each pass visits.
///
/// `Full` is the paper's method: every pass sweeps all `3·C(n,3)` metric
/// rows. `Active` is the project-and-forget layer ([`active`]): cheap
/// passes visit only the active set, a full discovery sweep runs every
/// `sweep_every` passes, and constraints whose duals stay zero for
/// `forget_after` consecutive active passes are forgotten until a sweep
/// rediscovers them. With convergence checks off (`check_every = 0`),
/// `Active { sweep_every: 1, .. }` degenerates to the full solver
/// (bitwise — tested); with checks on, the active solver's stopping
/// decisions trust the sweep's mid-pass measurement instead of the
/// exact post-pass scan, so stopping passes can differ.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Strategy {
    /// Visit every metric constraint every pass (the paper's solver).
    #[default]
    Full,
    /// Project-and-forget active set.
    Active {
        /// Run a full discovery sweep every this many passes (>= 1).
        sweep_every: usize,
        /// Forget a constraint after this many consecutive zero-dual
        /// active passes (0 = forget the moment its dual hits zero).
        forget_after: usize,
    },
}

impl Strategy {
    /// True for the active-set strategy.
    pub fn is_active(self) -> bool {
        matches!(self, Strategy::Active { .. })
    }

    /// Parse a CLI name (`full` / `active`), attaching the given active
    /// parameters when applicable.
    pub fn parse(s: &str, sweep_every: usize, forget_after: usize) -> Option<Strategy> {
        match s {
            "full" => Some(Strategy::Full),
            "active" | "project-and-forget" => {
                Some(Strategy::Active { sweep_every, forget_after })
            }
            _ => None,
        }
    }
}

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts {
    /// Regularization gamma = 1/eps of QP (5); larger tracks the LP closer.
    pub gamma: f64,
    /// Number of full passes through the constraint set (the paper's
    /// experiments fix this: 20 iterations for Table I).
    pub max_passes: usize,
    /// Stop early when max constraint violation falls below this…
    pub tol_violation: f64,
    /// …and the relative duality gap falls below this.
    pub tol_gap: f64,
    /// Check convergence every this many passes (0 = never, fixed passes).
    pub check_every: usize,
    /// Worker threads (1 = serial execution of the parallel schedule).
    pub threads: usize,
    /// Tile size `b` (paper uses 40 for Table I).
    pub tile: usize,
    /// Include `x_ij <= 1` box constraints.
    pub include_box: bool,
    /// Record per-pass wall times.
    pub track_pass_times: bool,
    /// Tile-to-worker assignment (paper's Fig 3 round-robin by default).
    pub assignment: schedule::Assignment,
    /// Metric-constraint visiting strategy (full sweeps vs active set).
    pub strategy: Strategy,
    /// Emit a [`checkpoint::SolverState`] every this many passes through
    /// the `solve_checkpointed` entry points (0 = never; a final state is
    /// always emitted when nonzero). Ignored by the plain `solve` calls.
    pub checkpoint_every: usize,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts {
            gamma: 5.0,
            max_passes: 20,
            tol_violation: 1e-4,
            tol_gap: 1e-4,
            check_every: 0,
            threads: 1,
            tile: 40,
            include_box: true,
            track_pass_times: false,
            assignment: schedule::Assignment::RoundRobin,
            strategy: Strategy::Full,
            checkpoint_every: 0,
        }
    }
}

/// Convergence / progress metrics at a checkpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct Residuals {
    /// Max violation over all constraint families.
    pub max_violation: f64,
    /// Primal QP objective c'x + (eps/2) x'Wx.
    pub qp_primal: f64,
    /// Dual QP objective -(eps/2) x'Wx - eps b'yhat.
    pub qp_dual: f64,
    /// (P - D) / max(1, |P|).
    pub rel_gap: f64,
    /// LP objective sum w |x - d| (the quantity the LP relaxation bounds).
    pub lp_objective: f64,
    /// Cumulative metric-constraint visits when this checkpoint was taken
    /// (3 per triplet visit) — the work axis for convergence-vs-work plots.
    pub metric_visits: u64,
    /// Active metric triplets at the checkpoint (= C(n,3) for the full
    /// strategy, which visits everything).
    pub active_triplets: usize,
}

impl Residuals {
    /// Stamp the work counters: cumulative `triplet_visits` (3 metric
    /// rows each) and the current active-triplet count (= C(n,3) for the
    /// full strategy). Full drivers pass their running counter, which a
    /// resume seeds from the checkpoint — so a cross-strategy resume
    /// (active checkpoint continued by a full driver) keeps billing the
    /// cheap passes at their true cost.
    pub(crate) fn stamp_work(&mut self, triplet_visits: u64, active_triplets: usize) {
        self.metric_visits = triplet_visits * 3;
        self.active_triplets = active_triplets;
    }
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Distance variables.
    pub x: PackedSym,
    /// Slack variables f (CC-LP only).
    pub f: Option<PackedSym>,
    /// Passes actually executed.
    pub passes: usize,
    /// Residuals at the end (computed if check_every > 0 or at completion).
    pub residuals: Residuals,
    /// Wall time per pass (if tracked).
    pub pass_times: Vec<f64>,
    /// Total nonzero metric duals at the end.
    pub nnz_duals: usize,
    /// Total metric-constraint visits performed over the whole solve
    /// (3 per triplet visit; the full strategy does `3·C(n,3)` per pass).
    pub metric_visits: u64,
    /// Metric triplets in the active set at the end (= C(n,3) for the
    /// full strategy).
    pub active_triplets: usize,
}

/// Mutable state of a CC-LP solve, shared by both solvers.
///
/// Variable layout follows DESIGN.md §6: packed `x` (distances) and `f`
/// (slacks), precomputed `winv = 1/w`, dense scaled duals for the 2 pair
/// constraints (+ optional box) per pair; metric duals live in sparse
/// [`duals::DualStore`]s owned by the solver.
pub struct CcState {
    pub n: usize,
    pub x: Vec<f64>,
    pub f: Vec<f64>,
    pub winv: Vec<f64>,
    pub d: Vec<f64>,
    pub w: Vec<f64>,
    pub y_upper: Vec<f64>,
    pub y_lower: Vec<f64>,
    pub y_box: Vec<f64>,
    pub col_starts: Vec<usize>,
    pub gamma: f64,
    pub include_box: bool,
}

impl CcState {
    /// Initialize at the Dykstra starting point `x0 = -(1/eps) W^{-1} c`:
    /// distances 0, slacks `-gamma` (DESIGN.md §6).
    pub fn new(inst: &CcLpInstance, gamma: f64, include_box: bool) -> CcState {
        let n = inst.n;
        let m = inst.w.len();
        let w: Vec<f64> = inst.w.as_slice().to_vec();
        let winv: Vec<f64> = w.iter().map(|&v| 1.0 / v).collect();
        CcState {
            n,
            x: vec![0.0; m],
            f: vec![-gamma; m],
            winv,
            d: inst.d.as_slice().to_vec(),
            w,
            y_upper: vec![0.0; m],
            y_lower: vec![0.0; m],
            y_box: vec![0.0; m],
            col_starts: inst.w.col_starts().to_vec(),
            gamma,
            include_box,
        }
    }

    /// Packed index of pair (i, j), i < j.
    #[inline(always)]
    pub fn pidx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < j);
        unsafe { *self.col_starts.get_unchecked(i) + (j - i - 1) }
    }

    /// Extract the distance matrix.
    pub fn x_matrix(&self) -> PackedSym {
        let mut m = PackedSym::zeros(self.n);
        m.as_mut_slice().copy_from_slice(&self.x);
        m
    }

    /// Extract the slack matrix.
    pub fn f_matrix(&self) -> PackedSym {
        let mut m = PackedSym::zeros(self.n);
        m.as_mut_slice().copy_from_slice(&self.f);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_initial_point() {
        let inst = CcLpInstance::random(6, 0.5, 1.0, 2.0, 1);
        let st = CcState::new(&inst, 5.0, true);
        assert!(st.x.iter().all(|&v| v == 0.0));
        assert!(st.f.iter().all(|&v| v == -5.0));
        for (a, b) in st.w.iter().zip(st.winv.iter()) {
            assert!((a * b - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn pidx_matches_packed() {
        let inst = CcLpInstance::random(9, 0.5, 1.0, 2.0, 2);
        let st = CcState::new(&inst, 5.0, true);
        for i in 0..9 {
            for j in (i + 1)..9 {
                assert_eq!(st.pidx(i, j), inst.w.idx(i, j));
            }
        }
    }

    #[test]
    fn default_opts_match_paper() {
        let o = SolveOpts::default();
        assert_eq!(o.max_passes, 20); // Table I runs 20 iterations
        assert_eq!(o.tile, 40); // Table I tile size b = 40
        assert_eq!(o.strategy, Strategy::Full); // paper's dense sweeps
    }

    #[test]
    fn strategy_parses() {
        assert_eq!(Strategy::parse("full", 8, 3), Some(Strategy::Full));
        assert_eq!(
            Strategy::parse("active", 8, 3),
            Some(Strategy::Active { sweep_every: 8, forget_after: 3 })
        );
        assert_eq!(
            Strategy::parse("project-and-forget", 4, 0),
            Some(Strategy::Active { sweep_every: 4, forget_after: 0 })
        );
        assert_eq!(Strategy::parse("dense", 8, 3), None);
        assert!(Strategy::Active { sweep_every: 8, forget_after: 3 }.is_active());
        assert!(!Strategy::Full.is_active());
    }
}
