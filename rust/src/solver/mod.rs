//! Projection solvers for metric-constrained optimization.
//!
//! * [`dykstra_serial`] — the serial baseline of [37] (standard
//!   lexicographic constraint order, single dual array).
//! * [`dykstra_parallel`] — the paper's contribution: wave-parallel
//!   execution over the conflict-free [`schedule`], tiled per
//!   [`tiling`], with per-worker [`duals`] arrays.
//! * [`active`] — the project-and-forget layer on top of the parallel
//!   solver: cheap passes visit only an *active set* of metric
//!   constraints, with periodic full discovery sweeps (Sonthalia &
//!   Gilbert 2020 style), selected via [`SolveOpts::strategy`].
//!
//! All solvers run the *identical* per-constraint visit
//! ([`projection`]); they differ only in constraint ordering, visit
//! sparsity, and parallelism, exactly as in the paper (§III-A: "this
//! amounts simply to a re-ordering of constraints").
//!
//! Every phase leases `x` from a [`crate::matrix::store::TileStore`]
//! rather than addressing a flat array — the metric phases through tile
//! leases, the CC pair phase and the residual scans through ascending
//! pair-range leases — so the same passes run over the resident packed
//! matrix or an out-of-core disk store (`--store disk`, for `solve` and
//! `nearness` alike) bitwise identically. The per-driver `x` ownership
//! lives in the crate-private `backing` module (`XBacking`). See
//! `docs/ARCHITECTURE.md` for the full data-flow picture.

pub mod active;
pub(crate) mod backing;
pub mod checkpoint;
pub mod duals;
pub mod dykstra_parallel;
pub mod dykstra_serial;
pub mod dykstra_xla;
pub mod error;
pub(crate) mod hot_loop;
pub mod nearness;
pub mod projection;
pub mod proximal;
pub mod recover;
pub mod schedule;
pub mod schedule_delta;
pub mod termination;
pub mod tiling;
pub mod watchdog;

pub use error::SolveError;

use crate::instance::CcLpInstance;
use crate::matrix::PackedSym;

/// Which metric constraints each pass visits.
///
/// `Full` is the paper's method: every pass sweeps all `3·C(n,3)` metric
/// rows. `Active` is the project-and-forget layer ([`active`]): cheap
/// passes visit only the active set, a full discovery sweep runs every
/// `sweep_every` passes, and constraints whose duals stay zero for
/// `forget_after` consecutive active passes are forgotten until a sweep
/// rediscovers them. With convergence checks off (`check_every = 0`),
/// `Active { sweep_every: 1, .. }` degenerates to the full solver
/// (bitwise — tested); with checks on, the active solver's stopping
/// decisions trust the sweep's mid-pass measurement instead of the
/// exact post-pass scan, so stopping passes can differ.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Strategy {
    /// Visit every metric constraint every pass (the paper's solver).
    #[default]
    Full,
    /// Project-and-forget active set.
    Active {
        /// Run a full discovery sweep every this many passes (>= 1).
        sweep_every: usize,
        /// Forget a constraint after this many consecutive zero-dual
        /// active passes (0 = forget the moment its dual hits zero).
        forget_after: usize,
    },
}

impl Strategy {
    /// True for the active-set strategy.
    pub fn is_active(self) -> bool {
        matches!(self, Strategy::Active { .. })
    }

    /// Parse a CLI name (`full` / `active`), attaching the given active
    /// parameters when applicable.
    pub fn parse(s: &str, sweep_every: usize, forget_after: usize) -> Option<Strategy> {
        match s {
            "full" => Some(Strategy::Full),
            "active" | "project-and-forget" => {
                Some(Strategy::Active { sweep_every, forget_after })
            }
            _ => None,
        }
    }
}

/// How a discovery sweep walks the `C(n,3)` triplets
/// (EXPERIMENTS.md §Perf, "screen-then-project").
///
/// `Scalar` is the original callback sweep: per-triplet index arithmetic,
/// key construction, and a branchy scalar visit for every triplet.
/// `Screened` splits each tile into contiguous `k`-runs and runs a
/// branch-free vectorizable *screen* over each run first; only triplets
/// that actually need work — violated at the moment of their visit, or
/// holding a nonzero dual — are projected with the fused scalar kernel,
/// in cube order. Skipping a satisfied zero-dual triplet is an exact
/// no-op ([`projection::visit_triplet`] would not move `x` or emit a
/// dual), so `Screened` is **bitwise identical** to `Scalar` (tested).
/// `Engine` additionally routes the phase-1 screen through the
/// PJRT-compiled batch kernels ([`crate::runtime::engine::XlaEngine`])
/// when artifacts are loaded, falling back to `Screened` when they are
/// not (the offline stub always falls back, which keeps `Engine` bitwise
/// equal to `Scalar` there). With real artifacts the engine screen is
/// f32-quantized: projections stay exact f64, and the active drivers
/// still confirm every stop with an exact scan — so `Engine` can never
/// report a falsely-converged solution — but a violation below f32
/// resolution screens as satisfied on *every* sweep, so tolerances near
/// f32 resolution may never be reached (the solve runs to `max_passes`).
/// Prefer `Screened` for tight tolerances; `Engine` targets throughput
/// at f32-scale accuracy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SweepBackend {
    /// The original per-triplet callback sweep.
    Scalar,
    /// Vectorized screen, then scalar projection of the worklist
    /// (bitwise equal to `Scalar`; the default).
    #[default]
    Screened,
    /// Screen through the PJRT engine in large batches; falls back to
    /// `Screened` when no artifacts are loaded.
    Engine,
}

impl SweepBackend {
    /// Parse a CLI name (`scalar` / `screened` / `engine`).
    pub fn parse(s: &str) -> Option<SweepBackend> {
        match s {
            "scalar" => Some(SweepBackend::Scalar),
            "screened" | "screen" => Some(SweepBackend::Screened),
            "engine" | "xla" => Some(SweepBackend::Engine),
            _ => None,
        }
    }

    /// CLI name of the backend.
    pub fn name(self) -> &'static str {
        match self {
            SweepBackend::Scalar => "scalar",
            SweepBackend::Screened => "screened",
            SweepBackend::Engine => "engine",
        }
    }
}

/// Which algorithm family runs the solve.
///
/// `Dykstra` is the paper's cyclic-projection family — every driver in
/// this crate (serial/parallel/active, any store, any sweep backend) is
/// a constraint-ordering variant of it, and all of them converge to the
/// *exact* weighted projection. The two `Prox*` members are the
/// proximal-distance family ([`proximal`]): the same metric-nearness
/// objective minimized by a completely independent route (penalized
/// unconstrained subproblems driven by an increasing penalty `rho`,
/// matrix-free over the same wave schedule). They agree with Dykstra
/// only *within tolerance* — the penalty path stops at finite `rho` —
/// which is exactly what makes them useful as a differential-testing
/// oracle ([`crate::eval::cross_check`]): a shared bug in one family is
/// vanishingly unlikely to reproduce in the other.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Algorithm {
    /// Cyclic Dykstra projections (the paper's family; exact).
    #[default]
    Dykstra,
    /// Proximal-distance majorize-minimize: Nesterov-accelerated outer
    /// iterations, each solving the penalized normal equations with
    /// matrix-free preconditioned CG ([`proximal::mm`]).
    ProxMm,
    /// Proximal-distance steepest descent with exact line search
    /// ([`proximal::sd`]) — cheaper per iteration, looser tolerance.
    ProxSd,
}

impl Algorithm {
    /// Parse a CLI name (`dykstra` / `prox-mm` / `prox-sd`).
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "dykstra" => Some(Algorithm::Dykstra),
            "prox-mm" | "mm" => Some(Algorithm::ProxMm),
            "prox-sd" | "sd" => Some(Algorithm::ProxSd),
            _ => None,
        }
    }

    /// CLI name of the algorithm.
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Dykstra => "dykstra",
            Algorithm::ProxMm => "prox-mm",
            Algorithm::ProxSd => "prox-sd",
        }
    }

    /// True for either proximal-distance member.
    pub fn is_proximal(self) -> bool {
        !matches!(self, Algorithm::Dykstra)
    }
}

/// When the active-set driver runs its next discovery sweep.
///
/// `Fixed(k)` is the classic cadence: a sweep every `k` passes (pass
/// indices divisible by `k`, so resumes preserve the phase). `Adaptive`
/// triggers the next sweep from observed signals instead — an active-set
/// shrinkage stall across cheap passes, a trusted-violation plateau in
/// the termination history, or an interval cap — so well-conditioned
/// stretches run long cheap-pass trains while stalls are re-examined
/// promptly. Adaptive decisions depend on runtime observations that are
/// not checkpointed, so a resumed adaptive run may schedule sweeps
/// differently than the uninterrupted one (fixed cadences resume
/// bitwise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepPolicy {
    /// Sweep on every pass index divisible by the given period (>= 1).
    Fixed(usize),
    /// Sweep when observed signals say the active set went stale.
    Adaptive,
}

impl SweepPolicy {
    /// Parse a CLI name (`fixed` / `adaptive`); `fixed` takes its period
    /// from the strategy's `sweep_every`.
    pub fn parse(s: &str, sweep_every: usize) -> Option<SweepPolicy> {
        match s {
            "fixed" => Some(SweepPolicy::Fixed(sweep_every.max(1))),
            "adaptive" => Some(SweepPolicy::Adaptive),
            _ => None,
        }
    }
}

/// What a driver's pass loop does when the process-wide interrupt flag
/// ([`crate::util::interrupt`]) is raised mid-solve.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OnInterrupt {
    /// Ignore the flag and run to convergence (the embedder handles
    /// signals itself; the default).
    #[default]
    Ignore,
    /// Finish the pass in flight, emit a checkpoint through the run's
    /// checkpoint sink, and unwind with
    /// [`error::SolveError::Interrupted`] — the CLI's
    /// `--on-interrupt checkpoint`.
    Checkpoint,
}

impl OnInterrupt {
    /// Parse a CLI name (`ignore` / `checkpoint`).
    pub fn parse(s: &str) -> Option<OnInterrupt> {
        match s {
            "ignore" => Some(OnInterrupt::Ignore),
            "checkpoint" => Some(OnInterrupt::Checkpoint),
            _ => None,
        }
    }
}

/// Solver configuration.
#[derive(Clone, Copy, Debug)]
pub struct SolveOpts {
    /// Regularization gamma = 1/eps of QP (5); larger tracks the LP closer.
    pub gamma: f64,
    /// Number of full passes through the constraint set (the paper's
    /// experiments fix this: 20 iterations for Table I).
    pub max_passes: usize,
    /// Stop early when max constraint violation falls below this…
    pub tol_violation: f64,
    /// …and the relative duality gap falls below this.
    pub tol_gap: f64,
    /// Check convergence every this many passes (0 = never, fixed passes).
    pub check_every: usize,
    /// Worker threads (1 = serial execution of the parallel schedule).
    pub threads: usize,
    /// Tile size `b` (paper uses 40 for Table I).
    pub tile: usize,
    /// Include `x_ij <= 1` box constraints.
    pub include_box: bool,
    /// Record per-pass wall times.
    pub track_pass_times: bool,
    /// Tile-to-worker assignment (paper's Fig 3 round-robin by default).
    pub assignment: schedule::Assignment,
    /// Metric-constraint visiting strategy (full sweeps vs active set).
    pub strategy: Strategy,
    /// How discovery sweeps walk the triplets (active strategy only).
    pub sweep_backend: SweepBackend,
    /// When discovery sweeps fire (active strategy only). `None` derives
    /// [`SweepPolicy::Fixed`] from the strategy's `sweep_every`.
    pub sweep_policy: Option<SweepPolicy>,
    /// Emit a [`checkpoint::SolverState`] every this many passes through
    /// the `solve_checkpointed` entry points (0 = never; a final state is
    /// always emitted when nonzero). Ignored by the plain `solve` calls.
    pub checkpoint_every: usize,
    /// What the pass loop does when the process-wide interrupt flag is
    /// raised (SIGINT/SIGTERM under the CLI's installed handlers).
    pub on_interrupt: OnInterrupt,
    /// Watchdog: unwind with a diagnostic dump after this many
    /// consecutive convergence checks without residual progress
    /// (0 = stall detection off; NaN/∞ divergence always trips).
    pub watchdog_stall: usize,
    /// Algorithm family. Only [`Algorithm::Dykstra`] is implemented for
    /// the CC-LP objective; the proximal members are metric-nearness
    /// only and make `solve` fail typed (the nearness drivers dispatch
    /// on [`nearness::NearnessOpts::algorithm`] instead).
    pub algorithm: Algorithm,
}

impl Default for SolveOpts {
    fn default() -> Self {
        SolveOpts {
            gamma: 5.0,
            max_passes: 20,
            tol_violation: 1e-4,
            tol_gap: 1e-4,
            check_every: 0,
            threads: 1,
            tile: 40,
            include_box: true,
            track_pass_times: false,
            assignment: schedule::Assignment::RoundRobin,
            strategy: Strategy::Full,
            sweep_backend: SweepBackend::default(),
            sweep_policy: None,
            checkpoint_every: 0,
            on_interrupt: OnInterrupt::default(),
            watchdog_stall: 0,
            algorithm: Algorithm::default(),
        }
    }
}

/// Convergence / progress metrics at a checkpoint.
#[derive(Clone, Copy, Debug, Default)]
pub struct Residuals {
    /// Max violation over all constraint families.
    pub max_violation: f64,
    /// Primal QP objective c'x + (eps/2) x'Wx.
    pub qp_primal: f64,
    /// Dual QP objective -(eps/2) x'Wx - eps b'yhat.
    pub qp_dual: f64,
    /// (P - D) / max(1, |P|).
    pub rel_gap: f64,
    /// LP objective sum w |x - d| (the quantity the LP relaxation bounds).
    pub lp_objective: f64,
    /// Cumulative metric-constraint visits when this checkpoint was taken
    /// (3 per triplet visit) — the work axis for convergence-vs-work plots.
    /// Screened sweeps bill every screened triplet here, so the counter
    /// stays comparable across backends and across checkpoint resumes.
    pub metric_visits: u64,
    /// Active metric triplets at the checkpoint (= C(n,3) for the full
    /// strategy, which visits everything).
    pub active_triplets: usize,
    /// Triplets examined by discovery sweeps over this run segment
    /// (0 for the full strategy, which has no sweeps).
    pub sweep_screened: u64,
    /// Of those, triplets that actually needed a projection (violated or
    /// holding a nonzero dual) — `sweep_projected / sweep_screened` is the
    /// screen hit rate that explains why screening wins.
    pub sweep_projected: u64,
}

impl Residuals {
    /// Stamp the work counters: cumulative `triplet_visits` (3 metric
    /// rows each) and the current active-triplet count (= C(n,3) for the
    /// full strategy). Full drivers pass their running counter, which a
    /// resume seeds from the checkpoint — so a cross-strategy resume
    /// (active checkpoint continued by a full driver) keeps billing the
    /// cheap passes at their true cost.
    pub(crate) fn stamp_work(&mut self, triplet_visits: u64, active_triplets: usize) {
        self.metric_visits = triplet_visits * 3;
        self.active_triplets = active_triplets;
    }
}

/// Result of a solve.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Distance variables.
    pub x: PackedSym,
    /// Slack variables f (CC-LP only).
    pub f: Option<PackedSym>,
    /// Passes actually executed.
    pub passes: usize,
    /// Residuals at the end (computed if check_every > 0 or at completion).
    pub residuals: Residuals,
    /// Wall time per pass (if tracked).
    pub pass_times: Vec<f64>,
    /// Total nonzero metric duals at the end.
    pub nnz_duals: usize,
    /// Total metric-constraint visits performed over the whole solve
    /// (3 per triplet visit; the full strategy does `3·C(n,3)` per pass).
    /// Screened sweeps bill every screened triplet, keeping the counter
    /// comparable across [`SweepBackend`]s and checkpoint resumes.
    pub metric_visits: u64,
    /// Metric triplets in the active set at the end (= C(n,3) for the
    /// full strategy).
    pub active_triplets: usize,
    /// Triplets examined by discovery sweeps (this run segment; 0 for the
    /// full strategy).
    pub sweep_screened: u64,
    /// Sweep triplets that actually needed a projection — see
    /// [`Residuals::sweep_projected`].
    pub sweep_projected: u64,
    /// Tile-store cache counters when the solve ran on a disk store
    /// (`None` for the resident path) — block loads, evictions,
    /// write-backs, streamed-`W` loads, and the peak resident cache
    /// bytes, mirroring
    /// [`nearness::NearnessSolution::store_stats`].
    pub store_stats: Option<crate::matrix::store::StoreStats>,
}

impl Solution {
    /// Snapshot the run's unified counters ([`crate::telemetry::Counters`]).
    ///
    /// Mirrors the `footer` event a traced run writes, minus the phase /
    /// worker-time breakdowns (those exist only when a recorder observed
    /// the run) — so untraced embedders still get one struct with the
    /// work, sweep, dual, and store-I/O totals.
    pub fn counters(&self) -> crate::telemetry::Counters {
        crate::telemetry::Counters {
            passes: self.passes as u64,
            metric_visits: self.metric_visits,
            active_triplets: self.active_triplets as u64,
            sweep_screened: self.sweep_screened,
            sweep_projected: self.sweep_projected,
            nnz_duals: self.nnz_duals as u64,
            max_violation: self.residuals.max_violation,
            rel_gap: self.residuals.rel_gap,
            phase_secs: Vec::new(),
            worker_busy_secs: Vec::new(),
            store: self.store_stats,
        }
    }
}

/// Mutable state of a CC-LP solve, shared by both solvers.
///
/// Variable layout follows DESIGN.md §6: packed `x` (distances) and `f`
/// (slacks), precomputed `winv = 1/w`, dense scaled duals for the 2 pair
/// constraints (+ optional box) per pair; metric duals live in sparse
/// [`duals::DualStore`]s owned by the solver.
pub struct CcState {
    pub n: usize,
    pub x: Vec<f64>,
    pub f: Vec<f64>,
    pub winv: Vec<f64>,
    pub d: Vec<f64>,
    pub w: Vec<f64>,
    pub y_upper: Vec<f64>,
    pub y_lower: Vec<f64>,
    pub y_box: Vec<f64>,
    pub col_starts: Vec<usize>,
    pub gamma: f64,
    pub include_box: bool,
}

impl CcState {
    /// Initialize at the Dykstra starting point `x0 = -(1/eps) W^{-1} c`:
    /// distances 0, slacks `-gamma` (DESIGN.md §6).
    pub fn new(inst: &CcLpInstance, gamma: f64, include_box: bool) -> CcState {
        let n = inst.n;
        let m = inst.w.len();
        let w: Vec<f64> = inst.w.as_slice().to_vec();
        let winv: Vec<f64> = w.iter().map(|&v| 1.0 / v).collect();
        CcState {
            n,
            x: vec![0.0; m],
            f: vec![-gamma; m],
            winv,
            d: inst.d.as_slice().to_vec(),
            w,
            y_upper: vec![0.0; m],
            y_lower: vec![0.0; m],
            y_box: vec![0.0; m],
            col_starts: inst.w.col_starts().to_vec(),
            gamma,
            include_box,
        }
    }

    /// Packed index of pair (i, j), i < j.
    #[inline(always)]
    pub fn pidx(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < j);
        unsafe { *self.col_starts.get_unchecked(i) + (j - i - 1) }
    }

    /// Extract the distance matrix.
    pub fn x_matrix(&self) -> PackedSym {
        let mut m = PackedSym::zeros(self.n);
        m.as_mut_slice().copy_from_slice(&self.x);
        m
    }

    /// Extract the slack matrix.
    pub fn f_matrix(&self) -> PackedSym {
        let mut m = PackedSym::zeros(self.n);
        m.as_mut_slice().copy_from_slice(&self.f);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_initial_point() {
        let inst = CcLpInstance::random(6, 0.5, 1.0, 2.0, 1);
        let st = CcState::new(&inst, 5.0, true);
        assert!(st.x.iter().all(|&v| v == 0.0));
        assert!(st.f.iter().all(|&v| v == -5.0));
        for (a, b) in st.w.iter().zip(st.winv.iter()) {
            assert!((a * b - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn pidx_matches_packed() {
        let inst = CcLpInstance::random(9, 0.5, 1.0, 2.0, 2);
        let st = CcState::new(&inst, 5.0, true);
        for i in 0..9 {
            for j in (i + 1)..9 {
                assert_eq!(st.pidx(i, j), inst.w.idx(i, j));
            }
        }
    }

    #[test]
    fn default_opts_match_paper() {
        let o = SolveOpts::default();
        assert_eq!(o.max_passes, 20); // Table I runs 20 iterations
        assert_eq!(o.tile, 40); // Table I tile size b = 40
        assert_eq!(o.strategy, Strategy::Full); // paper's dense sweeps
    }

    #[test]
    fn strategy_parses() {
        assert_eq!(Strategy::parse("full", 8, 3), Some(Strategy::Full));
        assert_eq!(
            Strategy::parse("active", 8, 3),
            Some(Strategy::Active { sweep_every: 8, forget_after: 3 })
        );
        assert_eq!(
            Strategy::parse("project-and-forget", 4, 0),
            Some(Strategy::Active { sweep_every: 4, forget_after: 0 })
        );
        assert_eq!(Strategy::parse("dense", 8, 3), None);
        assert!(Strategy::Active { sweep_every: 8, forget_after: 3 }.is_active());
        assert!(!Strategy::Full.is_active());
    }

    #[test]
    fn sweep_backend_parses_and_defaults_to_screened() {
        assert_eq!(SweepBackend::parse("scalar"), Some(SweepBackend::Scalar));
        assert_eq!(SweepBackend::parse("screened"), Some(SweepBackend::Screened));
        assert_eq!(SweepBackend::parse("engine"), Some(SweepBackend::Engine));
        assert_eq!(SweepBackend::parse("xla"), Some(SweepBackend::Engine));
        assert_eq!(SweepBackend::parse("gpu"), None);
        assert_eq!(SweepBackend::default(), SweepBackend::Screened);
        assert_eq!(SolveOpts::default().sweep_backend, SweepBackend::Screened);
        for b in [SweepBackend::Scalar, SweepBackend::Screened, SweepBackend::Engine] {
            assert_eq!(SweepBackend::parse(b.name()), Some(b));
        }
    }

    #[test]
    fn algorithm_parses_and_defaults_to_dykstra() {
        assert_eq!(Algorithm::parse("dykstra"), Some(Algorithm::Dykstra));
        assert_eq!(Algorithm::parse("prox-mm"), Some(Algorithm::ProxMm));
        assert_eq!(Algorithm::parse("mm"), Some(Algorithm::ProxMm));
        assert_eq!(Algorithm::parse("prox-sd"), Some(Algorithm::ProxSd));
        assert_eq!(Algorithm::parse("sd"), Some(Algorithm::ProxSd));
        assert_eq!(Algorithm::parse("admm"), None);
        assert_eq!(Algorithm::default(), Algorithm::Dykstra);
        assert_eq!(SolveOpts::default().algorithm, Algorithm::Dykstra);
        for a in [Algorithm::Dykstra, Algorithm::ProxMm, Algorithm::ProxSd] {
            assert_eq!(Algorithm::parse(a.name()), Some(a));
        }
        assert!(!Algorithm::Dykstra.is_proximal());
        assert!(Algorithm::ProxMm.is_proximal());
        assert!(Algorithm::ProxSd.is_proximal());
    }

    #[test]
    fn sweep_policy_parses() {
        assert_eq!(SweepPolicy::parse("fixed", 6), Some(SweepPolicy::Fixed(6)));
        assert_eq!(SweepPolicy::parse("fixed", 0), Some(SweepPolicy::Fixed(1)));
        assert_eq!(SweepPolicy::parse("adaptive", 6), Some(SweepPolicy::Adaptive));
        assert_eq!(SweepPolicy::parse("auto", 6), None);
        assert_eq!(SolveOpts::default().sweep_policy, None);
    }
}
