//! Shared utilities: deterministic RNG, parallel helpers, timing, stats,
//! and a minimal property-testing harness (no external crates offline).

pub mod hash;
pub mod interrupt;
pub mod json;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod shared;
pub mod stats;
pub mod timer;
