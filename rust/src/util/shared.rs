//! Unsynchronized shared mutable slices for conflict-free parallel writes.
//!
//! The whole point of the paper's execution schedule is that concurrent
//! projections touch **disjoint** entries of `X`, so no locks or atomics
//! are needed. Rust's aliasing rules still require us to say this
//! explicitly: [`SharedMut`] hands out raw unsynchronized access, and the
//! *scheduler* is the safety argument (verified by `solver::schedule`
//! tests: any two triplets in the same wave assigned to different workers
//! share at most one index, hence no variable).

use std::marker::PhantomData;

/// A shareable view of a mutable slice. All access is `unsafe`; callers
/// must guarantee data-race freedom (disjoint index sets per thread, or
/// synchronization via barriers between phases).
#[derive(Clone, Copy)]
pub struct SharedMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for SharedMut<'_, T> {}
unsafe impl<T: Send> Sync for SharedMut<'_, T> {}

impl<'a, T> SharedMut<'a, T> {
    /// Wrap a mutable slice.
    pub fn new(slice: &'a mut [T]) -> Self {
        SharedMut { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Length of the underlying slice.
    #[inline(always)]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True iff empty.
    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read element `i`.
    ///
    /// # Safety
    /// `i < len`, and no other thread may be writing element `i`.
    #[inline(always)]
    pub unsafe fn get(&self, i: usize) -> T
    where
        T: Copy,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i)
    }

    /// Write element `i`.
    ///
    /// # Safety
    /// `i < len`, and no other thread may be accessing element `i`.
    #[inline(always)]
    pub unsafe fn set(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Add `v` to element `i` (read-modify-write).
    ///
    /// # Safety
    /// Same contract as [`Self::set`].
    #[inline(always)]
    pub unsafe fn add(&self, i: usize, v: T)
    where
        T: Copy + std::ops::AddAssign,
    {
        debug_assert!(i < self.len);
        *self.ptr.add(i) += v;
    }

    /// Reborrow the contiguous range `[lo, hi)` as a plain mutable slice
    /// (the streamed pair phase hands workers their chunk directly).
    ///
    /// # Safety
    /// `lo <= hi <= len`, and no other thread may access any element of
    /// the range while the returned borrow lives.
    #[allow(clippy::mut_from_ref)]
    #[inline(always)]
    pub unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }

    /// Reborrow the contiguous range `[lo, hi)` as a plain shared slice.
    /// Lets hot read loops (the residual screen stripe) iterate with
    /// ordinary slice iterators — bounds-check-free and auto-vectorizable
    /// — instead of per-element [`Self::get`] calls.
    ///
    /// # Safety
    /// `lo <= hi <= len`, and no other thread may **write** any element of
    /// the range while the returned borrow lives.
    #[inline(always)]
    pub unsafe fn slice(&self, lo: usize, hi: usize) -> &[T] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts(self.ptr.add(lo), hi - lo)
    }
}

/// Per-worker mutable state: each worker `tid` may access only slot `tid`.
///
/// Used for the per-processor dual arrays of §III-D: the stores live across
/// the whole solve, each owned (dynamically) by one worker thread.
pub struct PerWorker<T> {
    slots: Vec<std::cell::UnsafeCell<T>>,
}

unsafe impl<T: Send> Sync for PerWorker<T> {}

impl<T> PerWorker<T> {
    /// Build from one value per worker.
    pub fn new(values: Vec<T>) -> Self {
        PerWorker { slots: values.into_iter().map(std::cell::UnsafeCell::new).collect() }
    }

    /// Number of workers.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True iff no slots.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Mutable access to worker `tid`'s slot.
    ///
    /// # Safety
    /// Only thread `tid` may call this for a given `tid` at a given time,
    /// and the returned reference must not outlive that exclusivity.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get_mut(&self, tid: usize) -> &mut T {
        &mut *self.slots[tid].get()
    }

    /// Consume, returning the inner values.
    pub fn into_inner(self) -> Vec<T> {
        self.slots.into_iter().map(std::cell::UnsafeCell::into_inner).collect()
    }

    /// Exclusive iteration (requires &mut self, hence no races).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        self.slots.iter_mut().map(|c| unsafe { &mut *c.get() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::parallel::{chunk_range, scoped_workers};

    #[test]
    fn basic_access() {
        let mut v = vec![1.0f64, 2.0, 3.0];
        let s = SharedMut::new(&mut v);
        unsafe {
            assert_eq!(s.get(1), 2.0);
            s.set(1, 5.0);
            s.add(2, 1.0);
        }
        assert_eq!(v, vec![1.0, 5.0, 4.0]);
    }

    #[test]
    fn disjoint_parallel_writes() {
        let n = 10_000;
        let mut v = vec![0usize; n];
        let s = SharedMut::new(&mut v);
        scoped_workers(4, |tid, _| {
            let (lo, hi) = chunk_range(n, 4, tid);
            for i in lo..hi {
                unsafe { s.set(i, i * 2) };
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn per_worker_isolated_slots() {
        let pw = PerWorker::new(vec![0u64; 4]);
        scoped_workers(4, |tid, _| {
            let slot = unsafe { pw.get_mut(tid) };
            for _ in 0..1000 {
                *slot += tid as u64 + 1;
            }
        });
        let vals = pw.into_inner();
        assert_eq!(vals, vec![1000, 2000, 3000, 4000]);
    }
}
