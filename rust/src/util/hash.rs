//! FNV-1a hashing — the one non-cryptographic hash the repo uses for
//! checksums and fingerprints (checkpoint files, tile-store files,
//! instance fingerprints). Guards against truncation and accidental
//! corruption, not against adversaries.

/// Incremental FNV-1a hasher over bytes.
pub struct Fnv1a(u64);

impl Fnv1a {
    /// Start a fresh hash (FNV-1a offset basis).
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf29ce484222325)
    }

    /// Fold `bytes` into the running hash.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

/// FNV-1a over a byte slice in one call.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.update(b"hello ");
        h.update(b"world");
        assert_eq!(h.finish(), fnv1a64(b"hello world"));
    }
}
