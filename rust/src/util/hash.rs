//! FNV-1a hashing — the one non-cryptographic hash the repo uses for
//! checksums and fingerprints (checkpoint files, tile-store files,
//! instance fingerprints). Guards against truncation and accidental
//! corruption, not against adversaries.

/// Incremental FNV-1a hasher over bytes.
pub struct Fnv1a(u64);

impl Fnv1a {
    /// Start a fresh hash (FNV-1a offset basis).
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf29ce484222325)
    }

    /// Resume hashing from a previously `finish()`ed state. Because
    /// FNV-1a folds one byte at a time into a single running word,
    /// `with_state(h(a)).update(b)` equals `h(a ‖ b)` — which lets
    /// per-shard fingerprints chain into one plane-wide hash.
    pub fn with_state(state: u64) -> Fnv1a {
        Fnv1a(state)
    }

    /// Fold `bytes` into the running hash.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    /// The current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

/// FNV-1a over a byte slice in one call.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// FNV-1a over the little-endian bit patterns of an `f64` slice,
/// continuing from `seed` (pass [`Fnv1a::new().finish()`] — the offset
/// basis — for a fresh hash). The store fingerprint and the CLI's
/// printed solution hash both use this, so a plane hashed shard-by-shard
/// (each shard seeded with its predecessor's result) equals the same
/// plane hashed in one pass.
pub fn fnv1a64_f64s(seed: u64, data: &[f64]) -> u64 {
    let mut h = Fnv1a::with_state(seed);
    for &v in data {
        h.update(&v.to_bits().to_le_bytes());
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_vectors() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let mut h = Fnv1a::new();
        h.update(b"hello ");
        h.update(b"world");
        assert_eq!(h.finish(), fnv1a64(b"hello world"));
    }

    #[test]
    fn chained_state_equals_one_shot() {
        let first = fnv1a64(b"hello ");
        let mut h = Fnv1a::with_state(first);
        h.update(b"world");
        assert_eq!(h.finish(), fnv1a64(b"hello world"));
    }

    #[test]
    fn f64_chaining_is_partition_independent() {
        let data: Vec<f64> = (0..17).map(|i| (i as f64) * 0.5 - 3.0).collect();
        let whole = fnv1a64_f64s(Fnv1a::new().finish(), &data);
        for split in 0..=data.len() {
            let head = fnv1a64_f64s(Fnv1a::new().finish(), &data[..split]);
            assert_eq!(fnv1a64_f64s(head, &data[split..]), whole, "split at {split}");
        }
    }
}
