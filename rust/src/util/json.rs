//! Minimal JSON tree, parser, and writer (no `serde` in the offline
//! build).
//!
//! Shared by the telemetry event stream ([`crate::telemetry`]), the
//! `report` trace reader, and the bench regression baseline
//! ([`crate::eval::regression`]). Numbers are `f64` throughout — every
//! integer the solvers serialize stays far below 2^53, so the round trip
//! is exact. Non-finite floats have no JSON spelling; writers map them
//! to `null` and [`Json::as_f64`] maps `null` back to NaN, which keeps
//! "violation not yet measured" representable in a trace line.

use std::fmt::Write as _;

/// A parsed JSON value. Objects preserve insertion order (the schema is
/// part of the trace contract, so field order is deterministic).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Look up a key of an object (`None` for other variants).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number value; `Null` reads as NaN (the writer's spelling of a
    /// non-finite float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            Json::Null => Some(f64::NAN),
            _ => None,
        }
    }

    /// The number value as an unsigned integer (counters).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 => Some(*v as u64),
            _ => None,
        }
    }

    /// The string value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The bool value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Serialize compactly (single line, no spaces after separators).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Append the compact serialization to `out`.
    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => write_num(*v, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document (trailing whitespace allowed, nothing
    /// else may follow).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(value)
    }
}

/// Convenience constructor: a number field (non-finite → `null`).
pub fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Convenience constructor: an unsigned counter field.
pub fn unum(v: u64) -> Json {
    debug_assert!(v < (1u64 << 53), "counter exceeds exact f64 range");
    Json::Num(v as f64)
}

fn write_num(v: f64, out: &mut String) {
    if !v.is_finite() {
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        // `{:?}` prints the shortest representation that round-trips.
        let _ = write!(out, "{v:?}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}", pos = *pos))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => expect(bytes, pos, "null").map(|_| Json::Null),
        Some(b't') => expect(bytes, pos, "true").map(|_| Json::Bool(true)),
        Some(b'f') => expect(bytes, pos, "false").map(|_| Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, ":")?;
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(c) if c.is_ascii_digit() || *c == b'-' => parse_number(bytes, pos),
        Some(c) => Err(format!("unexpected byte `{}` at {pos}", *c as char, pos = *pos)),
    }
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    if bytes.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex).map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        // Surrogates are not emitted by our writer; map
                        // them to the replacement character on read.
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (the input is a &str, so the
                // byte stream is valid UTF-8).
                let start = *pos;
                *pos += 1;
                while *pos < bytes.len() && (bytes[*pos] & 0xC0) == 0x80 {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&bytes[start..*pos]).expect("valid utf8"));
            }
        }
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
    text.parse::<f64>().map(Json::Num).map_err(|_| format!("bad number `{text}`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_roundtrip() {
        for text in ["null", "true", "false", "0", "-3", "2.5", "1e300", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(v.to_string(), text, "{text}");
        }
    }

    #[test]
    fn numbers_parse_exactly() {
        assert_eq!(Json::parse("0.1").unwrap().as_f64(), Some(0.1));
        assert_eq!(Json::parse("-2.5e-3").unwrap().as_f64(), Some(-0.0025));
        let big = (1u64 << 53) - 1;
        assert_eq!(Json::parse(&big.to_string()).unwrap().as_u64(), Some(big));
    }

    #[test]
    fn structures_roundtrip() {
        let text = r#"{"ev":"phase","secs":0.25,"workers":[0.1,0.15],"ok":true,"x":null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.to_string(), text);
        assert_eq!(v.get("ev").and_then(Json::as_str), Some("phase"));
        assert_eq!(v.get("secs").and_then(Json::as_f64), Some(0.25));
        assert_eq!(v.get("workers").and_then(Json::as_arr).map(|a| a.len()), Some(2));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert!(v.get("x").and_then(Json::as_f64).unwrap().is_nan());
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}f".to_string());
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → wörld"));
    }

    #[test]
    fn nonfinite_writes_null() {
        assert_eq!(num(f64::INFINITY).to_string(), "null");
        assert_eq!(num(f64::NAN).to_string(), "null");
        assert_eq!(num(1.5).to_string(), "1.5");
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" { \"a\" : [ 1 , 2 ] } \n").unwrap();
        assert_eq!(v.get("a").and_then(Json::as_arr).map(|a| a.len()), Some(2));
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn float_shortest_roundtrip() {
        for v in [0.1f64, 1.0 / 3.0, 6.02e23, -1.25e-7, f64::MAX, f64::MIN_POSITIVE] {
            let text = num(v).to_string();
            assert_eq!(Json::parse(&text).unwrap().as_f64(), Some(v), "{v}");
        }
    }
}
