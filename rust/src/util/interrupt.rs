//! Process-wide interrupt flag for graceful shutdown.
//!
//! [`install`] registers SIGINT/SIGTERM handlers that set one atomic
//! flag; solver pass loops poll [`interrupted`] between passes (under
//! `SolveOpts::on_interrupt`) so a Ctrl-C or a service-manager TERM
//! finishes the pass in flight, checkpoints, and unwinds cleanly instead
//! of killing workers mid-wave. No `libc` crate: the two POSIX calls are
//! declared directly and the whole module degrades to a manual flag on
//! non-Unix targets.

use std::sync::atomic::{AtomicBool, Ordering};

static INTERRUPTED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_sig: i32) {
    // Only async-signal-safe work here: one atomic store.
    INTERRUPTED.store(true, Ordering::SeqCst);
}

/// Install the SIGINT/SIGTERM handlers (idempotent). On non-Unix
/// targets this is a no-op and only [`raise`] can set the flag.
#[cfg(unix)]
pub fn install() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// Install the interrupt handlers (no-op off Unix).
#[cfg(not(unix))]
pub fn install() {}

/// Whether an interrupt has been requested since the last [`clear`].
pub fn interrupted() -> bool {
    INTERRUPTED.load(Ordering::SeqCst)
}

/// Set the flag by hand — what the signal handler does, callable from
/// tests and embedders that route their own shutdown signal.
pub fn raise() {
    INTERRUPTED.store(true, Ordering::SeqCst);
}

/// Reset the flag (start of a run, or after a handled interrupt).
pub fn clear() {
    INTERRUPTED.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raise_and_clear_roundtrip() {
        clear();
        assert!(!interrupted());
        raise();
        assert!(interrupted());
        clear();
        assert!(!interrupted());
    }
}
