//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! The offline build environment has no `rand` crate, so we carry our own
//! small, well-known generator. Determinism matters: every synthetic graph,
//! instance, and property test in this repo is reproducible from a seed.

/// xoshiro256** generator (Blackman & Vigna). Not cryptographic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, used to expand a single `u64` seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (bound > 0).
    #[inline]
    pub fn gen_range(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Rejection-free 128-bit multiply; bias is < 2^-64 per draw, which is
        // irrelevant for workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    #[inline]
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm: O(k) expected for k << n.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range((j + 1) as u64) as usize;
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Derive an independent child generator (for per-thread streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(3);
        for bound in [1u64, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let s = r.sample_indices(100, 20);
            assert_eq!(s.len(), 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&x| x < 100));
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(1234);
        let mut c1 = root.fork();
        let mut c2 = root.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
