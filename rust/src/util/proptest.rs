//! Minimal seeded property-testing harness.
//!
//! The offline environment has no `proptest` crate; this module provides the
//! small subset we need: run a property over many seeded random cases and
//! report the failing seed so the case can be replayed deterministically.

use super::rng::Rng;

/// Number of cases per property (overridable via `METRIC_PROJ_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("METRIC_PROJ_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop(rng, case_index)` for `cases` seeded cases derived from `seed`.
/// On failure (panic or `Err`), panics with the case seed for replay.
pub fn check<F>(name: &str, seed: u64, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!("property `{name}` failed on case {case} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Convenience assertion for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut seen = 0usize;
        check("trivial", 1, 16, |rng, _case| {
            let _ = rng.next_u64();
            seen += 1;
            Ok(())
        });
        assert_eq!(seen, 16);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 2, 8, |rng, _| {
            if rng.f64() >= 0.0 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn prop_assert_macro_works() {
        check("macro", 3, 4, |rng, _| {
            let v = rng.f64();
            prop_assert!((0.0..1.0).contains(&v), "v out of range: {v}");
            Ok(())
        });
    }
}
