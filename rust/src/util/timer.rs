//! Wall-clock timing helpers for the bench harness.

use std::time::Instant;

/// Time a closure, returning (result, seconds).
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A named phase timer that accumulates durations across calls.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` under phase `name`, accumulating its wall time.
    pub fn run<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let (out, secs) = time(f);
        self.add(name, secs);
        out
    }

    /// Accumulate `secs` into phase `name`.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(slot) = self.phases.iter_mut().find(|(n, _)| n == name) {
            slot.1 += secs;
        } else {
            self.phases.push((name.to_string(), secs));
        }
    }

    /// Total seconds of phase `name` (0 if never run).
    pub fn total(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// All (phase, seconds) pairs in first-seen order.
    pub fn phases(&self) -> &[(String, f64)] {
        &self.phases
    }

    /// Merge another timer into this one, phase by phase.
    ///
    /// Lets each worker keep a private `PhaseTimer` in the hot loop (no
    /// locking) and have the driver reduce them after the barrier:
    /// phases present in both accumulate, phases only in `other` are
    /// appended in `other`'s order.
    pub fn absorb(&mut self, other: &PhaseTimer) {
        for (name, secs) in other.phases() {
            self.add(name, *secs);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_returns_value() {
        let (v, secs) = time(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("a", 1.0);
        t.add("b", 2.0);
        t.add("a", 0.5);
        assert!((t.total("a") - 1.5).abs() < 1e-12);
        assert!((t.total("b") - 2.0).abs() < 1e-12);
        assert_eq!(t.total("missing"), 0.0);
        assert_eq!(t.phases().len(), 2);
    }

    #[test]
    fn absorb_merges_and_appends() {
        let mut a = PhaseTimer::new();
        a.add("metric", 1.0);
        a.add("pair", 0.25);
        let mut b = PhaseTimer::new();
        b.add("pair", 0.75);
        b.add("sweep", 2.0);
        a.absorb(&b);
        assert!((a.total("metric") - 1.0).abs() < 1e-12);
        assert!((a.total("pair") - 1.0).abs() < 1e-12);
        assert!((a.total("sweep") - 2.0).abs() < 1e-12);
        // First-seen order preserved; b-only phases appended.
        let names: Vec<&str> = a.phases().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["metric", "pair", "sweep"]);
    }

    #[test]
    fn absorb_empty_is_noop() {
        let mut a = PhaseTimer::new();
        a.add("x", 1.0);
        let before = a.phases().to_vec();
        a.absorb(&PhaseTimer::new());
        assert_eq!(a.phases(), before.as_slice());
        let mut empty = PhaseTimer::new();
        empty.absorb(&a);
        assert_eq!(empty.phases(), a.phases());
    }
}
