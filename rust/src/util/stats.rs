//! Small statistics helpers used by the bench harness and eval binaries.

/// Summary of a sample of measurements (seconds, counts, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

/// Compute a summary of `xs`. Panics on empty input.
pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty(), "summarize: empty sample");
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN measurement
    // (e.g. a zero-elapsed throughput row) must not panic the bench/eval
    // harness. NaNs sort to the positive end under the IEEE total order.
    sorted.sort_by(f64::total_cmp);
    let median = if n % 2 == 1 {
        sorted[n / 2]
    } else {
        0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
    };
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        median,
    }
}

/// Population coefficient of variation of nonneg data (= std/mean); 0 if mean is 0.
pub fn coeff_of_variation(xs: &[f64]) -> f64 {
    let s = summarize(xs);
    if s.mean == 0.0 {
        0.0
    } else {
        s.std / s.mean
    }
}

/// Relative imbalance of a load vector: (max - mean) / mean. 0 = perfect.
pub fn load_imbalance(loads: &[f64]) -> f64 {
    let s = summarize(loads);
    if s.mean == 0.0 {
        0.0
    } else {
        (s.max - s.mean) / s.mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = summarize(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        // sample std of 1..4 = sqrt(5/3)
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn median_odd() {
        assert_eq!(summarize(&[5.0, 1.0, 3.0]).median, 3.0);
    }

    #[test]
    fn summarize_tolerates_nan_measurements() {
        // Regression: `partial_cmp(..).unwrap()` used to panic here.
        let s = summarize(&[1.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 3);
        // NaN sorts last under the IEEE total order.
        assert_eq!(s.min, 1.0);
        assert!(s.max.is_nan());
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn imbalance_zero_when_equal() {
        assert_eq!(load_imbalance(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn imbalance_positive_when_skewed() {
        let im = load_imbalance(&[1.0, 1.0, 4.0]);
        assert!((im - 1.0).abs() < 1e-12); // mean 2, max 4
    }
}
