//! Deterministic parallel execution helpers (no rayon in this environment).
//!
//! The paper's schedule requires that (a) the r-th work item of a wave is
//! always handled by worker `r mod p`, and (b) each worker traverses its
//! items in the same order every pass. Plain scoped threads plus a barrier
//! give us exactly that with no extra machinery.

use std::sync::Barrier;

/// Run `p` scoped workers; `body(tid, &barrier)` runs on each.
///
/// The barrier is shared so workers can synchronize between waves. Panics in
/// any worker propagate (std::thread::scope joins and re-raises).
pub fn scoped_workers<F>(p: usize, body: F)
where
    F: Fn(usize, &Barrier) + Sync,
{
    assert!(p >= 1);
    let barrier = Barrier::new(p);
    if p == 1 {
        // Fast path: no thread spawn for the serial case.
        body(0, &barrier);
        return;
    }
    std::thread::scope(|s| {
        for tid in 0..p {
            let body = &body;
            let barrier = &barrier;
            s.spawn(move || body(tid, barrier));
        }
    });
}

/// Split `[0, n)` into `p` contiguous chunks whose sizes differ by <= 1.
/// Returns the half-open range of chunk `tid`.
pub fn chunk_range(n: usize, p: usize, tid: usize) -> (usize, usize) {
    debug_assert!(tid < p);
    let base = n / p;
    let rem = n % p;
    let lo = tid * base + tid.min(rem);
    let hi = lo + base + usize::from(tid < rem);
    (lo, hi)
}

/// Map `f` over `[0, n)` in parallel with `p` workers writing disjoint
/// chunks of `out`. `f` must be pure w.r.t. the index.
pub fn par_map_into<T: Send, F>(p: usize, out: &mut [T], f: F)
where
    F: Fn(usize) -> T + Sync,
{
    let n = out.len();
    if p <= 1 || n < 2 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = &mut *out;
        for tid in 0..p {
            // Chunks are contiguous, so chunk `tid` is the next hi-lo slots.
            let (lo, hi) = chunk_range(n, p, tid);
            let (mine, tail) = rest.split_at_mut(hi - lo);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                for (off, slot) in mine.iter_mut().enumerate() {
                    *slot = f(lo + off);
                }
            });
        }
    });
}

/// Parallel sum-reduction of `f(i)` over `[0, n)` with `p` workers.
pub fn par_reduce_sum<F>(p: usize, n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    if p <= 1 || n < 1024 {
        return (0..n).map(&f).sum();
    }
    let mut partials = vec![0.0f64; p];
    std::thread::scope(|s| {
        for (tid, slot) in partials.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                let (lo, hi) = chunk_range(n, p, tid);
                *slot = (lo..hi).map(f).sum();
            });
        }
    });
    partials.iter().sum()
}

/// Parallel max-reduction of `f(i)` over `[0, n)` with `p` workers.
/// Returns `f64::NEG_INFINITY` for n = 0.
pub fn par_reduce_max<F>(p: usize, n: usize, f: F) -> f64
where
    F: Fn(usize) -> f64 + Sync,
{
    if p <= 1 || n < 1024 {
        return (0..n).map(&f).fold(f64::NEG_INFINITY, f64::max);
    }
    let mut partials = vec![f64::NEG_INFINITY; p];
    std::thread::scope(|s| {
        for (tid, slot) in partials.iter_mut().enumerate() {
            let f = &f;
            s.spawn(move || {
                let (lo, hi) = chunk_range(n, p, tid);
                *slot = (lo..hi).map(f).fold(f64::NEG_INFINITY, f64::max);
            });
        }
    });
    partials.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Worker-thread count for tests: the `METRIC_PROJ_TEST_THREADS`
/// environment variable overrides `default` when set to a positive
/// integer. CI re-runs the suite at several counts (e.g. 1 and 8) to
/// catch wave-schedule/ordering bugs that only appear off the default —
/// safe to apply anywhere results are bitwise thread-count independent.
pub fn env_threads(default: usize) -> usize {
    match std::env::var("METRIC_PROJ_TEST_THREADS") {
        Err(_) => default,
        Ok(raw) => match parse_thread_override(&raw) {
            Ok(p) => p,
            Err(why) => {
                // A typo'd override must not silently run the suite at
                // the default count — say so through the global sink.
                crate::telemetry::warn(&format!(
                    "METRIC_PROJ_TEST_THREADS={raw:?} ignored ({why}); \
                     using {default} thread(s)"
                ));
                default
            }
        },
    }
}

/// Parse a `METRIC_PROJ_TEST_THREADS`-style override: a positive integer,
/// surrounding whitespace allowed. Returns the reason on rejection so
/// [`env_threads`] can report it.
pub(crate) fn parse_thread_override(raw: &str) -> Result<usize, String> {
    let trimmed = raw.trim();
    match trimmed.parse::<usize>() {
        Ok(0) => Err("thread count must be >= 1".to_string()),
        Ok(p) => Ok(p),
        Err(e) => Err(format!("not a positive integer: {e}")),
    }
}

/// Number of hardware threads available to this process.
pub fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_and_are_disjoint() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8] {
                let mut covered = vec![false; n];
                let mut prev_hi = 0;
                for tid in 0..p {
                    let (lo, hi) = chunk_range(n, p, tid);
                    assert_eq!(lo, prev_hi);
                    prev_hi = hi;
                    for slot in covered.iter_mut().take(hi).skip(lo) {
                        assert!(!*slot);
                        *slot = true;
                    }
                }
                assert_eq!(prev_hi, n);
                assert!(covered.iter().all(|&c| c));
            }
        }
    }

    #[test]
    fn chunk_sizes_balanced() {
        for n in [10usize, 11, 99] {
            for p in [2usize, 3, 7] {
                let sizes: Vec<usize> =
                    (0..p).map(|t| { let (l, h) = chunk_range(n, p, t); h - l }).collect();
                let mx = *sizes.iter().max().unwrap();
                let mn = *sizes.iter().min().unwrap();
                assert!(mx - mn <= 1);
            }
        }
    }

    #[test]
    fn scoped_workers_all_run() {
        let count = AtomicUsize::new(0);
        scoped_workers(4, |_tid, b| {
            count.fetch_add(1, Ordering::SeqCst);
            b.wait();
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn par_reduce_sum_matches_serial() {
        let f = |i: usize| (i as f64).sqrt();
        let serial: f64 = (0..10_000).map(f).sum();
        for p in [1usize, 2, 4] {
            let par = par_reduce_sum(p, 10_000, f);
            assert!((par - serial).abs() < 1e-6);
        }
    }

    #[test]
    fn par_reduce_max_matches_serial() {
        let f = |i: usize| ((i * 2654435761) % 10007) as f64;
        let serial = (0..5000).map(f).fold(f64::NEG_INFINITY, f64::max);
        for p in [1usize, 3, 8] {
            assert_eq!(par_reduce_max(p, 5000, f), serial);
        }
    }

    #[test]
    fn thread_override_accepts_positive_integers() {
        assert_eq!(parse_thread_override("4"), Ok(4));
        assert_eq!(parse_thread_override(" 8 "), Ok(8));
        assert_eq!(parse_thread_override("1"), Ok(1));
    }

    #[test]
    fn thread_override_rejects_garbage_with_a_reason() {
        for bad in ["", "zero", "1.5", "-2", "0x8"] {
            let why = parse_thread_override(bad).unwrap_err();
            assert!(
                why.contains("not a positive integer"),
                "{bad:?} -> {why:?}"
            );
        }
        assert_eq!(
            parse_thread_override("0").unwrap_err(),
            "thread count must be >= 1"
        );
    }

    #[test]
    fn par_map_into_writes_all() {
        let mut out = vec![0usize; 5000];
        par_map_into(4, &mut out, |i| i * 3);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 3);
        }
    }
}
